"""Config registry: all assigned archs present with the exact assigned
geometry, param counts in the right ballpark, reduced() well-formed."""
import pytest

from repro.configs import (
    ASSIGNED_ARCHS, INPUT_SHAPES, get_config, list_configs,
)

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "granite-34b": (88, 6144, 48, 1, 24576, 49152),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
}

# rough total-param expectations (within 40%)
PARAM_BALLPARK = {
    "granite-34b": 34e9,
    "starcoder2-15b": 15e9,
    "phi3-mini-3.8b": 3.8e9,
    "pixtral-12b": 12e9,
    "jamba-1.5-large-398b": 398e9,
    "phi3.5-moe-42b-a6.6b": 42e9,
    "xlstm-125m": 125e6,
    "qwen2.5-32b": 32e9,
    "granite-moe-3b-a800m": 3.3e9,
    "modernbert-149m": 149e6,
}


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_assigned_geometry(name):
    cfg = get_config(name)
    exp = EXPECTED[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == exp


@pytest.mark.parametrize("name", sorted(PARAM_BALLPARK))
def test_param_counts(name):
    cfg = get_config(name)
    n = cfg.param_count()
    target = PARAM_BALLPARK[name]
    assert 0.6 * target < n < 1.4 * target, f"{name}: {n:.3e} vs {target:.3e}"


def test_moe_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    active = cfg.param_count(active_only=True)
    assert 0.6 * 6.6e9 < active < 1.4 * 6.6e9
    assert active < cfg.param_count() / 3


def test_registry_lists_all():
    names = list_configs()
    for a in ASSIGNED_ARCHS:
        assert a in names
    assert "modernbert-149m" in names


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_reduced_variants(name):
    r = get_config(name).reduced()
    assert r.n_layers <= 8 and r.d_model <= 512
    assert r.n_layers % len(r.period) == 0
    if r.moe:
        assert r.moe.num_experts <= 4
    assert r.param_count() > 0


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


def test_long_context_variant():
    dense = get_config("qwen2.5-32b")
    assert dense.for_long_context().sliding_window == 8192
    ssm = get_config("xlstm-125m")
    assert ssm.for_long_context() is ssm  # unchanged: sub-quadratic
