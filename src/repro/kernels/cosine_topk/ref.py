"""Pure-jnp oracle for the cosine top-k cache lookup."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def cosine_topk(q, keys, valid, k: int = 1):
    """q: (Q, D) unit-norm queries; keys: (N, D) unit-norm corpus;
    valid: (N,) bool.  Returns (scores (Q,k) desc, indices (Q,k))."""
    scores = q.astype(jnp.float32) @ keys.astype(jnp.float32).T   # (Q, N)
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    top_scores, top_idx = jax.lax.top_k(scores, k)
    return top_scores, top_idx.astype(jnp.int32)
