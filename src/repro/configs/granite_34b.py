"""Granite-34B-Code — MQA code model.

[arXiv:2405.04324]  88L, d_model=6144, 48 heads, kv=1 (multi-query),
d_ff=24576, vocab=49152.  The 34B code models are gpt_bigcode-family:
2-projection GELU MLP (which is what makes the listed dims total ~34B —
a SwiGLU MLP would give 47B), LayerNorm, MQA.  RoPE per the assignment
line.  Embeddings tied (gpt_bigcode).
"""
from repro.configs.base import ModelConfig, LayerSpec, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    norm_type="layernorm",
    use_rope=True,
    tie_embeddings=True,
    period=(LayerSpec(ATTN, DENSE),),
))
