from repro.training.optim import (
    AdamState, adam, adamw, apply_updates, clip_by_global_norm, global_norm,
)
from repro.training.schedule import constant, linear_decay, linear_warmup_cosine
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.train import make_eval_step, make_train_step

__all__ = [
    "AdamState", "adam", "adamw", "apply_updates", "clip_by_global_norm",
    "global_norm", "constant", "linear_decay", "linear_warmup_cosine",
    "load_checkpoint", "save_checkpoint", "make_eval_step", "make_train_step",
]
