"""Pure-jnp oracle: dense masked softmax attention (GQA-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd).  Returns (B, H, Sq, hd).

    Positions are implicit (q row i is absolute position i; same for kv).
    """
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg, k.astype(jnp.float32))
    row = jnp.arange(Sq)[:, None]
    col = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= col <= row
    if window > 0:
        ok &= (row - col) < window
        if not causal:
            ok &= (col - row) < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", w, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
