"""Pallas TPU kernel: the tiered cache's cascade lookup, fused.

The unfused cascade (DESIGN.md §3) is four XLA ops — hot-tier matmul,
warm centroid matmul, IVF bucket gather, masked top-k — and the gather
round-trips its (Q × n_probe·bucket × D) candidate panel through HBM,
which dominates warm-tier latency.  This kernel extends
`kernels/cosine_topk`'s streaming running-top-k to the whole cascade in
one `pallas_call`:

  * grid steps 0..nb-1 stream the HOT tier through VMEM in
    (BLOCK_N × D) tiles, carrying a tenant-masked running top-k in
    scratch exactly like `cosine_topk`;
  * the last grid step runs the WARM side entirely in VMEM: centroid
    matmul, per-query probe selection (masked-argmax rounds), the IVF
    bucket gather done as in-kernel index arithmetic over the inverted
    lists (`members[probe]` row ids -> key gather -> (Q, bucket) score
    panel, one probe at a time so only one panel is ever live), the
    unindexed-tail scan (ring positions derived from `cursor` in
    SMEM-style meta), and the best-of-tiers merge — so neither the
    (Q × candidates) score matrix nor the gathered key panels ever
    materialize in HBM.

Candidate ordering matches `jax.lax.top_k` tie-breaking (lowest panel
index wins): within a panel, masked argmax picks the first occurrence;
across panels, the accumulator (earlier candidates) is concatenated
first.  That makes the kernel bit-compatible with the four-op path —
`ref.py` — including tenant masking, invalid slots and the tail window.

``quantized=True`` swaps the VMEM-resident warm panel for its int8
symmetric per-row quantization (``warm_keys`` arrives as int8 plus a
(cap,) fp32 scale vector): each (Q, bucket) panel is dequantized only
transiently, scores accumulate in fp32, and both VMEM residency and
the HBM→VMEM stream for the warm corpus shrink 4x (DESIGN.md §8).  The
returned ``warm_slots`` let the caller re-score the few selected rows
exactly from the fp32 panel at merge time.

VMEM budget: the warm corpus, centroids and inverted lists are held as
single VMEM-resident blocks.  At ~16 MB VMEM/core that caps the warm
slice around a few tens of thousands of rows at D=64 fp32 (4x more
quantized) — keys alone are cap·D·4 bytes (cap·D int8), plus one
(Q, bucket, D) panel — so production deployment runs the kernel on the
per-shard warm slice of the sharded tier (DESIGN.md §8), which is
exactly the size this budget was designed for; larger single-core
tiers need the warm keys streamed blockwise like the hot tier, which
this kernel does not do yet.  Valid masks travel as int32 and the hit
flags return as int32 (bool VMEM refs are a Mosaic lowering hazard);
`interpret=True` runs the same dataflow as pure XLA ops for CPU tests
— the only mode exercised in this repo's CPU CI, as with the other
kernel packages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_N = 512


def _select_topk(scores, idx, k):
    """scores: (Q, M) candidates with payload idx (Q, M) -> (Q, k) best
    by k rounds of masked argmax (unrolled, k small).  argmax picks the
    first occurrence, matching lax.top_k's lowest-index tie-break."""
    out_s, out_i = [], []
    for _ in range(k):
        best = jnp.argmax(scores, axis=-1)                       # (Q,)
        rows = jnp.arange(scores.shape[0])
        out_s.append(scores[rows, best])
        out_i.append(idx[rows, best])
        scores = scores.at[rows, best].set(NEG_INF)
    return jnp.stack(out_s, -1), jnp.stack(out_i, -1)


def _merge(acc_s, acc_i, blk_s, blk_i, k):
    """Running top-k merge; accumulator first so earlier candidates win
    ties (panel order)."""
    cand_s = jnp.concatenate([acc_s, blk_s], axis=-1)
    cand_i = jnp.concatenate([acc_i, blk_i], axis=-1)
    return _select_topk(cand_s, cand_i, k)


def _kernel(q_ref, qt_ref, thr_ref, hk_ref, hv_ref, ht_ref, hvid_ref,
            wk_ref, wscale_ref, wv_ref, wt_ref, wvid_ref, wseq_ref,
            cent_ref, mem_ref, meta_ref, out_s_ref, out_v_ref,
            out_wslot_ref, out_hslot_ref, out_flag_ref,
            acc_s, acc_i, *, k: int, block_n: int, n_hot: int,
            n_probe: int, tail: int, quantized: bool):
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        acc_s[...] = jnp.full_like(acc_s, NEG_INF)
        acc_i[...] = jnp.zeros_like(acc_i)

    q = q_ref[...].astype(jnp.float32)                 # (Q, D)
    qt = qt_ref[...]                                   # (Q,)

    # ---- hot tier: streamed block, tenant-masked running top-k ------
    kblk = hk_ref[...].astype(jnp.float32)             # (BN, D)
    s = jax.lax.dot_general(q, kblk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, BN)
    col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = (hv_ref[...] != 0)[None, :] & (ht_ref[...][None, :] == qt[:, None]) \
        & (col < n_hot)
    s = jnp.where(ok, s, NEG_INF)
    blk_s, blk_i = _select_topk(s, col, k)
    new_s, new_i = _merge(acc_s[...], acc_i[...], blk_s, blk_i, k)
    acc_s[...] = new_s
    acc_i[...] = new_i

    # ---- warm tier + merge: once, after the last hot block ----------
    @pl.when(j == nb - 1)
    def _finish():
        Q = q.shape[0]
        cap = wk_ref.shape[0]
        bucket = mem_ref.shape[1]
        cursor = meta_ref[0]
        indexed_total = meta_ref[1]
        wv = wv_ref[...] != 0
        wt = wt_ref[...]
        wseq = wseq_ref[...]
        rows = jnp.arange(Q)[:, None]
        if quantized:
            # int8 warm panel stays int8-resident: dequantize one
            # (Q, B, D) gather at a time, fp32 accumulation
            wk8 = wk_ref[...]                          # (cap, D) int8 VMEM
            wscale = wscale_ref[...]                   # (cap,) fp32

            def _panel_scores(safe):
                pan = wk8[safe].astype(jnp.float32)
                return jnp.einsum("qd,qbd->qb", q, pan) * wscale[safe]
        else:
            wk = wk_ref[...].astype(jnp.float32)       # (cap, D) VMEM

            def _panel_scores(safe):
                return jnp.einsum("qd,qbd->qb", q, wk[safe])

        # probe selection: centroid matmul + n_probe argmax rounds
        csims = jax.lax.dot_general(
            q, cent_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (Q, K)
        pcol = jax.lax.broadcasted_iota(jnp.int32, csims.shape, 1)
        _, probes = _select_topk(csims, pcol, n_probe)  # (Q, n_probe)

        # IVF gather: one (Q, bucket) candidate panel per probe, index
        # arithmetic over the inverted lists, never leaving VMEM
        mem = mem_ref[...]                             # (K, bucket)
        ws_acc = jnp.full((Q, k), NEG_INF, jnp.float32)
        wi_acc = jnp.zeros((Q, k), jnp.int32)
        for p in range(n_probe):
            cand = mem[probes[:, p]]                   # (Q, bucket)
            safe = jnp.clip(cand, 0, cap - 1)
            sc = _panel_scores(safe)
            okp = (cand >= 0) & wv[safe] & (wt[safe] == qt[:, None]) \
                & (wseq[safe] <= indexed_total)
            sc = jnp.where(okp, sc, NEG_INF)
            pb_s, pb_i = _select_topk(sc, safe, k)
            ws_acc, wi_acc = _merge(ws_acc, wi_acc, pb_s, pb_i, k)

        # unindexed-tail scan: last `tail` ring writes, newest first
        if tail:
            offs = jax.lax.broadcasted_iota(jnp.int32, (1, tail), 1)
            pos = (cursor - 1 - offs) % cap            # (1, tail)
            unindexed = wseq[pos] > indexed_total
            tcand = jnp.broadcast_to(jnp.where(unindexed, pos, -1),
                                     (Q, tail))
            tsafe = jnp.clip(tcand, 0, cap - 1)
            sc = _panel_scores(tsafe)
            okt = (tcand >= 0) & wv[tsafe] & (wt[tsafe] == qt[:, None])
            sc = jnp.where(okt, sc, NEG_INF)
            tb_s, tb_i = _select_topk(sc, tsafe, k)
            ws_acc, wi_acc = _merge(ws_acc, wi_acc, tb_s, tb_i, k)

        # best-of-tiers merge; hot candidates first so ties stay hot
        hs, hi = acc_s[...], acc_i[...]
        hvids = jnp.where(hs > NEG_INF / 2, hvid_ref[...][hi], -1)
        wvids = jnp.where(ws_acc > NEG_INF / 2, wvid_ref[...][wi_acc], -1)
        wslot_c = jnp.where(ws_acc > NEG_INF / 2, wi_acc, -1)
        cand_s = jnp.concatenate([hs, ws_acc], axis=-1)     # (Q, 2k)
        cand_v = jnp.concatenate([hvids, wvids], axis=-1)
        cand_w = jnp.concatenate(
            [jnp.full((Q, k), -1, jnp.int32), wslot_c], axis=-1)
        ppos = jax.lax.broadcasted_iota(jnp.int32, cand_s.shape, 1)
        out_s, out_p = _select_topk(cand_s, ppos, k)
        out_s_ref[...] = out_s
        out_v_ref[...] = cand_v[rows, out_p]
        out_wslot_ref[...] = cand_w[rows, out_p]
        out_hslot_ref[...] = hi[:, :1]
        hit = out_s[:, 0] >= thr_ref[...]
        out_flag_ref[...] = jnp.stack(
            [hit, hit & (out_p[:, 0] < k)], -1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "n_probe", "tail",
                                             "block_n", "interpret",
                                             "quantized"))
def cascade_lookup(q, q_tenants, thresholds,
                   hot_keys, hot_valid, hot_tenants, hot_value_ids,
                   warm_keys, warm_valid, warm_tenants, warm_value_ids,
                   warm_write_seq, centroids, members, cursor, indexed_total,
                   warm_keys_q=None, warm_scales=None,
                   k: int = 1, n_probe: int = 8, tail: int = 0, *,
                   quantized: bool = False,
                   block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """Array-level fused cascade; signature/semantics of `ref.py`.

    q: (Q, D) unit-norm.  Returns (scores (Q, k), value_ids (Q, k),
    warm_slots (Q, k), hot_slots (Q,), hot_hit (Q,), hit (Q,)).
    ``quantized=True`` streams ``warm_keys_q``/``warm_scales`` instead
    of the fp32 warm panel.
    """
    q = q.astype(jnp.float32)
    q_tenants = q_tenants.astype(jnp.int32)
    Q, D = q.shape
    n_hot = hot_keys.shape[0]
    n_clusters = centroids.shape[0]
    n_probe = min(n_probe, n_clusters)
    cap = warm_keys.shape[0]

    if quantized:
        wk_in = warm_keys_q
        wscale_in = warm_scales.astype(jnp.float32)
        wk_dtype = jnp.int8
    else:
        wk_in = warm_keys
        wscale_in = jnp.zeros((cap,), jnp.float32)      # unread placeholder
        wk_dtype = jnp.float32

    bn = min(block_n, n_hot)
    n_blocks = -(-n_hot // bn)
    pad = n_blocks * bn - n_hot
    # bool VMEM refs are a Mosaic lowering hazard: masks travel as int32
    hot_valid = hot_valid.astype(jnp.int32)
    warm_valid = warm_valid.astype(jnp.int32)
    if pad:
        hot_keys = jnp.pad(hot_keys, ((0, pad), (0, 0)))
        hot_valid = jnp.pad(hot_valid, (0, pad))
        hot_tenants = jnp.pad(hot_tenants, (0, pad), constant_values=-1)
        hot_value_ids = jnp.pad(hot_value_ids, (0, pad), constant_values=-1)
    meta = jnp.stack([jnp.asarray(cursor, jnp.int32),
                      jnp.asarray(indexed_total, jnp.int32)])

    bucket = members.shape[1]
    grid = (n_blocks,)
    whole = lambda shape: pl.BlockSpec(shape, lambda j: (0,) * len(shape))
    out_shape = (jax.ShapeDtypeStruct((Q, k), jnp.float32),
                 jax.ShapeDtypeStruct((Q, k), jnp.int32),
                 jax.ShapeDtypeStruct((Q, k), jnp.int32),
                 jax.ShapeDtypeStruct((Q, 1), jnp.int32),
                 jax.ShapeDtypeStruct((Q, 2), jnp.int32))
    fn = pl.pallas_call(
        functools.partial(_kernel, k=k, block_n=bn, n_hot=n_hot,
                          n_probe=n_probe, tail=tail, quantized=quantized),
        grid=grid,
        in_specs=[
            whole((Q, D)),                                # q
            whole((Q,)),                                  # q_tenants
            whole((Q,)),                                  # thresholds
            pl.BlockSpec((bn, D), lambda j: (j, 0)),      # hot keys stream
            pl.BlockSpec((bn,), lambda j: (j,)),          # hot valid
            pl.BlockSpec((bn,), lambda j: (j,)),          # hot tenants
            whole((n_blocks * bn,)),                      # hot value ids
            whole((cap, D)),                              # warm keys (f32/i8)
            whole((cap,)),                                # warm row scales
            whole((cap,)),                                # warm valid
            whole((cap,)),                                # warm tenants
            whole((cap,)),                                # warm value ids
            whole((cap,)),                                # warm write seq
            whole((n_clusters, D)),                       # centroids
            whole((n_clusters, bucket)),                  # inverted lists
            whole((2,)),                                  # cursor/indexed
        ],
        out_specs=(whole((Q, k)), whole((Q, k)), whole((Q, k)),
                   whole((Q, 1)), whole((Q, 2))),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )
    out_s, out_v, out_w, hslot, flags = fn(
        q, q_tenants, thresholds.astype(jnp.float32), hot_keys, hot_valid,
        hot_tenants, hot_value_ids, wk_in.astype(wk_dtype), wscale_in,
        warm_valid, warm_tenants, warm_value_ids, warm_write_seq, centroids,
        members, meta)
    return (out_s, out_v, out_w, hslot[:, 0], flags[:, 1] != 0,
            flags[:, 0] != 0)
