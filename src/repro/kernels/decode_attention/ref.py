"""Pure-jnp oracle: single-token GQA decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention(q, k, v, kv_valid):
    """q: (B, H, hd) one query token; k, v: (B, L, KV, hd) cache;
    kv_valid: (B, L) bool.  Returns (B, H, hd)."""
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgh,blkh->bkgl", qg, k.astype(jnp.float32))
    s = jnp.where(kv_valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkh->bkgh", w, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
