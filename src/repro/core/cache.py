"""SemanticCache — the paper's artifact, assembled.

Embedding model (compact fine-tuned encoder) + vector store + threshold
policy.  The device half (store state, query/insert/touch) is pure JAX;
this class is the thin host orchestration that also owns the response
strings (which never live on device).

Usage (see examples/serve_with_cache.py):

    cache = SemanticCache(capacity=4096, dim=768, threshold=0.85)
    hits, scores, values = cache.lookup(embeddings)     # (B, D)
    cache.insert(miss_embeddings, miss_responses)
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import store as store_lib


class SemanticCache:
    def __init__(self, capacity: int, dim: int, threshold: float = 0.85,
                 topk: int = 1, ttl: Optional[int] = None):
        self.capacity = capacity
        self.dim = dim
        self.threshold = threshold
        self.topk = topk
        self.ttl = ttl
        self.state = store_lib.init_store(capacity, dim)
        self.responses: List[str] = []
        self._query = jax.jit(
            lambda st, q: store_lib.query(st, q, threshold, topk))
        self._insert = jax.jit(store_lib.insert_batch)
        self._touch = jax.jit(store_lib.touch)
        self._evict = (jax.jit(lambda st: store_lib.evict_older_than(st, ttl))
                       if ttl else None)

    # ------------------------------------------------------------------
    def lookup(self, embs) -> Tuple[np.ndarray, np.ndarray, List[Optional[str]]]:
        """embs: (B, D).  Returns (hit (B,) bool, score (B,), values)."""
        if self._evict is not None:
            self.state = self._evict(self.state)
        res = self._query(self.state, jnp.asarray(embs))
        self.state = self._touch(self.state, res.slots[:, 0], res.hit)
        hit = np.asarray(res.hit)
        scores = np.asarray(res.scores[:, 0])
        vids = np.asarray(res.value_ids[:, 0])
        values = [self.responses[v] if h and 0 <= v < len(self.responses)
                  else None for h, v in zip(hit, vids)]
        return hit, scores, values

    def insert(self, embs, responses: Sequence[str]) -> None:
        embs = np.asarray(embs)
        assert embs.shape[0] == len(responses)
        base = len(self.responses)
        self.responses.extend(responses)
        vids = jnp.arange(base, base + len(responses), dtype=jnp.int32)
        self.state = self._insert(self.state, jnp.asarray(embs), vids)

    # ------------------------------------------------------------------
    @property
    def occupancy(self) -> float:
        return float(store_lib.occupancy(self.state))

    def __len__(self) -> int:
        return int(np.asarray(self.state.valid).sum())
