"""Pallas TPU kernel: the tiered cache's cascade lookup, fused.

The unfused cascade (DESIGN.md §3) is four XLA ops — hot-tier matmul,
warm centroid matmul, IVF bucket gather, masked top-k — and the gather
round-trips its (Q × n_probe·bucket × D) candidate panel through HBM,
which dominates warm-tier latency.  This kernel extends
`kernels/cosine_topk`'s streaming running-top-k to the whole cascade in
one `pallas_call`:

  * grid steps 0..nh-1 stream the HOT tier through VMEM in
    (BLOCK_N × D) tiles, carrying a tenant-masked running top-k in
    scratch exactly like `cosine_topk`;
  * grid steps nh..nh+nw-1 stream the WARM key panel through VMEM in
    (WARM_BLOCK_N × D) tiles.  Each step recomputes the (tiny) probe
    selection — centroid matmul + masked-argmax rounds over the
    VMEM-resident centroids — then scores the IVF candidates and
    unindexed-tail candidates *that live in the current block* via
    in-kernel index arithmetic over the inverted lists, merging them
    into a warm running top-k carried in scratch.  Neither the
    (Q × candidates) score matrix nor any gathered key panel ever
    materializes in HBM, and no step holds more than one key block
    plus one (Q, bucket, D) gather panel in VMEM;
  * the final grid step merges the two accumulators (best-of-tiers,
    hot candidates first so ties stay hot) and maps slots to value ids.

Candidate ordering matches `jax.lax.top_k` tie-breaking (lowest panel
index wins) exactly: the hot stream visits slots in index order with
the accumulator concatenated first, and the warm accumulator carries
each candidate's *flat panel position* (probe-major, tail last — the
position it occupies in the oracle's single gathered panel) as an
explicit tie key, so streaming the blocks in any order is
bit-compatible with the four-op path — `ref.py` — including tenant
masking, invalid slots and the tail window.

``quantized=True`` swaps the streamed warm blocks for their int8
symmetric per-row quantization (``warm_keys`` arrives as int8 plus a
per-row fp32 scale vector, both streamed blockwise): each (Q, bucket)
panel is dequantized only transiently, scores accumulate in fp32, and
both VMEM residency and the HBM→VMEM stream for the warm corpus shrink
4x (DESIGN.md §8).  The returned ``warm_slots`` let the caller re-score
the few selected rows exactly from the fp32 panel at merge time.

VMEM budget: only the centroids, inverted lists and the per-slot warm
metadata columns ((cap,) int32 each) are held whole; the key panels —
the VMEM hog — stream.  ``warm_block_n`` therefore bounds residency at
``warm_block_n·D`` key bytes regardless of warm capacity: a shard's
warm slice may exceed the old single-block design size (DESIGN.md §12)
at the cost of one extra probe-panel pass per additional block.  Valid
masks travel as int32 and the hit flags return as int32 (bool VMEM refs
are a Mosaic lowering hazard); `interpret=True` runs the same dataflow
as pure XLA ops for CPU tests — the only mode exercised in this repo's
CPU CI, as with the other kernel packages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_N = 512
# tie-key sentinel for consumed / masked candidates: larger than any
# real flat panel position (n_probe·bucket + tail << 2^30)
POS_PAD = 2 ** 30


def _select_topk(scores, idx, k):
    """scores: (Q, M) candidates with payload idx (Q, M) -> (Q, k) best
    by k rounds of masked argmax (unrolled, k small).  argmax picks the
    first occurrence, matching lax.top_k's lowest-index tie-break."""
    out_s, out_i = [], []
    for _ in range(k):
        best = jnp.argmax(scores, axis=-1)                       # (Q,)
        rows = jnp.arange(scores.shape[0])
        out_s.append(scores[rows, best])
        out_i.append(idx[rows, best])
        scores = scores.at[rows, best].set(NEG_INF)
    return jnp.stack(out_s, -1), jnp.stack(out_i, -1)


def _merge(acc_s, acc_i, blk_s, blk_i, k):
    """Running top-k merge; accumulator first so earlier candidates win
    ties (panel order)."""
    cand_s = jnp.concatenate([acc_s, blk_s], axis=-1)
    cand_i = jnp.concatenate([acc_i, blk_i], axis=-1)
    return _select_topk(cand_s, cand_i, k)


def _select_topk_pos(scores, pos, slot, k):
    """Top-k by score with ties broken by the lowest ``pos`` — the flat
    candidate-panel position each entry occupies in the oracle's single
    gathered panel.  Masked / already-consumed entries carry POS_PAD,
    so among equal (e.g. all-NEG) scores the selection order is
    ascending panel position: exactly `lax.top_k`'s stable
    lowest-index-first order, independent of the order blocks streamed
    their candidates in."""
    rows = jnp.arange(scores.shape[0])
    out_s, out_p, out_i = [], [], []
    for _ in range(k):
        m = jnp.max(scores, axis=-1, keepdims=True)
        tie_pos = jnp.where(scores >= m, pos, POS_PAD)
        col = jnp.argmin(tie_pos, axis=-1)
        out_s.append(scores[rows, col])
        out_p.append(pos[rows, col])
        out_i.append(slot[rows, col])
        scores = scores.at[rows, col].set(NEG_INF)
        pos = pos.at[rows, col].set(POS_PAD)
    return (jnp.stack(out_s, -1), jnp.stack(out_p, -1),
            jnp.stack(out_i, -1))


def _merge_pos(acc_s, acc_p, acc_i, blk_s, blk_p, blk_i, k):
    """Running top-k merge keyed on (score, panel position)."""
    cand_s = jnp.concatenate([acc_s, blk_s], axis=-1)
    cand_p = jnp.concatenate([acc_p, blk_p], axis=-1)
    cand_i = jnp.concatenate([acc_i, blk_i], axis=-1)
    return _select_topk_pos(cand_s, cand_p, cand_i, k)


def _kernel(q_ref, qt_ref, thr_ref, hk_ref, hv_ref, ht_ref, hvid_ref,
            wk_ref, wscale_ref, wv_ref, wt_ref, wvid_ref, wseq_ref,
            cent_ref, mem_ref, meta_ref, out_s_ref, out_v_ref,
            out_wslot_ref, out_hslot_ref, out_flag_ref,
            acc_s, acc_i, wacc_s, wacc_p, wacc_i, *, k: int, block_n: int,
            n_hot: int, n_hot_blocks: int, warm_block_n: int, n_warm: int,
            n_probe: int, tail: int, quantized: bool):
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        acc_s[...] = jnp.full_like(acc_s, NEG_INF)
        acc_i[...] = jnp.zeros_like(acc_i)
        wacc_s[...] = jnp.full_like(wacc_s, NEG_INF)
        wacc_p[...] = jnp.full_like(wacc_p, POS_PAD)
        wacc_i[...] = jnp.zeros_like(wacc_i)

    q = q_ref[...].astype(jnp.float32)                 # (Q, D)
    qt = qt_ref[...]                                   # (Q,)
    Q = q.shape[0]

    # ---- hot tier: streamed block, tenant-masked running top-k ------
    @pl.when(j < n_hot_blocks)
    def _hot():
        kblk = hk_ref[...].astype(jnp.float32)         # (BN, D)
        s = jax.lax.dot_general(q, kblk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = (hv_ref[...] != 0)[None, :] \
            & (ht_ref[...][None, :] == qt[:, None]) & (col < n_hot)
        s = jnp.where(ok, s, NEG_INF)
        blk_s, blk_i = _select_topk(s, col, k)
        new_s, new_i = _merge(acc_s[...], acc_i[...], blk_s, blk_i, k)
        acc_s[...] = new_s
        acc_i[...] = new_i

    # ---- warm tier: streamed block, position-keyed running top-k ----
    @pl.when(j >= n_hot_blocks)
    def _warm():
        b = j - n_hot_blocks
        base = b * warm_block_n
        bucket = mem_ref.shape[1]
        cursor = meta_ref[0]
        indexed_total = meta_ref[1]
        wv = wv_ref[...] != 0                          # (cap,) whole
        wt = wt_ref[...]
        wseq = wseq_ref[...]
        if quantized:
            # int8 warm block stays int8-resident: dequantize one
            # (Q, B, D) gather at a time, fp32 accumulation
            wkb = wk_ref[...]                          # (WB, D) int8 VMEM
            wscaleb = wscale_ref[...]                  # (WB,) fp32

            def _panel_scores(local):
                pan = wkb[local].astype(jnp.float32)
                return jnp.einsum("qd,qbd->qb", q, pan) * wscaleb[local]
        else:
            wkb = wk_ref[...].astype(jnp.float32)      # (WB, D) VMEM

            def _panel_scores(local):
                return jnp.einsum("qd,qbd->qb", q, wkb[local])

        # probe selection: centroid matmul + n_probe argmax rounds —
        # recomputed per block from the VMEM-resident centroids (tiny,
        # deterministic: every block sees identical probes)
        csims = jax.lax.dot_general(
            q, cent_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (Q, K)
        pcol = jax.lax.broadcasted_iota(jnp.int32, csims.shape, 1)
        _, probes = _select_topk(csims, pcol, n_probe)  # (Q, n_probe)

        # IVF gather: one (Q, bucket) candidate panel per probe, index
        # arithmetic over the inverted lists, restricted to candidates
        # whose row lives in this block — each live candidate is scored
        # exactly once across the sweep, in its own block, tagged with
        # its flat panel position so merge order is block-invariant
        mem = mem_ref[...]                             # (K, bucket)
        ws, wp, wi = wacc_s[...], wacc_p[...], wacc_i[...]
        for p in range(n_probe):
            cand = mem[probes[:, p]]                   # (Q, bucket)
            local = cand - base
            inblk = (cand >= 0) & (local >= 0) & (local < warm_block_n)
            gsafe = jnp.clip(cand, 0, n_warm - 1)
            sc = _panel_scores(jnp.clip(local, 0, warm_block_n - 1))
            okp = inblk & wv[gsafe] & (wt[gsafe] == qt[:, None]) \
                & (wseq[gsafe] <= indexed_total)
            sc = jnp.where(okp, sc, NEG_INF)
            fpos = p * bucket \
                + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
            fpos = jnp.where(okp, fpos, POS_PAD)
            pb_s, pb_p, pb_i = _select_topk_pos(sc, fpos, gsafe, k)
            ws, wp, wi = _merge_pos(ws, wp, wi, pb_s, pb_p, pb_i, k)

        # unindexed-tail scan: last `tail` ring writes, newest first
        if tail:
            offs = jax.lax.broadcasted_iota(jnp.int32, (1, tail), 1)
            pos = (cursor - 1 - offs) % n_warm         # (1, tail)
            unindexed = wseq[pos] > indexed_total
            tcand = jnp.broadcast_to(jnp.where(unindexed, pos, -1),
                                     (Q, tail))
            tlocal = tcand - base
            inblk = (tcand >= 0) & (tlocal >= 0) & (tlocal < warm_block_n)
            tsafe = jnp.clip(tcand, 0, n_warm - 1)
            sc = _panel_scores(jnp.clip(tlocal, 0, warm_block_n - 1))
            okt = inblk & wv[tsafe] & (wt[tsafe] == qt[:, None])
            sc = jnp.where(okt, sc, NEG_INF)
            fpos = n_probe * bucket \
                + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
            fpos = jnp.where(okt, fpos, POS_PAD)
            tb_s, tb_p, tb_i = _select_topk_pos(sc, fpos, tsafe, k)
            ws, wp, wi = _merge_pos(ws, wp, wi, tb_s, tb_p, tb_i, k)
        wacc_s[...] = ws
        wacc_p[...] = wp
        wacc_i[...] = wi

    # ---- best-of-tiers merge: once, after the last warm block -------
    @pl.when(j == nb - 1)
    def _finish():
        rows = jnp.arange(Q)[:, None]
        hs, hi = acc_s[...], acc_i[...]
        ws_acc, wi_acc = wacc_s[...], wacc_i[...]
        hvids = jnp.where(hs > NEG_INF / 2, hvid_ref[...][hi], -1)
        wvids = jnp.where(ws_acc > NEG_INF / 2, wvid_ref[...][wi_acc], -1)
        wslot_c = jnp.where(ws_acc > NEG_INF / 2, wi_acc, -1)
        cand_s = jnp.concatenate([hs, ws_acc], axis=-1)     # (Q, 2k)
        cand_v = jnp.concatenate([hvids, wvids], axis=-1)
        cand_w = jnp.concatenate(
            [jnp.full((Q, k), -1, jnp.int32), wslot_c], axis=-1)
        ppos = jax.lax.broadcasted_iota(jnp.int32, cand_s.shape, 1)
        out_s, out_p = _select_topk(cand_s, ppos, k)
        out_s_ref[...] = out_s
        out_v_ref[...] = cand_v[rows, out_p]
        out_wslot_ref[...] = cand_w[rows, out_p]
        out_hslot_ref[...] = hi[:, :1]
        hit = out_s[:, 0] >= thr_ref[...]
        out_flag_ref[...] = jnp.stack(
            [hit, hit & (out_p[:, 0] < k)], -1).astype(jnp.int32)


def _ens_kernel(q_ref, w_ref, qt_ref, thr_ref, hk_ref, hv_ref, ht_ref,
                hvid_ref, wk_ref, wscale_ref, wv_ref, wt_ref, wvid_ref,
                wseq_ref, cent_ref, mem_ref, meta_ref, out_s_ref, out_v_ref,
                out_wslot_ref, out_hslot_ref, out_flag_ref,
                acc_s, acc_i, wacc_s, wacc_p, wacc_i, *, k: int, block_n: int,
                n_hot: int, n_hot_blocks: int, warm_block_n: int, n_warm: int,
                n_probe: int, tail: int, quantized: bool):
    """E-panel variant of `_kernel` (DESIGN.md §13): the same grid,
    phases, accumulators and merge, but every key-panel stream carries
    E stacked panels and every score is the weighted fused similarity
    ``sum_e w[q, e] · cos(q_e, key_e)``.  The cross-panel weighted sum
    is one einsum contraction over the stacked per-panel scores —
    `ref.ensemble_lookup` uses the identical primitive, which is what
    keeps parity bit-exact (an unrolled multiply-add chain is not
    fusion-stable across eager/jit graph boundaries).
    Routing (probe selection and the IVF gather index arithmetic) runs
    once, on the unweighted pilot panel — the candidate *index* stream
    and all masks are shared across panels, which is where the
    sequential path's E× overhead goes away."""
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        acc_s[...] = jnp.full_like(acc_s, NEG_INF)
        acc_i[...] = jnp.zeros_like(acc_i)
        wacc_s[...] = jnp.full_like(wacc_s, NEG_INF)
        wacc_p[...] = jnp.full_like(wacc_p, POS_PAD)
        wacc_i[...] = jnp.zeros_like(wacc_i)

    q = q_ref[...].astype(jnp.float32)                 # (E, Q, D)
    w = w_ref[...].astype(jnp.float32)                 # (Q, E)
    qt = qt_ref[...]                                   # (Q,)
    E = q.shape[0]
    Q = q.shape[1]

    # ---- hot tier: streamed stacked block, fused running top-k ------
    @pl.when(j < n_hot_blocks)
    def _hot():
        kblk = hk_ref[...].astype(jnp.float32)         # (E, BN, D)
        pans = [jax.lax.dot_general(q[e], kblk[e], (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
                for e in range(E)]
        s = jnp.einsum("qne,qe->qn", jnp.stack(pans, -1), w)
        col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = (hv_ref[...] != 0)[None, :] \
            & (ht_ref[...][None, :] == qt[:, None]) & (col < n_hot)
        s = jnp.where(ok, s, NEG_INF)
        blk_s, blk_i = _select_topk(s, col, k)
        new_s, new_i = _merge(acc_s[...], acc_i[...], blk_s, blk_i, k)
        acc_s[...] = new_s
        acc_i[...] = new_i

    # ---- warm tier: pilot-routed, fused position-keyed top-k --------
    @pl.when(j >= n_hot_blocks)
    def _warm():
        b = j - n_hot_blocks
        base = b * warm_block_n
        bucket = mem_ref.shape[1]
        cursor = meta_ref[0]
        indexed_total = meta_ref[1]
        wv = wv_ref[...] != 0                          # (cap,) whole
        wt = wt_ref[...]
        wseq = wseq_ref[...]
        if quantized:
            # int8 stacked warm block: per-panel dequant + scale, then
            # one stacked contraction with the weights — same primitive
            # sequence as the oracle
            wkb = wk_ref[...]                          # (E, WB, D) int8
            wscaleb = wscale_ref[...]                  # (E, WB) fp32

            def _panel_scores(local):
                pans = [jnp.einsum("qd,qbd->qb", q[e],
                                   wkb[e][local].astype(jnp.float32))
                        * wscaleb[e][local] for e in range(E)]
                return jnp.einsum("qbe,qe->qb", jnp.stack(pans, -1), w)
        else:
            wkb = wk_ref[...].astype(jnp.float32)      # (E, WB, D)

            def _panel_scores(local):
                pans = [jnp.einsum("qd,qbd->qb", q[e], wkb[e][local])
                        for e in range(E)]
                return jnp.einsum("qbe,qe->qb", jnp.stack(pans, -1), w)

        # probe selection on the pilot panel only: one centroid matmul
        # and one set of probes shared by all E panels
        csims = jax.lax.dot_general(
            q[0], cent_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (Q, K)
        pcol = jax.lax.broadcasted_iota(jnp.int32, csims.shape, 1)
        _, probes = _select_topk(csims, pcol, n_probe)  # (Q, n_probe)

        # shared IVF gather indices: the (Q, bucket) candidate id panel
        # and its masks are computed once per probe and reused by every
        # panel's score term inside _panel_scores
        mem = mem_ref[...]                             # (K, bucket)
        ws, wp, wi = wacc_s[...], wacc_p[...], wacc_i[...]
        for p in range(n_probe):
            cand = mem[probes[:, p]]                   # (Q, bucket)
            local = cand - base
            inblk = (cand >= 0) & (local >= 0) & (local < warm_block_n)
            gsafe = jnp.clip(cand, 0, n_warm - 1)
            sc = _panel_scores(jnp.clip(local, 0, warm_block_n - 1))
            okp = inblk & wv[gsafe] & (wt[gsafe] == qt[:, None]) \
                & (wseq[gsafe] <= indexed_total)
            sc = jnp.where(okp, sc, NEG_INF)
            fpos = p * bucket \
                + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
            fpos = jnp.where(okp, fpos, POS_PAD)
            pb_s, pb_p, pb_i = _select_topk_pos(sc, fpos, gsafe, k)
            ws, wp, wi = _merge_pos(ws, wp, wi, pb_s, pb_p, pb_i, k)

        # unindexed-tail scan: last `tail` ring writes, newest first
        if tail:
            offs = jax.lax.broadcasted_iota(jnp.int32, (1, tail), 1)
            pos = (cursor - 1 - offs) % n_warm         # (1, tail)
            unindexed = wseq[pos] > indexed_total
            tcand = jnp.broadcast_to(jnp.where(unindexed, pos, -1),
                                     (Q, tail))
            tlocal = tcand - base
            inblk = (tcand >= 0) & (tlocal >= 0) & (tlocal < warm_block_n)
            tsafe = jnp.clip(tcand, 0, n_warm - 1)
            sc = _panel_scores(jnp.clip(tlocal, 0, warm_block_n - 1))
            okt = inblk & wv[tsafe] & (wt[tsafe] == qt[:, None])
            sc = jnp.where(okt, sc, NEG_INF)
            fpos = n_probe * bucket \
                + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
            fpos = jnp.where(okt, fpos, POS_PAD)
            tb_s, tb_p, tb_i = _select_topk_pos(sc, fpos, tsafe, k)
            ws, wp, wi = _merge_pos(ws, wp, wi, tb_s, tb_p, tb_i, k)
        wacc_s[...] = ws
        wacc_p[...] = wp
        wacc_i[...] = wi

    # ---- best-of-tiers merge: once, after the last warm block -------
    @pl.when(j == nb - 1)
    def _finish():
        rows = jnp.arange(Q)[:, None]
        hs, hi = acc_s[...], acc_i[...]
        ws_acc, wi_acc = wacc_s[...], wacc_i[...]
        hvids = jnp.where(hs > NEG_INF / 2, hvid_ref[...][hi], -1)
        wvids = jnp.where(ws_acc > NEG_INF / 2, wvid_ref[...][wi_acc], -1)
        wslot_c = jnp.where(ws_acc > NEG_INF / 2, wi_acc, -1)
        cand_s = jnp.concatenate([hs, ws_acc], axis=-1)     # (Q, 2k)
        cand_v = jnp.concatenate([hvids, wvids], axis=-1)
        cand_w = jnp.concatenate(
            [jnp.full((Q, k), -1, jnp.int32), wslot_c], axis=-1)
        ppos = jax.lax.broadcasted_iota(jnp.int32, cand_s.shape, 1)
        out_s, out_p = _select_topk(cand_s, ppos, k)
        out_s_ref[...] = out_s
        out_v_ref[...] = cand_v[rows, out_p]
        out_wslot_ref[...] = cand_w[rows, out_p]
        out_hslot_ref[...] = hi[:, :1]
        hit = out_s[:, 0] >= thr_ref[...]
        out_flag_ref[...] = jnp.stack(
            [hit, hit & (out_p[:, 0] < k)], -1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "n_probe", "tail",
                                             "block_n", "warm_block_n",
                                             "interpret", "quantized"))
def cascade_lookup_ensemble(q, weights, q_tenants, thresholds,
                            hot_keys, hot_valid, hot_tenants, hot_value_ids,
                            warm_keys, warm_valid, warm_tenants,
                            warm_value_ids, warm_write_seq, centroids,
                            members, cursor, indexed_total,
                            warm_keys_q=None, warm_scales=None,
                            k: int = 1, n_probe: int = 8, tail: int = 0, *,
                            quantized: bool = False,
                            block_n: int = DEFAULT_BLOCK_N,
                            warm_block_n: int | None = None,
                            interpret: bool = True):
    """Fused E-panel ensemble cascade; signature/semantics of
    `ref.ensemble_lookup`.

    q: (E, Q, D) unit-norm stacked queries; weights: (Q, E) per-query
    mixture weights; hot_keys: (E, Nh, D); warm panels (E, cap, D)
    (int8 + (E, cap) scales when ``quantized``).  Per-slot metadata and
    the pilot-built IVF are shared across panels.  One grid sweep
    streams all E panels block-aligned — each grid step fetches one
    (E, block, D) stacked tile, so HBM traffic grows with E only for
    the key panels themselves while routing, masks, index arithmetic
    and the running top-k stay single-copy.  Returns the 6-tuple of
    `cascade_lookup` with fused scores.
    """
    q = q.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    q_tenants = q_tenants.astype(jnp.int32)
    E, Q, D = q.shape
    n_hot = hot_keys.shape[1]
    n_clusters = centroids.shape[0]
    n_probe = min(n_probe, n_clusters)
    cap = warm_keys.shape[1]

    if quantized:
        wk_in = warm_keys_q
        wscale_in = warm_scales.astype(jnp.float32)
        wk_dtype = jnp.int8
    else:
        wk_in = warm_keys
        wscale_in = jnp.zeros((E, cap), jnp.float32)    # unread placeholder
        wk_dtype = jnp.float32

    bn = min(block_n, n_hot)
    n_blocks = -(-n_hot // bn)
    pad = n_blocks * bn - n_hot
    # bool VMEM refs are a Mosaic lowering hazard: masks travel as int32
    hot_valid = hot_valid.astype(jnp.int32)
    warm_valid = warm_valid.astype(jnp.int32)
    if pad:
        hot_keys = jnp.pad(hot_keys, ((0, 0), (0, pad), (0, 0)))
        hot_valid = jnp.pad(hot_valid, (0, pad))
        hot_tenants = jnp.pad(hot_tenants, (0, pad), constant_values=-1)
        hot_value_ids = jnp.pad(hot_value_ids, (0, pad), constant_values=-1)

    wb = min(warm_block_n or cap, cap)
    n_wblocks = -(-cap // wb)
    wpad = n_wblocks * wb - cap
    wk_in = wk_in.astype(wk_dtype)
    if wpad:
        wk_in = jnp.pad(wk_in, ((0, 0), (0, wpad), (0, 0)))
        wscale_in = jnp.pad(wscale_in, ((0, 0), (0, wpad)))
    meta = jnp.stack([jnp.asarray(cursor, jnp.int32),
                      jnp.asarray(indexed_total, jnp.int32)])

    bucket = members.shape[1]
    grid = (n_blocks + n_wblocks,)
    whole = lambda shape: pl.BlockSpec(shape, lambda j: (0,) * len(shape))
    # clamped index maps as in `cascade_lookup`, panel axis never tiled
    hblk = lambda j: (jnp.minimum(j, n_blocks - 1),)
    hblk3 = lambda j: (0, jnp.minimum(j, n_blocks - 1), 0)
    wblk3 = lambda j: (0, jnp.maximum(j - n_blocks, 0), 0)
    wblk2e = lambda j: (0, jnp.maximum(j - n_blocks, 0))
    out_shape = (jax.ShapeDtypeStruct((Q, k), jnp.float32),
                 jax.ShapeDtypeStruct((Q, k), jnp.int32),
                 jax.ShapeDtypeStruct((Q, k), jnp.int32),
                 jax.ShapeDtypeStruct((Q, 1), jnp.int32),
                 jax.ShapeDtypeStruct((Q, 2), jnp.int32))
    fn = pl.pallas_call(
        functools.partial(_ens_kernel, k=k, block_n=bn, n_hot=n_hot,
                          n_hot_blocks=n_blocks, warm_block_n=wb,
                          n_warm=cap, n_probe=n_probe, tail=tail,
                          quantized=quantized),
        grid=grid,
        in_specs=[
            whole((E, Q, D)),                             # stacked queries
            whole((Q, E)),                                # mixture weights
            whole((Q,)),                                  # q_tenants
            whole((Q,)),                                  # thresholds
            pl.BlockSpec((E, bn, D), hblk3),              # hot panel stream
            pl.BlockSpec((bn,), hblk),                    # hot valid
            pl.BlockSpec((bn,), hblk),                    # hot tenants
            whole((n_blocks * bn,)),                      # hot value ids
            pl.BlockSpec((E, wb, D), wblk3),              # warm panel stream
            pl.BlockSpec((E, wb), wblk2e),                # warm row scales
            whole((cap,)),                                # warm valid
            whole((cap,)),                                # warm tenants
            whole((cap,)),                                # warm value ids
            whole((cap,)),                                # warm write seq
            whole((n_clusters, D)),                       # centroids
            whole((n_clusters, bucket)),                  # inverted lists
            whole((2,)),                                  # cursor/indexed
        ],
        out_specs=(whole((Q, k)), whole((Q, k)), whole((Q, k)),
                   whole((Q, 1)), whole((Q, 2))),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
            pltpu.VMEM((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )
    out_s, out_v, out_w, hslot, flags = fn(
        q, weights, q_tenants, thresholds.astype(jnp.float32), hot_keys,
        hot_valid, hot_tenants, hot_value_ids, wk_in, wscale_in,
        warm_valid, warm_tenants, warm_value_ids, warm_write_seq, centroids,
        members, meta)
    return (out_s, out_v, out_w, hslot[:, 0], flags[:, 1] != 0,
            flags[:, 0] != 0)


@functools.partial(jax.jit, static_argnames=("k", "n_probe", "tail",
                                             "block_n", "warm_block_n",
                                             "interpret", "quantized"))
def cascade_lookup(q, q_tenants, thresholds,
                   hot_keys, hot_valid, hot_tenants, hot_value_ids,
                   warm_keys, warm_valid, warm_tenants, warm_value_ids,
                   warm_write_seq, centroids, members, cursor, indexed_total,
                   warm_keys_q=None, warm_scales=None,
                   k: int = 1, n_probe: int = 8, tail: int = 0, *,
                   quantized: bool = False,
                   block_n: int = DEFAULT_BLOCK_N,
                   warm_block_n: int | None = None, interpret: bool = True):
    """Array-level fused cascade; signature/semantics of `ref.py`.

    q: (Q, D) unit-norm.  Returns (scores (Q, k), value_ids (Q, k),
    warm_slots (Q, k), hot_slots (Q,), hot_hit (Q,), hit (Q,)).
    ``quantized=True`` streams ``warm_keys_q``/``warm_scales`` instead
    of the fp32 warm panel.  ``warm_block_n`` streams the warm key
    panel in blocks of that many rows (None = one block, the old
    whole-panel residency); results are bit-identical for every block
    count.
    """
    q = q.astype(jnp.float32)
    q_tenants = q_tenants.astype(jnp.int32)
    Q, D = q.shape
    n_hot = hot_keys.shape[0]
    n_clusters = centroids.shape[0]
    n_probe = min(n_probe, n_clusters)
    cap = warm_keys.shape[0]

    if quantized:
        wk_in = warm_keys_q
        wscale_in = warm_scales.astype(jnp.float32)
        wk_dtype = jnp.int8
    else:
        wk_in = warm_keys
        wscale_in = jnp.zeros((cap,), jnp.float32)      # unread placeholder
        wk_dtype = jnp.float32

    bn = min(block_n, n_hot)
    n_blocks = -(-n_hot // bn)
    pad = n_blocks * bn - n_hot
    # bool VMEM refs are a Mosaic lowering hazard: masks travel as int32
    hot_valid = hot_valid.astype(jnp.int32)
    warm_valid = warm_valid.astype(jnp.int32)
    if pad:
        hot_keys = jnp.pad(hot_keys, ((0, pad), (0, 0)))
        hot_valid = jnp.pad(hot_valid, (0, pad))
        hot_tenants = jnp.pad(hot_tenants, (0, pad), constant_values=-1)
        hot_value_ids = jnp.pad(hot_value_ids, (0, pad), constant_values=-1)

    wb = min(warm_block_n or cap, cap)
    n_wblocks = -(-cap // wb)
    wpad = n_wblocks * wb - cap
    wk_in = wk_in.astype(wk_dtype)
    if wpad:
        # only the streamed panels pad (their BlockSpec tiles the padded
        # extent); per-slot metadata stays (cap,) — no candidate id ever
        # reaches the pad rows, so they are dead weight, never read
        wk_in = jnp.pad(wk_in, ((0, wpad), (0, 0)))
        wscale_in = jnp.pad(wscale_in, (0, wpad))
    meta = jnp.stack([jnp.asarray(cursor, jnp.int32),
                      jnp.asarray(indexed_total, jnp.int32)])

    bucket = members.shape[1]
    grid = (n_blocks + n_wblocks,)
    whole = lambda shape: pl.BlockSpec(shape, lambda j: (0,) * len(shape))
    # clamped index maps: hot tiles only advance through the hot steps,
    # warm tiles only through the warm steps — a revisited index fetches
    # nothing new, so neither stream pays for the other's phase
    hblk = lambda j: (jnp.minimum(j, n_blocks - 1),)
    hblk2 = lambda j: (jnp.minimum(j, n_blocks - 1), 0)
    wblk = lambda j: (jnp.maximum(j - n_blocks, 0),)
    wblk2 = lambda j: (jnp.maximum(j - n_blocks, 0), 0)
    out_shape = (jax.ShapeDtypeStruct((Q, k), jnp.float32),
                 jax.ShapeDtypeStruct((Q, k), jnp.int32),
                 jax.ShapeDtypeStruct((Q, k), jnp.int32),
                 jax.ShapeDtypeStruct((Q, 1), jnp.int32),
                 jax.ShapeDtypeStruct((Q, 2), jnp.int32))
    fn = pl.pallas_call(
        functools.partial(_kernel, k=k, block_n=bn, n_hot=n_hot,
                          n_hot_blocks=n_blocks, warm_block_n=wb,
                          n_warm=cap, n_probe=n_probe, tail=tail,
                          quantized=quantized),
        grid=grid,
        in_specs=[
            whole((Q, D)),                                # q
            whole((Q,)),                                  # q_tenants
            whole((Q,)),                                  # thresholds
            pl.BlockSpec((bn, D), hblk2),                 # hot keys stream
            pl.BlockSpec((bn,), hblk),                    # hot valid
            pl.BlockSpec((bn,), hblk),                    # hot tenants
            whole((n_blocks * bn,)),                      # hot value ids
            pl.BlockSpec((wb, D), wblk2),                 # warm keys stream
            pl.BlockSpec((wb,), wblk),                    # warm row scales
            whole((cap,)),                                # warm valid
            whole((cap,)),                                # warm tenants
            whole((cap,)),                                # warm value ids
            whole((cap,)),                                # warm write seq
            whole((n_clusters, D)),                       # centroids
            whole((n_clusters, bucket)),                  # inverted lists
            whole((2,)),                                  # cursor/indexed
        ],
        out_specs=(whole((Q, k)), whole((Q, k)), whole((Q, k)),
                   whole((Q, 1)), whole((Q, 2))),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
            pltpu.VMEM((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )
    out_s, out_v, out_w, hslot, flags = fn(
        q, q_tenants, thresholds.astype(jnp.float32), hot_keys, hot_valid,
        hot_tenants, hot_value_ids, wk_in, wscale_in,
        warm_valid, warm_tenants, warm_value_ids, warm_write_seq, centroids,
        members, meta)
    return (out_s, out_v, out_w, hslot[:, 0], flags[:, 1] != 0,
            flags[:, 0] != 0)
