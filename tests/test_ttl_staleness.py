"""Property tests for TTL/staleness eviction (DESIGN.md §14.2) and the
conformal hit-calibration floor (§14.3).

The TTL properties run against a real ``CacheService`` under an
injectable logical clock (``StalenessConfig.clock``), with a hot tier
squeezed small enough that entries demote through warm mid-life — the
invariants must hold wherever an entry happens to live:

  * an expired entry is NEVER served, from any tier, fused or unfused,
    whether or not maintenance has reaped it yet (plan-time masking);
  * reaping never frees a live value_id — every unexpired entry is
    still served with its own response after any maintenance;
  * ``evict_tenant`` composes with pending expiry: evicting one tenant
    neither resurrects nor double-frees the other's entries.

The container ships no ``hypothesis``; when it is importable each
property runs under ``@given``, otherwise as a deterministic seed
sweep (same predicate, fixed draw per seed — do not pip install)."""
import numpy as np
import pytest

from repro.cache_service import (
    CacheConfig, CachePlan, CacheRequest, CacheService, ConformalWindow,
    LearningConfig, StalenessConfig, TieringConfig,
)
from repro.cache_service.feedback import (
    FeedbackAccumulator, FeedbackConfig,
)
from repro.cache_service.policy import PolicyTable, TenantPolicy

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # image has none
    HAVE_HYPOTHESIS = False


def _property(f):
    if HAVE_HYPOTHESIS:
        return settings(max_examples=10, deadline=None)(
            given(seed=st.integers(0, 2**31 - 1))(f))
    return pytest.mark.parametrize("seed", range(10))(f)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _svc(clock, *, fused=False, dim=16):
    # low flush watermark + small insert batches: every hot overflow
    # goes through the maintenance *demotion* path into warm (an
    # insert-time overflow hard-drops instead, which is legitimate
    # cache eviction but would make "live rows keep serving" vacuous)
    return CacheService(CacheConfig(
        dim=dim, threshold=0.9,
        tiering=TieringConfig(hot_capacity=8, warm_capacity=64,
                              n_clusters=2, bucket=32, n_probe=2,
                              flush_watermark=0.5, flush_size=4,
                              fused=fused),
        staleness=StalenessConfig(clock=lambda: clock["t"])))


def _commit_rows(svc, embs, responses, ttl, tenant=0):
    req = CacheRequest.build(embs, tenant, ttl=ttl)
    plan = CachePlan.for_insert(req, np.ones(len(req), bool),
                                epoch=svc._epoch,
                                embed_version=svc._embed_version)
    return svc.commit(plan, responses)


def _ttl_world(seed, clock, fused):
    """Random TTL pattern over enough rows to push through hot into
    warm; returns (svc, embs, deadlines)."""
    rng = np.random.default_rng(seed)
    svc = _svc(clock, fused=fused)
    n = int(rng.integers(12, 28))      # > hot_capacity=8: forces demotion
    embs = _unit(rng.standard_normal((n, 16)).astype(np.float32))
    ttl = np.where(rng.random(n) < 0.6,
                   rng.uniform(1.0, 20.0, n), np.inf).astype(np.float32)
    deadlines = clock["t"] + ttl
    for lo in range(0, n, 2):          # small batches interleave demotions
        hi = min(lo + 2, n)
        _commit_rows(svc, embs[lo:hi], [f"r{i}" for i in range(lo, hi)],
                     ttl[lo:hi])
        svc.maintenance()              # flush to warm + publish the IVF
    return svc, embs, deadlines


@_property
def test_expired_rows_never_served_any_tier(seed):
    fused = bool(seed % 2)
    clock = {"t": 100.0}
    svc, embs, deadlines = _ttl_world(seed, clock, fused)
    rng = np.random.default_rng(seed + 1)
    for now in sorted(rng.uniform(100.0, 125.0, 4)):
        clock["t"] = float(now)
        plan = svc.plan(CacheRequest.build(embs), coalesce=False)
        hit = np.asarray(plan.hit)
        live = deadlines >= now
        # an expired row must never hit — masked at plan time even
        # though maintenance hasn't reaped anything yet
        assert not np.any(hit & ~live), (
            f"expired row served at t={now} (fused={fused}): "
            f"{np.flatnonzero(hit & ~live)}")
        # and every still-live row must still be served with its own
        # response (expiry must not over-mask live neighbours)
        assert np.all(hit[live]), (
            f"live row lost at t={now}: {np.flatnonzero(live & ~hit)}")
        for i in np.flatnonzero(live):
            assert plan.responses[i] == f"r{i}"


@_property
def test_reaping_never_frees_live_value_id(seed):
    clock = {"t": 0.0}
    svc, embs, deadlines = _ttl_world(seed, clock, fused=False)
    rng = np.random.default_rng(seed + 2)
    for now in sorted(rng.uniform(0.0, 30.0, 5)):
        clock["t"] = float(now)
        svc.maintenance()              # reap everything expired by now
        live = deadlines >= now
        plan = svc.plan(CacheRequest.build(embs), coalesce=False)
        hit = np.asarray(plan.hit)
        assert np.all(hit[live]), (
            f"maintenance at t={now} reaped live row(s) "
            f"{np.flatnonzero(live & ~hit)}")
        for i in np.flatnonzero(live):
            assert plan.responses[i] == f"r{i}", \
                f"row {i} value freed while its deadline is in the future"
    clock["t"] = 1e9                   # every finite deadline passes...
    svc.maintenance()
    # ...freeing exactly the finite-TTL values; no-TTL rows live on
    assert len(svc.responses) == int(np.isinf(deadlines).sum())


@_property
def test_evict_tenant_composes_with_pending_expiry(seed):
    rng = np.random.default_rng(seed)
    clock = {"t": 0.0}
    svc = _svc(clock)
    n = 12
    e0 = _unit(rng.standard_normal((n, 16)).astype(np.float32))
    e1 = _unit(rng.standard_normal((n, 16)).astype(np.float32))
    ttl = np.where(rng.random(n) < 0.5, 5.0, np.inf).astype(np.float32)
    for lo in range(0, n, 2):          # through the flush path; no drops
        hi = min(lo + 2, n)
        _commit_rows(svc, e0[lo:hi], [f"a{i}" for i in range(lo, hi)],
                     ttl[lo:hi], tenant=0)
        _commit_rows(svc, e1[lo:hi], [f"b{i}" for i in range(lo, hi)],
                     ttl[lo:hi], tenant=1)
        svc.maintenance()
    clock["t"] = 10.0                  # finite-TTL rows now pending-expired
    svc.evict_tenant(0)
    svc.maintenance()                  # reap must not double-free t0 rows
    plan0 = svc.plan(CacheRequest.build(e0, 0), coalesce=False)
    assert not np.asarray(plan0.hit).any(), "evicted tenant still served"
    plan1 = svc.plan(CacheRequest.build(e1, 1), coalesce=False)
    hit1 = np.asarray(plan1.hit)
    live = np.isinf(ttl)
    assert np.all(hit1[live]), "tenant eviction dropped the other tenant"
    assert not np.any(hit1[~live]), "expired row of tenant 1 served"
    for i in np.flatnonzero(live):
        assert plan1.responses[i] == f"b{i}"
    # exactly tenant 1's live values remain held
    assert len(svc.responses) == int(live.sum())


@_property
def test_cold_tier_respects_expiry(seed):
    """Entries pushed all the way into the host-RAM cold tier must
    still honour their deadline on the routed fetch path."""
    rng = np.random.default_rng(seed)
    clock = {"t": 0.0}
    svc = CacheService(CacheConfig(
        dim=16, threshold=0.9,
        tiering=TieringConfig(hot_capacity=8, warm_capacity=16,
                              n_clusters=2, bucket=8, n_probe=2,
                              cold_capacity=128),
        staleness=StalenessConfig(clock=lambda: clock["t"])))
    n = 40                             # >> hot+warm: spills into cold
    embs = _unit(rng.standard_normal((n, 16)).astype(np.float32))
    ttl = np.where(rng.random(n) < 0.5, 4.0, np.inf).astype(np.float32)
    for lo in range(0, n, 8):
        hi = min(lo + 8, n)
        _commit_rows(svc, embs[lo:hi], [f"r{i}" for i in range(lo, hi)],
                     ttl[lo:hi])
        svc.maintenance()
    clock["t"] = 6.0
    svc.maintenance()
    plan = svc.plan(CacheRequest.build(embs), coalesce=False)
    hit = np.asarray(plan.hit)
    assert not np.any(hit[np.isfinite(ttl)]), \
        "expired row served (cold-backed tiering)"


# ---------------------------------------------------------------------------
# §14.3 conformal floor
# ---------------------------------------------------------------------------

def test_conformal_window_floor_is_order_statistic():
    w = ConformalWindow(capacity=64)
    for s in np.linspace(0.0, 0.63, 64):
        w.add(float(s))
    # alpha=0.25 over n=64: rank = ceil(65*0.75) = 49 -> 49th smallest
    scores = np.sort(w.scores[:w.fill])
    assert w.floor(0.25) == pytest.approx(scores[48] + 1e-6)
    # tiny alpha clamps to the max
    assert w.floor(1e-6) == pytest.approx(scores[-1] + 1e-6)


def test_conformal_window_is_recency_ring():
    w = ConformalWindow(capacity=8)
    for s in [0.9] * 8:                # old era: high negatives
        w.add(s)
    for s in [0.1] * 8:                # new era fully ages it out
        w.add(s)
    assert w.floor(0.3) < 0.2          # floor tracks the current era


def test_hit_audit_feeds_window_and_raises_floor():
    fb = FeedbackAccumulator(FeedbackConfig(conformal_min=8,
                                            max_false_hit_rate=0.05))
    for _ in range(16):
        fb.observe(0, 0.4, duplicate=False, admitted=True)
    low = fb.conformal_floor(0)
    assert low is not None and low < 0.5
    # audited FALSE hits above the threshold de-censor the stream...
    for _ in range(16):
        fb.observe_hit_audit(0, 0.8, duplicate=False)
    assert fb.conformal_floor(0) > 0.7
    assert fb.counters["hit_audits"] == 16
    assert fb.counters["audited_false_hits"] == 16
    # ...while audited TRUE hits never move the negative window
    before = fb.conformal_floor(0)
    for _ in range(16):
        fb.observe_hit_audit(0, 0.99, duplicate=True)
    assert fb.conformal_floor(0) == pytest.approx(before)


def test_effective_thresholds_only_ever_raise():
    fb = FeedbackAccumulator(FeedbackConfig(conformal_min=4))
    pol = PolicyTable(TenantPolicy(threshold=0.85))
    for _ in range(8):
        fb.observe(0, 0.95, duplicate=False, admitted=True)  # hostile band
        fb.observe(1, 0.10, duplicate=False, admitted=True)  # benign band
    eff = pol.effective_thresholds(np.asarray([0, 1, 2]), fb)
    assert eff[0] > 0.9                # floor raised above the policy
    assert eff[1] == pytest.approx(0.85)   # benign floor can't lower it
    assert eff[2] == pytest.approx(0.85)   # unseen tenant: no floor
    assert np.all(eff >= pol.thresholds_for(np.asarray([0, 1, 2])))
