"""Common neural-net layers: norms, RoPE, MLPs, embeddings.

All `init_*` functions return Param trees (see models/param.py); all
`apply_*` functions take the plain-value tree (after `param.split`) and
are pure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import Initializer


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(ini: Initializer, cfg: ModelConfig):
    if cfg.norm_type == "rmsnorm":
        return {"scale": ini.ones((cfg.d_model,), ("embed",))}
    return {
        "scale": ini.ones((cfg.d_model,), ("embed",)),
        "bias": ini.zeros((cfg.d_model,), ("embed",)),
    }


def apply_norm(p, cfg: ModelConfig, x):
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + cfg.norm_eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(cfg: ModelConfig, positions):
    """positions: (...,) int32 -> (sin, cos) of shape (..., head_dim//2)."""
    hd = cfg.head_dim
    exponent = jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
    inv_freq = 1.0 / (cfg.rope_theta ** exponent)           # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, sin, cos):
    """x: (B, S, H, hd); sin/cos: (B, S, hd/2) or (S, hd/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if sin.ndim == x1.ndim - 2:      # (S, hd/2) -> (1, S, hd/2)
        sin, cos = sin[None], cos[None]
    sin, cos = sin[:, :, None, :], cos[:, :, None, :]  # head axis
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, offset=0):
    """Classic sinusoidal table (used by the audio backbone in lieu of
    MusicGen's learned absolute positions — same shape/fan-in)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32) + offset
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    angle = pos[:, None] / jnp.power(10_000.0, dim / d_model)[None, :]
    emb = jnp.zeros((seq_len, d_model), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(angle))
    emb = emb.at[:, 1::2].set(jnp.cos(angle))
    return emb


# ---------------------------------------------------------------------------
# MLPs (dense FFN)
# ---------------------------------------------------------------------------

def init_mlp(ini: Initializer, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": ini.lecun((d, f), ("embed", "mlp")),
            "w_up": ini.lecun((d, f), ("embed", "mlp")),
            "w_down": ini.lecun((f, d), ("mlp", "embed")),
        }
    if cfg.mlp_type == "gelu":
        return {
            "w_up": ini.lecun((d, f), ("embed", "mlp")),
            "b_up": ini.zeros((f,), ("mlp",)),
            "w_down": ini.lecun((f, d), ("mlp", "embed")),
            "b_down": ini.zeros((d,), ("embed",)),
        }
    raise ValueError(cfg.mlp_type)


def apply_mlp(p, cfg: ModelConfig, x):
    dt = x.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        g = act(x @ p["w_gate"].astype(dt))
        u = x @ p["w_up"].astype(dt)
        return (g * u) @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# ---------------------------------------------------------------------------
# Token embedding / unembedding
# ---------------------------------------------------------------------------

def padded_vocab(cfg: ModelConfig) -> int:
    if cfg.pad_vocab_to:
        m = cfg.pad_vocab_to
        return -(-cfg.vocab_size // m) * m
    return cfg.vocab_size


def init_embedding(ini: Initializer, cfg: ModelConfig):
    v = padded_vocab(cfg)
    p = {"table": ini.normal((v, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        p["unembed"] = ini.normal((cfg.d_model, v), ("embed", "vocab"))
    return p


def embed_tokens(p, cfg: ModelConfig, tokens):
    return jnp.take(p["table"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))


def unembed(p, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = x @ p["table"].astype(x.dtype).T
    else:
        logits = x @ p["unembed"].astype(x.dtype)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    v = padded_vocab(cfg)
    if v != cfg.vocab_size:  # mask pad logits out of softmax/CE/argmax
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits
