from repro.serving.engine import CachedLLMService, GenerationResult, ServeEngine
from repro.serving.frontend import frontend_spec, stub_frontend_embeds
from repro.serving.scheduler import ContinuousBatcher, Request

__all__ = ["CachedLLMService", "GenerationResult", "ServeEngine",
           "frontend_spec", "stub_frontend_embeds",
           "ContinuousBatcher", "Request"]
