"""Pixtral-12B — VLM: Pixtral-ViT encoder + Mistral-NeMo-style decoder.

[hf:mistralai/Pixtral-12B-2409]  Decoder backbone: 40L, d_model=5120,
32 heads, kv=8, d_ff=14336, vocab=131072, head_dim=128 (explicit — NOT
d_model/n_heads).  The vision encoder + projector is the *vision
frontend stub*: ``input_specs`` provides precomputed patch embeddings of
shape (B, frontend_len, d_model) prepended to the token stream.
"""
from repro.configs.base import ModelConfig, LayerSpec, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    use_rope=True,
    rope_theta=1_000_000.0,
    period=(LayerSpec(ATTN, DENSE),),
    frontend="vision",
    frontend_len=256,
))
