"""Pure-jnp oracle for the fused online-contrastive loss kernel.

Returns the *components* (pos_loss_sum, neg_loss_sum, min_neg, max_pos)
— the op wrapper assembles the final scalar exactly like
repro.core.losses.online_contrastive_loss.
"""
from __future__ import annotations

import jax.numpy as jnp

BIG = 1e9


def contrastive_components(e1, e2, labels, margin: float = 0.5):
    e1 = e1.astype(jnp.float32)
    e2 = e2.astype(jnp.float32)
    num = jnp.sum(e1 * e2, axis=-1)
    den = jnp.linalg.norm(e1, axis=-1) * jnp.linalg.norm(e2, axis=-1)
    d = 1.0 - num / jnp.maximum(den, 1e-9)
    is_pos = labels.astype(bool)
    is_neg = ~is_pos
    min_neg = jnp.min(jnp.where(is_neg, d, BIG))
    max_pos = jnp.max(jnp.where(is_pos, d, -BIG))
    hard_pos = is_pos & (d > min_neg)
    hard_neg = is_neg & (d < max_pos)
    pos_loss = jnp.sum(jnp.square(d) * hard_pos)
    neg_loss = jnp.sum(jnp.square(jnp.maximum(margin - d, 0.0)) * hard_neg)
    return pos_loss, neg_loss, min_neg, max_pos
