"""Seeded serving-scenario traces for the macro-bench (DESIGN.md §14.1).

Each generator composes one production failure mode into a
reproducible trace: a list of ``Step``s, each one batch of embedded
queries with ground truth attached.  The harness
(``bench_scenarios.py``) replays a trace through a real
``CacheService`` under a *logical clock* (``StalenessConfig.clock``),
so arrival times, TTL expiry and maintenance cadence are exactly the
trace's — no wall-clock flake.

Ground-truth model
------------------
Every query row belongs to a **group** — the unit of answer identity.
A novel row opens a fresh group; a repeat/paraphrase row carries the
group of the entry it rephrases (``group[i]``).  The harness commits
every admitted miss with the response ``f"ans-g{gid}"``, so scoring
is pure string equality:

  * true hit   — served response == the row's own group answer;
  * false hit  — served response is some *other* group's answer
    (cross-group, cross-tenant, or an adversarial ``must_miss`` row
    that is geometrically close to a stored entry but semantically
    distinct — its own fresh group by construction);
  * stale serve — a hit on a group whose latest insert's TTL deadline
    has passed at arrival time (tracked by the harness; hard-asserted
    zero everywhere).

Scenarios (``SCENARIOS`` registry):

  * ``diurnal``      — sinusoidal arrival rate: batch sizes swell to
    ~3x base at peak; p99 must hold through the peak, not the mean.
  * ``zipf_tenants`` — tenant of each row drawn Zipf(a): one hot
    tenant dominates, a long tail of barely-seen tenants rides along.
  * ``drift``        — two-phase topic drift for the §14.3 conformal
    contrast: phase 1 is calibration traffic (duplicates ~0.95,
    negatives ~0.55 — a per-tenant learned threshold calibrated on it
    lands well below the default), phase 2 drifts the negative band up
    to 0.78–0.82, squarely above the learned threshold.  The fixed
    learned threshold serves them all as false hits; the conformal
    floor (a recency quantile of audited negatives) climbs past the
    band within a few batches.
  * ``bursty``       — Poisson-thinned trickle punctuated by large
    burst batches after idle gaps.
  * ``adversarial``  — paraphrase-shaped near-duplicates: cone
    rotations ``v = cos(θ)·u + sin(θ)·w`` of stored entries at cosine
    just *below* the serving threshold, labeled must-miss (distinct
    answers).  Any execution path that rounds them up to a hit
    (quantization, fused scoring) blows the false-hit budget.
  * ``ttl_churn``    — every insert carries a finite TTL; repeats
    arrive both before expiry (must hit) and after (must miss, then
    re-insert).  Small hot tier so live-but-doomed entries demote
    through warm into cold while their deadline runs — expiry has to
    hold in every tier.
  * ``cold_tenants`` — cache-hostile: many tenants, ~all-novel
    queries.  Hit rate ~0 by design; the scenario scores the miss
    path's p99 and the false-hit budget on pure-novelty traffic.

Generators take ``(seed, dim, smoke)`` and must be deterministic in
them.  Nothing here imports the service — traces are plain numpy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _rotate(u, cos_t, rng):
    """Cone rotation: a unit vector at exactly ``cos_t`` cosine to
    ``u`` along a random orthogonal direction."""
    w = rng.standard_normal(u.shape).astype(np.float32)
    w = w - (w @ u) * u
    w = w / max(float(np.linalg.norm(w)), 1e-9)
    return (cos_t * u + np.sqrt(max(1.0 - cos_t * cos_t, 0.0)) * w
            ).astype(np.float32)


@dataclass
class Step:
    """One arrival batch of the trace."""
    t: float                      # logical arrival time (seconds)
    embs: np.ndarray              # (B, D) float32 unit rows
    tenants: np.ndarray           # (B,) int32
    group: np.ndarray             # (B,) int64 answer-group id
    must_miss: np.ndarray         # (B,) bool — a hit here is false
    ttl: Optional[np.ndarray] = None   # (B,) float32 seconds, or None


@dataclass
class ScenarioTrace:
    name: str
    seed: int
    dim: int
    steps: List[Step]
    false_hit_budget: float       # per-scenario (and per-tenant) budget
    threshold: float = 0.85       # serving threshold the trace targets
    # per-tenant calibration pairs (scores, labels) the harness feeds
    # calibrate_tenant() before replay — only the drift scenario sets it
    calibration: Dict[int, tuple] = field(default_factory=dict)
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def n_queries(self) -> int:
        return sum(len(s.tenants) for s in self.steps)


class _GroupSpace:
    """Allocates answer groups and remembers each group's base
    embedding + tenant so repeats can be synthesized later."""

    def __init__(self, rng, dim):
        self.rng = rng
        self.dim = dim
        self.base: List[np.ndarray] = []
        self.tenant: List[int] = []

    def novel(self, tenant) -> int:
        self.base.append(_unit(
            self.rng.standard_normal(self.dim).astype(np.float32)))
        self.tenant.append(int(tenant))
        return len(self.base) - 1

    def paraphrase(self, gid, lo=0.93, hi=0.98):
        cos_t = float(self.rng.uniform(lo, hi))
        return _rotate(self.base[gid], cos_t, self.rng)

    def of_tenant(self, tenant) -> List[int]:
        return [g for g, t in enumerate(self.tenant) if t == int(tenant)]


def _mix_step(gs, rng, t, batch, tenants_of_row, repeat_frac,
              ttl=None) -> Step:
    """Generic batch: ``repeat_frac`` of rows paraphrase an existing
    same-tenant group, the rest open novel groups."""
    embs, groups, mm = [], [], []
    for tenant in tenants_of_row:
        pool = gs.of_tenant(tenant)
        if pool and rng.random() < repeat_frac:
            gid = int(pool[rng.integers(len(pool))])
            embs.append(gs.paraphrase(gid))
        else:
            gid = gs.novel(tenant)
            embs.append(gs.base[gid])
        groups.append(gid)
        mm.append(False)
    ttl_col = None
    if ttl is not None:
        ttl_col = np.full(batch, float(ttl), np.float32)
    return Step(t=t, embs=np.stack(embs),
                tenants=np.asarray(tenants_of_row, np.int32),
                group=np.asarray(groups, np.int64),
                must_miss=np.asarray(mm, bool), ttl=ttl_col)


# ---------------------------------------------------------------------------
# the scenarios
# ---------------------------------------------------------------------------

def make_diurnal(seed=0, dim=64, smoke=False) -> ScenarioTrace:
    rng = np.random.default_rng(seed + 101)
    gs = _GroupSpace(rng, dim)
    n_steps = 24 if smoke else 96
    base_b, amp = 8, 2.0
    steps, t = [], 0.0
    for i in range(n_steps):
        phase = 2.0 * np.pi * i / max(n_steps / 2, 1)
        b = max(2, int(round(base_b * (1.0 + amp * max(
            np.sin(phase), 0.0)))))
        tenants = rng.integers(0, 4, b)
        steps.append(_mix_step(gs, rng, t, b, tenants, repeat_frac=0.45))
        t += 1.0
    return ScenarioTrace("diurnal", seed, dim, steps,
                         false_hit_budget=0.02,
                         meta={"base_batch": base_b, "amp": amp})


def make_zipf_tenants(seed=0, dim=64, smoke=False) -> ScenarioTrace:
    rng = np.random.default_rng(seed + 202)
    gs = _GroupSpace(rng, dim)
    n_steps = 20 if smoke else 80
    n_tenants = 32
    steps, t = [], 0.0
    for _ in range(n_steps):
        b = 8
        tenants = np.minimum(rng.zipf(1.6, b) - 1, n_tenants - 1)
        steps.append(_mix_step(gs, rng, t, b, tenants, repeat_frac=0.5))
        t += 1.0
    return ScenarioTrace("zipf_tenants", seed, dim, steps,
                         false_hit_budget=0.02,
                         meta={"n_tenants": n_tenants, "zipf_a": 1.6})


def make_drift(seed=0, dim=64, smoke=False) -> ScenarioTrace:
    """Two tenants, two phases.  Phase 1 also yields the calibration
    pairs: duplicate scores ~N(0.95, .01), negatives ~N(0.55, .05) —
    a budgeted per-tenant calibration lands the learned threshold
    around ~0.7.  Phase 2 shifts the negative band to 0.78–0.82:
    below the default 0.85, above the learned threshold."""
    rng = np.random.default_rng(seed + 303)
    gs = _GroupSpace(rng, dim)
    tenants = (0, 1)
    p1 = 12 if smoke else 30
    p2 = 20 if smoke else 60
    steps, t = [], 0.0
    # phase 1: seed each tenant's bases, mild paraphrase traffic
    for _ in range(p1):
        row_t = np.asarray([tenants[i % 2] for i in range(8)], np.int32)
        steps.append(_mix_step(gs, rng, t, 8, row_t, repeat_frac=0.4))
        t += 1.0
    # calibration pairs per tenant (scores only — the geometry above is
    # what they summarize; calibrate_tenant takes raw pairs)
    calibration = {}
    for tn in tenants:
        dup = rng.normal(0.95, 0.01, 300)
        neg = rng.normal(0.55, 0.05, 300)
        scores = np.concatenate([dup, neg]).astype(np.float32)
        labels = np.concatenate([np.ones(300), np.zeros(300)]
                                ).astype(np.int32)
        calibration[tn] = (scores, labels)
    # phase 2: drifted near-threshold distractors (must-miss, own
    # groups) interleaved with true paraphrases that must keep hitting
    drift_start = t
    for _ in range(p2):
        embs, groups, mm, row_t = [], [], [], []
        for i in range(10):
            tn = tenants[i % 2]
            pool = gs.of_tenant(tn)
            if i % 5 == 4 and pool:          # 20%: true paraphrase
                gid = int(pool[rng.integers(len(pool))])
                embs.append(gs.paraphrase(gid))
                groups.append(gid)
                mm.append(False)
            else:                            # 80%: drifted distractor
                anchor = int(pool[rng.integers(len(pool))])
                cos_t = float(rng.uniform(0.78, 0.82))
                gid = gs.novel(tn)
                # distinct answer, but parked deliberately close to a
                # stored entry — the drifted topic crowding the band
                gs.base[gid] = _rotate(gs.base[anchor], cos_t, rng)
                embs.append(gs.base[gid])
                groups.append(gid)
                mm.append(True)
            row_t.append(tn)
        steps.append(Step(t=t, embs=np.stack(embs),
                          tenants=np.asarray(row_t, np.int32),
                          group=np.asarray(groups, np.int64),
                          must_miss=np.asarray(mm, bool)))
        t += 1.0
    return ScenarioTrace("drift", seed, dim, steps,
                         false_hit_budget=0.15,
                         calibration=calibration,
                         meta={"phase2_start_t": drift_start,
                               "distractor_cos": [0.78, 0.82],
                               "max_false_hit_rate": 0.02})


def make_bursty(seed=0, dim=64, smoke=False) -> ScenarioTrace:
    rng = np.random.default_rng(seed + 404)
    gs = _GroupSpace(rng, dim)
    n_steps = 16 if smoke else 60
    steps, t = [], 0.0
    for i in range(n_steps):
        if rng.random() < 0.15:              # burst after an idle gap
            t += float(rng.uniform(4.0, 8.0))
            b = 48
        else:
            t += 1.0
            b = 4
        tenants = rng.integers(0, 4, b)
        steps.append(_mix_step(gs, rng, t, b, tenants, repeat_frac=0.4))
    return ScenarioTrace("bursty", seed, dim, steps,
                         false_hit_budget=0.02,
                         meta={"burst_batch": 48, "trickle_batch": 4})


def make_adversarial(seed=0, dim=64, smoke=False) -> ScenarioTrace:
    """Stored entries first; then paraphrase-shaped near-duplicates at
    cosine 0.80–0.835 — below the 0.85 threshold, inside the band an
    over-eager scorer would round up.  All must-miss."""
    rng = np.random.default_rng(seed + 505)
    gs = _GroupSpace(rng, dim)
    warm_steps = 6 if smoke else 15
    atk_steps = 12 if smoke else 40
    steps, t = [], 0.0
    for _ in range(warm_steps):
        tenants = rng.integers(0, 2, 8)
        steps.append(_mix_step(gs, rng, t, 8, tenants, repeat_frac=0.2))
        t += 1.0
    for _ in range(atk_steps):
        embs, groups, mm, row_t = [], [], [], []
        for i in range(8):
            tn = int(rng.integers(0, 2))
            pool = gs.of_tenant(tn)
            if i % 4 == 3 and pool:          # keep some true repeats in
                gid = int(pool[rng.integers(len(pool))])
                embs.append(gs.paraphrase(gid))
                groups.append(gid)
                mm.append(False)
            else:
                anchor = int(pool[rng.integers(len(pool))])
                cos_t = float(rng.uniform(0.80, 0.835))
                gid = gs.novel(tn)
                gs.base[gid] = _rotate(gs.base[anchor], cos_t, rng)
                embs.append(gs.base[gid])
                groups.append(gid)
                mm.append(True)
            row_t.append(tn)
        steps.append(Step(t=t, embs=np.stack(embs),
                          tenants=np.asarray(row_t, np.int32),
                          group=np.asarray(groups, np.int64),
                          must_miss=np.asarray(mm, bool)))
        t += 1.0
    return ScenarioTrace("adversarial", seed, dim, steps,
                         false_hit_budget=0.01,
                         meta={"attack_cos": [0.80, 0.835]})


def make_ttl_churn(seed=0, dim=64, smoke=False) -> ScenarioTrace:
    """Every insert carries ttl=TTL logical seconds.  Each group is
    revisited twice: once inside its deadline (must hit) and once
    after (must miss — the harness flags any post-deadline serve as a
    stale serve and hard-asserts zero)."""
    rng = np.random.default_rng(seed + 606)
    gs = _GroupSpace(rng, dim)
    TTL = 12.0
    n_waves = 6 if smoke else 20
    steps, t = [], 0.0
    for _ in range(n_waves):
        # wave: 8 novel inserts with a finite TTL
        tenants = rng.integers(0, 3, 8)
        steps.append(_mix_step(gs, rng, t, 8, tenants, repeat_frac=0.0,
                               ttl=TTL))
        fresh = list(range(len(gs.base) - 8, len(gs.base)))
        # +4s: repeat them inside the deadline (expect hits)
        t += 4.0
        embs = np.stack([gs.paraphrase(g) for g in fresh])
        steps.append(Step(t=t, embs=embs,
                          tenants=np.asarray([gs.tenant[g] for g in fresh],
                                             np.int32),
                          group=np.asarray(fresh, np.int64),
                          must_miss=np.zeros(8, bool), ttl=None))
        # +10s (14s after insert > TTL): repeat again — expired, any
        # serve is stale; the re-miss re-inserts with a fresh deadline
        t += 10.0
        embs = np.stack([gs.paraphrase(g) for g in fresh])
        steps.append(Step(t=t, embs=embs,
                          tenants=np.asarray([gs.tenant[g] for g in fresh],
                                             np.int32),
                          group=np.asarray(fresh, np.int64),
                          must_miss=np.zeros(8, bool),
                          ttl=np.full(8, TTL, np.float32)))
        t += 2.0
    return ScenarioTrace("ttl_churn", seed, dim, steps,
                         false_hit_budget=0.02,
                         meta={"ttl_s": TTL})


def make_cold_tenants(seed=0, dim=64, smoke=False) -> ScenarioTrace:
    rng = np.random.default_rng(seed + 707)
    gs = _GroupSpace(rng, dim)
    n_steps = 16 if smoke else 64
    n_tenants = 48
    steps, t = [], 0.0
    for _ in range(n_steps):
        b = 8
        tenants = rng.integers(0, n_tenants, b)
        steps.append(_mix_step(gs, rng, t, b, tenants, repeat_frac=0.02))
        t += 1.0
    return ScenarioTrace("cold_tenants", seed, dim, steps,
                         false_hit_budget=0.01,
                         meta={"n_tenants": n_tenants})


SCENARIOS = {
    "diurnal": make_diurnal,
    "zipf_tenants": make_zipf_tenants,
    "drift": make_drift,
    "bursty": make_bursty,
    "adversarial": make_adversarial,
    "ttl_churn": make_ttl_churn,
    "cold_tenants": make_cold_tenants,
}


def build(name: str, seed: int = 0, dim: int = 64,
          smoke: bool = False) -> ScenarioTrace:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}")
    return SCENARIOS[name](seed=seed, dim=dim, smoke=smoke)
