"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family runs one forward and one train step on CPU with
shape + finiteness assertions, plus a decode step for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import (
    decode_step, encode, forward_lm, init_lm, init_lm_state, lm_loss,
    prefill, split,
)
from repro.serving.frontend import stub_frontend_embeds
from repro.training import adamw, apply_updates, make_train_step

ALL = list(ASSIGNED_ARCHS) + ["modernbert-149m"]


def _setup(name):
    cfg = get_config(name).reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    fe = stub_frontend_embeds(cfg, 2) if cfg.frontend else None
    return cfg, pv, toks, fe


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name):
    cfg, pv, toks, fe = _setup(name)
    if cfg.is_encoder:
        emb = encode(pv, cfg, toks)
        assert emb.shape == (2, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(emb)))
        norms = jnp.linalg.norm(emb, axis=-1)
        np.testing.assert_allclose(np.asarray(norms), 1.0, rtol=1e-4)
        return
    logits, aux = forward_lm(pv, cfg, toks, fe)
    S = 16 + (cfg.frontend_len if cfg.frontend else 0)
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL)
def test_one_train_step(name):
    cfg, pv, toks, fe = _setup(name)
    if cfg.is_encoder:
        pytest.skip("encoder trains via EmbedderTrainer (test_trainer)")
    init_opt, update = adamw(1e-3, max_grad_norm=1.0)
    opt = init_opt(pv)
    step = make_train_step(cfg, update)
    batch = {"tokens": toks}
    if fe is not None:
        batch["frontend_embeds"] = fe
    pv2, opt2, metrics = step(pv, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{name}: loss NaN"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                               pv, pv2)
    assert max(jax.tree_util.tree_leaves(d)) > 0


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_decode_matches_forward(name):
    cfg, pv, toks, fe = _setup(name)
    B, S = toks.shape
    full, _ = forward_lm(pv, cfg, toks)
    t0 = S - 2
    logits, state = prefill(pv, cfg, toks[:, :t0], cache_len=S)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, t0 - 1]),
                               atol=2e-4, rtol=1e-3)
    for t in range(t0, S):
        logits, state = decode_step(pv, cfg, state, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("name", ["qwen2.5-32b", "phi3-mini-3.8b"])
def test_sliding_window_decode(name):
    """Ring-buffer KV cache agrees with full attention inside the window
    horizon (dense archs' long_500k path)."""
    cfg = get_config(name).reduced(sliding_window=8)
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 14), 0,
                              cfg.vocab_size)
    full, _ = forward_lm(pv, cfg, toks)
    logits, state = prefill(pv, cfg, toks[:, :10], cache_len=14)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, 9]),
                               atol=2e-4, rtol=1e-3)
    for t in range(10, 14):
        logits, state = decode_step(pv, cfg, state, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("name", ["phi3-mini-3.8b", "jamba-1.5-large-398b"])
def test_unrolled_matches_scanned(name):
    """scan_layers=False (dry-run mode) is numerically the same model."""
    cfg = get_config(name).reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab_size)
    l1, _ = forward_lm(pv, cfg, toks)
    cfg2 = cfg.replace(scan_layers=False, unroll_inner=True, remat=False)
    l2, _ = forward_lm(pv, cfg2, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-4, rtol=1e-3)
