"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU adaptation (DESIGN.md §3): instead of a dense (tokens × experts)
dispatch einsum (which would charge num_experts× FLOPs) or torch-style
ragged gathers (dynamic shapes), tokens are routed with a static-shape
sort:  top-k expert ids are flattened, stably argsorted, each token gets
a position-within-expert via searchsorted-cumsum, and the first
``capacity`` tokens per expert are scattered into an (E, C, d) buffer.
Expert matmuls are a single stacked einsum — FLOPs scale with top_k, not
num_experts.  Experts shard over the `model` mesh axis; re-sharding the
token buffer from batch-sharding to expert-sharding is the all-to-all
the roofline's collective term sees.

Aux losses: switch-style load balance + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import Initializer


def padded_experts(cfg: ModelConfig) -> int:
    e = cfg.moe.num_experts
    if cfg.pad_experts_to:
        m = cfg.pad_experts_to
        return -(-e // m) * m
    return e


def init_moe(ini: Initializer, cfg: ModelConfig):
    m = cfg.moe
    d, f = cfg.d_model, m.expert_d_ff
    e = padded_experts(cfg)
    return {
        "router": ini.lecun((d, e), ("embed", "experts"), fan_in=d),
        "w_gate": ini.lecun((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "w_up": ini.lecun((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "w_down": ini.lecun((e, f, d), ("experts", "mlp", "embed"), fan_in=f),
    }


def capacity_for(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def apply_moe(p, cfg: ModelConfig, x):
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = padded_experts(cfg), m.top_k
    C = capacity_for(cfg, T)
    dt = x.dtype
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T,E)
    if E != m.num_experts:  # mask the padded dummy experts out
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(col < m.num_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, K)                  # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses -------------------------------------------------
    # load-balance: E * sum_e f_e * p_e  (switch transformer eq. 4)
    onehot = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    f_e = onehot.mean(0)
    p_e = probs.mean(0)
    lb_loss = E * jnp.sum(f_e * p_e) * m.load_balance_coef
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_coef
    aux = lb_loss + z_loss

    # ---- sort-based dispatch ---------------------------------------
    flat_e = expert_ids.reshape(-1)                              # (T*K,)
    sort_idx = jnp.argsort(flat_e, stable=True)                  # (T*K,)
    sorted_e = flat_e[sort_idx]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)       # OOB -> drop
    token_of = sort_idx // K                                     # (T*K,)

    buf = jnp.zeros((E * C, d), dt).at[dest].set(
        xf[token_of].astype(dt), mode="drop")
    buf = buf.reshape(E, C, d)

    # ---- expert compute (stacked SwiGLU) -----------------------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    out = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(dt))
    out_flat = out.reshape(E * C, d)

    # ---- combine ------------------------------------------------------
    gathered = out_flat[jnp.where(keep, dest, 0)] * keep[:, None].astype(dt)
    contrib = jnp.zeros((T * K, d), dt).at[sort_idx].set(gathered)
    contrib = contrib.reshape(T, K, d)
    y = jnp.sum(contrib * gate[..., None].astype(dt), axis=1)
    return y.reshape(B, S, d), aux
