"""Embedder fine-tuning — the paper's training recipe as a Trainer.

Defaults are the paper's hyperparameters (§3 Experimental Setup):
one epoch, lr = 6.5383156211679e-5, batch 16, Adam, max grad norm 0.5,
online contrastive loss.  The 1-epoch + clipped-norm discipline is the
catastrophic-forgetting control of §3.2 — ``epochs`` is a knob precisely
so the forgetting benchmark can turn it up to 6 and show the damage.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.losses import contrastive_loss, online_contrastive_loss
from repro.core.metrics import pair_classification_metrics
from repro.data.corpora import PairDataset
from repro.data.pairs import iter_batches, tokenize_pairs
from repro.data.tokenizer import HashTokenizer
from repro.models import encode, init_lm, split
from repro.training.optim import adam, apply_updates


@dataclass
class FinetuneConfig:
    epochs: int = 1
    lr: float = 6.5383156211679e-5
    batch_size: int = 16
    max_grad_norm: Optional[float] = 0.5
    margin: float = 0.5
    loss: str = "online"          # 'online' | 'contrastive'
    max_len: int = 32
    seed: int = 0
    log_every: int = 50


class EmbedderTrainer:
    def __init__(self, model_cfg: ModelConfig, ft: FinetuneConfig = None,
                 params=None):
        assert model_cfg.is_encoder, "embedder must be an encoder config"
        self.cfg = model_cfg
        self.ft = ft or FinetuneConfig()
        if params is None:
            params, _ = split(init_lm(model_cfg,
                                      jax.random.PRNGKey(self.ft.seed)))
        self.params = params
        init_opt, self._update = adam(self.ft.lr,
                                      max_grad_norm=self.ft.max_grad_norm)
        self.opt_state = init_opt(self.params)
        loss_fn = (online_contrastive_loss if self.ft.loss == "online"
                   else contrastive_loss)

        def step(params, opt_state, batch):
            def objective(p):
                # one stacked forward for both sides of every pair
                toks = jnp.concatenate([batch["tok1"], batch["tok2"]], axis=0)
                masks = jnp.concatenate([batch["mask1"], batch["mask2"]],
                                        axis=0)
                embs = encode(p, self.cfg, toks, masks)
                e1, e2 = jnp.split(embs, 2, axis=0)
                return loss_fn(e1, e2, batch["label"], margin=self.ft.margin)

            loss, grads = jax.value_and_grad(objective)(params)
            updates, opt_state, om = self._update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, {"loss": loss, **om}

        self._step = jax.jit(step)
        self._encode = jax.jit(lambda p, t, m: encode(p, self.cfg, t, m))
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    def fit(self, train: PairDataset, tokenizer: HashTokenizer,
            eval_ds: Optional[PairDataset] = None) -> dict:
        arrays = tokenize_pairs(train, tokenizer, self.ft.max_len)
        t0 = time.perf_counter()
        n_steps = 0
        for batch in iter_batches(arrays, self.ft.batch_size,
                                  seed=self.ft.seed, epochs=self.ft.epochs):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, m = self._step(
                self.params, self.opt_state, batch)
            n_steps += 1
            if n_steps % self.ft.log_every == 0:
                self.history.append(
                    {"step": n_steps, "loss": float(m["loss"])})
        out = {"steps": n_steps, "train_seconds": time.perf_counter() - t0}
        if eval_ds is not None:
            out["eval"] = self.evaluate(eval_ds, tokenizer)
        return out

    # ------------------------------------------------------------------
    def embed_texts(self, texts, tokenizer: HashTokenizer,
                    batch_size: int = 64) -> np.ndarray:
        out = []
        for i in range(0, len(texts), batch_size):
            chunk = list(texts[i:i + batch_size])
            pad_to = batch_size  # stable jit shape
            while len(chunk) < pad_to:
                chunk.append("")
            ids, mask = tokenizer.encode_batch(chunk, self.ft.max_len)
            e = self._encode(self.params, jnp.asarray(ids), jnp.asarray(mask))
            out.append(np.asarray(e)[: len(texts[i:i + batch_size])])
        return np.concatenate(out, axis=0)

    def pair_scores(self, ds: PairDataset, tokenizer: HashTokenizer
                    ) -> np.ndarray:
        e1 = self.embed_texts(ds.q1, tokenizer)
        e2 = self.embed_texts(ds.q2, tokenizer)
        return np.sum(e1 * e2, axis=-1)

    def evaluate(self, ds: PairDataset, tokenizer: HashTokenizer) -> dict:
        scores = self.pair_scores(ds, tokenizer)
        return pair_classification_metrics(scores, ds.labels)

    def make_embed_fn(self, tokenizer: HashTokenizer) -> Callable:
        """list[str] -> (B, D) unit-norm np — plugs into CachedLLMService."""
        return lambda texts: self.embed_texts(texts, tokenizer)
