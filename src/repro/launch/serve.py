"""Serving launcher: batched generation for any registry arch, with an
optional semantic cache in front (the paper's deployment).

    PYTHONPATH=src python -m repro.launch.serve \
        --arch phi3-mini-3.8b --smoke --requests 32 --batch 8 --cache
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import EmbedderTrainer, FinetuneConfig, SemanticCache
from repro.data import HashTokenizer, make_pair_dataset, make_query_stream
from repro.models import init_lm, split
from repro.serving import CachedLLMService, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--cache", action="store_true")
    ap.add_argument("--threshold", type=float, default=0.93)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    engine = ServeEngine(cfg, pv, max_len=64)
    print(f"serving {cfg.name} ({cfg.param_count():,} params)")

    if not args.cache:
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for i in range(0, args.requests, args.batch):
            prompts = rng.integers(0, cfg.vocab_size,
                                   (args.batch, 16)).astype(np.int32)
            res = engine.generate(prompts, args.max_new_tokens)
            print(f"batch {i//args.batch}: generated "
                  f"{res.tokens.shape[1]} tokens x {res.tokens.shape[0]}")
        print(f"total {time.perf_counter() - t0:.1f}s")
        return

    enc_cfg = get_config("modernbert-149m").reduced(vocab_size=4096)
    tok = HashTokenizer(vocab_size=enc_cfg.vocab_size)
    trainer = EmbedderTrainer(enc_cfg, FinetuneConfig(
        epochs=1, batch_size=32, lr=5e-4, max_len=24))
    trainer.fit(make_pair_dataset("medical", 512, seed=0), tok)
    cache = SemanticCache(capacity=4096, dim=enc_cfg.d_model,
                          threshold=args.threshold)
    svc = CachedLLMService(trainer.make_embed_fn(tok), cache, engine, tok,
                           max_new_tokens=args.max_new_tokens)
    stream = [q.text for q in make_query_stream("medical", args.requests,
                                                seed=1, repeat_frac=0.4)]
    t0 = time.perf_counter()
    for i in range(0, len(stream), args.batch):
        svc.handle(stream[i:i + args.batch])
    print(f"{args.requests} requests in {time.perf_counter() - t0:.1f}s; "
          f"hit rate {svc.hit_rate:.1%} "
          f"({svc.stats()['hits']} LLM calls saved)")


if __name__ == "__main__":
    main()
