"""End-to-end behaviour tests for the paper's system.

The full loop: domain corpus -> fine-tune compact embedder (1 epoch,
online contrastive, clip 0.5) -> semantic cache in front of an LLM
serving engine -> repeated paraphrased queries hit the cache; and the
paper's headline comparisons at smoke scale.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    EmbedderTrainer, FinetuneConfig, SemanticCache, TemplateGenerator,
    generate_synthetic_pairs, records_to_dataset,
)
from repro.data import HashTokenizer, make_pair_dataset, make_query_stream, sample_query
from repro.models import init_lm, split
from repro.serving import CachedLLMService, ServeEngine


@pytest.fixture(scope="module")
def finetuned_embedder():
    cfg = get_config("modernbert-149m").reduced(vocab_size=4096)
    tok = HashTokenizer(vocab_size=cfg.vocab_size)
    train = make_pair_dataset("medical", 256, seed=0)
    ft = FinetuneConfig(epochs=2, batch_size=16, max_len=24, lr=3e-4)
    trainer = EmbedderTrainer(cfg, ft)
    trainer.fit(train, tok)
    return cfg, tok, trainer


def test_cache_hit_rate_improves_with_finetuning(finetuned_embedder):
    """The system-level payoff claimed by the paper: a fine-tuned
    compact embedder gives a better true-hit/false-hit trade-off than
    the untuned base in an actual serving loop."""
    cfg, tok, trainer = finetuned_embedder
    base = EmbedderTrainer(cfg, FinetuneConfig(max_len=24))  # untuned

    stream = make_query_stream("medical", 150, seed=3, repeat_frac=0.4)

    def run(embed_trainer):
        cache = SemanticCache(capacity=1024, dim=cfg.d_model, threshold=0.92)
        svc = CachedLLMService(embed_trainer.make_embed_fn(tok), cache,
                               engine=None, tokenizer=tok)
        # correctness oracle: a hit is TRUE if the hit query shares
        # (entity, aspect) with the query that inserted the response
        inserted = {}
        true_hits = false_hits = 0
        for q in stream:
            r = svc.handle([q.text])[0]
            key = (q.entity, q.aspect)
            if r.cache_hit:
                src = inserted.get(r.response)
                if src == key:
                    true_hits += 1
                else:
                    false_hits += 1
            else:
                inserted[r.response] = key
        return true_hits, false_hits

    th_ft, fh_ft = run(trainer)
    th_b, fh_b = run(base)
    # fine-tuned must find strictly more true hits without exploding
    # false hits
    assert th_ft > th_b, (th_ft, fh_ft, th_b, fh_b)
    assert fh_ft <= max(fh_b, 2), (th_ft, fh_ft, th_b, fh_b)


def test_synthetic_data_finetune_beats_base(finetuned_embedder):
    """Table-1 mechanism at smoke scale: fine-tuning on purely synthetic
    pairs (dual-labeled pipeline output) improves real-pair metrics."""
    cfg, tok, _ = finetuned_embedder
    rng = np.random.default_rng(0)
    unlabeled = [sample_query(rng, "medical") for _ in range(100)]
    records = generate_synthetic_pairs(unlabeled, TemplateGenerator(1),
                                       n_pos=1, n_neg=1)
    synth_ds = records_to_dataset(records)
    real_eval = make_pair_dataset("medical", 128, seed=77)

    base = EmbedderTrainer(cfg, FinetuneConfig(max_len=24))
    before = base.evaluate(real_eval, tok)
    ft = EmbedderTrainer(cfg, FinetuneConfig(epochs=2, batch_size=16,
                                             max_len=24, lr=3e-4))
    ft.fit(synth_ds, tok)
    after = ft.evaluate(real_eval, tok)
    assert after["ap"] > before["ap"], (before["ap"], after["ap"])


def test_full_serving_stack_with_real_llm():
    """Cache in front of an actual JAX decoder: miss -> generate via the
    engine; repeat -> hit without generation."""
    dec_cfg = get_config("granite-moe-3b-a800m").reduced()
    pv, _ = split(init_lm(dec_cfg, jax.random.PRNGKey(0)))
    engine = ServeEngine(dec_cfg, pv, max_len=48)

    enc_cfg = get_config("modernbert-149m").reduced(vocab_size=4096)
    tok = HashTokenizer(vocab_size=enc_cfg.vocab_size)
    trainer = EmbedderTrainer(enc_cfg, FinetuneConfig(max_len=24))
    cache = SemanticCache(capacity=128, dim=enc_cfg.d_model, threshold=0.99)
    svc = CachedLLMService(trainer.make_embed_fn(tok), cache, engine, tok,
                           max_new_tokens=4)
    q = ["What are the symptoms of early-stage diabetes?"]
    r1 = svc.handle(q)[0]
    assert not r1.cache_hit and len(r1.response) > 0
    r2 = svc.handle(q)[0]
    assert r2.cache_hit and r2.response == r1.response
    st = svc.stats()
    assert st["hits"] == 1 and st["misses"] == 1
    assert st["generations"] == 1 and st["requests"] == 2
