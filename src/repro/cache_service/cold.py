"""Host-RAM cold tier: the memory level below the warm ring.

DESIGN.md §12.  The warm ring used to be the end of the line — a ring
overwrite dropped the evicted row's response forever.  The cold tier
catches those demotions in *host* memory, so corpus size is bounded by
host RAM (multi-million entries), not device HBM:

  * storage is the int8 symmetric per-row quantization the warm tier
    already maintains (`tiers.quantize_rows` — the PR 4 path): the key
    panel arrives pre-quantized from the warm ring's ``keys_q``/
    ``scales``, plus value ids and tenant ids, in flat pre-allocated
    numpy arrays (the host-pinned stand-in; a TPU runtime would place
    the same buffers in ``pinned_host`` memory so the fetch DMAs
    straight from them).  4 bytes/row of scale + D bytes/row of key:
    a 1M-row, 64-dim corpus is ~68 MB of host RAM;
  * routing is a coarse IVF of its own: spherical k-means centroids
    (fit on a bounded sample, host-side) plus a per-row cluster
    assignment maintained incrementally on insert — no inverted-list
    surgery, membership is recovered by a vectorized scan at lookup;
  * lookup is *budgeted and conditional*: the service consults the
    cold tier only for queries whose warm/hot verdict fell below
    threshold AND whose best cold-centroid similarity clears
    ``threshold - router_margin - route_slack`` (the router's
    is-the-fetch-worth-it rule).  ``route_slack`` is *calibrated at
    route-fit time*: a coarse centroid only bounds its members' query
    similarity up to the cluster's own spread, so ``rebuild_routes``
    measures the 10th-percentile member→centroid cosine and widens the
    gate by ``1 - q10`` — tight clusters give a selective router,
    loose clusters open it rather than falsely skipping reachable
    hits.  ``router_margin`` stays the fixed conservatism knob on top.
    Consulted queries gather the member rows of their
    ``n_probe`` nearest clusters, rank them by the approximate int8
    score on the host, and ship only the top ``fetch_budget`` rows per
    query to the device for an exact fp32 re-score of the dequantized
    keys (exact in fp32 over the stored representation; the stored
    representation itself carries the §8 quantization error bound
    ``amax·sqrt(D)/254`` — a cold hit's score is within that bound of
    the fp32-key cosine);
  * promotion is asynchronous: a cold row that produces a hit is
    queued, and the service's ``maintenance()`` idle tick drains the
    queue back into the *warm ring* (the same ``warm_append`` path as
    a demotion flush), invalidating the cold copy — re-hot rows climb
    back up the hierarchy without ever blocking a plan.

Eviction: the cold tier is itself a ring; overwriting a valid cold row
is the one place in the hierarchy where a response is finally dropped
(the service GCs the string and counts it under
``cold_evictions_dropped``).  ``evict_tenant`` invalidates a tenant's
cold rows *and* purges its pending promotions, so a tenant evicted
mid-demotion can never resurrect through the promotion path.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache_service.policy import ColdRoutingPolicy

NEG = -1e30


class ColdFetch(NamedTuple):
    """Per-batch result of a budgeted cold lookup.

    ``consulted`` marks queries whose fetch the router approved;
    non-consulted rows carry score NEG / vid -1.  ``scores`` are exact
    fp32 cosines of the *dequantized* keys (device re-score)."""
    scores: np.ndarray       # (Q,) float32, NEG where no candidate
    value_ids: np.ndarray    # (Q,) int64, -1 where no candidate
    slots: np.ndarray        # (Q,) int32 cold row of the best candidate
    consulted: np.ndarray    # (Q,) bool — router approved the fetch
    fetched_rows: int        # candidate rows shipped to device
    router_skips: int        # needy queries the router turned down


class Promotion(NamedTuple):
    """A drained promotion batch, ready for `tiers.warm_append`."""
    keys: np.ndarray         # (m, D) float32 dequantized keys
    value_ids: np.ndarray    # (m,) int32
    tenants: np.ndarray      # (m,) int32
    expires: np.ndarray      # (m,) float32 remaining wall-clock expiry
    #                          (+inf = no TTL) — a promoted row keeps the
    #                          deadline it was demoted with (DESIGN.md §14)


@functools.partial(jax.jit, static_argnames=())
def _rescore_device(qn, panel, mask):
    """Exact fp32 re-score of the fetched panel on device.

    qn: (Q, D) unit queries; panel: (Q, B, D) dequantized candidate
    keys; mask: (Q, B) live-candidate mask.  Returns (best score (Q,),
    best column (Q,)).
    """
    s = jnp.einsum("qd,qbd->qb", qn, panel)
    s = jnp.where(mask, s, NEG)
    best = jnp.argmax(s, axis=1)
    return s[jnp.arange(qn.shape[0]), best], best


def _kmeans_np(x: np.ndarray, k: int, iters: int, seed: int) -> np.ndarray:
    """Host-side spherical k-means (unit rows in, unit centroids out).

    Bounded-cost routing fit: the caller samples rows before fitting;
    assignment of the full corpus happens once, chunked, afterwards.
    """
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    if n <= k:
        cent = np.zeros((k, x.shape[1]), np.float32)
        cent[:n] = x
        return cent
    cent = x[rng.choice(n, k, replace=False)].copy()
    for _ in range(iters):
        a = np.argmax(x @ cent.T, axis=1)
        sums = np.zeros_like(cent)
        np.add.at(sums, a, x)
        norms = np.linalg.norm(sums, axis=1, keepdims=True)
        live = norms[:, 0] > 1e-9
        cent[live] = (sums / np.maximum(norms, 1e-9))[live]
    return cent.astype(np.float32)


class ColdTier:
    """Host-RAM int8 ring with coarse IVF routing (DESIGN.md §12).

    Host-side and single-writer by design: every mutating call happens
    on the service thread (commit flushes, maintenance drains), and the
    only device work is the jitted exact re-score of fetched panels.
    """

    def __init__(self, capacity: int, dim: int, *,
                 policy: Optional[ColdRoutingPolicy] = None):
        if capacity <= 0:
            raise ValueError(f"cold capacity must be positive: {capacity}")
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.policy = policy or ColdRoutingPolicy()
        # pre-allocated host panels (the pinned-host stand-in)
        self.keys_q = np.zeros((capacity, dim), np.int8)
        self.scales = np.zeros((capacity,), np.float32)
        self.value_ids = np.full((capacity,), -1, np.int64)
        self.tenants = np.full((capacity,), -1, np.int32)
        self.valid = np.zeros((capacity,), bool)
        # wall-clock expiry per row, +inf = no TTL (DESIGN.md §14); the
        # column rides demotions down and promotions back up unchanged
        self.expires_at = np.full((capacity,), np.inf, np.float32)
        self._cursor = 0
        # coarse routing state: centroids + incremental row assignment;
        # route_slack is the calibrated cluster spread the router gate
        # must absorb (module docstring) — 0 until the first fit
        self.centroids: Optional[np.ndarray] = None    # (Kc, D) unit
        self.route_slack = 0.0
        self._assign = np.full((capacity,), -1, np.int32)
        self._inserts_since_route = 0
        # pending promotions keyed by value id (dedup across lookups)
        self._promote: Dict[int, int] = {}             # vid -> cold slot
        # counters (mirrored into the telemetry registry by the service)
        self.n_inserted = 0
        self.n_dropped = 0          # cold-ring overwrites (final drops)
        self.n_fetches = 0          # consulted queries
        self.n_fetched_rows = 0
        self.n_hits = 0
        self.n_promoted = 0
        self.n_router_skips = 0
        self.n_route_rebuilds = 0
        self.n_expired_reaped = 0   # rows invalidated by reap_expired

    # ------------------------------------------------------------------
    # occupancy / introspection
    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return int(self.valid.sum())

    @property
    def occupancy(self) -> float:
        return float(self.valid.mean())

    @property
    def pending_promotions(self) -> int:
        return len(self._promote)

    @property
    def maintenance_due(self) -> bool:
        """An idle tick now would do cold work: drain queued
        promotions and/or re-fit the coarse routing."""
        return bool(self._promote) or self._route_due()

    def _dequant(self, slots: np.ndarray) -> np.ndarray:
        return self.keys_q[slots].astype(np.float32) \
            * self.scales[slots, None]

    # ------------------------------------------------------------------
    # writes: demotion insert / bulk load / eviction
    # ------------------------------------------------------------------
    def insert(self, keys_q: np.ndarray, scales: np.ndarray,
               value_ids: np.ndarray, tenants: np.ndarray,
               expires: Optional[np.ndarray] = None) -> np.ndarray:
        """Ring-append pre-quantized rows (the warm ring's own int8
        panel — demotion never re-quantizes).  ``expires`` is the
        per-row wall-clock deadline riding the demotion (None = no
        TTL).  Returns the value ids of overwritten valid cold rows
        (the hierarchy's final drops) for host GC; empty when the ring
        had room.
        """
        n = len(value_ids)
        if n == 0:
            return np.empty((0,), np.int64)
        if expires is None:
            expires = np.full((n,), np.inf, np.float32)
        expires = np.asarray(expires, np.float32)
        if n > self.capacity:
            # only the last `capacity` rows can survive a ring this size
            drop_head = np.asarray(value_ids[:n - self.capacity], np.int64)
            tail = self.insert(keys_q[n - self.capacity:],
                               scales[n - self.capacity:],
                               value_ids[n - self.capacity:],
                               tenants[n - self.capacity:],
                               expires[n - self.capacity:])
            self.n_dropped += len(drop_head)
            return np.concatenate([drop_head, tail])
        pos = (self._cursor + np.arange(n)) % self.capacity
        overwritten = self.valid[pos]
        dropped = np.asarray(self.value_ids[pos][overwritten], np.int64)
        # an overwritten row's pending promotion must die with it
        for v in dropped:
            self._promote.pop(int(v), None)
        self.keys_q[pos] = keys_q
        self.scales[pos] = scales
        self.value_ids[pos] = value_ids
        self.tenants[pos] = tenants
        self.valid[pos] = True
        self.expires_at[pos] = expires
        if self.centroids is not None:
            sims = (keys_q.astype(np.float32) * scales[:, None]) \
                @ self.centroids.T
            self._assign[pos] = np.argmax(sims, axis=1).astype(np.int32)
        else:
            self._assign[pos] = -1
        self._cursor = int((self._cursor + n) % self.capacity)
        self.n_inserted += n
        self.n_dropped += len(dropped)
        self._inserts_since_route += n
        if self._route_due():
            self.rebuild_routes()
        return dropped

    def bulk_load(self, keys: np.ndarray, value_ids: np.ndarray,
                  tenants: np.ndarray,
                  expires: Optional[np.ndarray] = None) -> np.ndarray:
        """Quantize (the §8 path) and insert fp32 keys, then rebuild
        the routing — for benches/migration, not the serving path."""
        from repro.cache_service import tiers
        kn = np.asarray(keys, np.float32)
        kn /= np.maximum(np.linalg.norm(kn, axis=1, keepdims=True), 1e-9)
        k8, sc = tiers.quantize_rows(jnp.asarray(kn))
        dropped = self.insert(np.asarray(k8), np.asarray(sc),
                              np.asarray(value_ids, np.int64),
                              np.asarray(tenants, np.int32), expires)
        self.rebuild_routes()
        return dropped

    def evict_tenant(self, tenant: int) -> np.ndarray:
        """Invalidate one tenant's cold rows and purge its pending
        promotions.  Returns the freed value ids for host GC."""
        kill = self.valid & (self.tenants == tenant)
        vids = np.asarray(self.value_ids[kill], np.int64)
        self.valid[kill] = False
        for v in vids:
            self._promote.pop(int(v), None)
        return vids

    def reap_expired(self, now: float) -> np.ndarray:
        """Invalidate TTL-expired cold rows and purge their pending
        promotions (DESIGN.md §14) — the maintenance-tick counterpart
        of the plan-time masking in ``lookup``.  Returns the freed
        value ids for host GC."""
        kill = self.valid & (self.expires_at <= np.float32(now))
        vids = np.asarray(self.value_ids[kill], np.int64)
        self.valid[kill] = False
        for v in vids:
            self._promote.pop(int(v), None)
        self.n_expired_reaped += len(vids)
        return vids

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def _route_due(self) -> bool:
        return (self.centroids is None
                and len(self) >= self.policy.min_rows_for_routing) \
            or self._inserts_since_route >= self.policy.route_rebuild_every

    def rebuild_routes(self) -> None:
        """Re-fit the coarse centroids (bounded sample) and re-assign
        every valid row.  Host-only; the service calls it from the
        maintenance tick or it self-triggers on insert cadence."""
        live = np.flatnonzero(self.valid)
        self._inserts_since_route = 0
        if len(live) < self.policy.min_rows_for_routing:
            return
        pol = self.policy
        rng = np.random.default_rng(pol.seed + self.n_route_rebuilds)
        fit = live if len(live) <= pol.kmeans_sample \
            else rng.choice(live, pol.kmeans_sample, replace=False)
        x = self._dequant(fit)
        x /= np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)
        self.centroids = _kmeans_np(x, pol.n_clusters, pol.kmeans_iters,
                                    pol.seed)
        own = np.empty((len(live),), np.float32)
        for lo in range(0, len(live), 1 << 16):
            chunk = live[lo:lo + (1 << 16)]
            rows = self._dequant(chunk)
            rows /= np.maximum(
                np.linalg.norm(rows, axis=1, keepdims=True), 1e-9)
            sims = rows @ self.centroids.T
            self._assign[chunk] = np.argmax(sims, axis=1).astype(np.int32)
            own[lo:lo + (1 << 16)] = sims.max(axis=1)
        # calibrate the router gate to the observed cluster spread: 90%
        # of members sit within `route_slack` of their centroid, so a
        # centroid more than margin+slack below threshold makes a hit
        # implausible — and a loose clustering opens the gate instead
        # of falsely skipping reachable rows (module docstring)
        self.route_slack = float(np.clip(1.0 - np.quantile(own, 0.1),
                                         0.0, 2.0))
        self.n_route_rebuilds += 1

    # ------------------------------------------------------------------
    # budgeted lookup
    # ------------------------------------------------------------------
    def lookup(self, qn: np.ndarray, q_tenants: np.ndarray,
               thresholds: np.ndarray, need: np.ndarray,
               now: Optional[float] = None) -> ColdFetch:
        """Consult the cold tier for the ``need`` queries (warm/hot
        verdict below threshold).  Router rule, budgeted host gather,
        one device re-score — see the module docstring.  ``now`` masks
        TTL-expired rows out of the candidate set (DESIGN.md §14): an
        expired cold row can never be served, hit, or queued for
        promotion; reclaiming its slot waits for ``reap_expired``."""
        qn = np.asarray(qn, np.float32)
        Q = qn.shape[0]
        out = ColdFetch(scores=np.full((Q,), NEG, np.float32),
                        value_ids=np.full((Q,), -1, np.int64),
                        slots=np.full((Q,), -1, np.int32),
                        consulted=np.zeros((Q,), bool),
                        fetched_rows=0, router_skips=0)
        need = np.asarray(need, bool)
        live = self.valid if now is None \
            else self.valid & (self.expires_at > np.float32(now))
        if not need.any() or not live.any():
            return out
        pol = self.policy
        B = pol.fetch_budget
        thresholds = np.asarray(thresholds, np.float32)
        if self.centroids is not None:
            csims = qn @ self.centroids.T                       # (Q, Kc)
            n_probe = min(pol.n_probe, self.centroids.shape[0])
            probes = np.argpartition(-csims, n_probe - 1,
                                     axis=1)[:, :n_probe]
            # router: the best centroid bounds the best member row's
            # cosine within the calibrated cluster spread; a centroid
            # further than margin+slack below threshold makes a hit
            # implausible (module docstring)
            worth = csims.max(axis=1) \
                >= thresholds - pol.router_margin - self.route_slack
        else:
            probes = None
            worth = np.ones((Q,), bool)     # unrouted: small corpus
        sel = need & worth
        skips = int((need & ~worth).sum())
        if not sel.any():
            self.n_router_skips += skips
            return out._replace(router_skips=skips)
        # membership scan: one vectorized pass per distinct probed
        # cluster in the batch (assignment array, no inverted lists)
        members: Dict[int, np.ndarray] = {}
        if probes is not None:
            for c in np.unique(probes[sel]):
                members[int(c)] = np.flatnonzero(
                    live & (self._assign == c))
        else:
            members[-1] = np.flatnonzero(live)
        slots = np.full((Q, B), -1, np.int64)
        fetched = 0
        for q in np.flatnonzero(sel):
            cl = probes[q] if probes is not None else [-1]
            cand = np.concatenate([members[int(c)] for c in cl]) \
                if len(cl) > 1 else members[int(cl[0])]
            cand = cand[self.tenants[cand] == q_tenants[q]]
            if len(cand) == 0:
                continue
            if len(cand) > B:
                # approximate int8 ranking picks the budgeted subset;
                # the device re-score below is what produces the score
                approx = self._dequant(cand) @ qn[q]
                cand = cand[np.argpartition(-approx, B - 1)[:B]]
            slots[q, :len(cand)] = cand
            fetched += len(cand)
        consulted = slots[:, 0] >= 0
        if not consulted.any():
            self.n_router_skips += skips
            return out._replace(router_skips=skips)
        # exact fp32 re-score of the dequantized fetch panel, on device
        safe = np.maximum(slots, 0)
        panel = self._dequant(safe.ravel()).reshape(Q, B, self.dim)
        best_s, best_c = _rescore_device(jnp.asarray(qn),
                                         jnp.asarray(panel),
                                         jnp.asarray(slots >= 0))
        best_s = np.asarray(best_s)
        best_slot = slots[np.arange(Q), np.asarray(best_c)]
        best_slot = np.where(consulted, best_slot, -1).astype(np.int32)
        vids = np.where(best_slot >= 0,
                        self.value_ids[np.maximum(best_slot, 0)], -1)
        self.n_fetches += int(consulted.sum())
        self.n_fetched_rows += fetched
        self.n_router_skips += skips
        # queue re-hot rows for async promotion at the next idle tick
        hits = consulted & (best_s >= thresholds)
        self.n_hits += int(hits.sum())
        for q in np.flatnonzero(hits):
            self._promote[int(vids[q])] = int(best_slot[q])
        return ColdFetch(
            scores=np.where(consulted, best_s, NEG).astype(np.float32),
            value_ids=vids.astype(np.int64), slots=best_slot,
            consulted=consulted, fetched_rows=fetched, router_skips=skips)

    # ------------------------------------------------------------------
    # async promotion (drained by the service's maintenance tick)
    # ------------------------------------------------------------------
    def take_promotions(self, max_rows: int) -> Optional[Promotion]:
        """Pop up to ``max_rows`` pending re-hot rows and invalidate
        their cold copies (they move to the warm ring — one live copy
        per value id).  Entries whose cold row was overwritten or
        tenant-evicted since they queued are silently dropped.  Returns
        None when nothing is pending."""
        taken: List[Tuple[int, int]] = []
        while self._promote and len(taken) < max_rows:
            vid, slot = self._promote.popitem()
            if self.valid[slot] and int(self.value_ids[slot]) == vid:
                taken.append((vid, slot))
        if not taken:
            return None
        slots = np.asarray([s for _, s in taken])
        keys = self._dequant(slots)
        keys /= np.maximum(np.linalg.norm(keys, axis=1, keepdims=True),
                           1e-9)
        prom = Promotion(keys=keys.astype(np.float32),
                         value_ids=np.asarray([v for v, _ in taken],
                                              np.int32),
                         tenants=self.tenants[slots].copy(),
                         expires=self.expires_at[slots].copy())
        self.valid[slots] = False
        self.n_promoted += len(taken)
        return prom

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "cold_occupancy": self.occupancy,
            "cold_rows": len(self),
            "cold_inserted": self.n_inserted,
            "cold_dropped": self.n_dropped,
            "cold_fetches": self.n_fetches,
            "cold_fetched_rows": self.n_fetched_rows,
            "cold_hits": self.n_hits,
            "cold_promoted": self.n_promoted,
            "cold_pending_promotions": self.pending_promotions,
            "cold_router_skips": self.n_router_skips,
            "cold_route_rebuilds": self.n_route_rebuilds,
            "cold_routed": self.centroids is not None,
            "cold_route_slack": round(self.route_slack, 4),
            "cold_expired_reaped": self.n_expired_reaped,
        }
