"""CacheService — the serving-path facade over the tiered store.

Replaces bare ``SemanticCache`` in front of the LLM engine.  The host
half owns response strings (a dict keyed by value id, garbage-collected
from the eviction reports every device op returns) and the per-tenant
policy table; the device half is `tiers`: a hot exact store, a warm IVF
ring, and a single jitted cascaded lookup.

Lifecycle of an entry:

  insert (admitted miss) -> hot tier -> [cold] demotion flush -> warm
  ring -> [ring wraps or tenant evicted] -> value id reported back ->
  host frees the response string.

The hot tier flushes its ``flush_size`` coldest rows to the warm ring
whenever occupancy crosses ``flush_watermark``; every
``rebuild_every``-th flush re-clusters the warm IVF (jittable k-means).
Between rebuilds the warm lookup scans a fixed tail window sized to
cover everything appended since the last rebuild, so recall does not
dip while the index is stale.

Serving surface (DESIGN.md §7): the typed ``CacheBackend`` lifecycle —
``plan(CacheRequest) -> CachePlan`` (read side: cascade verdicts, hit
responses, admission pre-decision, miss coalescing) then
``commit(plan, responses) -> CommitReceipt`` (write side: admissions,
demotion flush, GC, maintenance obligations).  With
``background_rebuild=True`` the warm IVF re-clusters double-buffered:
a shadow index builds on a host thread from a snapshot while lookups
keep reading the published index, and ``maintenance()`` performs the
atomic publish; the tail window covers every row appended since the
*snapshot*, so recall never dips during the overlap.  The legacy
``lookup(embs) / insert(embs, responses)`` shims and the flat
``stats()`` view were removed in v2.0 — callers use plan/commit and
``stats_snapshot()`` (README has the migration table).
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache_service import tiers
from repro.cache_service.cold import ColdTier
from repro.cache_service.feedback import (
    FeedbackAccumulator, FeedbackConfig, record_refit,
)
from repro.cache_service.policy import (
    ColdRoutingPolicy, EmbedderRefreshPolicy, PolicyTable, TenantPolicy,
)
from repro.cache_service.protocol import (
    CacheCapabilities, CachePlan, CacheRequest, CommitReceipt,
    MaintenanceReport, coalesce_misses, ungrouped_misses,
)
from repro.core.calibration import Calibration
from repro.obs import Telemetry
from repro.obs.registry import SCHEMA, tenant_label


@dataclass(frozen=True)
class ServiceStats:
    """Typed, schema-stable ``CacheService`` snapshot (DESIGN.md §10.1).

    Every count is read from the telemetry registry (the single source
    of truth since the registry replaced the ad-hoc counter dict); the
    grouping mirrors the metric families.  ``to_dict()`` is the wire
    form the serve launcher emits under ``--metrics-json``.
    """
    schema: str                      # repro.obs/v1
    traffic: Dict[str, int]          # plans/commits/lookup_rows/hits...
    admission: Dict[str, int]        # admitted / skipped rows
    tiers: Dict[str, object]         # occupancies, demotions, evictions
    rebuild: Dict[str, object]       # rebuild counts + wall times
    learning: Optional[Dict[str, object]]   # §9 feedback state
    health: Optional[Dict[str, object]]     # §10.3 SLO snapshot
    refresh: Optional[Dict[str, object]] = None  # §11 embedder refresh

    def to_dict(self) -> Dict[str, object]:
        return {
            "schema": self.schema, "traffic": dict(self.traffic),
            "admission": dict(self.admission), "tiers": dict(self.tiers),
            "rebuild": dict(self.rebuild),
            "learning": dict(self.learning) if self.learning else None,
            "health": dict(self.health) if self.health else None,
            "refresh": dict(self.refresh) if self.refresh else None,
        }


class CacheService:
    supports_tenants = True          # legacy sniffing hook; see DESIGN.md §7
    _kwargs_warned = False           # one-release flat-kwargs shim flag

    def __init__(self, config=None, **kwargs):
        """Build the tiered service from a ``CacheConfig``.

        ``config`` is the typed v2 surface (`cache_service/config.py`):
        top-level operating point plus grouped sub-configs — tiering,
        sharding, learning, ensemble, staleness.  The pre-v2 flat
        keyword form ``CacheService(dim=..., hot_capacity=..., ...)``
        still works for one release: it warns once per process and
        maps onto the config via ``CacheConfig.from_kwargs`` (README
        migration table).

        Feature semantics (the prose below names the legacy flat
        keywords; each lives on the sub-config given in parentheses).

        Tail invariant (``TieringConfig``; see ``tiers.warm_query``):
        rows demoted into the
        warm ring stay unindexed until the next IVF rebuild and are only
        reachable through the brute-force tail window over the last
        ``tail`` ring writes.  The window is sized
        ``tail = flush_size * rebuild_every`` so that every row
        appended between rebuilds is covered — that product therefore
        must not exceed ``warm_capacity``.  When it does, the window is
        clamped to ``warm_capacity`` and ``_do_flush`` forces rebuilds
        earlier than ``rebuild_every`` would suggest (correct, but the
        configured cadence is unattainable); a warning is emitted at
        construction instead of silently accepting the config.  In the
        sharded tier every quantity in the invariant divides by the
        shard count — each flush lands ``flush_size/shards`` rows per
        shard ring, so the window, the clamp and the warning are all
        per shard.

        ``fused=True`` routes the cascade through the fused Pallas
        lookup kernel (`kernels/cascade_lookup`) on TPU — subject to
        the kernel's VMEM budget: the warm slice must fit on-chip
        (DESIGN.md §3.1).  On CPU the flag falls back to the same
        four-op math, so it never changes results or CPU latency.

        ``background_rebuild=True`` double-buffers the IVF rebuild
        (DESIGN.md §7): flushes that would have re-clustered inline
        instead start a shadow build on a host thread; lookups keep
        reading the published index and ``maintenance()`` swaps the
        finished shadow in.  A flush that would push the unindexed
        backlog past the tail window first joins the in-flight build
        (or re-clusters inline if none is running), so no row is ever
        stranded out of reach.

        ``mesh`` shards the warm tier over its ``shard_axis``
        (DESIGN.md §8): the warm ring/IVF becomes
        ``mesh.shape[shard_axis]`` independent per-shard rings
        (capacity, clusters and the tail window split per shard; flush
        batches round-robin across shards), looked up via shard_map
        with a tiny (Q, k·shards) merge collective.  The hot tier
        stays replicated.  ``warm_dtype="int8"`` scans the warm panel
        from its symmetric per-row int8 quantization (~4x less
        HBM/VMEM bandwidth) and re-scores the selected rows exactly —
        reported scores stay true fp32 cosines; only candidate
        *selection* sees the bounded quantization error.

        ``learned_admission=True`` turns the static per-tenant
        operating points into a feedback loop (DESIGN.md §9): every
        commit labels its miss rows against their stored neighbours
        (duplicate / distinct), a per-tenant reservoir accumulates the
        labeled scores, and ``maintenance()`` re-derives each tenant's
        threshold and admission margin from its own observed stream —
        under hysteresis guards (min samples, max step per refit,
        monotone false-hit budget), so the points drift with the
        workload but never thrash.  ``feedback_config`` tunes the
        guards (implies ``learned_admission``).

        ``learned_embedder=True`` closes the paper's training loop at
        serving time (DESIGN.md §11): the feedback stream also pools
        labeled *text* pairs, and ``maintenance()`` periodically runs a
        one-epoch contrastive refresh of the compact embedder
        (``embedder_trainer`` + ``embedder_tokenizer``, both required)
        on a background thread — synthetic grammar pairs backfill a
        thin reservoir — then re-embeds both tiers into shadow key
        panels and hot-swaps them exactly like the double-buffered IVF
        publish.  Every plan is stamped with the embedder version it
        embedded under; commit rejects admissions from a stale version
        instead of planting old-space keys in the new panel.  A
        candidate that fails the held-out eval gate is rolled back
        (discarded) without ever becoming visible.  ``refresh_policy``
        tunes the trigger/gate (implies ``learned_embedder``).

        ``cold_capacity > 0`` adds the host-RAM cold tier (DESIGN.md
        §12): warm-ring overwrites demote their int8 rows into it
        instead of dropping them, plan-time lookups consult it for
        below-threshold queries the router deems worth a budgeted
        host→device fetch, and ``maintenance()`` asynchronously
        promotes re-hot rows back into the warm ring.  ``cold_policy``
        tunes the router (implies a cold tier of its default capacity
        when ``cold_capacity`` is 0).  The cold tier piggybacks on the
        *unsharded* warm ring's quantized panel; combine it with
        ``mesh`` and construction raises.

        ``warm_block`` streams the warm panel through the fused kernel
        in blocks of that many rows (DESIGN.md §12), lifting the
        single-block VMEM ceiling on warm capacity; None keeps the
        whole-panel residency.  Results are bit-identical either way.

        ``embedders`` turns on the fused multi-embedder ensemble
        (DESIGN.md §13): an int E (or a sequence of E embedder handles,
        retained for the caller's convenience — the service itself only
        ever sees embeddings).  Requests then carry (B, E, D)
        embeddings — one row per embedder, row 0 the *pilot* that IVF
        routing, the cold tier and the §11 machinery run on — and one
        cascade pass scores all E key panels, fusing them with
        per-tenant mixture weights (``ensemble_weights`` seeds the
        default mixture; uniform 1/E otherwise).  With
        ``learned_admission`` the weights are re-learned per tenant at
        refit time from the feedback stream, and each refit
        recalibrates the tenant's threshold against the fused score.
        A candidate embedder hot-swaps through ``publish_panel`` — the
        ensemble generalization of the §11 publish (serving panel e at
        its mixture weight IS A/B shadow serving).  ``learned_embedder``
        and ``embedders`` are mutually exclusive: the §11 refresh loop
        retrains the single pilot embedder, while ensemble candidates
        publish per panel.

        ``StalenessConfig`` (§14.2) turns on TTL eviction: admitted
        rows are stamped ``now + ttl`` (the request's per-row TTL, or
        ``default_ttl``), expired rows are masked out of every tier's
        plan-time view — hot, warm and cold, fused and unfused — and
        reaped (slots + host strings freed) on the maintenance tick.
        ``clock`` injects the time source for deterministic benches.

        ``LearningConfig.conformal`` (§14.3) floors each tenant's
        serving threshold at the split-conformal quantile of its
        recent observed negatives, so the false-hit budget holds under
        drift even while the §9 learned threshold lags or loosens.
        """
        from repro.cache_service.config import CacheConfig
        if isinstance(config, CacheConfig):
            if kwargs:
                raise TypeError(
                    f"CacheConfig construction takes no extra kwargs: "
                    f"{sorted(kwargs)}")
            cfg = config
        else:
            if config is not None:           # legacy positional dim
                kwargs.setdefault("dim", config)
            if "dim" not in kwargs:
                raise TypeError("CacheService needs a CacheConfig "
                                "(or the legacy dim=... kwargs form)")
            if not CacheService._kwargs_warned:
                CacheService._kwargs_warned = True
                warnings.warn(
                    "flat-kwargs CacheService(...) construction is "
                    "deprecated and will be removed next release; "
                    "build a CacheConfig (cache_service/config.py) — "
                    "see the README migration table",
                    DeprecationWarning, stacklevel=2)
            cfg = CacheConfig.from_kwargs(kwargs.pop("dim"), **kwargs)
        self.config = cfg
        tc, shc, lc = cfg.tiering, cfg.sharding, cfg.learning
        ec, stc = cfg.ensemble, cfg.staleness
        dim = cfg.dim
        topk, threshold = cfg.topk, cfg.threshold
        admission_margin, seed = cfg.admission_margin, cfg.seed
        telemetry = cfg.telemetry
        hot_capacity, warm_capacity = tc.hot_capacity, tc.warm_capacity
        n_clusters, bucket, n_probe = tc.n_clusters, tc.bucket, tc.n_probe
        flush_watermark, flush_size = tc.flush_watermark, tc.flush_size
        rebuild_every, kmeans_iters = tc.rebuild_every, tc.kmeans_iters
        fused, background_rebuild = tc.fused, tc.background_rebuild
        warm_dtype, warm_block = tc.warm_dtype, tc.warm_block
        cold_capacity, cold_policy = tc.cold_capacity, tc.cold_policy
        mesh, shard_axis = shc.mesh, shc.shard_axis
        learned_admission = lc.learned_admission
        feedback_config = lc.feedback
        learned_embedder = lc.learned_embedder
        embedder_trainer = lc.embedder_trainer
        embedder_tokenizer = lc.embedder_tokenizer
        refresh_policy = lc.refresh_policy
        embedders, ensemble_weights = ec.embedders, ec.weights

        sharded = mesh is not None
        shards = int(mesh.shape[shard_axis]) if sharded else 1
        if embedders is None:
            self.embedders: Optional[Tuple] = None
            n_embedders = 0
        elif isinstance(embedders, int):
            self.embedders = None
            n_embedders = embedders
        else:
            self.embedders = tuple(embedders)
            n_embedders = len(self.embedders)
        if n_embedders < 0 or n_embedders == 0 and embedders is not None:
            raise ValueError(f"embedders must name at least one "
                             f"embedder, got {embedders!r}")
        self.n_embedders = n_embedders
        if n_embedders and (learned_embedder or refresh_policy is not None
                            or embedder_trainer is not None):
            raise ValueError(
                "embedders= and learned_embedder= are mutually "
                "exclusive: the §11 refresh retrains the single pilot "
                "embedder in place; under an ensemble a candidate "
                "embedder is A/B-published per panel via "
                "publish_panel() instead (DESIGN.md §13)")
        if ensemble_weights is not None and not n_embedders:
            raise ValueError("ensemble_weights without embedders")
        if cold_policy is not None and cold_capacity <= 0:
            cold_capacity = 4 * warm_capacity
        if cold_capacity > 0 and sharded:
            raise ValueError(
                "cold_capacity > 0 requires the unsharded warm tier: "
                "demotion capture reads the single warm ring's int8 "
                "panel (DESIGN.md §12)")
        if warm_dtype not in ("float32", "int8"):
            raise ValueError(f"warm_dtype must be float32|int8, "
                             f"got {warm_dtype!r}")
        if flush_size is None:
            flush_size = max(hot_capacity // 4, 1)
        flush_size = min(flush_size, hot_capacity, warm_capacity)
        if sharded:
            if hot_capacity < shards:
                raise ValueError(
                    f"hot_capacity {hot_capacity} < {shards} shards: one "
                    "demotion flush cannot feed every warm shard")
            # flushes split round-robin over shards: keep them divisible
            flush_size = max(shards, (flush_size // shards) * shards)
            warm_capacity = -(-warm_capacity // shards) * shards
        rebuild_every = max(rebuild_every, 1)
        cap_local = warm_capacity // shards
        flush_local = flush_size // shards
        n_clusters_local = max(n_clusters // shards, 1)
        # every row appended since the last rebuild lies in this window
        # (per shard: each flush lands flush_local rows on each shard)
        if flush_local * rebuild_every > cap_local:
            warnings.warn(
                f"tail window flush_size*rebuild_every ("
                f"{flush_local}*{rebuild_every}="
                f"{flush_local * rebuild_every} per shard) exceeds the "
                f"per-shard warm capacity {cap_local}; clamping and "
                "forcing IVF rebuilds before the unindexed backlog "
                "outgrows the window (the configured rebuild cadence "
                "will not be honored)", stacklevel=2)
        tail = min(flush_local * rebuild_every, cap_local)

        self.dim = dim
        self.hot_capacity = hot_capacity
        self.warm_capacity = warm_capacity
        self.flush_size = flush_size
        self.flush_watermark = flush_watermark
        self.rebuild_every = rebuild_every
        self.topk = topk
        self.background_rebuild = bool(background_rebuild)
        self.warm_shards = shards
        self.warm_dtype = warm_dtype
        self._mesh = mesh
        self._shard_axis = shard_axis
        self._flush_local = flush_local
        self.warm_block = warm_block
        self.cold: Optional[ColdTier] = \
            ColdTier(cold_capacity, dim, policy=cold_policy) \
            if cold_capacity > 0 else None

        self.hot = tiers.init_hot(hot_capacity, dim)
        if sharded:
            self.warm = tiers.place_warm_sharded(
                tiers.init_warm_sharded(shards, cap_local, dim,
                                        n_clusters_local, bucket),
                mesh, shard_axis)
        else:
            self.warm = tiers.init_warm(warm_capacity, dim, n_clusters,
                                        bucket)
        self.policies = PolicyTable(TenantPolicy(threshold, admission_margin))
        # §13: E row-aligned key panels over the shared tiers; panel 0
        # (the pilot) duplicates the base keys, so every single-embedder
        # code path keeps reading the state it always did
        self.ens: Optional[tiers.EnsembleState] = None
        if n_embedders:
            ens = tiers.init_ensemble(n_embedders, self.hot, self.warm)
            self.ens = tiers.place_ensemble_sharded(ens, mesh, shard_axis) \
                if sharded else ens
            if ensemble_weights is not None:
                self.policies.set_default_weights(ensemble_weights)
        self.learned_admission = bool(learned_admission
                                      or feedback_config is not None)
        learned_embedder = bool(learned_embedder
                                or refresh_policy is not None)
        if learned_embedder and (embedder_trainer is None
                                 or embedder_tokenizer is None):
            raise ValueError(
                "learned_embedder=True needs embedder_trainer and "
                "embedder_tokenizer — the refresh trains the candidate "
                "and re-embeds the corpus through them (DESIGN.md §11)")
        self.trainer = embedder_trainer if learned_embedder else None
        self._embed_tok = embedder_tokenizer if learned_embedder else None
        self._refresh_policy = (refresh_policy or EmbedderRefreshPolicy()) \
            if learned_embedder else None
        # both learning loops (§9 admission, §11 embedder) share one
        # feedback accumulator: scores feed the per-tenant reservoirs,
        # texts feed the pooled pair reservoir
        self.feedback: Optional[FeedbackAccumulator] = \
            FeedbackAccumulator(feedback_config) \
            if (self.learned_admission or learned_embedder
                or lc.conformal) else None
        self.responses: Dict[int, str] = {}
        # raw query text per admitted value id (§11): re-embedding a
        # stored key under a refreshed embedder needs its original text
        self._texts: Dict[int, str] = {}
        self._next_vid = 0
        self._tail = tail
        self._n_probe = n_probe
        self._epoch = 0              # bumped by evict_tenant (plan staleness)
        self._embed_version = 0      # bumped by a published refresh (§11)
        self._pairs_at_refresh = 0   # pair-reservoir watermark (§11)
        self._recalibrated_thr: Optional[float] = None
        self._last_rebuild_s = 0.0
        self._rebuild_total_s = 0.0
        self._last_refresh_s = 0.0
        self._refresh_total_s = 0.0
        # counters live on the telemetry registry (DESIGN.md §10.1);
        # the few quantities receipts/overlap accounting need even with
        # telemetry disabled stay plain host ints
        self._n_plans = 0
        self._n_evictions = 0
        self._n_demoted_cold = 0
        # §14.2 TTL/staleness: masking only activates once any finite
        # deadline exists (default_ttl configured, or a request carried
        # one) — TTL-free services never pay the plan-time mask
        self.default_ttl = stc.default_ttl
        # deadlines live in float32 device arrays, where wall-clock
        # epoch seconds (~1.8e9) quantize to ~256s steps — coarser
        # than any sane TTL.  All internal times are therefore
        # *relative* to the clock's value at construction.
        raw_clock = stc.clock if stc.clock is not None else time.time
        t0 = float(raw_clock())
        self._clock = lambda: float(raw_clock()) - t0
        self._ttl_active = stc.default_ttl is not None
        # §14.3 conformal hit calibration (needs the feedback stream)
        self.conformal = bool(lc.conformal)
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        if self.telemetry.health is not None and self.feedback is not None:
            fb_cfg = self.feedback.config
            self.telemetry.health.set_budget_source(
                lambda t: fb_cfg.max_false_hit_rate)
        reg = self.telemetry.registry
        self._stage_h = self.telemetry.stage_histogram()
        self._c_plans = reg.counter(
            "cache_plans_total", "plan() calls").labels()
        self._c_commits = reg.counter(
            "cache_commits_total", "commit() calls").labels()
        self._c_stale = reg.counter(
            "cache_stale_commits_total",
            "commits whose plan predates an epoch bump").labels()
        self._c_rows = reg.counter(
            "cache_lookup_rows_total", "rows planned").labels()
        c_hits = reg.counter("cache_hits_total", "plan-time hits by tier",
                             labels=("tier",))
        self._c_hot_hits = c_hits.labels(tier="hot")
        self._c_warm_hits = c_hits.labels(tier="warm")
        self._c_cold_hits = c_hits.labels(tier="cold")
        self._m_admissions = reg.counter(
            "cache_admissions_total", "commit-time admission decisions",
            labels=("tenant", "decision"))
        self._c_demotions = reg.counter(
            "cache_demotions_total", "rows demoted hot -> warm").labels()
        self._c_evictions = reg.counter(
            "cache_evictions_total", "host response strings freed").labels()
        # §12 eviction split: a warm-ring overwrite either *demotes*
        # (cold tier captured the row — nothing was lost) or *drops*
        # (no cold tier — the string is freed).  With a cold tier the
        # dropped count must stay zero; the final drops of the
        # hierarchy happen on cold-ring overwrites instead.
        self._c_ev_demoted = reg.counter(
            "cache_evictions_demoted_total",
            "warm-ring overwrites captured into the cold tier").labels()
        self._c_ev_dropped = reg.counter(
            "cache_evictions_dropped_total",
            "warm-ring overwrites freed with no cold tier to catch "
            "them").labels()
        self._c_cold_evictions = reg.counter(
            "cache_cold_evictions_total",
            "cold-ring overwrites — the hierarchy's final drops"
        ).labels()
        self._c_cold_promotions = reg.counter(
            "cache_cold_promotions_total",
            "re-hot rows promoted cold -> warm by maintenance()"
        ).labels()
        self._c_cold_fetches = reg.counter(
            "cache_cold_fetches_total",
            "queries whose cold fetch the router approved").labels()
        self._c_cold_fetched_rows = reg.counter(
            "cache_cold_fetched_rows_total",
            "candidate rows shipped host -> device for the exact "
            "re-score").labels()
        self._c_cold_router_skips = reg.counter(
            "cache_cold_router_skips_total",
            "below-threshold queries whose cold fetch the router "
            "declined as not worth the transfer").labels()
        self._c_rebuilds = reg.counter(
            "cache_rebuilds_total",
            "IVF re-clusters completed (published or inline)").labels()
        self._c_shadow = reg.counter(
            "cache_shadow_rebuilds_total", "shadow builds started").labels()
        self._c_stale_ver = reg.counter(
            "cache_stale_version_commits_total",
            "admissions rejected because the plan embedded under an "
            "older embedder version than is live (§11)").labels()
        c_ref = reg.counter(
            "cache_embedder_refreshes_total",
            "embedder refresh lifecycle events (§11)",
            labels=("outcome",))
        self._c_refresh_started = c_ref.labels(outcome="started")
        self._c_refresh_published = c_ref.labels(outcome="published")
        self._c_refresh_rolled_back = c_ref.labels(outcome="rolled_back")
        self._c_ttl_stamped = reg.counter(
            "cache_ttl_stamped_total",
            "admitted rows stamped with a finite expiry (§14.2)").labels()
        self._c_expired_masked = reg.counter(
            "cache_expired_masked_total",
            "TTL-expired rows masked out of plan-time tier views "
            "(§14.2)").labels()
        self._c_expired_reaped = reg.counter(
            "cache_expired_reaped_total",
            "TTL-expired rows reaped by maintenance() across all "
            "tiers (§14.2)").labels()

        # double-buffer state: the shadow thread re-clusters a snapshot;
        # the host publishes (atomic _replace of the index leaves) from
        # _publish_shadow only — lookups always read self.warm
        self._shadow_thread: Optional[threading.Thread] = None
        self._shadow_box: Dict[str, object] = {}
        # refresh double-buffer (§11): the thread trains a candidate
        # embedder and re-embeds tier snapshots; _finish_refresh either
        # publishes (panels + params + version bump) or rolls back
        self._refresh_thread: Optional[threading.Thread] = None
        self._refresh_box: Dict[str, object] = {}

        self.set_fused(fused)
        self._insert = jax.jit(tiers.hot_insert_batch)
        self._touch = jax.jit(tiers.hot_touch)
        self._demote = jax.jit(partial(tiers.demote_coldest, m=flush_size))
        if sharded:
            self._append = jax.jit(tiers.warm_append_sharded)
            self._rebuild = jax.jit(partial(tiers.warm_rebuild_sharded,
                                            iters=kmeans_iters, seed=seed))
        else:
            self._append = jax.jit(tiers.warm_append)
            self._rebuild = jax.jit(partial(tiers.warm_rebuild,
                                            iters=kmeans_iters, seed=seed))
        self._evict_tenant = jax.jit(tiers.evict_tenant)
        self._publish_keys = jax.jit(tiers.publish_reembedded_keys)
        self._mask_expired = jax.jit(tiers.mask_expired)
        self._reap_expired = jax.jit(tiers.reap_expired)
        if self.ens is not None:
            self._ens_insert = jax.jit(tiers.ensemble_hot_insert_batch)
            self._coldest = jax.jit(partial(tiers.coldest_slots,
                                            m=flush_size))
            self._ens_append = jax.jit(
                tiers.ensemble_warm_append_sharded if sharded
                else tiers.ensemble_warm_append)
            self._ens_publish_panel = jax.jit(tiers.publish_panel,
                                              static_argnames=("e",))

    def set_fused(self, fused: bool) -> None:
        """Select the cascade execution path (four-op vs fused kernel);
        re-jits the lookup, so flipping it mid-serve costs one trace."""
        self.fused = bool(fused)
        self._lookup = jax.jit(partial(
            tiers.cascade_query, k=self.topk, n_probe=self._n_probe,
            tail=self._tail, fused=self.fused,
            quantized=self.warm_dtype == "int8",
            mesh=self._mesh, axis=self._shard_axis,
            warm_block_n=self.warm_block))
        if getattr(self, "ens", None) is not None:
            self._ens_lookup = jax.jit(partial(
                tiers.ensemble_cascade_query, k=self.topk,
                n_probe=self._n_probe, tail=self._tail, fused=self.fused,
                quantized=self.warm_dtype == "int8",
                mesh=self._mesh, axis=self._shard_axis,
                warm_block_n=self.warm_block))

    # ------------------------------------------------------------------
    # tenant policy surface
    # ------------------------------------------------------------------
    def set_tenant_policy(self, tenant: int, threshold: float,
                          admission_margin: float = 0.0) -> None:
        self.policies.set(tenant, TenantPolicy(threshold, admission_margin))

    def calibrate_tenant(self, tenant: int, scores, labels,
                         max_false_hit_rate: float = 0.01) -> Calibration:
        """Set this tenant's threshold from its own eval pairs under a
        false-hit budget."""
        return self.policies.calibrate(tenant, scores, labels,
                                       max_false_hit_rate)

    def set_tenant_weights(self, tenant: int, weights) -> None:
        """Pin one tenant's ensemble mixture weights (§13) — normalized
        to the simplex; learned refits may still move them later."""
        if self.ens is None:
            raise ValueError("set_tenant_weights needs embedders=")
        self.policies.set_weights(tenant, weights)

    def publish_panel(self, e: int, hot_keys, warm_keys) -> None:
        """Versioned publish of ONE embedder's key panels (DESIGN.md
        §13) — the ensemble generalization of the §11 re-embed publish.

        ``hot_keys`` is the (Nh, D) full-capacity hot panel under the
        candidate embedder, ``warm_keys`` the (Nw, D) warm panel
        ((S, Nw_local, D) stacked when sharded), built host-side
        exactly like `_finish_refresh` builds them: valid rows
        re-embedded, everything else carrying its current key.  The
        swap is atomic between lookups; per-slot metadata and the
        pilot-built IVF are untouched.  Serving panel ``e`` at mixture
        weight w IS A/B shadow serving of the candidate embedder at
        traffic share w — ramp w per tenant (or let the §9 weight
        learner earn it) to graduate the candidate.  Publishing the
        pilot (e=0) also swaps the base tiers' keys, since panel 0
        duplicates them.  The embedder version bumps either way, so
        plans embedded under the old panel set are rejected at commit
        (§11 staleness discipline).
        """
        if self.ens is None:
            raise ValueError("publish_panel needs embedders=")
        if not 0 <= int(e) < self.n_embedders:
            raise ValueError(f"panel {e} out of range "
                             f"[0, {self.n_embedders})")
        hk = jnp.asarray(hot_keys)
        wk = jnp.asarray(warm_keys)
        self.ens = self._ens_publish_panel(self.ens, int(e), hk, wk)
        if int(e) == 0:
            self.hot, self.warm = self._publish_keys(self.hot, self.warm,
                                                     hk, wk)
            if self._mesh is not None:
                self.warm = tiers.place_warm_sharded(
                    self.warm, self._mesh, self._shard_axis)
        if self._mesh is not None:
            self.ens = tiers.place_ensemble_sharded(
                self.ens, self._mesh, self._shard_axis)
        self._embed_version += 1

    # ------------------------------------------------------------------
    # CacheBackend protocol: plan / commit / maintenance / stats
    # ------------------------------------------------------------------
    def capabilities(self) -> CacheCapabilities:
        return CacheCapabilities(tenants=True, fused_lookup=True,
                                 admission=True,
                                 background_rebuild=self.background_rebuild,
                                 tiered=True,
                                 warm_sharded=self._mesh is not None,
                                 warm_dtype=self.warm_dtype,
                                 learned_admission=self.learned_admission,
                                 learned_embedder=self.trainer is not None,
                                 cold_tier=self.cold is not None,
                                 ensemble=self.n_embedders,
                                 ttl=True, conformal=self.conformal)

    def plan(self, request: CacheRequest, *,
             coalesce: bool = True) -> CachePlan:
        """Read side: one jitted cascade over both tiers, LRU touch,
        response resolution, admission pre-decision, miss coalescing
        (``coalesce=False`` skips the O(misses²) grouping when the
        caller won't use it — the legacy lookup shim does)."""
        t0 = time.perf_counter()
        qt = request.tenants
        # §14.3: the conformal floor rides every threshold resolution —
        # a tenant whose recent negatives crowd the learned threshold
        # serves strictly above them, budget held even mid-drift
        thr = self.policies.effective_thresholds(
            qt, self.feedback if self.conformal else None)
        # §14.2: expired rows are masked out of this plan's *view* of
        # the tiers (valid &= not-expired, before the jitted cascade —
        # elementwise, so fused/unfused/sharded/ensemble all inherit
        # it); the slots themselves are reclaimed by maintenance()
        now = float(self._clock()) if self._ttl_active else None
        hot_view, warm_view = self.hot, self.warm
        n_masked = 0
        if now is not None:
            hot_view, warm_view, nm = self._mask_expired(
                self.hot, self.warm, now)
            n_masked = int(nm)
            if n_masked:
                self._c_expired_masked.inc(n_masked)
        panel_scores = None
        if self.ens is not None:
            # §13: one fused pass over all E panels; the pilot slice
            # (row 0) feeds every single-embedder consumer downstream
            # (cold routing, miss coalescing)
            emb_np = np.asarray(request.embeddings)
            if emb_np.ndim != 3 or emb_np.shape[1] != self.n_embedders:
                raise ValueError(
                    f"ensemble backend expects (B, {self.n_embedders}, D)"
                    f" embeddings, got {emb_np.shape}")
            pilot = emb_np[:, 0]
            weights = self.policies.weights_for(qt, self.n_embedders)
            res = self._ens_lookup(hot_view, warm_view, self.ens,
                                   jnp.asarray(emb_np),
                                   jnp.asarray(weights), jnp.asarray(qt),
                                   jnp.asarray(thr))
            panel_scores = np.asarray(res.panel_scores)
        else:
            pilot = np.asarray(request.embeddings)
            res = self._lookup(hot_view, warm_view, jnp.asarray(pilot),
                               jnp.asarray(qt), jnp.asarray(thr))
        self.hot = self._touch(self.hot, res.hot_slots, res.hot_hit)
        hit = np.asarray(res.hit)
        scores = np.asarray(res.scores[:, 0])
        vids = np.asarray(res.value_ids[:, 0]).astype(np.int64)
        hot_hit = np.asarray(res.hot_hit)
        self._n_plans += 1
        self._c_plans.inc()
        self._c_rows.inc(len(hit))
        self._c_hot_hits.inc(int(hot_hit.sum()))
        self._c_warm_hits.inc(int((hit & ~hot_hit).sum()))
        if self.cold is not None and bool((~hit).any()):
            # §12 cold fallback: only the below-threshold rows are
            # offered, and the cold tier's own router decides which of
            # those justify a host->device fetch.  Verdicts merge
            # *before* the pre-decision/feedback/coalescing below, so
            # a cold hit is a hit everywhere downstream.
            tc = time.perf_counter()
            qn = np.asarray(pilot, np.float32)
            qn = qn / np.maximum(
                np.linalg.norm(qn, axis=1, keepdims=True), 1e-9)
            cf = self.cold.lookup(qn, np.asarray(qt),
                                  np.asarray(thr, np.float32), ~hit,
                                  now=now)
            self._stage_h.observe(time.perf_counter() - tc,
                                  stage="cold_fetch",
                                  tenant=tenant_label(qt))
            self._c_cold_fetches.inc(int(cf.consulted.sum()))
            self._c_cold_fetched_rows.inc(cf.fetched_rows)
            self._c_cold_router_skips.inc(cf.router_skips)
            chit = cf.consulted & (cf.scores >= np.asarray(thr, np.float32))
            if bool(chit.any()):
                self._c_cold_hits.inc(int(chit.sum()))
                hit = hit | chit
                scores = np.where(chit, cf.scores, scores)
                vids = np.where(chit, cf.value_ids, vids)
        responses = [self.responses.get(int(v)) if h else None
                     for h, v in zip(hit, vids)]
        admit = self.policies.pre_decision(qt, scores, hit)
        if self.feedback is not None:
            self.feedback.observe_plan(hit)
        if self.telemetry.health is not None:
            self.telemetry.health.observe_plan(qt, hit)
        leader = coalesce_misses(pilot, hit, qt, thr) \
            if coalesce else ungrouped_misses(hit)
        wall = time.perf_counter() - t0
        self._stage_h.observe(wall, stage="plan", tenant=tenant_label(qt))
        return CachePlan(
            request=request, hit=hit, scores=scores,
            value_ids=np.where(hit, vids, -1), responses=responses,
            admit=admit, miss_leader=leader,
            epoch=self._epoch,
            margins=np.asarray(thr, np.float32) - scores,
            top_value_ids=vids, plan_wall_s=wall,
            embed_version=self._embed_version,
            panel_scores=panel_scores, expired_masked=n_masked)

    def commit(self, plan: CachePlan,
               responses: Sequence[Optional[str]]) -> CommitReceipt:
        """Write side: admit planned misses (fresh value ids — a stale
        plan can never resurrect an id freed since plan time), flush if
        over the watermark, GC reported evictions."""
        t0 = time.perf_counter()
        self._c_commits.inc()
        if plan.epoch != self._epoch:
            # an evict_tenant landed between plan and commit; admission
            # stays safe because ids are fresh and strings are only
            # freed off device eviction reports
            self._c_stale.inc()
        rows = plan.miss_rows()
        admit = plan.admit[rows]
        n_stale_ver = 0
        if plan.embed_version != self._embed_version and len(rows):
            # the plan's embeddings were produced by an embedder version
            # that has since been hot-swapped away (§11): its hit
            # responses were already served consistently (scored against
            # the panel of its own version), but admitting its rows now
            # would plant old-space keys into the new-space panel and
            # silently mis-score every later neighbour.  Reject the
            # admissions outright and surface the count on the receipt.
            n_stale_ver = int(np.asarray(admit, bool).sum())
            admit = np.zeros_like(np.asarray(admit, bool))
            if n_stale_ver:
                self._c_stale_ver.inc(n_stale_ver)
        texts: List[Optional[str]] = [responses[i] for i in rows]
        for pos in np.nonzero(admit)[0]:
            if texts[pos] is None:
                raise ValueError(
                    f"admitted row {int(rows[pos])} has no response")
        if self.feedback is not None:
            self._observe_feedback(plan, rows, admit, texts)
        req_texts = plan.request.texts
        vids = np.full(len(rows), -1, np.int64)
        for pos in np.nonzero(admit)[0]:
            vids[pos] = self._next_vid
            self.responses[self._next_vid] = texts[pos]
            if req_texts is not None:
                self._texts[self._next_vid] = str(req_texts[int(rows[pos])])
            self._next_vid += 1
        n_admit = int(admit.sum())
        row_tenants = plan.request.tenants[rows]
        for tid in np.unique(row_tenants):
            m = row_tenants == tid
            n_a = int(admit[m].sum())
            if n_a:
                self._m_admissions.inc(n_a, tenant=int(tid),
                                       decision="admitted")
            if int(m.sum()) - n_a:
                self._m_admissions.inc(int(m.sum()) - n_a,
                                       tenant=int(tid), decision="skipped")
        evicted_before = self._n_evictions
        demoted_cold_before = self._n_demoted_cold
        n_ttl = 0
        if len(rows):
            # §14.2: stamp each admitted row's expiry deadline — the
            # request's per-row TTL wins, else the configured default,
            # else +inf (never expires).  The first finite deadline
            # activates plan-time masking for the service's lifetime.
            if plan.request.ttl is not None:
                ttl_rows = np.asarray(plan.request.ttl, np.float32)[rows]
            else:
                ttl_rows = np.full(
                    len(rows),
                    np.inf if self.default_ttl is None
                    else float(self.default_ttl), np.float32)
            expires = np.full(len(rows), np.inf, np.float32)
            fin = np.isfinite(ttl_rows)
            if fin.any():
                expires[fin] = np.float32(float(self._clock())) \
                    + ttl_rows[fin]
            n_ttl = int((fin & np.asarray(admit, bool)).sum())
            if n_ttl:
                self._ttl_active = True
                self._c_ttl_stamped.inc(n_ttl)
            if self.ens is not None:
                # (B, E, D) rows: the base insert takes the pilot slice,
                # the mirrored panels take the same slot (§13)
                self.hot, self.ens, evicted = self._ens_insert(
                    self.hot, self.ens,
                    jnp.asarray(plan.request.embeddings[rows]),
                    jnp.asarray(vids, dtype=jnp.int32),
                    jnp.asarray(plan.request.tenants[rows]),
                    jnp.asarray(expires))
            else:
                self.hot, evicted = self._insert(
                    self.hot, jnp.asarray(plan.request.embeddings[rows]),
                    jnp.asarray(vids, dtype=jnp.int32),
                    jnp.asarray(plan.request.tenants[rows]),
                    jnp.asarray(expires))
            self._gc(evicted)
            self._maybe_flush()
        wall = time.perf_counter() - t0
        self._stage_h.observe(wall, stage="commit",
                              tenant=tenant_label(plan.request.tenants))
        return CommitReceipt(
            admitted=n_admit, skipped=int((~admit).sum()),
            evicted=self._n_evictions - evicted_before,
            # a due policy refit or embedder refresh is a maintenance
            # obligation exactly like a due rebuild: the pipeline
            # discharges all three with one maintenance() call between
            # batches
            rebuild_due=self._rebuild_due()
            or (self.learned_admission and self.feedback is not None
                and self.feedback.refit_due())
            or self._refresh_thread is not None or self._refresh_due(),
            commit_wall_s=wall, trace_id=plan.request.trace_id,
            embed_version=self._embed_version,
            stale_version_skipped=n_stale_ver,
            ttl_stamped=n_ttl,
            demoted_cold=self._n_demoted_cold - demoted_cold_before,
            cold_maintenance_due=self.cold is not None
            and self.cold.maintenance_due)

    def maintenance(self, block: bool = False) -> MaintenanceReport:
        """Drive the double-buffered rebuild: publish a finished shadow
        index (atomic swap), start one if the backlog calls for it.
        ``block=True`` quiesces: it joins an in-flight build and never
        starts a new one, so the service returns with no rebuild
        running.  This is the idle tick (DESIGN.md §10.3): the health
        tracker drains here — per-tenant SLO gauges, occupancy and
        rebuild-overlap accounting all publish off the hot path."""
        t0 = time.perf_counter()
        published = started = False
        wall = 0.0
        if self._shadow_thread is not None and (
                block or not self._shadow_thread.is_alive()):
            wall = self._publish_shadow()
            published = True
        if (not block and self.background_rebuild
                and self._shadow_thread is None and self._tail_pressure()):
            self._start_shadow()
            started = True
        # §11 embedder refresh rides the same idle tick: publish (or
        # roll back) a finished candidate, then start one if the pair
        # reservoir says a refresh is due
        r_published = r_started = r_rolled = False
        r_wall = 0.0
        if self.trainer is not None:
            if self._refresh_thread is not None and (
                    block or not self._refresh_thread.is_alive()):
                r_wall, r_published, r_rolled = self._finish_refresh()
            if (not block and self._refresh_thread is None
                    and self._refresh_due()):
                self._start_refresh()
                r_started = True
        refits_applied = refits_checked = 0
        if self.feedback is not None and self.learned_admission:
            # online admission learning (DESIGN.md §9): republish every
            # tenant policy whose reservoir survives the hysteresis
            # guards — host-only work, cheap enough for every idle tick
            reports = self.policies.refit(self.feedback)
            refits_checked = len(reports)
            refits_applied = sum(r.applied for r in reports)
            for rep in reports:
                record_refit(self.telemetry.registry, rep)
        if self.feedback is not None and self.ens is not None:
            # §13: per-tenant mixture-weight refits ride the same idle
            # tick; an applied fit republishes the tenant's weights and
            # its fused-score-recalibrated threshold together
            wreps = self.policies.refit_weights(self.feedback,
                                                self.n_embedders)
            refits_checked += len(wreps)
            refits_applied += sum(r.applied for r in wreps)
            wc = self.telemetry.registry.counter(
                "ensemble_weight_refits_total",
                "per-tenant mixture-weight refit decisions by outcome "
                "(§13)", labels=("tenant", "outcome"))
            wg = self.telemetry.registry.gauge(
                "ensemble_weight", "published per-tenant mixture weight",
                labels=("tenant", "embedder"))
            for rep in wreps:
                wc.inc(1, tenant=rep.tenant,
                       outcome="applied" if rep.applied else rep.reason)
                if rep.applied:
                    for e, w in enumerate(rep.new_weights):
                        wg.set(float(w), tenant=rep.tenant, embedder=e)
        expired_reaped = 0
        if self._ttl_active:
            # §14.2 staleness reap: plan() only *masks* expired rows;
            # this is where their slots and host strings are reclaimed.
            # One jitted pass over both device tiers + the host cold
            # scan, all off the serving path.
            now = float(self._clock())
            self.hot, self.warm, h_ev, w_ev = self._reap_expired(
                self.hot, self.warm, now)
            expired_reaped = self._gc(h_ev) + self._gc(w_ev)
            if self.cold is not None:
                expired_reaped += self._gc(self.cold.reap_expired(now))
            if expired_reaped:
                self._c_expired_reaped.inc(expired_reaped)
        cold_promoted = 0
        cold_route_rebuilt = False
        if self.cold is not None:
            # §12 async promotion: re-hot cold rows climb back into the
            # warm ring here, never on the plan path.  The drain is
            # bounded by the policy's promote_max per tick.
            prom = self.cold.take_promotions(self.cold.policy.promote_max)
            if prom is not None:
                self._promote_into_warm(prom)
                cold_promoted = len(prom.value_ids)
                self._c_cold_promotions.inc(cold_promoted)
                if self._backlog() > self._tail:
                    # promotions are ring appends like any flush: the
                    # tail window must keep covering them
                    self._rebuild_inline()
            if self.cold._route_due():
                self.cold.rebuild_routes()
                cold_route_rebuilt = True
        reg = self.telemetry.registry
        reg.gauge("cache_hot_occupancy",
                  "hot-tier occupancy fraction").set(self.hot_occupancy)
        reg.gauge("cache_warm_occupancy",
                  "warm-ring occupancy fraction").set(self.warm_occupancy)
        reg.gauge("cache_live_responses",
                  "host response strings held").set(len(self.responses))
        reg.gauge("cache_warm_backlog_rows",
                  "rows appended since the published index (demotion "
                  "pressure vs the tail window)").set(self._backlog())
        if self.trainer is not None:
            reg.gauge("cache_embed_version",
                      "published embedder version (§11)"
                      ).set(self._embed_version)
        if self.cold is not None:
            reg.gauge("cache_cold_occupancy",
                      "cold-tier occupancy fraction"
                      ).set(self.cold.occupancy)
            reg.gauge("cache_cold_pending_promotions",
                      "re-hot cold rows queued for warm promotion"
                      ).set(self.cold.pending_promotions)
        if self.telemetry.health is not None:
            self.telemetry.health.drain(reg)
        host_wall = time.perf_counter() - t0
        self._stage_h.observe(host_wall, stage="maintenance", tenant="-")
        return MaintenanceReport(
            rebuild_started=started, rebuild_published=published,
            rebuild_in_flight=self._shadow_thread is not None,
            rebuild_wall_s=wall,
            refits_applied=refits_applied, refits_checked=refits_checked,
            wall_s=host_wall,
            refresh_started=r_started, refresh_published=r_published,
            refresh_rolled_back=r_rolled,
            refresh_in_flight=self._refresh_thread is not None,
            refresh_wall_s=r_wall, embed_version=self._embed_version,
            cold_promoted=cold_promoted,
            cold_route_rebuilt=cold_route_rebuilt,
            expired_reaped=expired_reaped)

    def stats_snapshot(self) -> ServiceStats:
        """The typed stats surface (DESIGN.md §10.1): every count read
        back from the telemetry registry.  With
        ``telemetry=Telemetry.disabled()`` the counter-derived fields
        read 0 — disabling telemetry trades the stats surface for zero
        recording cost (the bench's overhead guard measures that gap).
        """
        reg = self.telemetry.registry
        traffic = {
            "plans": int(reg.value("cache_plans_total")),
            "commits": int(reg.value("cache_commits_total")),
            "stale_commits": int(reg.value("cache_stale_commits_total")),
            "lookup_rows": int(reg.value("cache_lookup_rows_total")),
            "hot_hits": int(reg.value("cache_hits_total", tier="hot")),
            "warm_hits": int(reg.value("cache_hits_total", tier="warm")),
            "cold_hits": int(reg.value("cache_hits_total", tier="cold")),
        }
        admission = {
            "admitted": int(reg.value("cache_admissions_total",
                                      decision="admitted")),
            "skipped": int(reg.value("cache_admissions_total",
                                     decision="skipped")),
        }
        tiers_d = {
            "hot_occupancy": self.hot_occupancy,
            "warm_occupancy": self.warm_occupancy,
            "demotions": int(reg.value("cache_demotions_total")),
            "evictions": self._n_evictions,
            "evictions_demoted": int(
                reg.value("cache_evictions_demoted_total")),
            "evictions_dropped": int(
                reg.value("cache_evictions_dropped_total")),
            "live_responses": len(self.responses),
            "warm_shards": self.warm_shards,
            "warm_dtype": self.warm_dtype,
        }
        if self.ens is not None:
            tiers_d["ensemble"] = self.n_embedders
        if self.cold is not None:
            tiers_d["cold"] = self.cold.stats()
        if self._ttl_active:
            tiers_d["staleness"] = {
                "default_ttl": self.default_ttl,
                "ttl_stamped": int(
                    reg.value("cache_ttl_stamped_total")),
                "expired_masked": int(
                    reg.value("cache_expired_masked_total")),
                "expired_reaped": int(
                    reg.value("cache_expired_reaped_total")),
            }
        rebuild = {
            "rebuilds": int(reg.value("cache_rebuilds_total")),
            "shadow_started": int(
                reg.value("cache_shadow_rebuilds_total")),
            "in_flight": self._shadow_thread is not None,
            "last_wall_s": self._last_rebuild_s,
            "total_wall_s": self._rebuild_total_s,
        }
        learning = None
        if self.feedback is not None:
            learning = dict(self.feedback.state())
            learning["learned_policies"] = self.policies.learned_state()
            if self.ens is not None:
                learning["ensemble_weights"] = self.policies.weights_state()
            if self.conformal:
                learning["conformal"] = self.feedback.conformal_state()
        refresh = None
        if self.trainer is not None:
            refresh = {
                "embed_version": self._embed_version,
                "refreshes_started": int(reg.value(
                    "cache_embedder_refreshes_total", outcome="started")),
                "refreshes_published": int(reg.value(
                    "cache_embedder_refreshes_total", outcome="published")),
                "refreshes_rolled_back": int(reg.value(
                    "cache_embedder_refreshes_total",
                    outcome="rolled_back")),
                "stale_version_commits": int(reg.value(
                    "cache_stale_version_commits_total")),
                "refresh_in_flight": self._refresh_thread is not None,
                "last_refresh_s": self._last_refresh_s,
                "refresh_total_s": self._refresh_total_s,
                "pairs_held": len(self.feedback.pairs),
                "recalibrated_threshold": self._recalibrated_thr,
            }
        health = self.telemetry.health.snapshot() \
            if self.telemetry.health is not None else None
        return ServiceStats(schema=SCHEMA, traffic=traffic,
                            admission=admission, tiers=tiers_d,
                            rebuild=rebuild, learning=learning,
                            health=health, refresh=refresh)

    def evict_tenant(self, tenant: int) -> int:
        """Drop every entry of one tenant from both tiers; frees the
        host strings.  Returns the number of entries evicted."""
        self._epoch += 1
        self.hot, self.warm, h_ev, w_ev = self._evict_tenant(
            self.hot, self.warm, jnp.asarray(tenant, jnp.int32))
        n = self._gc(h_ev) + self._gc(w_ev)
        if self.cold is not None:
            # also purges the tenant's queued promotions: an evicted
            # tenant must not resurrect through the async drain (§12)
            n += self._gc(self.cold.evict_tenant(int(tenant)))
        return n

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _observe_feedback(self, plan: CachePlan, rows: np.ndarray,
                          admit: np.ndarray,
                          texts: List[Optional[str]]) -> None:
        """Label each committed miss against its stored neighbour and
        feed the per-tenant reservoir (DESIGN.md §9): duplicate <=> the
        generated response equals the best same-tenant neighbour's
        stored response (the plan carried its id).  A row with no
        same-tenant candidate is a definite non-duplicate; a row whose
        neighbour string was GC'd between plan and commit is
        unknowable and skipped rather than mislabeled.  Runs before
        commit mints fresh ids, so neighbour lookups only ever see
        plan-era entries.  All event/wasted-admission accounting lives
        on the accumulator (surfaced through ``stats()``)."""
        top = plan.top_value_ids
        if top is None:
            return
        tenants = plan.request.tenants
        req_texts = plan.request.texts
        if req_texts is not None and self.trainer is not None:
            # hit rows: the query cleared its tenant's threshold against
            # the stored neighbour — a served duplicate, and the
            # strongest positive contrastive pair the §11 pool sees
            for row in np.nonzero(np.asarray(plan.hit, bool))[0]:
                neigh = self._texts.get(int(plan.value_ids[row]))
                if neigh is not None:
                    self.feedback.observe_hit_pair(req_texts[int(row)],
                                                   neigh)
        for pos, row in enumerate(rows):
            text = texts[pos]
            if text is None:
                continue
            vid = int(top[row])
            if vid < 0:
                dup = False
                score = max(float(plan.scores[row]), -1.0)  # NEG sentinel
                neigh_text = None
            else:
                neighbour = self.responses.get(vid)
                if neighbour is None:
                    continue
                dup = text == neighbour
                score = float(plan.scores[row])
                # the §11 contrastive pair is (query, neighbour *query*)
                # — the texts whose embeddings the score was computed
                # between; missing when the neighbour predates text
                # retention (legacy insert path)
                neigh_text = self._texts.get(vid)
            q_text = None if req_texts is None else req_texts[int(row)]
            self.feedback.observe(int(tenants[row]), score, dup,
                                  bool(admit[pos]), text=q_text,
                                  neighbour_text=neigh_text)
            if self.ens is not None and plan.panel_scores is not None \
                    and vid >= 0:
                # §13: the same verdict, labeled with the candidate's
                # unweighted per-embedder cosines — the mixture-weight
                # learner's training event
                self.feedback.observe_ensemble(
                    int(tenants[row]), plan.panel_scores[row], dup)
            if self.telemetry.health is not None:
                self.telemetry.health.observe_admission(
                    int(tenants[row]), dup, bool(admit[pos]))

    def _gc(self, evicted) -> int:
        """Free response strings whose ids a device op reported evicted."""
        ids = np.asarray(evicted)
        n = 0
        for v in ids[ids >= 0]:
            self._texts.pop(int(v), None)
            if self.responses.pop(int(v), None) is not None:
                n += 1
        self._n_evictions += n
        self._c_evictions.inc(n)
        return n

    def _backlog(self) -> int:
        """Rows appended since the *published* index was built (the
        worst shard's backlog in the sharded tier — each shard has its
        own ring, so the window must cover the deepest one)."""
        return int(np.max(np.asarray(self.warm.total
                                     - self.warm.indexed_total)))

    def _tail_pressure(self) -> bool:
        """One more flush would push the unindexed backlog past the
        tail window — the single rebuild-trigger predicate shared by
        inline flushes, background starts and maintenance()."""
        return self._backlog() + self._flush_local > self._tail

    def _rebuild_due(self) -> bool:
        """A maintenance() call now would publish or start a rebuild."""
        if self._shadow_thread is not None:
            return True
        return self.background_rebuild and self._tail_pressure()

    # ------------------------------------------------------------------
    # §11: online embedder refresh (train -> gate -> re-embed -> publish)
    # ------------------------------------------------------------------
    def _refresh_due(self) -> bool:
        """The pair reservoir justifies a refresh attempt: enough pooled
        pairs of both labels, and enough *new* pair events since the
        last attempt (the §9 hysteresis discipline, applied to
        training runs).  With a ``synth_domain`` configured the
        class-balance guard is waived — a skewed pool (e.g. a stream
        where every observed neighbour really was a duplicate) is
        exactly what the synthetic backfill balances."""
        if self.trainer is None or self._refresh_thread is not None \
                or self.feedback is None:
            return False
        pol = self._refresh_policy
        pairs = self.feedback.pairs
        if len(pairs) < pol.min_pairs:
            return False
        if pol.synth_domain is None and (pairs.n_pos < pol.min_class
                                         or pairs.n_neg < pol.min_class):
            return False
        return self._pairs_at_refresh == 0 \
            or pairs.seen - self._pairs_at_refresh >= pol.refresh_interval

    def _start_refresh(self) -> None:
        """Kick off the refresh on a host thread: one-epoch contrastive
        fit of a *candidate* trainer (the paper's recipe — the live
        params are copied, never touched), eval gate against the frozen
        embedder on the held-out reservoir slice, then re-embed of a
        snapshot of both tiers' texts.  Everything the thread reads is
        snapshotted here; everything it produces lands in the box for
        ``_finish_refresh`` to publish or discard."""
        from repro.core.trainer import EmbedderTrainer
        pol = self._refresh_policy
        self._pairs_at_refresh = self.feedback.pairs.seen
        train_ds, eval_ds = self.feedback.pairs.split(pol.eval_frac,
                                                      seed=pol.seed)
        if pol.synth_domain is not None and (
                len(train_ds.labels) < pol.synth_min_pairs
                or _single_class(train_ds) or _single_class(eval_ds)):
            train_ds, eval_ds = _synth_backfill(train_ds, eval_ds, pol)
        snap_hot, snap_warm = self.hot, self.warm   # immutable pytrees
        snap_texts = dict(self._texts)
        baseline, tok = self.trainer, self._embed_tok
        self._refresh_box = box = {}

        def run() -> None:
            t0 = time.perf_counter()
            try:
                cand = EmbedderTrainer(baseline.cfg, baseline.ft,
                                       params=baseline.params)
                box["fit"] = cand.fit(train_ds, tok)
                gate = _eval_gate(cand, baseline, eval_ds, tok, pol)
                box["gate"] = gate
                if gate["pass"]:
                    box["trainer"] = cand
                    box["embeddings"] = _reembed_snapshot(
                        cand, tok, snap_hot, snap_warm, snap_texts)
            except BaseException as e:      # surfaced at publish time
                box["error"] = e
            box["wall"] = time.perf_counter() - t0

        self._refresh_thread = threading.Thread(
            target=run, name="embedder-refresh", daemon=True)
        self._refresh_thread.start()
        self._c_refresh_started.inc()

    def _finish_refresh(self) -> Tuple[float, bool, bool]:
        """Join the refresh thread; publish or roll back.

        Publish is the §7.1 discipline replayed against the embedder:
        the shadow re-embeddings are grafted onto the *current* tiers
        by value id (a row admitted while the thread ran is re-embedded
        inline here, so the published panel is single-space; a row
        evicted meanwhile simply has no key to graft — ``valid`` never
        moves, so nothing resurrects), the panels swap atomically
        between lookups, the live trainer adopts the candidate's params
        (the serving embed closure reads them per call — that
        assignment IS the hot swap), and the version bumps so in-flight
        plans are rejected at commit instead of mis-scored.  Rollback
        is nothing but discarding the candidate: its params were never
        visible anywhere.  Returns (wall_s, published, rolled_back).
        """
        assert self._refresh_thread is not None
        self._refresh_thread.join()
        self._refresh_thread = None
        box, self._refresh_box = self._refresh_box, {}
        err = box.get("error")
        if err is not None:
            raise RuntimeError("background embedder refresh failed") from err
        wall = float(box.get("wall", 0.0))
        self._last_refresh_s = wall
        gate = box.get("gate", {"pass": False})
        reg = self.telemetry.registry
        g = reg.gauge(
            "cache_refresh_eval",
            "last refresh's eval-gate metrics on the held-out slice "
            "(candidate vs the then-frozen baseline)",
            labels=("embedder", "metric"))
        for side in ("candidate", "baseline"):
            for k, v in (gate.get(side) or {}).items():
                if k in ("precision", "recall", "f1"):
                    g.set(float(v), embedder=side, metric=k)
        if not gate.get("pass"):
            self._c_refresh_rolled_back.inc()
            return wall, False, True
        emb: Dict[int, np.ndarray] = box["embeddings"]
        cand = box["trainer"]
        # rows admitted while the refresh ran: re-embed inline with the
        # candidate so the published panel is single-space (the §7.1
        # tail-window analogue — the snapshot covers the bulk, the
        # publish covers the delta)
        delta = [(int(v), self._texts[int(v)]) for v in self._live_vids()
                 if int(v) not in emb and int(v) in self._texts]
        if delta:
            de = cand.embed_texts([t for _, t in delta], self._embed_tok)
            emb.update({v: de[i] for i, (v, _) in enumerate(delta)})
        hot_keys = np.asarray(self.hot.keys).copy()
        hvids = np.asarray(self.hot.value_ids)
        for i in np.nonzero(np.asarray(self.hot.valid))[0]:
            e = emb.get(int(hvids[i]))
            if e is not None:
                hot_keys[i] = e
        warm_keys = np.asarray(self.warm.keys).copy()
        wvids = np.asarray(self.warm.value_ids)
        for idx in np.argwhere(np.asarray(self.warm.valid)):
            e = emb.get(int(wvids[tuple(idx)]))
            if e is not None:
                warm_keys[tuple(idx)] = e
        self.hot, self.warm = self._publish_keys(
            self.hot, self.warm, jnp.asarray(hot_keys),
            jnp.asarray(warm_keys))
        if self._mesh is not None:
            self.warm = tiers.place_warm_sharded(self.warm, self._mesh,
                                                 self._shard_axis)
        self.trainer.params = cand.params
        self.trainer.opt_state = cand.opt_state
        self._embed_version += 1
        if self._refresh_policy.recalibrate:
            # a threshold is only meaningful against one embedder's
            # score distribution: remap every tenant to the published
            # candidate's best-F1 operating point on the gate slice,
            # and drop the §9 score reservoirs (their samples live in
            # the old version's score space)
            lo, hi = self._refresh_policy.recalibrate_bounds
            new_thr = float(np.clip(
                gate["candidate"]["f1_threshold"], lo, hi))
            self.policies.recalibrate_all(new_thr)
            if self.feedback is not None:
                self.feedback.reset_scores()
            self._recalibrated_thr = new_thr
            reg.gauge(
                "cache_refresh_recalibrated_threshold",
                "serving threshold adopted at the last embedder "
                "publish (the candidate's held-out best-F1 operating "
                "point, clipped to the policy's recalibrate_bounds)"
            ).set(new_thr)
        self._refresh_total_s += wall
        self._c_refresh_published.inc()
        return wall, True, False

    def _live_vids(self) -> np.ndarray:
        """Value ids currently valid in either tier (host view)."""
        h = np.asarray(self.hot.value_ids)[np.asarray(self.hot.valid)]
        w = np.asarray(self.warm.value_ids)[np.asarray(self.warm.valid)]
        return np.unique(np.concatenate([h.ravel(), w.ravel()]))

    def _start_shadow(self) -> None:
        """Kick off a shadow re-cluster of a snapshot of the warm tier.
        The snapshot is an immutable pytree, so serving mutations keep
        building fresh states while the thread reads the old one."""
        snapshot = self.warm
        self._shadow_box = box = {}
        rebuild = self._rebuild

        def run() -> None:
            t0 = time.perf_counter()
            try:
                box["warm"] = jax.block_until_ready(rebuild(snapshot))
            except BaseException as e:          # surfaced at publish time
                box["error"] = e
            # stamped in-thread: the build itself, not the idle wait
            # for the next maintenance() tick to publish it
            box["wall"] = time.perf_counter() - t0

        self._shadow_thread = threading.Thread(
            target=run, name="warm-ivf-rebuild", daemon=True)
        self._shadow_thread.start()
        self._c_shadow.inc()
        if self.telemetry.health is not None:
            # overlap accounting (§10.3): plans served between here and
            # the publish ran against the pre-snapshot index
            self.telemetry.health.observe_rebuild_start(self._n_plans)

    def _publish_shadow(self) -> float:
        """Join the shadow thread and atomically swap its index in.

        ``indexed_total`` becomes the snapshot's total, so every row
        appended *after* the snapshot stays covered by the tail window
        — recall never dips across the swap (`tiers.warm_query`'s
        epoch partition keeps slots overwritten post-snapshot out of
        the stale inverted lists).
        """
        assert self._shadow_thread is not None
        t0 = time.perf_counter()
        self._shadow_thread.join()
        self._shadow_thread = None
        err = self._shadow_box.get("error")
        if err is not None:
            raise RuntimeError("background IVF rebuild failed") from err
        shadow = self._shadow_box["warm"]
        self.warm = tiers.warm_publish_index(self.warm, shadow)
        # the stall the serve loop actually felt: join wait + swap —
        # near zero when the build finished before the idle tick
        stall = time.perf_counter() - t0
        wall = float(self._shadow_box["wall"])
        self._last_rebuild_s = wall
        self._rebuild_total_s += wall
        self._c_rebuilds.inc()
        if self.telemetry.health is not None:
            self.telemetry.health.observe_rebuild_publish(
                self._n_plans, stall)
        return wall

    def _rebuild_inline(self) -> None:
        t0 = time.perf_counter()
        self.warm = jax.block_until_ready(self._rebuild(self.warm))
        self._last_rebuild_s = time.perf_counter() - t0
        self._rebuild_total_s += self._last_rebuild_s
        self._c_rebuilds.inc()

    def _capture_and_append(self, dem: tiers.Demoted,
                            panel_keys=None) -> None:
        """Land a batch on the warm ring; route its overwrites.

        Without a cold tier a ring overwrite is the end of the line:
        GC the reported value ids and count them dropped.  With one,
        the rows about to be overwritten demote instead (§12): their
        ring positions are recomputed host-side from the pre-append
        cursor (the same arithmetic as `tiers.warm_append`, sound
        because `demote_coldest` keeps ``mask`` a True-prefix), their
        int8 panel rows are captured into the cold ring *before* the
        jitted append lands, and only the cold ring's own overwrites —
        the hierarchy's final drops — are GC'd.

        Under an ensemble (§13) ``panel_keys`` carries the batch's
        (E, m, D) stacked panel rows; the mirrored append replays the
        base ring arithmetic from the pre-append state, so the panels
        stay row-aligned.  ``None`` (the cold-promotion path, which
        only retains pilot keys) backfills every panel with the pilot
        row — exact for the pilot, a well-formed stand-in for the rest
        until the row is re-admitted.
        """
        warm_pre = self.warm
        if self.ens is not None and panel_keys is None:
            panel_keys = jnp.broadcast_to(
                dem.keys[None], (self.n_embedders,) + dem.keys.shape)
        if self.cold is None:
            self.warm, evicted = self._append(self.warm, dem)
            if self.ens is not None:
                self.ens = self._ens_append(self.ens, warm_pre, dem,
                                            panel_keys)
            self._c_ev_dropped.inc(self._gc(evicted))
            return
        n = int(np.asarray(dem.mask).sum())
        if n:
            cap = self.warm.keys.shape[0]
            pos = (int(np.asarray(self.warm.cursor))
                   + np.arange(n)) % cap
            pos = pos[np.asarray(self.warm.valid)[pos]]
            if len(pos):
                dropped = self.cold.insert(
                    np.asarray(self.warm.keys_q)[pos],
                    np.asarray(self.warm.scales)[pos],
                    np.asarray(self.warm.value_ids)[pos].astype(np.int64),
                    np.asarray(self.warm.tenants)[pos],
                    expires=np.asarray(self.warm.expires_at)[pos])
                self._c_ev_demoted.inc(len(pos))
                self._n_demoted_cold += len(pos)
                self._c_cold_evictions.inc(self._gc(dropped))
        # the append's own eviction report covers exactly the captured
        # rows — their strings stay alive behind the cold copies
        self.warm, _ = self._append(self.warm, dem)
        if self.ens is not None:
            self.ens = self._ens_append(self.ens, warm_pre, dem,
                                        panel_keys)

    def _promote_into_warm(self, prom) -> None:
        """Append a drained cold `Promotion` to the warm ring through
        the same jitted ``flush_size``-shaped path as a demotion flush
        (chunks pad with masked rows, so no new shape is traced).
        Ring rows a promotion overwrites demote straight back into the
        cold tier — promotion must never become a covert drop path."""
        m = self.flush_size
        for lo in range(0, len(prom.value_ids), m):
            keys = np.asarray(prom.keys[lo:lo + m], np.float32)
            v = np.asarray(prom.value_ids[lo:lo + m], np.int32)
            t = np.asarray(prom.tenants[lo:lo + m], np.int32)
            x = np.asarray(prom.expires[lo:lo + m], np.float32)
            pad = m - len(v)
            dem = tiers.Demoted(
                keys=jnp.asarray(np.concatenate(
                    [keys, np.zeros((pad, self.dim), np.float32)])),
                value_ids=jnp.asarray(np.concatenate(
                    [v, np.full(pad, -1, np.int32)])),
                tenants=jnp.asarray(np.concatenate(
                    [t, np.full(pad, -1, np.int32)])),
                mask=jnp.asarray(np.concatenate(
                    [np.ones(len(v), bool), np.zeros(pad, bool)])),
                expires=jnp.asarray(np.concatenate(
                    [x, np.full(pad, np.inf, np.float32)])))
            self._capture_and_append(dem)

    def _do_flush(self, rebuild: bool) -> None:
        pk = None
        if self.ens is not None:
            # gather the demoting rows' stacked panel keys before the
            # demote flips their valid bits — `coldest_slots` is the
            # exact selection `demote_coldest` pops (§13)
            slots = self._coldest(self.hot)
            pk = self.ens.hot_keys[:, slots]
        self.hot, dem = self._demote(self.hot)
        self._capture_and_append(dem, pk)
        self._c_demotions.inc(int(np.asarray(dem.mask).sum()))
        # the tail window only covers the last `tail` ring writes; a
        # rebuild is forced before the unindexed backlog outgrows it,
        # else demoted rows would silently fall out of reach
        if not self.background_rebuild:
            if rebuild or self._tail_pressure():
                self._rebuild_inline()
            return
        # double-buffered: publish any finished shadow, then make sure
        # the window still covers the backlog before serving resumes
        if self._shadow_thread is not None \
                and not self._shadow_thread.is_alive():
            self._publish_shadow()
        if self._backlog() > self._tail:
            if self._shadow_thread is not None:
                self._publish_shadow()          # blocks: join + swap
            if self._backlog() > self._tail:
                self._rebuild_inline()          # snapshot was too old
        if (rebuild or self._tail_pressure()) \
                and self._shadow_thread is None:
            self._start_shadow()

    def _maybe_flush(self) -> None:
        n_valid = int(np.asarray(self.hot.valid).sum())
        if n_valid >= self.flush_watermark * self.hot_capacity:
            self._do_flush(rebuild=False)

    def flush(self, rebuild: bool = True) -> None:
        """Force one demotion flush now.  ``rebuild=False`` still
        rebuilds if skipping would leave rows beyond the tail window.
        With ``background_rebuild`` the re-cluster runs double-buffered
        (shadow build + later publish) instead of inline."""
        self._do_flush(rebuild)

    # ------------------------------------------------------------------
    @property
    def hot_occupancy(self) -> float:
        return float(np.asarray(self.hot.valid).mean())

    @property
    def warm_occupancy(self) -> float:
        return float(np.asarray(self.warm.valid).mean())

    @property
    def occupancy(self) -> float:
        """Drop-in parity with SemanticCache (fraction of total rows)."""
        n = int(np.asarray(self.hot.valid).sum()) \
            + int(np.asarray(self.warm.valid).sum())
        return n / (self.hot_capacity + self.warm_capacity)

    def __len__(self) -> int:
        n = int(np.asarray(self.hot.valid).sum()) \
            + int(np.asarray(self.warm.valid).sum())
        return n + len(self.cold) if self.cold is not None else n


# ---------------------------------------------------------------------------
# §11 refresh helpers (module-level: they run on the refresh thread and
# must only touch the snapshots they are handed)
# ---------------------------------------------------------------------------

def _eval_gate(cand, baseline, eval_ds, tok,
               pol: EmbedderRefreshPolicy) -> Dict[str, object]:
    """Judge the candidate on the held-out slice: absolute
    precision/recall floors plus no-F1-regression against the frozen
    embedder on the *same* slice.  An eval slice without both labels
    cannot support the metrics — fail closed (rollback), never publish
    unjudged."""
    labels = np.asarray(eval_ds.labels)
    if len(labels) == 0 or len(np.unique(labels)) < 2:
        return {"pass": False, "reason": "eval-starved"}
    cand_m = cand.evaluate(eval_ds, tok)
    base_m = baseline.evaluate(eval_ds, tok)
    ok = (cand_m["precision"] >= pol.min_precision
          and cand_m["recall"] >= pol.min_recall
          and cand_m["f1"] >= base_m["f1"] - pol.max_f1_regression)
    return {"pass": bool(ok), "reason": "ok" if ok else "gate-failed",
            "candidate": cand_m, "baseline": base_m}


def _reembed_snapshot(trainer, tok, hot, warm,
                      texts: Dict[int, str]) -> Dict[int, np.ndarray]:
    """Re-embed every snapshot row whose query text is retained.
    Returns value id -> new embedding (the publish grafts them onto the
    then-current tiers by id, so rows evicted since the snapshot are
    simply never looked up)."""
    vids: set = set()
    for state in (hot, warm):
        v = np.asarray(state.value_ids)[np.asarray(state.valid)]
        vids.update(int(x) for x in v.ravel())
    todo = [(v, texts[v]) for v in sorted(vids) if v in texts]
    if not todo:
        return {}
    embs = trainer.embed_texts([t for _, t in todo], tok)
    return {v: embs[i] for i, (v, _) in enumerate(todo)}


def _single_class(ds) -> bool:
    labels = np.asarray(ds.labels)
    return len(labels) == 0 or len(np.unique(labels)) < 2


def _synth_backfill(train, eval_ds, pol: EmbedderRefreshPolicy):
    """Top a thin or class-skewed split up with grammar-synthesized
    paraphrase/distinct pairs (the paper's synthetic augmentation,
    DESIGN.md §6) from ``pol.synth_domain``.  The synthetic pool is
    itself split train/eval with the reservoir's ``eval_frac``
    discipline — but only when the held-out slice is class-starved
    (otherwise the gate keeps judging on pure serving pairs); the
    split is deterministic in ``synth_seed``, so every candidate
    trained from the same reservoir state faces the same gate.
    Returns the augmented ``(train, eval)`` datasets."""
    from repro.core.synth import (
        TemplateGenerator, generate_synthetic_pairs, records_to_dataset,
    )
    from repro.data.corpora import PairDataset, sample_query
    need = max(pol.synth_min_pairs - len(train.labels), 8)
    rng = np.random.default_rng(pol.synth_seed)
    # each seed query yields 2 paraphrase + 2 distinct records
    seeds = [sample_query(rng, pol.synth_domain)
             for _ in range(max(-(-need // 4), 1))]
    synth = records_to_dataset(generate_synthetic_pairs(
        seeds, TemplateGenerator(pol.synth_seed), n_pos=2, n_neg=2))
    perm = np.random.default_rng(pol.synth_seed).permutation(
        len(synth.labels))
    n_eval = int(np.ceil(len(perm) * pol.eval_frac)) \
        if _single_class(eval_ds) else 0
    ev, tr = perm[:n_eval], perm[n_eval:]

    def cat(ds: PairDataset, idx: np.ndarray) -> PairDataset:
        return PairDataset(
            q1=list(ds.q1) + [synth.q1[i] for i in idx],
            q2=list(ds.q2) + [synth.q2[i] for i in idx],
            labels=np.concatenate(
                [np.asarray(ds.labels, np.int32),
                 np.asarray([synth.labels[i] for i in idx], np.int32)]),
            domain=ds.domain)

    return cat(train, tr), cat(eval_ds, ev)
