"""xLSTM-125M — sLSTM + mLSTM recurrent blocks.

[arXiv:2405.04517]  12L, d_model=768, 4 heads, vocab=50304, d_ff=0 (the
up/down projections live inside the xLSTM blocks themselves).  We use an
alternating mLSTM/sLSTM period (xLSTM[1:1] flavour).  Fully recurrent —
decode state is O(1) in sequence length, so ``long_500k`` runs natively.
"""
from repro.configs.base import (
    ModelConfig, LayerSpec, XLSTMConfig, MLSTM, SLSTM, NONE, register,
)

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    mlp_type="none",
    norm_type="layernorm",
    use_rope=False,
    tie_embeddings=True,
    xlstm=XLSTMConfig(),
    period=(LayerSpec(MLSTM, NONE), LayerSpec(SLSTM, NONE)),
))
