"""Contrastive objectives for duplicate-query embedding fine-tuning.

``online_contrastive_loss`` is the paper's training objective
(sentence-transformers' OnlineContrastiveLoss): within each batch, only
the *hard* pairs contribute —

  hard positives: duplicate pairs whose cosine distance exceeds the
                  smallest negative distance in the batch;
  hard negatives: distinct pairs whose distance is below the largest
                  positive distance.

The reference torch implementation selects these with boolean indexing
(dynamic shapes).  XLA requires static shapes, so we compute identical
math with *masked reductions* (DESIGN.md §3) — same gradients, jittable,
and shardable under pjit.  ``contrastive_loss`` (all pairs weighted
equally) is kept as the paper's implicit baseline objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_distance(e1, e2):
    """1 - cosine similarity.  e1,e2: (B, D)."""
    e1 = e1.astype(jnp.float32)
    e2 = e2.astype(jnp.float32)
    num = jnp.sum(e1 * e2, axis=-1)
    den = jnp.linalg.norm(e1, axis=-1) * jnp.linalg.norm(e2, axis=-1)
    return 1.0 - num / jnp.maximum(den, 1e-9)


def contrastive_loss(e1, e2, labels, margin: float = 0.5):
    """Classic (non-online) contrastive loss — every pair contributes."""
    d = cosine_distance(e1, e2)
    lab = labels.astype(jnp.float32)
    pos = lab * jnp.square(d)
    neg = (1.0 - lab) * jnp.square(jnp.maximum(margin - d, 0.0))
    return 0.5 * jnp.mean(pos + neg)


def online_contrastive_loss(e1, e2, labels, margin: float = 0.5):
    """Hard-pair-mined contrastive loss (static-shape formulation).

    e1, e2: (B, D) embeddings of the two queries in each pair;
    labels: (B,) 1 = duplicate, 0 = distinct.
    """
    d = cosine_distance(e1, e2)                      # (B,)
    is_pos = labels.astype(bool)
    is_neg = ~is_pos
    big = jnp.asarray(1e9, jnp.float32)

    any_pos = jnp.any(is_pos)
    any_neg = jnp.any(is_neg)
    # batch statistics over the *other* class
    min_neg = jnp.min(jnp.where(is_neg, d, big))     # smallest negative dist
    max_pos = jnp.max(jnp.where(is_pos, d, -big))    # largest positive dist

    # hard-pair masks; if the opposite class is absent, fall back to all
    # pairs of the class (matches the torch implementation's behaviour)
    hard_pos = is_pos & (jnp.where(any_neg, d > min_neg, True))
    hard_neg = is_neg & (jnp.where(any_pos, d < max_pos, True))

    pos_loss = jnp.sum(jnp.square(d) * hard_pos.astype(jnp.float32))
    neg_loss = jnp.sum(
        jnp.square(jnp.maximum(margin - d, 0.0)) * hard_neg.astype(jnp.float32))
    # normalise by batch for lr stability across batch sizes
    return (pos_loss + neg_loss) / d.shape[0]


def hard_pair_fractions(e1, e2, labels, margin: float = 0.5):
    """Diagnostics: fraction of pairs that are 'hard' (for EXPERIMENTS)."""
    d = cosine_distance(e1, e2)
    is_pos = labels.astype(bool)
    is_neg = ~is_pos
    big = jnp.asarray(1e9, jnp.float32)
    min_neg = jnp.min(jnp.where(is_neg, d, big))
    max_pos = jnp.max(jnp.where(is_pos, d, -big))
    hp = jnp.sum(is_pos & (d > min_neg)) / jnp.maximum(jnp.sum(is_pos), 1)
    hn = jnp.sum(is_neg & (d < max_pos)) / jnp.maximum(jnp.sum(is_neg), 1)
    return {"hard_pos_frac": hp, "hard_neg_frac": hn}
