"""Optimizer, schedules, checkpointing, and the embedder fine-tune loop
(paper recipe: 1 epoch, online contrastive, grad-norm clip 0.5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EmbedderTrainer, FinetuneConfig
from repro.data import HashTokenizer, make_pair_dataset
from repro.training import (
    adamw, apply_updates, clip_by_global_norm, constant, global_norm,
    linear_warmup_cosine, load_checkpoint, save_checkpoint,
)


def test_adam_reduces_quadratic():
    init, update = adamw(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        ups, opt, _ = update(grads, opt, params)
        params = apply_updates(params, ups)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, raw = clip_by_global_norm(tree, 0.5)
    np.testing.assert_allclose(float(global_norm(clipped)), 0.5, rtol=1e-5)
    assert float(raw) > 30


def test_adam_bf16_moments():
    init, update = adamw(0.01, state_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = init(params)
    assert opt.m["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4,))}
    ups, opt, _ = update(grads, opt, params)
    assert bool(jnp.all(jnp.isfinite(ups["w"])))


def test_schedules():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)
    assert float(constant(0.3)(0)) == pytest.approx(0.3)


def test_checkpoint_roundtrip(tmp_path):
    from repro.training.optim import AdamState
    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
        "opt": AdamState(step=np.asarray(3, np.int32),
                         m={"w": np.ones((2, 3), np.float32)},
                         v={"w": np.zeros((2, 3), np.float32)}),
        "meta": {"name": "test", "lr": 1e-4, "tags": ["a", "b"]},
    }
    p = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(p, tree)
    back = load_checkpoint(p)
    np.testing.assert_array_equal(back["params"]["w"], tree["params"]["w"])
    assert back["opt"].step == 3
    np.testing.assert_array_equal(back["opt"].m["w"], np.ones((2, 3)))
    assert back["meta"] == tree["meta"]


@pytest.fixture(scope="module")
def tiny_trainer_setup():
    cfg = get_config("modernbert-149m").reduced(vocab_size=2048)
    tok = HashTokenizer(vocab_size=cfg.vocab_size)
    train = make_pair_dataset("medical", 192, seed=0)
    evl = make_pair_dataset("medical", 96, seed=99)
    return cfg, tok, train, evl


def test_finetune_improves_metrics(tiny_trainer_setup):
    """The paper's central claim at smoke scale: 1 epoch of online
    contrastive fine-tuning lifts pair-classification metrics over the
    untuned base encoder."""
    cfg, tok, train, evl = tiny_trainer_setup
    ft = FinetuneConfig(epochs=2, batch_size=16, max_len=24, lr=3e-4)
    trainer = EmbedderTrainer(cfg, ft)
    before = trainer.evaluate(evl, tok)
    out = trainer.fit(train, tok)
    after = trainer.evaluate(evl, tok)
    assert out["steps"] == 2 * (192 // 16)
    assert after["ap"] > before["ap"] + 0.03, (before, after)
    assert after["f1"] > before["f1"]


def test_finetune_grad_clip_applied(tiny_trainer_setup):
    cfg, tok, train, _ = tiny_trainer_setup
    ft = FinetuneConfig(epochs=1, batch_size=16, max_len=24,
                        max_grad_norm=0.5, log_every=1)
    trainer = EmbedderTrainer(cfg, ft)
    trainer.fit(train, tok)
    assert len(trainer.history) > 0


def test_embed_fn_unit_norm(tiny_trainer_setup):
    cfg, tok, _, _ = tiny_trainer_setup
    trainer = EmbedderTrainer(cfg, FinetuneConfig(max_len=24))
    f = trainer.make_embed_fn(tok)
    e = f(["hello world", "semantic caching"])
    assert e.shape == (2, cfg.d_model)
    np.testing.assert_allclose(np.linalg.norm(e, axis=-1), 1.0, rtol=1e-4)
