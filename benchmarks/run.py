"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select benches with
``python -m benchmarks.run [fig1 fig2 fig3 table1 fig4 cache kernels]``.
"""
from __future__ import annotations

import sys


def _registry():
    from benchmarks.paper_benches import (
        bench_ablation_loss, bench_cache_hit_rate, bench_fig1_quora,
        bench_fig2_medical, bench_fig3_forgetting, bench_fig4_latency,
        bench_table1_synthetic,
    )
    from benchmarks.kernel_benches import bench_kernels
    from benchmarks.bench_tiered_cache import bench_tiered_cache
    return {
        "fig1": bench_fig1_quora,
        "fig2": bench_fig2_medical,
        "fig3": bench_fig3_forgetting,
        "table1": bench_table1_synthetic,
        "fig4": bench_fig4_latency,
        "cache": bench_cache_hit_rate,
        "ablation": bench_ablation_loss,
        "kernels": bench_kernels,
        "tiered": bench_tiered_cache,
    }


def main() -> None:
    registry = _registry()
    selected = sys.argv[1:] or list(registry)
    unknown = [s for s in selected if s not in registry]
    if unknown:
        raise SystemExit(f"unknown benches {unknown}; have {list(registry)}")
    print("name,us_per_call,derived")
    for key in selected:
        for name, us, derived in registry[key]():
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
