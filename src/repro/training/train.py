"""LM training step factory — the `train_4k` path of every backbone.

``make_train_step(cfg, update_fn)`` builds the pure function that the
launcher jits with in/out shardings; the same function is what
``launch/dryrun.py`` lowers for the multi-pod pass.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm_loss
from repro.training.optim import apply_updates


def make_train_step(cfg: ModelConfig, update_fn: Callable):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).  batch: {"tokens": (B,S) int32,
    ["frontend_embeds": (B,F,d)]}.
    """

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch["tokens"],
                       batch.get("frontend_embeds"))

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        updates, opt_state, opt_metrics = update_fn(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, parts = lm_loss(params, cfg, batch["tokens"],
                              batch.get("frontend_embeds"))
        return {"loss": loss, **parts}
    return eval_step
