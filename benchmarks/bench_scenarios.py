"""Trace-driven scenario macro-bench for the tiered cache service
(DESIGN.md §14.1).

Replays the seeded ``benchmarks/scenarios.py`` traces through a real
``CacheService`` built from a ``CacheConfig``, under a **logical
clock** (``StalenessConfig.clock`` reads the trace's arrival times),
and scores each scenario on:

  * SLO-style latency — p50/p99 of per-batch ``plan()`` wall time,
    µs per row, with the first batch of every distinct batch *size*
    excluded (that batch pays the jit trace; production pays it once
    at warmup, not per request);
  * false-hit budget — served hits whose response belongs to another
    answer group (including every adversarial ``must_miss`` row),
    per scenario and per tenant;
  * staleness — ANY hit served after the row's answer group passed
    its TTL deadline is a stale serve; hard-asserted **zero**.

The ``drift`` trace runs twice for the §14.3 conformal contrast:
once with the fixed per-tenant *learned* threshold (calibrated on
phase-1 pairs — it must LEAK once the negative band drifts above it)
and once with conformal hit calibration on (the recency-window floor
must pull the false-hit rate back under the scenario budget).  Both
outcomes are hard asserts: the bench fails if the learned arm stops
leaking (the scenario lost its teeth) or the conformal arm leaks.

Every replay audits each served hit against trace ground truth and
feeds the verdict to ``FeedbackLoop.observe_hit_audit`` — the §14.3
channel that de-censors the score stream above the threshold.

Results append to ``results/BENCH_scenarios.json`` (override path
with ``BENCH_SCENARIOS_JSON``; set it empty to skip writing).
``results/make_tables.py scenarios`` renders the table;
``scripts/check_bench_trajectory.py`` gates regressions per scenario.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from scenarios import SCENARIOS, ScenarioTrace, build  # noqa: E402

from repro.cache_service import (  # noqa: E402
    CacheConfig, CacheRequest, CacheService, LearningConfig,
    StalenessConfig, TieringConfig,
)
from repro.cache_service.feedback import FeedbackConfig  # noqa: E402

# hard-assert ledger: every claim this bench certifies lands in
# "checked"; anything environment-skipped lands in "skipped" with the
# reason.  check_bench_trajectory.py cross-checks the owed names.
_ASSERTS = {"checked": [], "skipped": []}

OWED_ASSERTS = (
    "scenario_zero_stale_serves",
    "scenario_false_hit_budgets",
    "drift_learned_threshold_leaks",
    "drift_conformal_holds_budget",
    "adversarial_must_miss_budget",
    "ttl_expiry_enforced",
    "ttl_prewindow_hits",
)


def _assert_checked(name, cond, msg=""):
    assert cond, f"[{name}] {msg}"
    if name not in _ASSERTS["checked"]:
        _ASSERTS["checked"].append(name)


# per-scenario tier sizing: ttl_churn deliberately squeezes the hot
# tier so live-but-doomed entries demote through warm (and capture
# into cold) while their deadline runs — expiry must hold in every
# tier, not just where the row was born.
def _tiering(name: str) -> TieringConfig:
    if name == "ttl_churn":
        return TieringConfig(hot_capacity=32, warm_capacity=512,
                             n_clusters=8, bucket=64, n_probe=8,
                             cold_capacity=1024)
    return TieringConfig(hot_capacity=2048, warm_capacity=4096,
                         n_clusters=8, bucket=256, n_probe=8)


def _service(trace: ScenarioTrace, clock, *, conformal: bool):
    cfg = CacheConfig(
        dim=trace.dim,
        threshold=trace.threshold,
        tiering=_tiering(trace.name),
        learning=LearningConfig(
            conformal=conformal,
            # a small split so the floor activates off calibration-scale
            # traffic; the window/alpha defaults are the serving ones
            feedback=FeedbackConfig(conformal_min=16)),
        staleness=StalenessConfig(clock=lambda: clock["t"]),
    )
    svc = CacheService(cfg)
    for tenant, (scores, labels) in trace.calibration.items():
        budget = float(trace.meta.get("max_false_hit_rate", 0.02))
        svc.calibrate_tenant(tenant, scores, labels,
                             max_false_hit_rate=budget)
    return svc


def replay(trace: ScenarioTrace, *, conformal: bool, audit: bool = True):
    """Run one trace through a fresh service; returns the scored row."""
    clock = {"t": 0.0}
    svc = _service(trace, clock, conformal=conformal)
    answer = {}                      # gid committed at least once
    deadline = {}                    # gid -> latest live TTL deadline
    n_q = hits = false_hits = stale = 0
    per_tenant = {}                  # tenant -> [queries, false_hits]
    timed, compile_sizes = [], set()
    expired_masked = ttl_stamped = expired_reaped = 0
    prewin_hits = prewin_total = 0   # ttl_churn inside-deadline repeats

    for step in trace.steps:
        clock["t"] = float(step.t)
        B = len(step.tenants)
        req = CacheRequest.build(step.embs, step.tenants, ttl=step.ttl)
        t0 = time.perf_counter()
        plan = svc.plan(req, coalesce=False)
        np.asarray(plan.hit)         # force any async dispatch home
        dt = time.perf_counter() - t0
        if B in compile_sizes:
            timed.append(dt / B * 1e6)
        else:
            compile_sizes.add(B)     # first sight of this shape: jit
        expired_masked += plan.expired_masked

        responses = [None] * B
        for i in range(B):
            gid = int(step.group[i])
            tn = int(step.tenants[i])
            own = f"ans-g{gid}"
            n_q += 1
            pt = per_tenant.setdefault(tn, [0, 0])
            pt[0] += 1
            if plan.hit[i]:
                hits += 1
                served = plan.responses[i]
                is_dup = served == own
                if is_dup:
                    if deadline.get(gid, np.inf) < step.t:
                        stale += 1
                else:
                    false_hits += 1
                    pt[1] += 1
                if audit and conformal:
                    svc.feedback.observe_hit_audit(
                        tn, float(plan.scores[i]), is_dup)
                # pre-deadline repeats in ttl_churn must keep hitting
                if (trace.name == "ttl_churn" and step.ttl is None
                        and not step.must_miss[i]):
                    prewin_hits += 1
            else:
                responses[i] = own
            if (trace.name == "ttl_churn" and step.ttl is None
                    and not step.must_miss[i]):
                prewin_total += 1

        receipt = svc.commit(plan, responses)
        ttl_stamped += receipt.ttl_stamped
        admitted = np.asarray(plan.admit, bool) & ~np.asarray(
            plan.hit, bool)
        ttl_col = (np.asarray(step.ttl, np.float32)
                   if step.ttl is not None else None)
        for i in np.flatnonzero(admitted):
            gid = int(step.group[i])
            answer[gid] = True
            if ttl_col is None or not np.isfinite(ttl_col[i]):
                deadline[gid] = np.inf
            else:
                deadline[gid] = max(deadline.get(gid, -np.inf),
                                    float(step.t) + float(ttl_col[i]))
        report = svc.maintenance()
        expired_reaped += report.expired_reaped

    timed_a = np.asarray(timed) if timed else np.asarray([0.0])
    row = {
        "scenario": trace.name,
        "mode": "conformal" if conformal else "learned",
        "seed": trace.seed,
        "dim": trace.dim,
        "n_steps": len(trace.steps),
        "n_queries": n_q,
        "hits": hits,
        "hit_rate": hits / max(n_q, 1),
        "false_hits": false_hits,
        "false_hit_rate": false_hits / max(n_q, 1),
        "false_hit_budget": trace.false_hit_budget,
        "stale_serves": stale,
        "p50_us_per_row": float(np.percentile(timed_a, 50)),
        "p99_us_per_row": float(np.percentile(timed_a, 99)),
        "timed_batches": len(timed),
        "ttl_stamped": ttl_stamped,
        "expired_masked": expired_masked,
        "expired_reaped": expired_reaped,
        "per_tenant_false_hit_rate": {
            str(t): (fh / q if q else 0.0)
            for t, (q, fh) in sorted(per_tenant.items())},
        "per_tenant_queries": {str(t): q for t, (q, _)
                               in sorted(per_tenant.items())},
    }
    if trace.name == "ttl_churn":
        row["prewindow_hit_rate"] = prewin_hits / max(prewin_total, 1)
    if conformal:
        cs = svc.feedback.conformal_state()
        row["conformal_floors"] = {
            str(t): v["floor"] for t, v in cs["tenants"].items()
            if v["floor"] is not None}
        row["hit_audits"] = cs["hit_audits"]
        row["audited_false_hits"] = cs["audited_false_hits"]
    return row


def _check_budget(row, min_tenant_q):
    """Per-scenario AND per-tenant false-hit budget."""
    b = row["false_hit_budget"]
    assert row["false_hit_rate"] <= b, (
        f"{row['scenario']}: false-hit rate {row['false_hit_rate']:.4f} "
        f"over budget {b}")
    for t, r in row["per_tenant_false_hit_rate"].items():
        if row["per_tenant_queries"][t] >= min_tenant_q:
            assert r <= b, (f"{row['scenario']} tenant {t}: per-tenant "
                            f"false-hit rate {r:.4f} over budget {b}")


def bench_scenarios(names=None, seed=0, dim=64, smoke=False):
    """Yields one scored row per (scenario, mode) replay."""
    _ASSERTS["checked"].clear()
    _ASSERTS["skipped"].clear()
    names = list(names or SCENARIOS)
    min_tenant_q = 20 if smoke else 100
    rows = []
    for name in names:
        trace = build(name, seed=seed, dim=dim, smoke=smoke)
        if name == "drift":
            # the §14.3 contrast: same trace, fixed learned threshold
            # vs conformal floor.  The leak is part of the spec.
            fixed = replay(trace, conformal=False)
            _assert_checked(
                "drift_learned_threshold_leaks",
                fixed["false_hit_rate"] > trace.false_hit_budget,
                f"calibrated-but-fixed threshold no longer leaks under "
                f"drift ({fixed['false_hit_rate']:.4f} <= "
                f"{trace.false_hit_budget}); the scenario lost its "
                f"teeth — retune the distractor band")
            rows.append(fixed)
            yield fixed
            conf = replay(trace, conformal=True)
            _assert_checked(
                "drift_conformal_holds_budget",
                conf["false_hit_rate"] <= trace.false_hit_budget,
                f"conformal floor leaked {conf['false_hit_rate']:.4f} > "
                f"budget {trace.false_hit_budget}")
            _check_budget(conf, min_tenant_q)
            rows.append(conf)
            yield conf
            continue
        row = replay(trace, conformal=True)
        _check_budget(row, min_tenant_q)
        _ASSERTS["checked"].append("scenario_false_hit_budgets") \
            if "scenario_false_hit_budgets" not in _ASSERTS["checked"] \
            else None
        if name == "adversarial":
            _assert_checked(
                "adversarial_must_miss_budget",
                row["false_hit_rate"] <= trace.false_hit_budget,
                f"near-duplicate paraphrases leaked "
                f"{row['false_hit_rate']:.4f}")
        if name == "ttl_churn":
            _assert_checked(
                "ttl_expiry_enforced",
                row["stale_serves"] == 0 and row["ttl_stamped"] > 0
                and row["expired_masked"] > 0
                and row["expired_reaped"] > 0,
                f"TTL machinery not engaged: stamped="
                f"{row['ttl_stamped']} masked={row['expired_masked']} "
                f"reaped={row['expired_reaped']} "
                f"stale={row['stale_serves']}")
            _assert_checked(
                "ttl_prewindow_hits",
                row["prewindow_hit_rate"] >= 0.9,
                f"inside-deadline repeats only hit at "
                f"{row['prewindow_hit_rate']:.3f}")
        rows.append(row)
        yield row
    _assert_checked(
        "scenario_zero_stale_serves",
        all(r["stale_serves"] == 0 for r in rows),
        "stale serve(s) slipped through plan-time expiry masking: "
        + json.dumps({r["scenario"]: r["stale_serves"]
                      for r in rows if r["stale_serves"]}))
    _assert_checked(
        "scenario_false_hit_budgets",
        all(r["false_hit_rate"] <= r["false_hit_budget"]
            for r in rows if r["mode"] == "conformal"),
        "a conformal-mode scenario is over its false-hit budget")


def _json_path():
    env = os.environ.get("BENCH_SCENARIOS_JSON")
    if env is not None:
        return Path(env) if env else None
    return Path(__file__).resolve().parent.parent \
        / "results" / "BENCH_scenarios.json"


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="short traces (CI-sized); same asserts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--scenario", action="append", default=None,
                    choices=sorted(SCENARIOS),
                    help="run only these (repeatable); default: all")
    args = ap.parse_args(argv)
    if args.scenario:
        owed = {"scenario_zero_stale_serves",
                "scenario_false_hit_budgets"}
        if "drift" in args.scenario:
            owed |= {"drift_learned_threshold_leaks",
                     "drift_conformal_holds_budget"}
        if "adversarial" in args.scenario:
            owed.add("adversarial_must_miss_budget")
        if "ttl_churn" in args.scenario:
            owed |= {"ttl_expiry_enforced", "ttl_prewindow_hits"}
        for name in sorted(set(OWED_ASSERTS) - owed):
            _ASSERTS["skipped"].append(
                {"name": name, "reason":
                 "scenario subset via --scenario"})
    rows = []
    import jax
    for row in bench_scenarios(args.scenario, seed=args.seed,
                               dim=args.dim, smoke=args.smoke):
        rows.append(row)
        print(f"  {row['scenario']:>14s}/{row['mode']:<9s} "
              f"q={row['n_queries']:>5d} hit={row['hit_rate']:.3f} "
              f"false={row['false_hit_rate']:.4f}"
              f"(<={row['false_hit_budget']}) "
              f"stale={row['stale_serves']} "
              f"p99={row['p99_us_per_row']:.0f}us/row")
    # --scenario subsets skip cross-scenario asserts recorded above;
    # a full run must come out owing nothing
    if not args.scenario:
        missing = set(OWED_ASSERTS) - set(_ASSERTS["checked"])
        assert not missing, f"owed asserts never ran: {sorted(missing)}"
    payload = {
        "bench": "scenarios",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "smoke": bool(args.smoke),
        "seed": args.seed,
        "dim": args.dim,
        "checked_asserts": list(_ASSERTS["checked"]),
        "skipped_asserts": list(_ASSERTS["skipped"]),
        "rows": rows,
    }
    path = _json_path()
    if path is not None:
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(payload, indent=1, sort_keys=True)
                        + "\n")
        print(f"wrote {path} ({len(rows)} rows)")
    else:
        print("BENCH_SCENARIOS_JSON empty — not writing results")


if __name__ == "__main__":
    main()
