"""Phi-3-mini-3.8B — compact dense decoder.

[arXiv:2404.14219]  32L, d_model=3072, 32 heads, kv=32 (MHA),
d_ff=8192, vocab=32064.  RoPE + SwiGLU + RMSNorm, tied embeddings.
"""
from repro.configs.base import ModelConfig, LayerSpec, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    source="arXiv:2404.14219",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    use_rope=True,
    tie_embeddings=True,
    period=(LayerSpec(ATTN, DENSE),),
))
