"""CacheService — the serving-path facade over the tiered store.

Replaces bare ``SemanticCache`` in front of the LLM engine.  The host
half owns response strings (a dict keyed by value id, garbage-collected
from the eviction reports every device op returns) and the per-tenant
policy table; the device half is `tiers`: a hot exact store, a warm IVF
ring, and a single jitted cascaded lookup.

Lifecycle of an entry:

  insert (admitted miss) -> hot tier -> [cold] demotion flush -> warm
  ring -> [ring wraps or tenant evicted] -> value id reported back ->
  host frees the response string.

The hot tier flushes its ``flush_size`` coldest rows to the warm ring
whenever occupancy crosses ``flush_watermark``; every
``rebuild_every``-th flush re-clusters the warm IVF (jittable k-means).
Between rebuilds the warm lookup scans a fixed tail window sized to
cover everything appended since the last rebuild, so recall does not
dip while the index is stale.

Serving surface (DESIGN.md §7): the typed ``CacheBackend`` lifecycle —
``plan(CacheRequest) -> CachePlan`` (read side: cascade verdicts, hit
responses, admission pre-decision, miss coalescing) then
``commit(plan, responses) -> CommitReceipt`` (write side: admissions,
demotion flush, GC, maintenance obligations).  With
``background_rebuild=True`` the warm IVF re-clusters double-buffered:
a shadow index builds on a host thread from a snapshot while lookups
keep reading the published index, and ``maintenance()`` performs the
atomic publish; the tail window covers every row appended since the
*snapshot*, so recall never dips during the overlap.  The legacy
``lookup(embs) / insert(embs, responses)`` calls remain as deprecated
shims delegating to plan/commit.
"""
from __future__ import annotations

import threading
import time
import warnings
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache_service import tiers
from repro.cache_service.feedback import FeedbackAccumulator, FeedbackConfig
from repro.cache_service.policy import PolicyTable, TenantPolicy
from repro.cache_service.protocol import (
    CacheCapabilities, CachePlan, CacheRequest, CommitReceipt,
    MaintenanceReport, TenantArg, coalesce_misses, ungrouped_misses,
)
from repro.core.calibration import Calibration


class CacheService:
    supports_tenants = True          # legacy sniffing hook; see DESIGN.md §7

    def __init__(self, dim: int, *, hot_capacity: int = 1024,
                 warm_capacity: int = 16384, n_clusters: int = 64,
                 bucket: int = 256, n_probe: int = 8, topk: int = 1,
                 threshold: float = 0.85, admission_margin: float = 0.0,
                 flush_watermark: float = 0.85,
                 flush_size: Optional[int] = None, rebuild_every: int = 1,
                 kmeans_iters: int = 4, seed: int = 0,
                 fused: bool = False, background_rebuild: bool = False,
                 mesh=None, shard_axis: str = "model",
                 warm_dtype: str = "float32",
                 learned_admission: bool = False,
                 feedback_config: Optional[FeedbackConfig] = None):
        """Build the tiered service.

        Tail invariant (see ``tiers.warm_query``): rows demoted into the
        warm ring stay unindexed until the next IVF rebuild and are only
        reachable through the brute-force tail window over the last
        ``tail`` ring writes.  The window is sized
        ``tail = flush_size * rebuild_every`` so that every row
        appended between rebuilds is covered — that product therefore
        must not exceed ``warm_capacity``.  When it does, the window is
        clamped to ``warm_capacity`` and ``_do_flush`` forces rebuilds
        earlier than ``rebuild_every`` would suggest (correct, but the
        configured cadence is unattainable); a warning is emitted at
        construction instead of silently accepting the config.  In the
        sharded tier every quantity in the invariant divides by the
        shard count — each flush lands ``flush_size/shards`` rows per
        shard ring, so the window, the clamp and the warning are all
        per shard.

        ``fused=True`` routes the cascade through the fused Pallas
        lookup kernel (`kernels/cascade_lookup`) on TPU — subject to
        the kernel's VMEM budget: the warm slice must fit on-chip
        (DESIGN.md §3.1).  On CPU the flag falls back to the same
        four-op math, so it never changes results or CPU latency.

        ``background_rebuild=True`` double-buffers the IVF rebuild
        (DESIGN.md §7): flushes that would have re-clustered inline
        instead start a shadow build on a host thread; lookups keep
        reading the published index and ``maintenance()`` swaps the
        finished shadow in.  A flush that would push the unindexed
        backlog past the tail window first joins the in-flight build
        (or re-clusters inline if none is running), so no row is ever
        stranded out of reach.

        ``mesh`` shards the warm tier over its ``shard_axis``
        (DESIGN.md §8): the warm ring/IVF becomes
        ``mesh.shape[shard_axis]`` independent per-shard rings
        (capacity, clusters and the tail window split per shard; flush
        batches round-robin across shards), looked up via shard_map
        with a tiny (Q, k·shards) merge collective.  The hot tier
        stays replicated.  ``warm_dtype="int8"`` scans the warm panel
        from its symmetric per-row int8 quantization (~4x less
        HBM/VMEM bandwidth) and re-scores the selected rows exactly —
        reported scores stay true fp32 cosines; only candidate
        *selection* sees the bounded quantization error.

        ``learned_admission=True`` turns the static per-tenant
        operating points into a feedback loop (DESIGN.md §9): every
        commit labels its miss rows against their stored neighbours
        (duplicate / distinct), a per-tenant reservoir accumulates the
        labeled scores, and ``maintenance()`` re-derives each tenant's
        threshold and admission margin from its own observed stream —
        under hysteresis guards (min samples, max step per refit,
        monotone false-hit budget), so the points drift with the
        workload but never thrash.  ``feedback_config`` tunes the
        guards (implies ``learned_admission``).
        """
        sharded = mesh is not None
        shards = int(mesh.shape[shard_axis]) if sharded else 1
        if warm_dtype not in ("float32", "int8"):
            raise ValueError(f"warm_dtype must be float32|int8, "
                             f"got {warm_dtype!r}")
        if flush_size is None:
            flush_size = max(hot_capacity // 4, 1)
        flush_size = min(flush_size, hot_capacity, warm_capacity)
        if sharded:
            if hot_capacity < shards:
                raise ValueError(
                    f"hot_capacity {hot_capacity} < {shards} shards: one "
                    "demotion flush cannot feed every warm shard")
            # flushes split round-robin over shards: keep them divisible
            flush_size = max(shards, (flush_size // shards) * shards)
            warm_capacity = -(-warm_capacity // shards) * shards
        rebuild_every = max(rebuild_every, 1)
        cap_local = warm_capacity // shards
        flush_local = flush_size // shards
        n_clusters_local = max(n_clusters // shards, 1)
        # every row appended since the last rebuild lies in this window
        # (per shard: each flush lands flush_local rows on each shard)
        if flush_local * rebuild_every > cap_local:
            warnings.warn(
                f"tail window flush_size*rebuild_every ("
                f"{flush_local}*{rebuild_every}="
                f"{flush_local * rebuild_every} per shard) exceeds the "
                f"per-shard warm capacity {cap_local}; clamping and "
                "forcing IVF rebuilds before the unindexed backlog "
                "outgrows the window (the configured rebuild cadence "
                "will not be honored)", stacklevel=2)
        tail = min(flush_local * rebuild_every, cap_local)

        self.dim = dim
        self.hot_capacity = hot_capacity
        self.warm_capacity = warm_capacity
        self.flush_size = flush_size
        self.flush_watermark = flush_watermark
        self.rebuild_every = rebuild_every
        self.topk = topk
        self.background_rebuild = bool(background_rebuild)
        self.warm_shards = shards
        self.warm_dtype = warm_dtype
        self._mesh = mesh
        self._shard_axis = shard_axis
        self._flush_local = flush_local

        self.hot = tiers.init_hot(hot_capacity, dim)
        if sharded:
            self.warm = tiers.place_warm_sharded(
                tiers.init_warm_sharded(shards, cap_local, dim,
                                        n_clusters_local, bucket),
                mesh, shard_axis)
        else:
            self.warm = tiers.init_warm(warm_capacity, dim, n_clusters,
                                        bucket)
        self.policies = PolicyTable(TenantPolicy(threshold, admission_margin))
        self.feedback: Optional[FeedbackAccumulator] = \
            FeedbackAccumulator(feedback_config) \
            if learned_admission or feedback_config is not None else None
        self.responses: Dict[int, str] = {}
        self._next_vid = 0
        self._tail = tail
        self._n_probe = n_probe
        self._epoch = 0              # bumped by evict_tenant (plan staleness)
        self._counters = {
            "lookups": 0, "hot_hits": 0, "warm_hits": 0, "inserts": 0,
            "admission_skips": 0, "demotions": 0, "rebuilds": 0,
            "bg_rebuilds": 0, "evictions": 0, "plans": 0, "commits": 0,
            "stale_commits": 0,
        }
        self._last_rebuild_s = 0.0
        self._rebuild_total_s = 0.0

        # double-buffer state: the shadow thread re-clusters a snapshot;
        # the host publishes (atomic _replace of the index leaves) from
        # _publish_shadow only — lookups always read self.warm
        self._shadow_thread: Optional[threading.Thread] = None
        self._shadow_box: Dict[str, object] = {}

        self.set_fused(fused)
        self._insert = jax.jit(tiers.hot_insert_batch)
        self._touch = jax.jit(tiers.hot_touch)
        self._demote = jax.jit(partial(tiers.demote_coldest, m=flush_size))
        if sharded:
            self._append = jax.jit(tiers.warm_append_sharded)
            self._rebuild = jax.jit(partial(tiers.warm_rebuild_sharded,
                                            iters=kmeans_iters, seed=seed))
        else:
            self._append = jax.jit(tiers.warm_append)
            self._rebuild = jax.jit(partial(tiers.warm_rebuild,
                                            iters=kmeans_iters, seed=seed))
        self._evict_tenant = jax.jit(tiers.evict_tenant)

    def set_fused(self, fused: bool) -> None:
        """Select the cascade execution path (four-op vs fused kernel);
        re-jits the lookup, so flipping it mid-serve costs one trace."""
        self.fused = bool(fused)
        self._lookup = jax.jit(partial(
            tiers.cascade_query, k=self.topk, n_probe=self._n_probe,
            tail=self._tail, fused=self.fused,
            quantized=self.warm_dtype == "int8",
            mesh=self._mesh, axis=self._shard_axis))

    # ------------------------------------------------------------------
    # tenant policy surface
    # ------------------------------------------------------------------
    def set_tenant_policy(self, tenant: int, threshold: float,
                          admission_margin: float = 0.0) -> None:
        self.policies.set(tenant, TenantPolicy(threshold, admission_margin))

    def calibrate_tenant(self, tenant: int, scores, labels,
                         max_false_hit_rate: float = 0.01) -> Calibration:
        """Set this tenant's threshold from its own eval pairs under a
        false-hit budget."""
        return self.policies.calibrate(tenant, scores, labels,
                                       max_false_hit_rate)

    # ------------------------------------------------------------------
    # CacheBackend protocol: plan / commit / maintenance / stats
    # ------------------------------------------------------------------
    def capabilities(self) -> CacheCapabilities:
        return CacheCapabilities(tenants=True, fused_lookup=True,
                                 admission=True,
                                 background_rebuild=self.background_rebuild,
                                 tiered=True,
                                 warm_sharded=self._mesh is not None,
                                 warm_dtype=self.warm_dtype,
                                 learned_admission=self.feedback is not None)

    def plan(self, request: CacheRequest, *,
             coalesce: bool = True) -> CachePlan:
        """Read side: one jitted cascade over both tiers, LRU touch,
        response resolution, admission pre-decision, miss coalescing
        (``coalesce=False`` skips the O(misses²) grouping when the
        caller won't use it — the legacy lookup shim does)."""
        embs = jnp.asarray(request.embeddings)
        qt = request.tenants
        thr = self.policies.thresholds_for(qt)
        res = self._lookup(self.hot, self.warm, embs, jnp.asarray(qt),
                           jnp.asarray(thr))
        self.hot = self._touch(self.hot, res.hot_slots, res.hot_hit)
        hit = np.asarray(res.hit)
        scores = np.asarray(res.scores[:, 0])
        vids = np.asarray(res.value_ids[:, 0]).astype(np.int64)
        hot_hit = np.asarray(res.hot_hit)
        self._counters["plans"] += 1
        self._counters["lookups"] += len(hit)
        self._counters["hot_hits"] += int(hot_hit.sum())
        self._counters["warm_hits"] += int((hit & ~hot_hit).sum())
        responses = [self.responses.get(int(v)) if h else None
                     for h, v in zip(hit, vids)]
        admit = self.policies.pre_decision(qt, scores, hit)
        if self.feedback is not None:
            self.feedback.observe_plan(hit)
        return CachePlan(
            request=request, hit=hit, scores=scores,
            value_ids=np.where(hit, vids, -1), responses=responses,
            admit=admit,
            miss_leader=coalesce_misses(request.embeddings, hit, qt, thr)
            if coalesce else ungrouped_misses(hit),
            epoch=self._epoch,
            margins=np.asarray(thr, np.float32) - scores,
            top_value_ids=vids)

    def commit(self, plan: CachePlan,
               responses: Sequence[Optional[str]]) -> CommitReceipt:
        """Write side: admit planned misses (fresh value ids — a stale
        plan can never resurrect an id freed since plan time), flush if
        over the watermark, GC reported evictions."""
        self._counters["commits"] += 1
        if plan.epoch != self._epoch:
            # an evict_tenant landed between plan and commit; admission
            # stays safe because ids are fresh and strings are only
            # freed off device eviction reports
            self._counters["stale_commits"] += 1
        rows = plan.miss_rows()
        admit = plan.admit[rows]
        texts: List[Optional[str]] = [responses[i] for i in rows]
        for pos in np.nonzero(admit)[0]:
            if texts[pos] is None:
                raise ValueError(
                    f"admitted row {int(rows[pos])} has no response")
        if self.feedback is not None:
            self._observe_feedback(plan, rows, admit, texts)
        vids = np.full(len(rows), -1, np.int64)
        for pos in np.nonzero(admit)[0]:
            vids[pos] = self._next_vid
            self.responses[self._next_vid] = texts[pos]
            self._next_vid += 1
        n_admit = int(admit.sum())
        self._counters["inserts"] += n_admit
        self._counters["admission_skips"] += int((~admit).sum())
        evicted_before = self._counters["evictions"]
        if len(rows):
            self.hot, evicted = self._insert(
                self.hot, jnp.asarray(plan.request.embeddings[rows]),
                jnp.asarray(vids, dtype=jnp.int32),
                jnp.asarray(plan.request.tenants[rows]))
            self._gc(evicted)
            self._maybe_flush()
        return CommitReceipt(
            admitted=n_admit, skipped=int((~admit).sum()),
            evicted=self._counters["evictions"] - evicted_before,
            # a due policy refit is a maintenance obligation exactly
            # like a due rebuild: the pipeline discharges both with one
            # maintenance() call between batches
            rebuild_due=self._rebuild_due()
            or (self.feedback is not None and self.feedback.refit_due()))

    def maintenance(self, block: bool = False) -> MaintenanceReport:
        """Drive the double-buffered rebuild: publish a finished shadow
        index (atomic swap), start one if the backlog calls for it.
        ``block=True`` quiesces: it joins an in-flight build and never
        starts a new one, so the service returns with no rebuild
        running."""
        published = started = False
        wall = 0.0
        if self._shadow_thread is not None and (
                block or not self._shadow_thread.is_alive()):
            wall = self._publish_shadow()
            published = True
        if (not block and self.background_rebuild
                and self._shadow_thread is None and self._tail_pressure()):
            self._start_shadow()
            started = True
        refits_applied = refits_checked = 0
        if self.feedback is not None:
            # online admission learning (DESIGN.md §9): republish every
            # tenant policy whose reservoir survives the hysteresis
            # guards — host-only work, cheap enough for every idle tick
            reports = self.policies.refit(self.feedback)
            refits_checked = len(reports)
            refits_applied = sum(r.applied for r in reports)
        return MaintenanceReport(
            rebuild_started=started, rebuild_published=published,
            rebuild_in_flight=self._shadow_thread is not None,
            rebuild_wall_s=wall,
            refits_applied=refits_applied, refits_checked=refits_checked)

    def stats(self) -> Dict[str, object]:
        """One unified snapshot: lookup/hit/admission counters plus
        rebuild accounting (count, in-flight flag, wall times) and,
        with learned admission on, the feedback-loop state (event and
        refit counters, per-tenant learned operating points)."""
        out = {
            **self._counters,
            "hot_occupancy": self.hot_occupancy,
            "warm_occupancy": self.warm_occupancy,
            "live_responses": len(self.responses),
            "rebuild_in_flight": self._shadow_thread is not None,
            "last_rebuild_s": self._last_rebuild_s,
            "rebuild_total_s": self._rebuild_total_s,
            "warm_shards": self.warm_shards,
            "warm_dtype": self.warm_dtype,
        }
        if self.feedback is not None:
            out.update(self.feedback.state())
            out["learned_policies"] = self.policies.learned_state()
        return out

    # ------------------------------------------------------------------
    # legacy serving surface (deprecated shims over plan/commit)
    # ------------------------------------------------------------------
    def lookup(self, embs, tenant: TenantArg = 0
               ) -> Tuple[np.ndarray, np.ndarray, List[Optional[str]]]:
        """Deprecated: use ``plan``.  embs: (B, D).  Returns
        (hit (B,) bool, score (B,), values)."""
        warnings.warn("CacheService.lookup is deprecated; use "
                      "plan(CacheRequest)", DeprecationWarning, stacklevel=2)
        plan = self.plan(CacheRequest.build(np.asarray(embs), tenant),
                         coalesce=False)
        return plan.hit, plan.scores, plan.responses

    def insert(self, embs, responses: Sequence[str], tenant: TenantArg = 0,
               scores: Optional[np.ndarray] = None) -> int:
        """Deprecated: use ``commit`` on a plan.  Caches miss results;
        ``scores`` (the best same-tenant score each query saw at lookup)
        enables the admission rule; without it every entry is admitted.
        Returns the number admitted."""
        warnings.warn("CacheService.insert is deprecated; use "
                      "commit(plan, responses)", DeprecationWarning,
                      stacklevel=2)
        embs = np.asarray(embs)
        assert embs.shape[0] == len(responses)
        req = CacheRequest.build(embs, tenant)
        admit = self.policies.admit_mask(req.tenants, scores)
        plan = CachePlan.for_insert(req, admit, scores, epoch=self._epoch)
        return self.commit(plan, list(responses)).admitted

    def evict_tenant(self, tenant: int) -> int:
        """Drop every entry of one tenant from both tiers; frees the
        host strings.  Returns the number of entries evicted."""
        self._epoch += 1
        self.hot, self.warm, h_ev, w_ev = self._evict_tenant(
            self.hot, self.warm, jnp.asarray(tenant, jnp.int32))
        return self._gc(h_ev) + self._gc(w_ev)

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _observe_feedback(self, plan: CachePlan, rows: np.ndarray,
                          admit: np.ndarray,
                          texts: List[Optional[str]]) -> None:
        """Label each committed miss against its stored neighbour and
        feed the per-tenant reservoir (DESIGN.md §9): duplicate <=> the
        generated response equals the best same-tenant neighbour's
        stored response (the plan carried its id).  A row with no
        same-tenant candidate is a definite non-duplicate; a row whose
        neighbour string was GC'd between plan and commit is
        unknowable and skipped rather than mislabeled.  Runs before
        commit mints fresh ids, so neighbour lookups only ever see
        plan-era entries.  All event/wasted-admission accounting lives
        on the accumulator (surfaced through ``stats()``)."""
        top = plan.top_value_ids
        if top is None:
            return
        tenants = plan.request.tenants
        for pos, row in enumerate(rows):
            text = texts[pos]
            if text is None:
                continue
            vid = int(top[row])
            if vid < 0:
                dup = False
                score = max(float(plan.scores[row]), -1.0)  # NEG sentinel
            else:
                neighbour = self.responses.get(vid)
                if neighbour is None:
                    continue
                dup = text == neighbour
                score = float(plan.scores[row])
            self.feedback.observe(int(tenants[row]), score, dup,
                                  bool(admit[pos]))

    def _gc(self, evicted) -> int:
        """Free response strings whose ids a device op reported evicted."""
        ids = np.asarray(evicted)
        n = 0
        for v in ids[ids >= 0]:
            if self.responses.pop(int(v), None) is not None:
                n += 1
        self._counters["evictions"] += n
        return n

    def _backlog(self) -> int:
        """Rows appended since the *published* index was built (the
        worst shard's backlog in the sharded tier — each shard has its
        own ring, so the window must cover the deepest one)."""
        return int(np.max(np.asarray(self.warm.total
                                     - self.warm.indexed_total)))

    def _tail_pressure(self) -> bool:
        """One more flush would push the unindexed backlog past the
        tail window — the single rebuild-trigger predicate shared by
        inline flushes, background starts and maintenance()."""
        return self._backlog() + self._flush_local > self._tail

    def _rebuild_due(self) -> bool:
        """A maintenance() call now would publish or start a rebuild."""
        if self._shadow_thread is not None:
            return True
        return self.background_rebuild and self._tail_pressure()

    def _start_shadow(self) -> None:
        """Kick off a shadow re-cluster of a snapshot of the warm tier.
        The snapshot is an immutable pytree, so serving mutations keep
        building fresh states while the thread reads the old one."""
        snapshot = self.warm
        self._shadow_box = box = {}
        rebuild = self._rebuild

        def run() -> None:
            t0 = time.perf_counter()
            try:
                box["warm"] = jax.block_until_ready(rebuild(snapshot))
            except BaseException as e:          # surfaced at publish time
                box["error"] = e
            # stamped in-thread: the build itself, not the idle wait
            # for the next maintenance() tick to publish it
            box["wall"] = time.perf_counter() - t0

        self._shadow_thread = threading.Thread(
            target=run, name="warm-ivf-rebuild", daemon=True)
        self._shadow_thread.start()
        self._counters["bg_rebuilds"] += 1

    def _publish_shadow(self) -> float:
        """Join the shadow thread and atomically swap its index in.

        ``indexed_total`` becomes the snapshot's total, so every row
        appended *after* the snapshot stays covered by the tail window
        — recall never dips across the swap (`tiers.warm_query`'s
        epoch partition keeps slots overwritten post-snapshot out of
        the stale inverted lists).
        """
        assert self._shadow_thread is not None
        self._shadow_thread.join()
        self._shadow_thread = None
        err = self._shadow_box.get("error")
        if err is not None:
            raise RuntimeError("background IVF rebuild failed") from err
        shadow = self._shadow_box["warm"]
        self.warm = tiers.warm_publish_index(self.warm, shadow)
        wall = float(self._shadow_box["wall"])
        self._last_rebuild_s = wall
        self._rebuild_total_s += wall
        self._counters["rebuilds"] += 1
        return wall

    def _rebuild_inline(self) -> None:
        t0 = time.perf_counter()
        self.warm = jax.block_until_ready(self._rebuild(self.warm))
        self._last_rebuild_s = time.perf_counter() - t0
        self._rebuild_total_s += self._last_rebuild_s
        self._counters["rebuilds"] += 1

    def _do_flush(self, rebuild: bool) -> None:
        self.hot, dem = self._demote(self.hot)
        self.warm, evicted = self._append(self.warm, dem)
        self._gc(evicted)
        self._counters["demotions"] += int(np.asarray(dem.mask).sum())
        # the tail window only covers the last `tail` ring writes; a
        # rebuild is forced before the unindexed backlog outgrows it,
        # else demoted rows would silently fall out of reach
        if not self.background_rebuild:
            if rebuild or self._tail_pressure():
                self._rebuild_inline()
            return
        # double-buffered: publish any finished shadow, then make sure
        # the window still covers the backlog before serving resumes
        if self._shadow_thread is not None \
                and not self._shadow_thread.is_alive():
            self._publish_shadow()
        if self._backlog() > self._tail:
            if self._shadow_thread is not None:
                self._publish_shadow()          # blocks: join + swap
            if self._backlog() > self._tail:
                self._rebuild_inline()          # snapshot was too old
        if (rebuild or self._tail_pressure()) \
                and self._shadow_thread is None:
            self._start_shadow()

    def _maybe_flush(self) -> None:
        n_valid = int(np.asarray(self.hot.valid).sum())
        if n_valid >= self.flush_watermark * self.hot_capacity:
            self._do_flush(rebuild=False)

    def flush(self, rebuild: bool = True) -> None:
        """Force one demotion flush now.  ``rebuild=False`` still
        rebuilds if skipping would leave rows beyond the tail window.
        With ``background_rebuild`` the re-cluster runs double-buffered
        (shadow build + later publish) instead of inline."""
        self._do_flush(rebuild)

    # ------------------------------------------------------------------
    @property
    def hot_occupancy(self) -> float:
        return float(np.asarray(self.hot.valid).mean())

    @property
    def warm_occupancy(self) -> float:
        return float(np.asarray(self.warm.valid).mean())

    @property
    def occupancy(self) -> float:
        """Drop-in parity with SemanticCache (fraction of total rows)."""
        n = int(np.asarray(self.hot.valid).sum()) \
            + int(np.asarray(self.warm.valid).sum())
        return n / (self.hot_capacity + self.warm_capacity)

    def __len__(self) -> int:
        return int(np.asarray(self.hot.valid).sum()) \
            + int(np.asarray(self.warm.valid).sum())
