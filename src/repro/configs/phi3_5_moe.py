"""Phi-3.5-MoE (42B total / 6.6B active) — 16-expert top-2 MoE.

[hf:microsoft/Phi-3.5-MoE-instruct]  32L, d_model=4096, 32 heads, kv=8,
expert d_ff=6400, vocab=32064, 16 experts top-2.  Every FFN is MoE.
"""
from repro.configs.base import (
    ModelConfig, LayerSpec, MoEConfig, ATTN, MOE, register,
)

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    use_rope=True,
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=6400),
    period=(LayerSpec(ATTN, MOE),),
))
