"""Baseline embedders — local stand-ins for the paper's comparison rows.

The paper compares LangCache-Embed against OpenAI/Cohere/Titan APIs and
7B-class open models, none of which are callable offline.  These
baselines span the same design space (DESIGN.md §5):

  * ``EncoderEmbedder``  — an *untuned* JAX encoder (any registry
    config).  The untuned ModernBERT config IS the paper's true base
    row; a scaled-up untuned config plays the "big general model" row.
  * ``HashNgramEmbedder`` — character-3-gram hashing (classic cheap
    lexical baseline; roughly what a BM25-ish cache key gives you).
  * ``RandomProjectionEmbedder`` — mean-pooled random token projections
    (the floor: position-free lexical identity only).

All expose ``embed(list[str]) -> (B, D) float32`` (unit-norm) plus a
``name`` for the benchmark tables.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import HashTokenizer
from repro.models import encode, init_lm, split


def _l2(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


class EncoderEmbedder:
    def __init__(self, cfg: ModelConfig, params=None, max_len: int = 32,
                 name: str | None = None, seed: int = 0):
        self.cfg = cfg
        self.max_len = max_len
        self.name = name or f"encoder:{cfg.name}(untuned)"
        if params is None:
            params, _ = split(init_lm(cfg, jax.random.PRNGKey(seed)))
        self.params = params
        self.tok = HashTokenizer(vocab_size=cfg.vocab_size)
        self._encode = jax.jit(lambda p, t, m: encode(p, cfg, t, m))

    def embed(self, texts: List[str], batch_size: int = 64) -> np.ndarray:
        out = []
        for i in range(0, len(texts), batch_size):
            chunk = list(texts[i:i + batch_size])
            n = len(chunk)
            while len(chunk) < batch_size:
                chunk.append("")
            ids, mask = self.tok.encode_batch(chunk, self.max_len)
            e = self._encode(self.params, jnp.asarray(ids), jnp.asarray(mask))
            out.append(np.asarray(e)[:n])
        return np.concatenate(out, 0)


class HashNgramEmbedder:
    name = "hash-3gram"

    def __init__(self, dim: int = 768):
        self.dim = dim

    def embed(self, texts: List[str], batch_size: int = 0) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            s = f"  {t.lower()}  "
            for j in range(len(s) - 2):
                g = s[j:j + 3]
                h = hash_3gram(g)
                out[i, h % self.dim] += 1.0 if (h >> 16) % 2 else -1.0
        return _l2(out)


def hash_3gram(g: str) -> int:
    h = 0xCBF29CE484222325
    for b in g.encode("utf-8"):
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


class RandomProjectionEmbedder:
    name = "random-projection"

    def __init__(self, dim: int = 768, vocab: int = 50368, seed: int = 0):
        self.dim = dim
        self.tok = HashTokenizer(vocab_size=vocab)
        rng = np.random.default_rng(seed)
        self.proj = rng.standard_normal((vocab, dim)).astype(np.float32)
        self.proj /= np.sqrt(dim)

    def embed(self, texts: List[str], batch_size: int = 0) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            ids, mask = self.tok.encode(t, 32)
            v = self.proj[ids[mask]].mean(0) if mask.any() else np.zeros(self.dim)
            out[i] = v
        return _l2(out)
