"""Serving telemetry (DESIGN.md §10): registry semantics, exporter
round-trip, span trees through the full serving pipeline, SLO health,
the batcher's maintenance accounting, and the stats() migration."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.cache_service import CacheService
from repro.core import SemanticCache
from repro.core.embedders import HashNgramEmbedder
from repro.data import HashTokenizer
from repro.obs import (
    DEFAULT_LATENCY_BUCKETS_S, SCHEMA, HealthTracker, MetricsRegistry,
    Telemetry, Tracer, check_overhead_budget, read_jsonl, tenant_label,
    to_jsonl, to_prometheus, validate_lines, write_jsonl,
)
from repro.serving import CachedLLMService


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_counter_label_separation():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", labels=("tenant",))
    c.inc(3, tenant=0)
    c.inc(2, tenant=1)
    c.labels(tenant=0).inc(5)          # handle path == kwargs path
    assert c.total(tenant=0) == 8
    assert c.total(tenant=1) == 2
    assert c.total() == 10
    assert reg.value("req_total") == 10
    assert reg.value("req_total", tenant=1) == 2
    assert reg.value("absent_total") == 0


def test_registry_registration_is_idempotent_but_checked():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x", labels=("tenant",))
    assert reg.counter("x_total", labels=("tenant",)) is a
    with pytest.raises(ValueError):    # kind mismatch
        reg.gauge("x_total", labels=("tenant",))
    with pytest.raises(ValueError):    # label-schema mismatch
        reg.counter("x_total", labels=("stage",))
    with pytest.raises(ValueError):    # typo'd label at the call site
        a.inc(1, tenannt=0)


def test_histogram_bucket_boundaries():
    """A value equal to a bound lands in that bound's bucket (`le` is
    inclusive, the Prometheus convention), strictly-greater values in
    the next; beyond the last bound is the overflow bucket."""
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "lat", buckets=(1.0, 2.0, 4.0))
    s = h.labels()
    for v in (0.5, 1.0, 1.5, 2.0, 2.5, 4.0, 9.0):
        s.observe(v)
    assert s.counts == [2, 2, 2, 1]    # le=1: {0.5,1.0}; le=2: {1.5,2.0}
    assert s.count == 7 and s.vmin == 0.5 and s.vmax == 9.0
    assert s.sum == pytest.approx(20.5)
    with pytest.raises(ValueError):    # unsorted bounds refused
        reg.histogram("bad_seconds", buckets=(2.0, 1.0))


def test_histogram_quantiles_interpolate():
    reg = MetricsRegistry()
    s = reg.histogram("q_seconds", buckets=(1.0, 2.0, 4.0)).labels()
    for v in (0.2, 0.4, 1.2, 1.8, 3.0, 8.0):
        s.observe(v)
    q50 = s.quantile(0.5)
    assert 1.0 <= q50 <= 2.0           # rank 3 lands in the (1, 2] bucket
    # overflow interpolates toward the observed max, stays finite
    assert 4.0 <= s.quantile(1.0) <= 8.0
    assert s.mean == pytest.approx(sum((0.2, 0.4, 1.2, 1.8, 3.0, 8.0)) / 6)
    # aggregate() over label subsets is a vector add of fixed buckets
    h2 = reg.histogram("stage_h_seconds", labels=("stage", "tenant"),
                       buckets=(1.0, 2.0))
    h2.observe(0.5, stage="plan", tenant="0")
    h2.observe(0.7, stage="plan", tenant="1")
    h2.observe(1.5, stage="commit", tenant="0")
    assert h2.aggregate(stage="plan").count == 2
    assert h2.aggregate(tenant="0").count == 2
    assert h2.aggregate().count == 3


def test_tenant_label():
    assert tenant_label(np.zeros(4, np.int32)) == "0"
    assert tenant_label(np.array([3, 3, 3])) == "3"
    assert tenant_label(np.array([1, 2])) == "mixed"
    assert tenant_label(np.array([], np.int32)) == "none"
    assert tenant_label(7) == "7"


def test_snapshot_under_concurrent_writer():
    """snapshot() from a drain thread while the single writer records:
    every snapshot is well-formed JSON with monotone counters (the
    torn-across-metrics-never-within-a-value contract)."""
    reg = MetricsRegistry()
    c = reg.counter("w_total").labels()
    h = reg.histogram("w_seconds", buckets=DEFAULT_LATENCY_BUCKETS_S
                      ).labels()
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            c.inc()
            h.observe(3e-3)

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    try:
        last = 0
        for _ in range(100):
            snap = reg.snapshot()
            json.dumps(snap)                       # JSON-able as-is
            cur = snap["metrics"]["w_total"]["series"][0]["value"]
            assert cur >= last                     # counters never rewind
            last = cur
    finally:
        stop.set()
        t.join()
    # quiescent snapshot is internally consistent and validates clean
    snap = reg.snapshot()
    s = snap["metrics"]["w_seconds"]["series"][0]
    assert sum(s["buckets"]) == s["count"]
    assert validate_lines(to_jsonl(snap).splitlines()) == []


def test_export_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a_total", labels=("tenant",)).inc(4, tenant=2)
    reg.gauge("b_occupancy").set(0.75)
    reg.histogram("c_seconds", labels=("stage",),
                  buckets=(1e-3, 1.0)).observe(2e-3, stage="plan")
    path = tmp_path / "metrics.jsonl"
    write_jsonl(path, reg.snapshot(), meta={"run": "t"})
    write_jsonl(path, reg.snapshot(), meta={"run": "t"}, append=True)
    metas, series = read_jsonl(path)
    assert len(metas) == 2 and metas[0]["schema"] == SCHEMA
    assert metas[0]["run"] == "t"
    by_name = {(s["name"], tuple(sorted(s["labels"].items()))): s
               for s in series}
    assert by_name[("a_total", (("tenant", "2"),))]["value"] == 4
    assert by_name[("b_occupancy", ())]["value"] == 0.75
    hist = by_name[("c_seconds", (("stage", "plan"),))]
    assert hist["count"] == 1 and sum(hist["buckets"]) == 1
    assert validate_lines(path.read_text().splitlines()) == []
    prom = to_prometheus(reg.snapshot())
    assert '# TYPE a_total counter' in prom
    assert 'a_total{tenant="2"} 4' in prom
    assert 'c_seconds_bucket{stage="plan",le="+Inf"} 1' in prom
    assert 'c_seconds_count{stage="plan"} 1' in prom


def test_export_validate_catches_corruption():
    reg = MetricsRegistry()
    reg.counter("ok_total").inc()
    lines = to_jsonl(reg.snapshot()).splitlines()
    assert validate_lines(lines) == []
    assert validate_lines(["not json"])
    assert validate_lines(['{"kind": "counter"}'])   # no leading meta
    bad = json.loads(lines[1])
    bad["value"] = "NaN-ish"
    assert validate_lines([lines[0], json.dumps(bad)])


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_ring():
    tr = Tracer(keep=2)
    with tr.span("request", tenant="0") as root:
        with tr.span("embed"):
            pass
        with tr.span("plan"):
            with tr.span("warm_probe"):
                pass
    assert tr.current() is None
    assert tr.last_root() is root
    assert root.stage_names() == ["embed", "plan"]
    assert root.find("warm_probe") is not None
    assert [s.name for s in root.walk()] == [
        "request", "embed", "plan", "warm_probe"]
    d = root.to_dict()
    assert d["name"] == "request" and len(d["children"]) == 2
    assert d["duration_s"] >= d["children"][0]["duration_s"]
    for i in range(3):                 # ring keeps the 2 most recent
        with tr.span(f"r{i}"):
            pass
    assert [s.name for s in tr.roots()] == ["r1", "r2"]
    assert [s.name for s in tr.drain()] == ["r1", "r2"]
    assert tr.roots() == []


def test_disabled_tracer_is_inert():
    tel = Telemetry.disabled()
    with tel.tracer.span("request") as s:
        assert s.duration_s == 0.0
    assert tel.tracer.last_root() is None
    tel.registry.counter("x_total").inc(5)
    assert tel.registry.value("x_total") == 0
    assert tel.health is None


# ---------------------------------------------------------------------------
# health / SLO budget
# ---------------------------------------------------------------------------

def test_health_rates_and_budget_burn():
    h = HealthTracker(budget_for=lambda t: 0.10)
    h.observe_plan(np.zeros(8, np.int32), np.array([1, 1, 1, 1, 0, 0, 0, 0],
                                                   bool))
    for dup in (True, True, False, False):
        h.observe_admission(0, duplicate=dup, admitted=True)
    snap = h.snapshot()
    t0 = snap["tenants"]["0"]
    assert t0["hit"]["windowed"] == pytest.approx(0.5)
    assert t0["wasted_admission"]["windowed"] == pytest.approx(0.5)
    assert t0["budget"] == pytest.approx(0.10)
    assert t0["budget_burn"] == pytest.approx(5.0)    # 0.5 / 0.1
    # rebuild overlap accounting
    h.observe_rebuild_start(plans_now=10)
    assert h.snapshot()["rebuild"]["in_overlap"]
    h.observe_rebuild_publish(plans_now=17, stall_s=2e-3)
    reb = h.snapshot()["rebuild"]
    assert reb["last_overlap_plans"] == 7 and reb["publishes"] == 1
    assert reb["stall_p99_s"] == pytest.approx(2e-3)
    # drain publishes the gauges into a registry
    reg = MetricsRegistry()
    h.drain(reg)
    assert reg.value("slo_budget_burn", tenant=0) == pytest.approx(5.0)
    assert reg.value("slo_hit_rate", tenant=0, kind="window") \
        == pytest.approx(0.5)
    assert reg.value("rebuild_overlap_plans") == 7


def test_overhead_budget_check():
    assert check_overhead_budget(1.0, 1.0) == []
    assert check_overhead_budget(1.02e-3, 1e-3) == []   # inside ratio+floor
    assert check_overhead_budget(2.0, 1.0)              # 2x: violation
    msg = check_overhead_budget(1.2e-1, 1e-1)
    assert msg and "over budget" in msg[0]


# ---------------------------------------------------------------------------
# the span tree + registry deltas through the full pipeline
# ---------------------------------------------------------------------------

def _service(fused: bool):
    tel = Telemetry()
    cache = CacheService(dim=32, hot_capacity=16, warm_capacity=256,
                         n_clusters=4, bucket=32, n_probe=2,
                         threshold=0.93, flush_watermark=0.5, flush_size=4,
                         kmeans_iters=2, seed=0, fused=fused,
                         background_rebuild=True, telemetry=tel)
    embedder = HashNgramEmbedder(dim=32)
    svc = CachedLLMService(lambda qs: embedder.embed(qs), cache, None,
                           HashTokenizer(vocab_size=512))
    return tel, cache, svc


@pytest.mark.parametrize("fused", [False, True])
def test_handle_produces_complete_span_tree(fused):
    """One request through handle() yields the full §10.2 span tree —
    embed/plan/generate/commit and, once the flush watermark trips,
    maintenance — plus tenant-labeled registry deltas, for both the
    fused and unfused cascade paths."""
    tel, cache, svc = _service(fused)
    queries = [f"distinct query number {i} about topic {i}"
               for i in range(12)]
    svc.handle(queries, tenant=3)

    root = tel.tracer.last_root()
    assert root is not None and root.name == "request"
    assert root.attrs["tenant"] == "3" and root.attrs["n"] == 12
    stages = root.stage_names()
    assert stages[:4] == ["embed", "plan", "generate", "commit"]
    # 12 admissions over a 16-slot hot tier crossed the 0.5 watermark,
    # so the receipt demanded maintenance and its span is in the tree
    assert "maintenance" in stages
    gen = root.find("generate")
    assert gen.attrs["n_leaders"] >= 1
    assert sum(c.duration_s for c in root.children) <= root.duration_s * 1.5

    reg = tel.registry
    assert reg.value("serve_requests_total", tenant=3) == 12
    hits = reg.value("serve_hits_total", tenant=3)
    misses = reg.value("serve_misses_total", tenant=3)
    assert hits + misses == 12
    assert reg.value("cache_plans_total") == 1
    assert reg.value("cache_commits_total") == 1
    assert reg.value("cache_admissions_total", tenant=3,
                     decision="admitted") >= 1
    assert reg.value("serve_maintenance_calls_total") == 1

    # the stage histogram saw each stage exactly once, tenant-labeled
    stage_h = tel.stage_histogram()
    for stage in ("embed", "plan", "generate", "commit"):
        agg = stage_h.aggregate(stage=stage)
        assert agg.count == 1, stage
        assert stage_h.aggregate(stage=stage, tenant="3").count == 1
    assert stage_h.aggregate(stage="maintenance").count >= 1

    # repeated batch: hits this time, span tree again complete
    svc.handle(queries, tenant=3)
    assert reg.value("serve_hits_total", tenant=3) > hits
    assert tel.tracer.last_root().stage_names()[:4] == [
        "embed", "plan", "generate", "commit"]


def test_flat_cache_shares_telemetry_with_engine():
    """The engine adopts the backend's bundle, so one registry sees
    both serve_* and cache_* without explicit wiring."""
    tel = Telemetry()
    cache = SemanticCache(capacity=64, dim=32, threshold=0.93,
                          telemetry=tel)
    embedder = HashNgramEmbedder(dim=32)
    svc = CachedLLMService(lambda qs: embedder.embed(qs), cache, None,
                           HashTokenizer(vocab_size=512))
    assert svc.telemetry is tel
    svc.handle(["alpha beta", "gamma delta"])
    assert tel.registry.value("serve_requests_total") == 2
    assert tel.registry.value("cache_plans_total") == 1
    root = tel.tracer.last_root()
    assert root.stage_names()[:4] == ["embed", "plan", "generate",
                                      "commit"]


# ---------------------------------------------------------------------------
# stats_snapshot schema + batcher accounting
# ---------------------------------------------------------------------------

def test_stats_snapshot_schema():
    _, cache, svc = _service(fused=False)
    svc.handle(["one query", "two query"], tenant=1)
    snap = cache.stats_snapshot()
    assert snap.schema == SCHEMA
    d = snap.to_dict()
    assert set(d) >= {"schema", "traffic", "admission", "tiers",
                      "rebuild", "health"}
    assert d["traffic"]["plans"] == 1
    assert d["admission"]["admitted"] >= 1
    assert d["health"]["tenants"]["1"]["hit"]["events"] == 2
    # v2.0: the flat stats() view is gone — the typed snapshot is the
    # only stats surface
    assert not hasattr(cache, "stats")


def test_batcher_idle_tick_accounts_exactly_once():
    """Every tick with a maintenance hook increments exactly one of
    runs/skips (the satellite regression: an idle tick must never
    count as both, or as neither)."""
    from repro.configs import get_config
    from repro.models import init_lm, split
    from repro.serving import ContinuousBatcher, Request

    cfg = get_config("phi3-mini-3.8b").reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    b = ContinuousBatcher(cfg, pv, n_slots=2, max_len=48, prompt_len=8,
                          maintenance=lambda: "ran",
                          maintenance_max_interval=4)
    rng = np.random.default_rng(5)
    before = (b.maintenance_runs, b.maintenance_skips)
    assert before == (0, 0)
    b.tick()                                 # no work at all: idle
    assert (b.maintenance_runs, b.maintenance_skips) == (1, 0)
    assert b.last_maintenance == "ran"
    for i in range(6):
        b.submit(Request(uid=i, prompt=rng.integers(
            4, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=4))
    while b.pending or any(r is not None for r in b.slot_req):
        runs0, skips0 = b.maintenance_runs, b.maintenance_skips
        b.tick()
        assert (b.maintenance_runs - runs0) \
            + (b.maintenance_skips - skips0) == 1
    st = b.stats()
    assert st["ticks"] == b.maintenance_runs + b.maintenance_skips
    assert st["finished"] == 6
    assert st["admission_wait_p50_s"] >= 0.0
