"""The typed construction surface (DESIGN.md §14.4).

``CacheConfig`` is a frozen dataclass of grouped sub-configs, each
validating its own fields at construction; the legacy flat-kwargs
constructor maps onto it through ``CacheConfig.from_kwargs`` (kept one
release, warns ``DeprecationWarning`` once per process).  These tests
pin the contract: field validation fires at dataclass construction,
the legacy mapping covers every renamed key, unknown kwargs are a
``TypeError`` not a silent drop, and the config path refuses to mix
with flat kwargs."""
import dataclasses
import warnings

import pytest

from repro.cache_service import (
    CacheConfig, CacheService, EnsembleConfig, LearningConfig,
    ShardingConfig, StalenessConfig, TieringConfig,
)
from repro.cache_service.feedback import FeedbackConfig


# ---------------------------------------------------------------------------
# field validation fires in __post_init__
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(dim=0), dict(dim=-4),
    dict(dim=16, topk=0),
    dict(dim=16, threshold=0.0), dict(dim=16, threshold=1.2),
    dict(dim=16, admission_margin=-0.1),
])
def test_cache_config_rejects_bad_top_level(bad):
    with pytest.raises(ValueError):
        CacheConfig(**bad)


@pytest.mark.parametrize("bad", [
    dict(hot_capacity=0), dict(warm_capacity=0),
    dict(n_clusters=0), dict(bucket=0), dict(n_probe=0),
    dict(flush_watermark=0.0), dict(flush_watermark=1.5),
    dict(flush_size=0), dict(rebuild_every=0),
    dict(warm_dtype="bfloat16"), dict(warm_block=0),
    dict(cold_capacity=-1),
])
def test_tiering_config_rejects_bad_fields(bad):
    with pytest.raises(ValueError):
        TieringConfig(**bad)


def test_sub_config_validation():
    with pytest.raises(ValueError):
        ShardingConfig(shard_axis="")
    with pytest.raises(ValueError):
        EnsembleConfig(embedders=0)
    with pytest.raises(ValueError):
        StalenessConfig(default_ttl=0.0)
    # frozen: configs are immutable once built
    with pytest.raises(dataclasses.FrozenInstanceError):
        CacheConfig(dim=16).dim = 32


# ---------------------------------------------------------------------------
# legacy flat-kwargs mapping
# ---------------------------------------------------------------------------

def test_from_kwargs_groups_every_renamed_key():
    fb = FeedbackConfig()
    cfg = CacheConfig.from_kwargs(
        32, threshold=0.9, hot_capacity=64, warm_capacity=256,
        fused=True, cold_capacity=512, learned_admission=True,
        feedback_config=fb,                  # renamed -> learning.feedback
        embedders=3, ensemble_weights=None,  # renamed -> ensemble.weights
        default_ttl=30.0,
    )
    assert cfg.dim == 32 and cfg.threshold == 0.9
    assert cfg.tiering.hot_capacity == 64
    assert cfg.tiering.fused and cfg.tiering.cold_capacity == 512
    assert cfg.learning.learned_admission
    assert cfg.learning.feedback is fb
    assert cfg.ensemble.embedders == 3
    assert cfg.staleness.default_ttl == 30.0


def test_from_kwargs_rejects_unknown_keyword():
    with pytest.raises(TypeError, match="unknown CacheService kwargs"):
        CacheConfig.from_kwargs(32, capacty=64)    # typo must not be dropped


def test_legacy_kwargs_construction_warns_once():
    CacheService._kwargs_warned = False            # reset the process latch
    with pytest.warns(DeprecationWarning, match="flat-kwargs"):
        svc = CacheService(dim=16, hot_capacity=8, warm_capacity=32,
                           n_clusters=2, bucket=16)
    assert svc.config.tiering.hot_capacity == 8
    with warnings.catch_warnings():
        warnings.simplefilter("error")             # second build: silent
        CacheService(dim=16, hot_capacity=8, warm_capacity=32,
                     n_clusters=2, bucket=16)


def test_config_path_rejects_extra_kwargs():
    cfg = CacheConfig(dim=16)
    with pytest.raises(TypeError, match="no extra kwargs"):
        CacheService(cfg, hot_capacity=64)


def test_config_and_legacy_paths_build_identically():
    cfg = CacheConfig(dim=16, threshold=0.9,
                      tiering=TieringConfig(hot_capacity=8, warm_capacity=32,
                                            n_clusters=2, bucket=16),
                      learning=LearningConfig(conformal=True))
    a = CacheService(cfg)
    CacheService._kwargs_warned = True             # silence the shim
    b = CacheService(dim=16, threshold=0.9, hot_capacity=8,
                     warm_capacity=32, n_clusters=2, bucket=16,
                     conformal=True)
    assert a.config == b.config
