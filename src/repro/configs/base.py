"""Configuration system for the LangCache reproduction framework.

Every assigned architecture is expressed as a :class:`ModelConfig`.  A
config is a *complete* description of the backbone: layer pattern (for
hybrids), attention geometry (GQA/MQA, RoPE, bias, sliding window), FFN
type (dense / MoE), SSM parameters, and modality frontend stubs.

Configs are frozen dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# mixer kinds
ATTN = "attn"          # full (or sliding-window) self attention
MAMBA = "mamba"        # selective SSM (Mamba-1 style)
SLSTM = "slstm"        # xLSTM scalar-memory block
MLSTM = "mlstm"        # xLSTM matrix-memory block

# ffn kinds
DENSE = "dense"
MOE = "moe"
NONE = "none"


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period of a model."""

    mixer: str = ATTN
    ffn: str = DENSE


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 2
    expert_d_ff: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25
    load_balance_coef: float = 0.01
    router_z_coef: float = 1e-3


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    # conv window used in front of the mLSTM qk path
    d_conv: int = 4
    mlstm_expand: int = 2
    slstm_ffn_factor: float = 1.3333


@dataclass(frozen=True)
class ModelConfig:
    name: str = "unnamed"
    family: str = "dense"            # dense|moe|ssm|hybrid|audio|vlm|encoder
    source: str = ""                 # citation for the config
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"         # swiglu | gelu | geglu | none
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-6
    use_rope: bool = True
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    sliding_window: int = 0          # 0 -> full attention
    causal: bool = True              # False for encoder-only
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # Repeating layer pattern.  n_layers % len(period) == 0.  For uniform
    # models the period has length 1.
    period: Tuple[LayerSpec, ...] = (LayerSpec(ATTN, DENSE),)
    # Modality frontend stub: '', 'audio', or 'vision'.  When set,
    # input_specs() provides precomputed frontend embeddings of shape
    # (batch, frontend_len, d_model) that are prepended to token embeds.
    frontend: str = ""
    frontend_len: int = 256
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"     # master weight dtype
    remat: bool = True               # checkpoint the scanned layer body
    # scan_layers=False unrolls the layer loop (and inner seq chunks):
    # XLA's cost_analysis counts a while-loop body ONCE regardless of
    # trip count, so the dry-run/roofline path must lower unrolled to
    # get honest FLOP/byte counts.  Real training keeps the scan.
    scan_layers: bool = True
    unroll_inner: bool = False
    # attention softmax/accumulation precision: f32 (default, safest) or
    # bf16 probabilities+accumulator — the §Perf mixed-precision lever
    # that halves attention HBM traffic (what the Pallas flash kernel's
    # VMEM residency achieves structurally on TPU).
    attn_f32: bool = True
    # chunked cross-entropy: >0 fuses unembed into the loss over
    # sequence chunks of this many tokens, so the (B,S,vocab) logits
    # tensor never fully materialises (the §Perf train-memory lever).
    loss_chunk: int = 0
    # pad the embedding/unembedding tables to a multiple of this, so an
    # awkward vocab (granite-moe's 49155) can shard over the model axis;
    # pad logits are masked to -inf in unembed (§Perf H5 lever).
    pad_vocab_to: int = 0
    # pad the expert count to a multiple of this (router-masked dummy
    # experts) so fine-grained MoEs (granite-moe's 40 experts) can go
    # expert-parallel on the model axis (§Perf H7 lever).
    pad_experts_to: int = 0

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_layers % len(self.period) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period length {len(self.period)}"
            )

    # ------------------------------------------------------------------
    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer does *full* attention over arbitrary length."""
        if self.sliding_window > 0:
            return True
        return all(s.mixer != ATTN for s in self.period)

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """The full unrolled list of layer specs."""
        return tuple(self.period[i % len(self.period)] for i in range(self.n_layers))

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def for_long_context(self, window: int = 8192) -> "ModelConfig":
        """Variant safe to decode at 500k+ tokens.

        SSM / hybrid configs are already sub-quadratic in state and are
        returned unchanged; full-attention configs get a sliding window
        (ring-buffer KV cache), per DESIGN.md §Arch-applicability.
        """
        if all(s.mixer != ATTN for s in self.period):
            return self
        if self.sliding_window > 0:
            return self
        # Hybrids keep their attention layers full in the real model; for
        # 500k decode we window them too so the cache stays bounded on
        # dense archs.  Jamba/xLSTM never reach this branch for mixers
        # without attention.
        return self.replace(sliding_window=window, name=self.name + "-swa")

    # ------------------------------------------------------------------
    # Parameter counting (for roofline MODEL_FLOPS = 6*N*D)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        hd = self.head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _dense_ffn_params(self) -> int:
        if self.mlp_type in ("swiglu", "geglu"):
            return 3 * self.d_model * self.d_ff
        if self.mlp_type == "gelu":
            return 2 * self.d_model * self.d_ff
        return 0

    def _moe_ffn_params(self, active_only: bool) -> int:
        m = self.moe
        assert m is not None
        e = m.top_k if active_only else m.num_experts
        per_expert = 3 * self.d_model * m.expert_d_ff
        router = self.d_model * m.num_experts
        return e * per_expert + router

    def _mamba_params(self) -> int:
        s = self.ssm or SSMConfig()
        d_in = s.expand * self.d_model
        dt_rank = s.dt_rank or -(-self.d_model // 16)
        return (
            self.d_model * 2 * d_in            # in_proj
            + s.d_conv * d_in                  # depthwise conv
            + d_in * (dt_rank + 2 * s.d_state) # x_proj
            + dt_rank * d_in                   # dt_proj
            + d_in * s.d_state                 # A_log
            + d_in                             # D
            + d_in * self.d_model              # out_proj
        )

    def _xlstm_params(self, kind: str) -> int:
        x = self.xlstm or XLSTMConfig()
        d = self.d_model
        if kind == MLSTM:
            d_in = x.mlstm_expand * d
            return d * 2 * d_in + 3 * d_in * d_in // max(1, 1) + d_in * d + 3 * d_in
        # slstm: 4 gates (i,f,z,o) each d->d plus recurrent per-head block
        hd = d // self.n_heads
        ffn = int(2 * d * d * x.slstm_ffn_factor)
        return 4 * d * d + 4 * self.n_heads * hd * hd + ffn

    def param_count(self, active_only: bool = False) -> int:
        n = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for spec in self.layer_specs():
            if spec.mixer == ATTN:
                n += self._attn_params()
            elif spec.mixer == MAMBA:
                n += self._mamba_params()
            elif spec.mixer in (SLSTM, MLSTM):
                n += self._xlstm_params(spec.mixer)
            if spec.ffn == DENSE:
                n += self._dense_ffn_params()
            elif spec.ffn == MOE:
                n += self._moe_ffn_params(active_only)
            n += 2 * self.d_model  # norms
        n += self.d_model  # final norm
        return n

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test variant of the same family (2 layers, tiny dims).

        Keeps the layer pattern / family shape but shrinks every
        dimension so a forward + train step runs on CPU in seconds.
        """
        period = self.period
        n_layers = len(period)
        if n_layers > 4:  # trim absurdly long periods while keeping variety
            period = period[:4]
            n_layers = 4
        if n_layers <= 2:
            n_layers = 2 * len(period)
        d_model = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        moe = None
        if self.moe is not None:
            # capacity_factor 4.0: smoke tests check prefill/decode
            # equivalence, which requires no capacity drops (the full
            # configs keep the production 1.25)
            moe = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                expert_d_ff=64, capacity_factor=4.0)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, d_state=8)
        kw = dict(
            name=self.name + "-smoke",
            n_layers=n_layers,
            period=period,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            moe=moe,
            ssm=ssm,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            frontend_len=8 if self.frontend else 0,
            max_seq_len=2048,
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )
        kw.update(overrides)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}
_LOADED = [False]


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = (
    "musicgen-large",
    "granite-34b",
    "starcoder2-15b",
    "phi3-mini-3.8b",
    "pixtral-12b",
    "jamba-1.5-large-398b",
    "phi3.5-moe-42b-a6.6b",
    "xlstm-125m",
    "qwen2.5-32b",
    "granite-moe-3b-a800m",
)


def _ensure_loaded():
    # import the per-arch modules exactly once
    if _LOADED[0]:
        return
    _LOADED[0] = True
    from repro.configs import archs  # noqa: F401
