"""Threshold calibration for deployed caches.

The paper evaluates at the best-F1 threshold; a production cache
operator instead fixes a FALSE-HIT budget (serving a wrong answer is
much worse than a miss) and wants the loosest threshold that respects
it.  Given scored eval pairs, these utilities map an operating
constraint to a threshold with held-out estimates.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Calibration:
    threshold: float
    expected_precision: float
    expected_recall: float
    false_hit_rate: float      # P(score >= thr | negative)
    true_hit_rate: float       # P(score >= thr | positive)


def calibrate_for_precision(scores, labels, min_precision: float = 0.95
                            ) -> Calibration:
    """Loosest threshold whose eval precision >= min_precision.

    Candidate cuts are *distinct* score boundaries only: with tied
    scores, ``score >= thr`` admits every tie, so a cut landing inside
    a tie group would report cumulative stats the threshold cannot
    realize.  When no cut reaches ``min_precision`` (e.g. all-negative
    labels) the threshold is placed just above the top score — an
    empty, vacuously precise hit set — rather than a top-1 cut whose
    actual precision silently misses the target.
    """
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels, np.int32)
    order = np.argsort(-scores, kind="stable")
    s = scores[order]
    lab = labels[order]
    tp = np.cumsum(lab)
    fp = np.cumsum(1 - lab)
    precision = tp / np.maximum(tp + fp, 1)
    n_pos = max(int(labels.sum()), 1)
    n_neg = max(int((1 - labels).sum()), 1)
    # a cut at i means thr = s[i]: only valid where s[i] > s[i+1]
    # (ties below i would be admitted too); the last row always is
    boundary = np.ones(len(s), bool)
    boundary[:-1] = s[:-1] > s[1:]
    ok = np.nonzero(boundary & (precision >= min_precision))[0]
    if len(ok) == 0:
        thr = float(s[0]) + 1e-9 if len(s) else 1.0  # admit nothing
        return Calibration(threshold=thr, expected_precision=1.0,
                           expected_recall=0.0, false_hit_rate=0.0,
                           true_hit_rate=0.0)
    i = ok[-1]
    return Calibration(
        threshold=float(s[i]),
        expected_precision=float(precision[i]),
        expected_recall=float(tp[i] / n_pos),
        false_hit_rate=float(fp[i] / n_neg),
        true_hit_rate=float(tp[i] / n_pos),
    )


def calibrate_for_false_hit_budget(scores, labels, max_false_hit_rate: float
                                   = 0.01) -> Calibration:
    """Loosest threshold with P(hit | negative) <= budget."""
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels, np.int32)
    neg = np.sort(scores[labels == 0])
    n_neg = len(neg)
    pos = scores[labels == 1]
    if n_neg == 0:
        # no negatives observed: any threshold satisfies the budget, so
        # take the loosest one that still hits every positive
        thr = float(pos.min()) if len(pos) else 1.0
    else:
        # threshold just above the (1-budget) negative quantile
        idx = int(np.ceil((1.0 - max_false_hit_rate) * n_neg))
        thr = float(neg[min(idx, n_neg - 1)] + 1e-9)
    tp = float((pos >= thr).sum())
    fp = float((neg >= thr).sum())
    return Calibration(
        threshold=thr,
        expected_precision=tp / max(tp + fp, 1.0),
        expected_recall=tp / max(len(pos), 1),
        false_hit_rate=fp / max(n_neg, 1),
        true_hit_rate=tp / max(len(pos), 1),
    )
