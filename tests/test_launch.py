"""Launch layer: sharding resolution, program building (abstract — no
512-device init here), roofline parsing, and a real small-mesh pjit run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.programs import build_program, resolve_config
from repro.launch.roofline import collective_bytes, model_flops, roofline_terms
from repro.launch.sharding import TRAIN_RULES, resolve_pspec, sharding_tree


class FakeMesh:
    """Shape-only stand-in for a 16x16 production mesh."""
    def __init__(self, shape):
        self.shape = shape


MESH_SP = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_resolve_basic_rules():
    # weight (embed, mlp): embed->data, mlp->model
    assert resolve_pspec((6144, 24576), "embed,mlp", MESH_SP,
                         TRAIN_RULES) == P("data", "model")
    # batch over (pod, data) multi-pod
    assert resolve_pspec((256, 4096), "batch,seq", MESH_MP,
                         TRAIN_RULES) == P(("pod", "data"))


def test_resolve_divisibility_fallback():
    # qwen 40 heads don't divide 16 -> replicated head dim
    spec = resolve_pspec((5120, 40, 128), "embed,heads,head_dim", MESH_SP,
                         TRAIN_RULES)
    assert spec == P("data")
    # granite kv=1 -> replicated
    spec = resolve_pspec((6144, 1, 128), "embed,kv_heads,head_dim", MESH_SP,
                         TRAIN_RULES)
    assert spec == P("data")


def test_resolve_cache_takes_data_axes_when_batch_cannot():
    # long_500k: batch=1 -> cache dim picks up (pod, data); kv=8 does
    # not divide the 16-way model axis -> replicated kv heads
    spec = resolve_pspec((1, 524288, 8, 128), "batch,cache,kv_heads,head_dim",
                         MESH_MP, TRAIN_RULES)
    assert spec == P(None, ("pod", "data"))
    # decode_32k: batch=128 claims the data axes; cache replicated
    spec = resolve_pspec((128, 32768, 8, 128), "batch,cache,kv_heads,head_dim",
                         MESH_MP, TRAIN_RULES)
    assert spec == P(("pod", "data"))
    # divisible kv heads DO take the model axis
    spec = resolve_pspec((128, 32768, 16, 128), "batch,cache,kv_heads,head_dim",
                         MESH_MP, TRAIN_RULES)
    assert spec == P(("pod", "data"), None, "model")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_programs_build_abstract(arch, shape):
    """All 40 programs assemble from structs with consistent axes trees
    (the cheap 90% of the dry-run, no device mesh needed)."""
    prog = build_program(get_config(arch), INPUT_SHAPES[shape])
    flat_args = jax.tree_util.tree_leaves(prog.args)
    assert all(isinstance(a, jax.ShapeDtypeStruct) for a in flat_args)
    for a_tree, x_tree in zip(prog.args, prog.arg_axes):
        va = jax.tree_util.tree_leaves(a_tree)
        xa = jax.tree_util.tree_leaves(x_tree)
        assert len(va) == len(xa)
        for v, x in zip(va, xa):
            assert len(v.shape) == len([s for s in x.split(",") if s != ""]) \
                or (x == "" and v.shape == ())


def test_long500k_swa_for_dense_only():
    dense = resolve_config(get_config("qwen2.5-32b"),
                           INPUT_SHAPES["long_500k"])
    assert dense.sliding_window == 8192
    hybrid = resolve_config(get_config("jamba-1.5-large-398b"),
                            INPUT_SHAPES["long_500k"])
    assert hybrid.sliding_window == 0  # native sub-quadratic


def test_collective_parsing():
    hlo = """
  %ag = f32[256,1024]{1,0} all-gather(f32[16,1024]{1,0} %p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[512]{0} all-reduce(bf16[512]{0} %p1), replica_groups=[4,16]<=[64], to_apply=%add
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %p2), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo, 64)
    assert out["counts"]["all-gather"] == 1
    ag = 256 * 1024 * 4 * (3 / 4)
    assert abs(out["all-gather"] - ag) < 1
    ar = 512 * 2 * 2 * (15 / 16)
    assert abs(out["all-reduce"] - ar) < 1
    assert out["collective-permute"] == 8 * 8 * 4
    assert out["total"] == pytest.approx(out["all-gather"] + out["all-reduce"]
                                         + out["collective-permute"])


def test_roofline_terms_structure():
    cost = {"flops": 1e12, "bytes accessed": 1e11}
    terms = roofline_terms(cost, "", 256)
    assert terms["t_compute"] == pytest.approx(1e12 / 197e12)
    assert terms["t_memory"] == pytest.approx(1e11 / 819e9)
    assert terms["bottleneck"] == "memory"


def test_model_flops_train_vs_decode():
    cfg = get_config("phi3-mini-3.8b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr > 1e15 and de < 1e13 and tr > de


def test_real_small_mesh_train_step():
    """An actual sharded train step on the host mesh (1 device) — the
    integration proof that shardings + jit + optimizer compose."""
    from repro.training import adamw, make_train_step
    mesh = make_host_mesh(1, 1)
    cfg = get_config("granite-moe-3b-a800m").reduced()
    from repro.models import init_lm, split
    params = init_lm(cfg, jax.random.PRNGKey(0))
    pv, pax = split(params)
    init_opt, update = adamw(1e-3, max_grad_norm=0.5)
    opt = init_opt(pv)
    step = make_train_step(cfg, update)
    in_sh = (sharding_tree(pv, pax, mesh),)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)), jnp.int32)
    with mesh:
        jitted = jax.jit(step)
        pv2, opt2, m = jitted(pv, opt, {"tokens": toks})
    assert bool(jnp.isfinite(m["loss"]))
