"""Serving telemetry: metrics registry, span tracer, SLO health
(DESIGN.md §10).

Three layers, all optional and all zero-cost when disabled:

  * :mod:`repro.obs.registry` — counters / gauges / fixed-bucket
    latency histograms with declared label schemas (§10.1);
  * :mod:`repro.obs.trace` — context-managed spans forming one tree
    per request, with optional XLA profiler annotations (§10.2);
  * :mod:`repro.obs.health` — per-tenant SLO-budget rates and rebuild
    overlap accounting, drained at the idle tick (§10.3);
  * :mod:`repro.obs.export` — JSON-lines and Prometheus renderers for
    registry snapshots.

``Telemetry`` bundles the three so a serving stack can thread one
object instead of three; ``Telemetry.disabled()`` is the no-op twin.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .export import (read_jsonl, to_jsonl, to_prometheus, validate_file,
                     validate_lines, write_jsonl)
from .health import (HealthConfig, HealthTracker, TenantHealth,
                     check_overhead_budget)
from .registry import (DEFAULT_LATENCY_BUCKETS_S, NULL_REGISTRY, SCHEMA,
                       Counter, Gauge, Histogram, MetricsRegistry,
                       NullRegistry, tenant_label)
from .trace import NULL_TRACER, Span, Tracer


@dataclass
class Telemetry:
    """One handle for the three layers, shared across a serving stack.

    The engine, service, backend, and batcher all accept a
    ``telemetry=`` and record into the same registry, so one
    ``snapshot()`` sees the whole request path.
    """
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    tracer: Tracer = field(default_factory=Tracer)
    health: Optional[HealthTracker] = None
    enabled: bool = True

    def __post_init__(self):
        if self.enabled and self.health is None:
            self.health = HealthTracker()

    @classmethod
    def disabled(cls) -> "Telemetry":
        return cls(registry=NULL_REGISTRY, tracer=NULL_TRACER,
                   health=None, enabled=False)

    def stage_histogram(self) -> Histogram:
        """The shared per-stage latency histogram (§10.1): one
        ``observe`` per stage per batch, labeled (stage, tenant)."""
        return self.registry.histogram(
            "stage_latency_seconds",
            "wall time of one serving stage over one batch",
            labels=("stage", "tenant"))


DISABLED = Telemetry.disabled()

__all__ = [
    "Telemetry", "DISABLED",
    "MetricsRegistry", "NullRegistry", "NULL_REGISTRY",
    "Counter", "Gauge", "Histogram",
    "DEFAULT_LATENCY_BUCKETS_S", "SCHEMA", "tenant_label",
    "Tracer", "Span", "NULL_TRACER",
    "HealthTracker", "HealthConfig", "TenantHealth",
    "check_overhead_budget",
    "to_jsonl", "write_jsonl", "read_jsonl", "validate_lines",
    "validate_file", "to_prometheus",
]
