"""Serving engine + the cache-fronted LLM service (end-to-end path)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SemanticCache
from repro.core.embedders import HashNgramEmbedder
from repro.data import HashTokenizer, make_query_stream
from repro.models import init_lm, split
from repro.serving import CachedLLMService, ServeEngine


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = get_config("phi3-mini-3.8b").reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    return cfg, ServeEngine(cfg, pv, max_len=64)


def test_generate_batched(tiny_engine):
    cfg, engine = tiny_engine
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 12)).astype(np.int32)
    res = engine.generate(prompts, max_new_tokens=8)
    assert res.tokens.shape == (4, 8)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()


def test_generate_deterministic_greedy(tiny_engine):
    cfg, engine = tiny_engine
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 10)).astype(np.int32)
    a = engine.generate(prompts, 6).tokens
    b = engine.generate(prompts, 6).tokens
    np.testing.assert_array_equal(a, b)


def test_cached_service_hit_rate():
    """The paper's deployment loop: repeated paraphrased queries should
    produce cache hits and skip the LLM."""
    emb = HashNgramEmbedder(dim=256)
    cache = SemanticCache(capacity=512, dim=256, threshold=0.80)
    svc = CachedLLMService(emb.embed, cache, engine=None,
                           tokenizer=HashTokenizer())
    stream = [q.text for q in make_query_stream("medical", 120, seed=0,
                                                repeat_frac=0.4)]
    for i in range(0, len(stream), 8):
        out = svc.handle(stream[i:i + 8])
        assert all(r.response is not None for r in out)
    st = svc.stats()
    assert st["hits"] > 8, st
    assert st["hits"] + st["misses"] == 120
    # every hit's response must be a previously generated response
    assert svc.hit_rate > 0.05


def test_cached_service_identical_query_always_hits():
    emb = HashNgramEmbedder(dim=128)
    cache = SemanticCache(capacity=64, dim=128, threshold=0.95)
    svc = CachedLLMService(emb.embed, cache, engine=None,
                           tokenizer=HashTokenizer())
    q = ["What are the symptoms of early stage diabetes?"]
    first = svc.handle(q)[0]
    assert not first.cache_hit
    second = svc.handle(q)[0]
    assert second.cache_hit
    assert second.response == first.response
