"""Metrics registry: counters, gauges, fixed-bucket histograms
(DESIGN.md §10.1).

One process-local registry owns every serving metric.  The design is
sized for the single-writer serve loop:

  * **Recording is lock-free.**  A metric resolves its label values to
    a *series handle* once (``metric.labels(...)``), after which every
    ``inc``/``set``/``observe`` is a couple of attribute/bisect
    operations on plain Python ints — no locks, no allocation on the
    hot path.  The serve loop is the single writer; the only other
    reader is a drain/export thread taking ``snapshot()``, which under
    the GIL sees each individual value intact (a snapshot may straddle
    two increments of *different* metrics — torn across metrics, never
    within a value — which is the standard Prometheus contract).
  * **Labels are declared per metric** (e.g. ``("tenant", "stage")``)
    and resolved positionally, so a typo'd label name fails fast at
    the call site instead of minting a ghost series.
  * **Histograms use fixed bucket boundaries** (default: a 1-2.5-5
    latency ladder from 10 us to 30 s) so two snapshots are always
    mergeable/diffable and the export schema never depends on the
    data.  ``quantile()`` interpolates inside the landing bucket
    (log-linear) and tracks per-series min/max so the overflow bucket
    still yields a finite estimate.

``snapshot()`` returns plain dicts (JSON-able as-is); the exporters in
``repro.obs.export`` render them as JSON-lines or Prometheus text.
``NULL_REGISTRY`` is a full no-op implementation so telemetry-off code
paths keep the exact call shape at zero cost (the bench's < 2%
overhead guard measures the difference).
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SCHEMA = "repro.obs/v1"

# 1-2.5-5 ladder, 10 us .. 30 s, in seconds.  Fixed across the repo so
# every exported histogram is diffable against every other.
DEFAULT_LATENCY_BUCKETS_S: Tuple[float, ...] = (
    10e-6, 25e-6, 50e-6, 100e-6, 250e-6, 500e-6,
    1e-3, 2.5e-3, 5e-3, 10e-3, 25e-3, 50e-3,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
)


def tenant_label(tenants) -> str:
    """The batch-level tenant label: one tenant's id, or ``mixed``.

    Per-row tenant attribution goes through per-tenant *counters*; the
    latency histograms are per batch (one wall time per plan/commit),
    so a heterogeneous batch is labeled ``mixed`` rather than charged
    to an arbitrary member.
    """
    import numpy as np
    t = np.asarray(tenants).reshape(-1)
    if t.size == 0:
        return "none"
    first = int(t[0])
    return str(first) if bool((t == first).all()) else "mixed"


class _CounterSeries:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class _GaugeSeries:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, v: float) -> None:
        self.value += float(v)


class _HistogramSeries:
    __slots__ = ("counts", "sum", "count", "vmin", "vmax", "_bounds")

    def __init__(self, bounds: Sequence[float]):
        self._bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # last = +inf overflow
        self.sum = 0.0
        self.count = 0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self._bounds, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (0 when empty).

        Exact only at bucket boundaries; inside a bucket the mass is
        assumed uniform.  The overflow bucket interpolates toward the
        observed max, so a p99 beyond the last bound stays finite.
        """
        if self.count == 0:
            return 0.0
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if acc + c >= rank:
                lo = self._bounds[i - 1] if i > 0 else max(
                    min(self.vmin, self._bounds[0] if self._bounds
                        else self.vmin), 0.0)
                hi = self._bounds[i] if i < len(self._bounds) else self.vmax
                hi = max(hi, lo)
                frac = (rank - acc) / c
                return lo + (hi - lo) * frac
            acc += c
        return self.vmax

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class _Metric:
    """Base: a named family of label-resolved series."""

    kind = "abstract"
    _series_cls = _CounterSeries

    def __init__(self, name: str, help: str = "",
                 label_names: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._series: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(labels)}")
        return tuple(str(labels[k]) for k in self.label_names)

    def labels(self, **labels):
        """Resolve label values to a series handle — do this once per
        distinct label set, then record through the handle."""
        key = self._key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = self._make_series()
        return s

    def _make_series(self):
        return self._series_cls()

    def series_items(self) -> List[Tuple[Dict[str, str], object]]:
        return [(dict(zip(self.label_names, k)), s)
                for k, s in list(self._series.items())]


class Counter(_Metric):
    kind = "counter"
    _series_cls = _CounterSeries

    def inc(self, n: int = 1, **labels) -> None:
        self.labels(**labels).inc(n)

    def total(self, **match) -> int:
        """Sum of every series whose labels include ``match``."""
        tot = 0
        for lab, s in self.series_items():
            if all(lab.get(k) == str(v) for k, v in match.items()):
                tot += s.value
        return tot


class Gauge(_Metric):
    kind = "gauge"
    _series_cls = _GaugeSeries

    def set(self, v: float, **labels) -> None:
        self.labels(**labels).set(v)

    def value(self, **labels) -> float:
        return self.labels(**labels).value


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 label_names: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        super().__init__(name, help, label_names)
        b = tuple(float(x) for x in buckets)
        if list(b) != sorted(b) or len(set(b)) != len(b):
            raise ValueError(f"histogram {name!r} buckets must be "
                             f"strictly increasing, got {b}")
        self.buckets = b

    def _make_series(self):
        return _HistogramSeries(self.buckets)

    def observe(self, v: float, **labels) -> None:
        self.labels(**labels).observe(v)

    def aggregate(self, **match) -> _HistogramSeries:
        """Merge every series whose labels include ``match`` (fixed
        buckets make this a plain vector add)."""
        agg = _HistogramSeries(self.buckets)
        for lab, s in self.series_items():
            if all(lab.get(k) == str(v) for k, v in match.items()):
                agg.counts = [a + b for a, b in zip(agg.counts, s.counts)]
                agg.sum += s.sum
                agg.count += s.count
                agg.vmin = min(agg.vmin, s.vmin)
                agg.vmax = max(agg.vmax, s.vmax)
        return agg


class MetricsRegistry:
    """Name -> metric.  Registration is idempotent: asking for an
    existing name returns the existing metric, provided kind and label
    schema match (a mismatch is a programming error and raises)."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _register(self, cls, name: str, help: str,
                  label_names: Sequence[str], **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls) or m.label_names != tuple(label_names):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind} "
                    f"with labels {m.label_names}")
            return m
        m = self._metrics[name] = cls(name, help, label_names, **kw)
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S
                  ) -> Histogram:
        return self._register(Histogram, name, help, labels,
                              buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def metrics(self) -> Iterable[_Metric]:
        return list(self._metrics.values())

    def value(self, name: str, **match) -> float:
        """Counter total / gauge value shortcut (0 when absent)."""
        m = self._metrics.get(name)
        if m is None:
            return 0
        if isinstance(m, Counter):
            return m.total(**match)
        if isinstance(m, Gauge):
            tot = 0.0
            for lab, s in m.series_items():
                if all(lab.get(k) == str(v) for k, v in match.items()):
                    tot += s.value
            return tot
        raise TypeError(f"value() is for counters/gauges, {name!r} is "
                        f"{m.kind}")

    def snapshot(self) -> Dict[str, object]:
        """Plain-dict view of every series (JSON-able; the exporters'
        single input).  Safe to call from a drain thread — see the
        module docstring for the consistency contract."""
        out: Dict[str, object] = {"schema": SCHEMA, "metrics": {}}
        for m in self.metrics():
            series = []
            for lab, s in m.series_items():
                if m.kind == "histogram":
                    series.append({
                        "labels": lab, "count": s.count, "sum": s.sum,
                        "le": list(m.buckets), "buckets": list(s.counts),
                        "min": s.vmin if s.count else 0.0,
                        "max": s.vmax if s.count else 0.0,
                    })
                else:
                    series.append({"labels": lab, "value": s.value})
            out["metrics"][m.name] = {
                "kind": m.kind, "help": m.help,
                "label_names": list(m.label_names), "series": series,
            }
        return out


# ---------------------------------------------------------------------------
# no-op twins: telemetry-off call sites keep the exact call shape
# ---------------------------------------------------------------------------

class _NullSeries:
    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def add(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0


_NULL_SERIES = _NullSeries()


class _NullMetric:
    __slots__ = ()
    kind = "null"
    buckets = ()

    def labels(self, **labels):
        return _NULL_SERIES

    def inc(self, n: int = 1, **labels) -> None:
        pass

    def set(self, v: float, **labels) -> None:
        pass

    def observe(self, v: float, **labels) -> None:
        pass

    def total(self, **match) -> int:
        return 0

    def value(self, **labels) -> float:
        return 0.0

    def aggregate(self, **match):
        return _HistogramSeries(())

    def series_items(self):
        return []


_NULL_METRIC = _NullMetric()


class NullRegistry(MetricsRegistry):
    """Telemetry-off registry: every metric is a shared no-op."""

    def __init__(self):
        super().__init__()

    def counter(self, name, help="", labels=()):
        return _NULL_METRIC

    def gauge(self, name, help="", labels=()):
        return _NULL_METRIC

    def histogram(self, name, help="", labels=(),
                  buckets=DEFAULT_LATENCY_BUCKETS_S):
        return _NULL_METRIC

    def value(self, name, **match):
        return 0

    def snapshot(self):
        return {"schema": SCHEMA, "metrics": {}}


NULL_REGISTRY = NullRegistry()
