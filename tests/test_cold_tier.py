"""Host-RAM cold tier (DESIGN.md §12): demotion capture, budgeted
lookup + router, async promotion, tenant eviction races, and the
eviction-accounting split."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import commit_insert, plan_lookup

from repro.cache_service import (
    CacheRequest, CacheService, ColdRoutingPolicy, ColdTier, tiers,
)

rng = np.random.default_rng(29)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _service(d=16, cold_capacity=128, **kw):
    """A service small enough that the warm ring wraps quickly; the
    router margin is opened wide so uniform-random test keys (whose
    coarse centroids sit far from any query) still get fetched."""
    pol = kw.pop("cold_policy", ColdRoutingPolicy(
        min_rows_for_routing=16, n_clusters=4, route_rebuild_every=64,
        router_margin=2.0, promote_max=16))
    return CacheService(dim=d, hot_capacity=16, warm_capacity=32,
                        n_clusters=4, bucket=16, flush_size=8,
                        threshold=0.8, cold_capacity=cold_capacity,
                        cold_policy=pol, **kw)


def _fill(svc, keys, tenant=0, tag=""):
    for lo in range(0, len(keys), 8):
        plan = svc.plan(CacheRequest.build(keys[lo:lo + 8], tenant))
        svc.commit(plan, [f"r{tag}{lo + i}" for i in range(8)])
    svc.flush()


# ---------------------------------------------------------------------------
# eviction accounting split (satellite: dropped == 0 with a cold tier)
# ---------------------------------------------------------------------------

def test_no_drops_with_cold_tier_enabled():
    """Every warm-ring overwrite must be captured (demoted), never
    dropped, while the cold tier has a slot to catch it."""
    d = 16
    keys = _unit(rng.standard_normal((200, d)).astype(np.float32))
    svc = _service(d, cold_capacity=512)
    _fill(svc, keys)
    t = svc.stats_snapshot().tiers
    assert t["evictions_demoted"] > 0
    assert t["evictions_dropped"] == 0
    # the demoted strings are still alive behind the cold copies
    cold = t["cold"]
    assert cold["cold_rows"] == cold["cold_inserted"]  # ring never wrapped
    assert cold["cold_dropped"] == 0
    assert len(svc.responses) == len(svc)


def test_drops_counted_without_cold_tier():
    d = 16
    keys = _unit(rng.standard_normal((200, d)).astype(np.float32))
    svc = CacheService(dim=d, hot_capacity=16, warm_capacity=32,
                       n_clusters=4, bucket=16, flush_size=8, threshold=0.8)
    assert svc.cold is None and not svc.capabilities().cold_tier
    _fill(svc, keys)
    t = svc.stats_snapshot().tiers
    assert t["evictions_demoted"] == 0
    assert t["evictions_dropped"] > 0
    assert t["evictions_dropped"] <= t["evictions"]


def test_cold_ring_overwrites_are_the_final_drops():
    """Once the cold ring itself wraps, the overwritten rows' strings
    are freed — and only then."""
    d = 16
    keys = _unit(rng.standard_normal((240, d)).astype(np.float32))
    svc = _service(d, cold_capacity=64)
    _fill(svc, keys)
    t = svc.stats_snapshot().tiers
    assert t["evictions_dropped"] == 0
    assert t["cold"]["cold_dropped"] > 0
    assert t["evictions"] == t["cold"]["cold_dropped"]
    assert len(svc.responses) == len(svc)


# ---------------------------------------------------------------------------
# demotion LRU tie-break (satellite: insertion sequence, not slot order)
# ---------------------------------------------------------------------------

def test_demote_tie_breaks_on_insertion_sequence():
    """After a batched `hot_touch` every hit slot carries the same
    ``last_used`` clock; the demotion order must then follow the
    insertion sequence (oldest first), not the slot index — slot-order
    tie-breaking churned low-index slots under uniform traffic."""
    cap, d, m = 8, 4, 3
    keys = _unit(rng.standard_normal((cap, d)).astype(np.float32))
    hot = tiers.init_hot(cap, d)._replace(
        keys=jnp.asarray(keys), valid=jnp.ones((cap,), bool),
        tenants=jnp.zeros((cap,), jnp.int32),
        last_used=jnp.full((cap,), 7, jnp.int32),
        # insertion ages run *against* slot order: slot 7 is oldest
        inserted_at=jnp.asarray(np.arange(cap)[::-1].copy(), jnp.int32),
        value_ids=jnp.arange(cap, dtype=jnp.int32),
        clock=jnp.asarray(8, jnp.int32))
    _, dem = tiers.demote_coldest(hot, m)
    assert np.asarray(dem.mask).all()
    assert sorted(np.asarray(dem.value_ids).tolist()) == [5, 6, 7]


# ---------------------------------------------------------------------------
# ColdTier unit behavior
# ---------------------------------------------------------------------------

def test_cold_tier_budgeted_lookup_and_router():
    d, n = 16, 256
    keys = _unit(rng.standard_normal((n, d)).astype(np.float32))
    cold = ColdTier(n, d, policy=ColdRoutingPolicy(
        min_rows_for_routing=16, n_clusters=8, fetch_budget=8,
        router_margin=2.0))
    cold.bulk_load(keys, np.arange(n), np.zeros(n, np.int32))
    assert cold.centroids is not None
    q = keys[:6]
    thr = np.full(6, 0.9, np.float32)
    need = np.array([True, True, True, False, False, True])
    cf = cold.lookup(q, np.zeros(6, np.int32), thr, need)
    # only the offered rows are consulted; each exact self-match wins
    assert (cf.consulted == need).all()
    assert (cf.value_ids[need] == np.array([0, 1, 2, 5])).all()
    # int8 storage: scores within the §8 quantization bound of 1.0
    assert np.allclose(cf.scores[need], 1.0, atol=np.sqrt(d) / 254 + 1e-5)
    assert cf.scores[~need].min() <= -1e29 and (cf.value_ids[~need] == -1).all()
    assert cf.fetched_rows <= need.sum() * cold.policy.fetch_budget
    # the hits queued themselves for promotion
    assert cold.pending_promotions == int(need.sum())

    # uniform-random rows cluster badly; the calibrated gate must have
    # opened rather than falsely skipping reachable rows
    assert cold.route_slack > 0.2

    # on *tight* clusters the calibrated slack is small and the router
    # declines fetches whose best centroid sits far below threshold
    # (4 groups under 8 centroids: k-means cannot be forced to merge
    # two groups, so the fit is tight regardless of its local optimum)
    cents = _unit(rng.standard_normal((4, d)).astype(np.float32))
    tkeys = _unit(np.repeat(cents, n // 4, axis=0)
                  + 0.02 * rng.standard_normal((n, d)).astype(np.float32))
    tight = ColdTier(n, d, policy=ColdRoutingPolicy(
        min_rows_for_routing=16, n_clusters=8, router_margin=0.01))
    tight.bulk_load(tkeys, np.arange(n), np.zeros(n, np.int32))
    assert tight.route_slack < 0.2
    far = _unit(rng.standard_normal((4, d)).astype(np.float32))
    cf2 = tight.lookup(far, np.zeros(4, np.int32),
                       np.full(4, 0.99, np.float32), np.ones(4, bool))
    assert cf2.router_skips == 4 and not cf2.consulted.any()
    assert tight.stats()["cold_router_skips"] == 4  # early-exit path too


def test_cold_tier_tenant_isolation():
    d, n = 8, 64
    keys = _unit(rng.standard_normal((n, d)).astype(np.float32))
    cold = ColdTier(n, d, policy=ColdRoutingPolicy(
        min_rows_for_routing=1024, router_margin=2.0))
    cold.bulk_load(keys, np.arange(n), (np.arange(n) % 2).astype(np.int32))
    cf = cold.lookup(keys[:4], np.full(4, 1, np.int32),
                     np.full(4, 0.9, np.float32), np.ones(4, bool))
    # vids 0 and 2 belong to tenant 0: invisible to tenant 1
    assert (cf.value_ids[[1, 3]] == [1, 3]).all()
    assert not (cf.scores[[0, 2]] >= 0.9).any()


def test_take_promotions_skips_stale_entries():
    d, n = 8, 32
    keys = _unit(rng.standard_normal((n, d)).astype(np.float32))
    cold = ColdTier(n, d, policy=ColdRoutingPolicy(
        min_rows_for_routing=1024, router_margin=2.0))
    cold.bulk_load(keys, np.arange(n), np.zeros(n, np.int32))
    cold.lookup(keys[:4], np.zeros(4, np.int32),
                np.full(4, 0.9, np.float32), np.ones(4, bool))
    assert cold.pending_promotions == 4
    # tenant eviction between queueing and draining: nothing survives
    cold.evict_tenant(0)
    assert cold.pending_promotions == 0
    assert cold.take_promotions(16) is None


# ---------------------------------------------------------------------------
# end-to-end: wraparound demotion, cold hit, promotion, eviction race
# ---------------------------------------------------------------------------

def test_wraparound_demotes_to_cold_and_serves_back():
    """Rows pushed off the wrapped warm ring stay servable through the
    cold tier, and a cold hit is promoted back to warm by the next
    maintenance tick."""
    d = 16
    keys = _unit(rng.standard_normal((200, d)).astype(np.float32))
    svc = _service(d, cold_capacity=512)
    _fill(svc, keys)
    cold_vids = sorted(int(v) for v in svc.cold.value_ids[svc.cold.valid])
    assert len(cold_vids) > 100          # the ring wrapped many times
    idx = cold_vids[:8]                  # vid == insertion index here
    plan = svc.plan(CacheRequest.build(keys[idx], 0))
    assert plan.hit.all()
    assert [plan.responses[i] for i in range(8)] == [f"r{j}" for j in idx]
    s = svc.stats_snapshot()
    assert s.traffic["cold_hits"] >= 8
    assert s.tiers["cold"]["cold_fetches"] >= 8
    receipt = svc.commit(plan, [None] * 8)
    assert receipt.cold_maintenance_due
    rep = svc.maintenance()
    assert rep.cold_promoted >= 8
    # promoted rows now answer from the device tiers
    plan2 = svc.plan(CacheRequest.build(keys[idx], 0))
    assert plan2.hit.all()
    t2 = svc.stats_snapshot()
    assert t2.traffic["hot_hits"] + t2.traffic["warm_hits"] >= 8
    assert t2.tiers["evictions_dropped"] == 0


def test_commit_receipt_reports_cold_demotions():
    d = 16
    keys = _unit(rng.standard_normal((96, d)).astype(np.float32))
    svc = _service(d, cold_capacity=256)
    demoted = 0
    for lo in range(0, len(keys), 8):
        plan = svc.plan(CacheRequest.build(keys[lo:lo + 8], 0))
        demoted += svc.commit(plan,
                              [f"r{lo + i}" for i in range(8)]).demoted_cold
    svc.flush()
    assert demoted + svc.stats_snapshot().tiers["cold"]["cold_inserted"] \
        >= svc.cold.n_inserted
    assert svc.cold.n_inserted > 0


def test_evict_tenant_between_cold_hit_and_maintenance():
    """Mirror of the §7 plan/commit race one level down: a tenant
    evicted after a cold hit queued its promotion must not resurrect
    through the maintenance drain, and its host strings are freed."""
    d = 16
    keys = _unit(rng.standard_normal((200, d)).astype(np.float32))
    svc = _service(d, cold_capacity=512)
    _fill(svc, keys, tenant=0)
    other = _unit(rng.standard_normal((8, d)).astype(np.float32))
    commit_insert(svc, other, [f"t1-{i}" for i in range(8)], tenant=1)
    cold_vids = sorted(int(v) for v in svc.cold.value_ids[svc.cold.valid])
    plan = svc.plan(CacheRequest.build(keys[cold_vids[:8]], 0))
    assert plan.hit.all() and svc.cold.pending_promotions >= 8

    assert svc.evict_tenant(0) > 0       # the race
    rep = svc.maintenance()
    assert rep.cold_promoted == 0        # nothing resurrected
    assert svc.cold.pending_promotions == 0
    plan2 = svc.plan(CacheRequest.build(keys[cold_vids[:8]], 0))
    assert not plan2.hit.any()
    # tenant 1 is untouched; tenant 0's strings are gone
    assert sorted(svc.responses.values()) == [f"t1-{i}" for i in range(8)]
    hit, _, vals = plan_lookup(svc, other, tenant=1)
    assert hit.all() and all(v.startswith("t1-") for v in vals)


def test_cold_with_warm_block_streaming():
    """The two §12 halves compose: blockwise warm streaming underneath,
    cold tier behind — same verdicts as the monolithic service."""
    d = 16
    keys = _unit(rng.standard_normal((120, d)).astype(np.float32))
    svc = _service(d, cold_capacity=256, warm_block=16)
    _fill(svc, keys)
    base = CacheService(dim=d, hot_capacity=16, warm_capacity=32,
                        n_clusters=4, bucket=16, flush_size=8,
                        threshold=0.8)
    _fill(base, keys)
    q = np.concatenate([keys[100:110],
                        _unit(rng.standard_normal((6, d)).astype(np.float32))])
    p_cold = svc.plan(CacheRequest.build(q, 0))
    p_base = base.plan(CacheRequest.build(q, 0))
    # cold-enabled hits are a superset of warm-only hits on served keys
    assert (p_cold.hit | ~p_base.hit).all() or p_base.hit.sum() == 0
    assert not p_cold.hit[10:].any()     # random queries never hit


def test_sharded_plus_cold_rejected():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError, match="unsharded"):
        CacheService(dim=8, mesh=mesh, cold_capacity=64)
