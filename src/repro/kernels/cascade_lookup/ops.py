"""jit'd dispatch wrapper for the fused cascade lookup.

Chooses the Pallas kernel on TPU (or interpret mode when asked) and the
four-op jnp oracle otherwise — the oracle IS the original unfused
cascade math, so the CPU fallback costs nothing over the four-op path.
Both share the exact signature, so `tiers.cascade_query` is agnostic.
The ``quantized`` flag selects the int8 warm-panel variant in both
implementations (DESIGN.md §8); callers re-score the returned
``warm_slots`` exactly from the fp32 panel at merge time.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.cascade_lookup import kernel as _kernel
from repro.kernels.cascade_lookup import ref as _ref


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cascade_lookup(q, q_tenants, thresholds,
                   hot_keys, hot_valid, hot_tenants, hot_value_ids,
                   warm_keys, warm_valid, warm_tenants, warm_value_ids,
                   warm_write_seq, centroids, members, cursor, indexed_total,
                   warm_keys_q=None, warm_scales=None,
                   k: int = 1, n_probe: int = 8, tail: int = 0, *,
                   quantized: bool = False,
                   use_kernel: bool | None = None,
                   block_n: int = _kernel.DEFAULT_BLOCK_N,
                   warm_block_n: int | None = None):
    """q: (Q, D) unit-norm -> (scores, value_ids, warm_slots, hot_slots,
    hot_hit, hit); see `ref.cascade_lookup`.

    use_kernel: None -> kernel on TPU, oracle elsewhere (interpret-mode
    kernels are for correctness tests, not the CPU hot path).
    warm_block_n streams the warm panel through the kernel in blocks of
    that many rows (None = whole panel, the pre-§12 residency); the
    oracle ignores it — blocking never changes results.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return _kernel.cascade_lookup(
            q, q_tenants, thresholds, hot_keys, hot_valid, hot_tenants,
            hot_value_ids, warm_keys, warm_valid, warm_tenants,
            warm_value_ids, warm_write_seq, centroids, members, cursor,
            indexed_total, warm_keys_q, warm_scales, k, n_probe, tail,
            quantized=quantized, block_n=block_n,
            warm_block_n=warm_block_n, interpret=not _on_tpu())
    return _ref.cascade_lookup(
        q, q_tenants, thresholds, hot_keys, hot_valid, hot_tenants,
        hot_value_ids, warm_keys, warm_valid, warm_tenants, warm_value_ids,
        warm_write_seq, centroids, members, cursor, indexed_total,
        warm_keys_q, warm_scales, k, n_probe, tail, quantized=quantized)


def ensemble_lookup(q, weights, q_tenants, thresholds,
                    hot_keys, hot_valid, hot_tenants, hot_value_ids,
                    warm_keys, warm_valid, warm_tenants, warm_value_ids,
                    warm_write_seq, centroids, members, cursor, indexed_total,
                    warm_keys_q=None, warm_scales=None,
                    k: int = 1, n_probe: int = 8, tail: int = 0, *,
                    quantized: bool = False,
                    use_kernel: bool | None = None,
                    block_n: int = _kernel.DEFAULT_BLOCK_N,
                    warm_block_n: int | None = None):
    """E-panel fused ensemble dispatch (DESIGN.md §13): q (E, Q, D)
    stacked unit-norm queries, weights (Q, E) mixture weights, key
    panels stacked (E, N, D) with shared per-slot metadata and
    pilot-built IVF -> the same 6-tuple as `cascade_lookup` with the
    weighted fused score; see `ref.ensemble_lookup`.

    Dispatch rules match `cascade_lookup`: kernel on TPU (interpret
    mode when forced elsewhere), four-op oracle otherwise.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return _kernel.cascade_lookup_ensemble(
            q, weights, q_tenants, thresholds, hot_keys, hot_valid,
            hot_tenants, hot_value_ids, warm_keys, warm_valid, warm_tenants,
            warm_value_ids, warm_write_seq, centroids, members, cursor,
            indexed_total, warm_keys_q, warm_scales, k, n_probe, tail,
            quantized=quantized, block_n=block_n,
            warm_block_n=warm_block_n, interpret=not _on_tpu())
    return _ref.ensemble_lookup(
        q, weights, q_tenants, thresholds, hot_keys, hot_valid, hot_tenants,
        hot_value_ids, warm_keys, warm_valid, warm_tenants, warm_value_ids,
        warm_write_seq, centroids, members, cursor, indexed_total,
        warm_keys_q, warm_scales, k, n_probe, tail, quantized=quantized)
