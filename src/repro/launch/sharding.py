"""Logical-axis -> mesh-axis resolution (GSPMD / MaxText style).

Every parameter/state leaf carries encoded logical axes ("embed,mlp",
"batch,cache,kv_heads,head_dim", ...).  Rules map logical names to mesh
axes; resolution is *divisibility-aware* per tensor: a mesh axis that
does not divide the dimension, or was already consumed by an earlier
dimension of the same tensor, is dropped (replicated) rather than
padded.  This is what makes qwen2.5's 40 heads (∤16) or granite's kv=1
degrade gracefully, and what makes the KV-cache 'cache' axis
automatically pick up the data axes exactly when the batch cannot use
them (the long_500k batch=1 case) — see DESIGN.md §3.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.param import decode_axes

# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

# training: FSDP over 'data' on the embed axis of every weight + tensor
# parallel over 'model'; batch over (pod, data).
TRAIN_RULES: Dict[str, tuple] = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
    "cache": ("pod", "data"),
    "conv": (),
    "ssm": (),
    "ssm_state": (),
    "corpus": ("model",),
}

# serving: same tensor-parallel layout; weights additionally sharded over
# 'data' (weight-stationary FSDP-for-inference keeps the 34B+ configs
# within HBM; the §Perf loop revisits this for latency).
SERVE_RULES = dict(TRAIN_RULES)

# ---------------------------------------------------------------------------
# §Perf hillclimb variants (EXPERIMENTS.md §Perf documents the deltas)
# ---------------------------------------------------------------------------

# H1: serving WITHOUT weight-FSDP — weights replicated across 'data',
# sharded only over 'model'.  Hypothesis: kills the per-layer weight
# all-gathers that dominate the collective term of prefill, at the cost
# of 16x weight HBM (fine below ~100B params at bf16).
SERVE_NOFSDP_RULES = dict(TRAIN_RULES)
SERVE_NOFSDP_RULES["embed"] = ()

# H2: sequence-sharded KV cache for decode — the cache-length axis gets
# first claim on 'model' (flash-decode style partial-softmax combine).
# Hypothesis: for GQA archs whose kv_heads don't divide the model axis
# (kv=8 or 1 vs 16), the baseline replicates the KV cache 16x over
# 'model'; seq-sharding cuts decode per-device KV bytes ~16x for a tiny
# partial-attention all-reduce.
SERVE_SEQSHARD_RULES = dict(TRAIN_RULES)
SERVE_SEQSHARD_RULES["cache"] = ("model", "pod", "data")

# H3 (cache_serve): the 149M encoder needs NO tensor parallelism — its
# per-layer all-reduces dominate the lookup's collective term.  Pure
# data-parallel encoder (weights replicated, 600MB), corpus sharded over
# the otherwise-idle 'model' axis, local-topk + tiny merge.
CACHE_DP_RULES = {**TRAIN_RULES,
                  "embed": (), "heads": (), "kv_heads": (), "mlp": (),
                  "vocab": (), "experts": ()}

RULE_SETS = {
    "train": TRAIN_RULES,
    "serve": SERVE_RULES,
    "serve_nofsdp": SERVE_NOFSDP_RULES,
    "serve_seqshard": SERVE_SEQSHARD_RULES,
    "cache_dp": CACHE_DP_RULES,
}


def resolve_pspec(shape, axes_str: str, mesh, rules: Dict[str, tuple]
                  ) -> PartitionSpec:
    axes = decode_axes(axes_str)
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} do not match shape {shape}")
    # H4 (§Perf): 1-D parameter vectors (norm scales, biases) are tiny —
    # sharding them makes GSPMD reshard the *activations* they touch
    # (batch-replicating 8GB tensors around every norm).  Replicate all
    # weight vectors except genuinely large ones.
    if len(shape) == 1 and axes and axes[0] not in ("batch", "cache",
                                                    "corpus", "seq"):
        return PartitionSpec()
    used = set()
    parts = []
    for dim, name in zip(shape, axes):
        cand = rules.get(name, ()) if name else ()
        if isinstance(cand, str):
            cand = (cand,)
        sel = [a for a in cand if a in mesh.shape and a not in used]
        # drop trailing axes until the product divides the dimension
        while sel and dim % math.prod(mesh.shape[a] for a in sel) != 0:
            sel.pop()
        if sel:
            used.update(sel)
            parts.append(tuple(sel) if len(sel) > 1 else sel[0])
        else:
            parts.append(None)
    # trim trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def sharding_tree(values, axes_tree, mesh, rules=TRAIN_RULES):
    """Map (value_tree, encoded_axes_tree) -> NamedSharding tree."""

    def one(v, s):
        return NamedSharding(mesh, resolve_pspec(v.shape, s, mesh, rules))

    return jax.tree_util.tree_map(one, values, axes_tree)


def scalar_sharding(mesh):
    return NamedSharding(mesh, PartitionSpec())


def replicate_tree(values, mesh):
    return jax.tree_util.tree_map(lambda v: scalar_sharding(mesh), values)


def sharded_bytes(values, axes_tree, mesh, rules=TRAIN_RULES) -> int:
    """Per-device bytes for a (values, axes) tree under the rules."""
    total = 0
    flat_v, _ = jax.tree_util.tree_flatten(values)
    flat_s, _ = jax.tree_util.tree_flatten(axes_tree)
    for v, s in zip(flat_v, flat_s):
        spec = resolve_pspec(v.shape, s, mesh, rules)
        shard = 1
        for dim, part in zip(v.shape, tuple(spec) + (None,) * (len(v.shape) - len(spec))):
            if part is None:
                shard_dim = dim
            else:
                names = part if isinstance(part, tuple) else (part,)
                shard_dim = dim // math.prod(mesh.shape[a] for a in names)
            shard *= shard_dim
        total += shard * np.dtype(v.dtype).itemsize
    return total
