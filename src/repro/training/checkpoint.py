"""msgpack checkpointing for arbitrary pytrees of arrays.

No orbax offline — nested dicts/lists/tuples/NamedTuples of jnp/np
arrays and scalars round-trip through msgpack with an ``__nd__`` framing
for ndarray leaves.  Atomic write (tmp + rename).
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_ND = "__nd__"


def _encode(obj):
    if isinstance(obj, (jnp.ndarray, np.ndarray)) or hasattr(obj, "__array__"):
        arr = np.asarray(obj)
        return {_ND: True, "dtype": str(arr.dtype), "shape": list(arr.shape),
                "data": arr.tobytes()}
    if isinstance(obj, dict):
        return {"__map__": [[_encode(k), _encode(v)] for k, v in obj.items()]}
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):  # NamedTuple
        return {"__nt__": type(obj).__name__,
                "fields": {f: _encode(getattr(obj, f)) for f in obj._fields}}
    if isinstance(obj, (list, tuple)):
        return {"__seq__": "tuple" if isinstance(obj, tuple) else "list",
                "items": [_encode(x) for x in obj]}
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    raise TypeError(f"cannot checkpoint {type(obj)}")


def _decode(obj, namedtuple_types=None):
    ntt = namedtuple_types or {}
    if isinstance(obj, dict):
        if obj.get(_ND):
            return np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"])
                                 ).reshape(obj["shape"]).copy()
        if "__map__" in obj:
            return {_decode(k, ntt): _decode(v, ntt) for k, v in obj["__map__"]}
        if "__nt__" in obj:
            fields = {f: _decode(v, ntt) for f, v in obj["fields"].items()}
            cls = ntt.get(obj["__nt__"])
            if cls is not None:
                return cls(**fields)
            return fields  # degrade to a dict if the type isn't supplied
        if "__seq__" in obj:
            items = [_decode(x, ntt) for x in obj["items"]]
            return tuple(items) if obj["__seq__"] == "tuple" else items
    return obj


def save_checkpoint(path: str, tree) -> None:
    host_tree = jax.tree_util.tree_map(np.asarray, tree)
    payload = msgpack.packb(_encode(host_tree), use_bin_type=True)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, namedtuple_types: dict | None = None):
    from repro.training.optim import AdamState
    ntt = {"AdamState": AdamState}
    ntt.update(namedtuple_types or {})
    with open(path, "rb") as f:
        return _decode(msgpack.unpackb(f.read(), raw=False), ntt)
