"""Serving launcher: batched generation for any registry arch, with an
optional semantic cache in front (the paper's deployment).

    PYTHONPATH=src python -m repro.launch.serve \
        --arch phi3-mini-3.8b --smoke --requests 32 --batch 8 --cache

``--tiered`` swaps the flat SemanticCache for the tiered CacheService;
``--cache-shards N`` then lays its warm tier over an N-device `model`
mesh (local IVF probe per shard + tiny merge, DESIGN.md §8),
``--warm-dtype int8`` scans the warm panel from its quantized form,
``--learned-admission`` turns the static per-tenant operating
points into the online feedback loop (DESIGN.md §9), and
``--learned-embedder`` additionally fine-tunes the compact embedder
from pooled serving feedback in the background, hot-swapping it with a
versioned shadow re-embed of the cached corpus (DESIGN.md §11), and
``--cold-capacity N`` backs the warm ring with an N-row host-RAM cold
tier — warm evictions demote instead of dropping, below-threshold
queries fall through to a budgeted cold fetch, and re-hot rows promote
back up on the idle tick (DESIGN.md §12).  ``--ensemble E`` serves E
embedders through the fused multi-embedder cascade — the fine-tuned
embedder is the pilot, the extra panels are random-projection
embedders, and the feedback loop learns per-tenant mixture weights
(DESIGN.md §13).

``--metrics-json PATH`` dumps the telemetry registry (DESIGN.md §10)
as JSON-lines — one meta line then one line per metric series — after
the run; ``--metrics-interval N`` additionally appends a snapshot
every N batches, so the file holds a time series.  Validate with
``python -m repro.obs.export --validate PATH``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import EmbedderTrainer, FinetuneConfig, SemanticCache
from repro.data import HashTokenizer, make_pair_dataset, make_query_stream
from repro.models import init_lm, split
from repro.obs import Telemetry, write_jsonl
from repro.serving import CachedLLMService, ServeEngine


def run_scenario(args):
    """--scenario NAME: load the §14.1 trace generators by path (the
    benchmarks tree is not a package) and replay one trace against a
    fresh tiered cache under the trace's logical clock."""
    import importlib.util
    from pathlib import Path
    bench = Path(__file__).resolve().parents[3] / "benchmarks" \
        / "bench_scenarios.py"
    spec = importlib.util.spec_from_file_location("bench_scenarios",
                                                  bench)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    if args.scenario not in mod.SCENARIOS:
        raise SystemExit(f"unknown scenario {args.scenario!r}; have "
                         f"{sorted(mod.SCENARIOS)}")
    trace = mod.build(args.scenario, smoke=args.smoke)
    row = mod.replay(trace, conformal=args.conformal)
    print(f"scenario {row['scenario']} ({row['mode']} mode): "
          f"{row['n_queries']} queries over {row['n_steps']} steps")
    print(f"  hit rate {row['hit_rate']:.3f}, false-hit rate "
          f"{row['false_hit_rate']:.4f} (budget "
          f"{row['false_hit_budget']}), stale serves "
          f"{row['stale_serves']}")
    print(f"  plan p50 {row['p50_us_per_row']:.0f} us/row, "
          f"p99 {row['p99_us_per_row']:.0f} us/row "
          f"({row['timed_batches']} timed batches)")
    if row.get("ttl_stamped"):
        print(f"  ttl: {row['ttl_stamped']} stamped, "
              f"{row['expired_masked']} masked, "
              f"{row['expired_reaped']} reaped")
    if row.get("conformal_floors"):
        floors = ", ".join(f"t{t}={v:.3f}"
                           for t, v in sorted(row["conformal_floors"]
                                              .items()))
        print(f"  conformal: {row['hit_audits']} hits audited, "
              f"{row['audited_false_hits']} false; floors {floors}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--cache", action="store_true")
    ap.add_argument("--threshold", type=float, default=0.93)
    ap.add_argument("--tiered", action="store_true",
                    help="tiered CacheService instead of the flat "
                         "SemanticCache")
    ap.add_argument("--cache-shards", type=int, default=0,
                    help="shard the warm tier over a model-axis mesh of "
                         "N devices (0 = unsharded; implies --tiered)")
    ap.add_argument("--warm-dtype", choices=("float32", "int8"),
                    default="float32",
                    help="warm-panel scan precision; int8 quantizes the "
                         "warm keys (exact re-score at merge, DESIGN.md "
                         "§8; implies --tiered)")
    ap.add_argument("--learned-admission", action="store_true",
                    help="learn per-tenant thresholds/admission margins "
                         "online from observed duplicate rates "
                         "(DESIGN.md §9; implies --tiered)")
    ap.add_argument("--cold-capacity", type=int, default=0,
                    help="host-RAM cold-tier rows behind the warm ring "
                         "(0 = no cold tier; DESIGN.md §12; implies "
                         "--tiered, incompatible with --cache-shards)")
    ap.add_argument("--warm-block", type=int, default=0,
                    help="stream the fused kernel's warm panel in blocks "
                         "of N rows (0 = whole-panel residency; "
                         "DESIGN.md §12)")
    ap.add_argument("--ensemble", type=int, default=0, metavar="E",
                    help="serve E embedders through the fused multi-"
                         "embedder cascade: the fine-tuned embedder is "
                         "the pilot, panels 1..E-1 are random-projection "
                         "embedders, mixture weights learned per tenant "
                         "(DESIGN.md §13; implies --tiered, incompatible "
                         "with --learned-embedder)")
    ap.add_argument("--learned-embedder", action="store_true",
                    help="refresh the compact embedder online from pooled "
                         "serving feedback and hot-swap it with a "
                         "versioned shadow re-embed (DESIGN.md §11; "
                         "implies --tiered)")
    ap.add_argument("--ttl", type=float, default=0.0, metavar="SECONDS",
                    help="default TTL stamped on every admitted entry "
                         "(0 = never expire); expired entries are masked "
                         "at plan time and reaped on the maintenance "
                         "tick (DESIGN.md §14.2; implies --tiered)")
    ap.add_argument("--conformal", action="store_true",
                    help="per-tenant split-conformal hit calibration: "
                         "serve only above a recency-window quantile of "
                         "observed negative scores, bounding the "
                         "false-hit rate under drift (DESIGN.md §14.3; "
                         "implies --tiered)")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="replay one benchmarks/scenarios.py trace "
                         "against a fresh tiered cache under its logical "
                         "clock and print the scored row (no LLM engine; "
                         "DESIGN.md §14.1) — e.g. drift, ttl_churn")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the telemetry registry snapshot as "
                         "JSON-lines after the run (DESIGN.md §10.1; "
                         "requires --cache)")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    metavar="N",
                    help="with --metrics-json: also append a snapshot "
                         "every N batches (0 = final snapshot only)")
    args = ap.parse_args()
    if args.scenario:
        return run_scenario(args)
    if args.metrics_json and not args.cache:
        ap.error("--metrics-json instruments the cached serving path; "
                 "add --cache")
    if args.cache_shards or args.warm_dtype != "float32" \
            or args.learned_admission or args.learned_embedder \
            or args.cold_capacity or args.warm_block or args.ensemble \
            or args.ttl or args.conformal:
        args.tiered = True
    if args.cold_capacity and args.cache_shards:
        ap.error("--cold-capacity needs the unsharded warm ring; drop "
                 "--cache-shards (DESIGN.md §12)")
    if args.ensemble == 1:
        ap.error("--ensemble needs E >= 2 (a single embedder is the "
                 "default cascade)")
    if args.ensemble and args.learned_embedder:
        ap.error("--ensemble and --learned-embedder are exclusive: the "
                 "§11 refresh re-embeds one key panel, the §13 ensemble "
                 "serves several (swap panels via publish_panel instead)")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    engine = ServeEngine(cfg, pv, max_len=64)
    print(f"serving {cfg.name} ({cfg.param_count():,} params)")

    if not args.cache:
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for i in range(0, args.requests, args.batch):
            prompts = rng.integers(0, cfg.vocab_size,
                                   (args.batch, 16)).astype(np.int32)
            res = engine.generate(prompts, args.max_new_tokens)
            print(f"batch {i//args.batch}: generated "
                  f"{res.tokens.shape[1]} tokens x {res.tokens.shape[0]}")
        print(f"total {time.perf_counter() - t0:.1f}s")
        return

    enc_cfg = get_config("modernbert-149m").reduced(vocab_size=4096)
    tok = HashTokenizer(vocab_size=enc_cfg.vocab_size)
    trainer = EmbedderTrainer(enc_cfg, FinetuneConfig(
        epochs=1, batch_size=32, lr=5e-4, max_len=24))
    trainer.fit(make_pair_dataset("medical", 512, seed=0), tok)
    telemetry = Telemetry()
    if args.tiered:
        from repro.cache_service import (
            CacheConfig, CacheService, EmbedderRefreshPolicy,
            EnsembleConfig, LearningConfig, ShardingConfig,
            StalenessConfig, TieringConfig,
        )
        from repro.launch.mesh import make_cache_mesh
        mesh = make_cache_mesh(args.cache_shards) if args.cache_shards \
            else None
        # smoke-scale refresh policy: trip the trigger inside a short
        # stream, backfill thin splits from the medical grammar (§11)
        refresh = EmbedderRefreshPolicy(
            min_pairs=24, min_class=4, refresh_interval=32,
            synth_domain="medical", synth_min_pairs=128,
            recalibrate=True,
        ) if args.learned_embedder else None
        cache = CacheService(CacheConfig(
            dim=enc_cfg.d_model, threshold=args.threshold,
            telemetry=telemetry,
            tiering=TieringConfig(hot_capacity=512, warm_capacity=4096,
                                  n_clusters=32, bucket=256,
                                  warm_dtype=args.warm_dtype,
                                  warm_block=args.warm_block or None,
                                  cold_capacity=args.cold_capacity),
            sharding=ShardingConfig(mesh=mesh),
            learning=LearningConfig(
                learned_admission=args.learned_admission,
                conformal=args.conformal,
                learned_embedder=args.learned_embedder,
                embedder_trainer=trainer
                if args.learned_embedder else None,
                embedder_tokenizer=tok
                if args.learned_embedder else None,
                refresh_policy=refresh),
            ensemble=EnsembleConfig(embedders=args.ensemble or None),
            staleness=StalenessConfig(default_ttl=args.ttl or None)))
        caps = cache.capabilities()
        print(f"tiered cache: warm shards "
              f"{cache.warm_shards if caps.warm_sharded else 0}, "
              f"warm dtype {caps.warm_dtype}, learned admission "
              f"{'on' if caps.learned_admission else 'off'}, "
              f"learned embedder "
              f"{'on' if caps.learned_embedder else 'off'}, "
              f"cold tier {args.cold_capacity if caps.cold_tier else 0} "
              f"rows, ensemble "
              f"{f'E={caps.ensemble}' if caps.ensemble else 'off'}, "
              f"ttl {args.ttl or 'off'}, conformal "
              f"{'on' if caps.conformal else 'off'}")
    else:
        cache = SemanticCache(capacity=4096, dim=enc_cfg.d_model,
                              threshold=args.threshold, telemetry=telemetry)
    embed_fn = trainer.make_embed_fn(tok)
    if args.ensemble:
        # pilot = the fine-tuned embedder; the extra panels are cheap
        # independent views (random projections, distinct seeds) so the
        # fused cascade and the weight learner see genuine diversity
        from repro.core.embedders import RandomProjectionEmbedder
        extras = [RandomProjectionEmbedder(dim=enc_cfg.d_model,
                                           seed=101 + e)
                  for e in range(args.ensemble - 1)]
        pilot_fn = embed_fn

        def embed_fn(texts):
            panels = [pilot_fn(texts)] + [np.asarray(e.embed(texts))
                                          for e in extras]
            return np.stack(panels, axis=1)        # (B, E, D)
    svc = CachedLLMService(embed_fn, cache, engine, tok,
                           max_new_tokens=args.max_new_tokens)

    def dump_metrics(batch_idx, append):
        write_jsonl(args.metrics_json, telemetry.registry.snapshot(),
                    meta={"arch": cfg.name, "batch": batch_idx,
                          "tiered": args.tiered}, append=append)

    stream = [q.text for q in make_query_stream("medical", args.requests,
                                                seed=1, repeat_frac=0.4)]
    t0 = time.perf_counter()
    wrote = False
    for i in range(0, len(stream), args.batch):
        svc.handle(stream[i:i + args.batch])
        b = i // args.batch
        if args.metrics_json and args.metrics_interval \
                and (b + 1) % args.metrics_interval == 0:
            dump_metrics(b, append=wrote)
            wrote = True
    cache.maintenance(block=True)     # final idle tick: drain SLO gauges
    print(f"{args.requests} requests in {time.perf_counter() - t0:.1f}s; "
          f"hit rate {svc.hit_rate:.1%} "
          f"({int(svc.stats()['hits'])} LLM calls saved)")
    stage_h = telemetry.stage_histogram()
    for stage in ("embed", "plan", "cold_fetch", "generate", "commit",
                  "maintenance"):
        agg = stage_h.aggregate(stage=stage)
        if agg.count:
            print(f"  stage {stage:<12} p50 {agg.quantile(0.5) * 1e3:7.2f} "
                  f"ms  mean {agg.mean * 1e3:7.2f} ms  x{agg.count}")
    if args.cold_capacity:
        cd = cache.stats_snapshot().tiers["cold"]
        print(f"cold tier: {cd['cold_rows']} rows "
              f"({cd['cold_occupancy']:.0%} of {args.cold_capacity}), "
              f"{cd['cold_hits']} hits from {cd['cold_fetches']} fetches "
              f"({cd['cold_fetched_rows']} rows shipped, "
              f"{cd['cold_router_skips']} router skips); "
              f"{cd['cold_promoted']} promoted back to warm, "
              f"{cd['cold_dropped']} final drops")
    if args.ensemble:
        ws = cache.policies.weights_state()
        print(f"ensemble: {cache.capabilities().ensemble} embedders, "
              f"{len(ws)} tenant(s) with learned mixture weights")
    # backend sections nest under svc.stats()["backend"] since the flat
    # stats() view was removed in v2.0
    if args.learned_admission:
        lrn = svc.stats()["backend"]["learning"]
        print(f"learned admission: {lrn['refits_applied']} refits from "
              f"{lrn['feedback_events']} events "
              f"({lrn['duplicate_events']} duplicates, "
              f"{lrn['wasted_admissions']} wasted admissions); "
              f"policies {lrn['learned_policies']}")
    if args.learned_embedder:
        bk = svc.stats()["backend"]
        rf, lrn = bk["refresh"], bk["learning"]
        print(f"learned embedder: version {rf['embed_version']} "
              f"({rf['refreshes_published']} published, "
              f"{rf['refreshes_rolled_back']} rolled back from "
              f"{rf['refreshes_started']} started; "
              f"{rf['pairs_held']} pairs pooled, "
              f"{rf['stale_version_commits']} stale-version commits; "
              f"recalibrated threshold "
              f"{rf['recalibrated_threshold']})")
    if args.ttl:
        stl = cache.stats_snapshot().tiers["staleness"]
        print(f"ttl: {stl['ttl_stamped']} stamped, "
              f"{stl['expired_masked']} masked at plan time, "
              f"{stl['expired_reaped']} reaped")
    if args.conformal:
        cs = cache.stats_snapshot().learning["conformal"]
        print(f"conformal: {cs['hit_audits']} hit audits "
              f"({cs['audited_false_hits']} false), "
              f"{len(cs['tenants'])} tenant window(s)")
    if args.metrics_json:
        dump_metrics(args.requests // args.batch, append=wrote)
        print(f"metrics -> {args.metrics_json}")


if __name__ == "__main__":
    main()
