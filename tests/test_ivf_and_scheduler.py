"""IVF index, threshold calibration, and the continuous batcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.calibration import (
    calibrate_for_false_hit_budget, calibrate_for_precision,
)
from repro.core.ivf import build_ivf, ivf_occupancy, ivf_query
from repro.core.store import init_store, insert_batch, query
from repro.models import init_lm, split
from repro.serving.scheduler import ContinuousBatcher, Request

rng = np.random.default_rng(21)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _clustered_keys(n_clusters=16, per=32, d=32, spread=0.15):
    cents = _unit(rng.standard_normal((n_clusters, d)).astype(np.float32))
    keys = np.repeat(cents, per, axis=0)
    keys = _unit(keys + spread * rng.standard_normal(keys.shape
                                                     ).astype(np.float32))
    return keys


# ---------------------------------------------------------------------------
# IVF
# ---------------------------------------------------------------------------

def test_ivf_recall_on_clustered_keys():
    keys = _clustered_keys()
    N = len(keys)
    valid = jnp.ones(N, bool)
    vids = jnp.arange(N)
    state = build_ivf(jnp.asarray(keys), valid, vids, n_clusters=16,
                      bucket=64)
    assert float(ivf_occupancy(state)) > 0.99
    # query with slightly perturbed members: exact match must be found
    q_idx = rng.choice(N, 32, replace=False)
    q = jnp.asarray(_unit(keys[q_idx] + 0.01 * rng.standard_normal(
        (32, keys.shape[1])).astype(np.float32)))
    s, slots, v, hit = ivf_query(state, q, threshold=0.9, k=1, n_probe=4)
    exact_s, exact_i = None, None
    flat = init_store(N, keys.shape[1])
    flat = insert_batch(flat, jnp.asarray(keys), vids)
    res = query(flat, q, threshold=0.9, k=1)
    agreement = np.mean(np.asarray(v[:, 0]) == np.asarray(
        res.value_ids[:, 0]))
    assert agreement > 0.9, agreement     # >90% top-1 recall vs exact
    assert bool(jnp.all(hit == res.hit)) or agreement > 0.9


def test_ivf_respects_validity():
    keys = _clustered_keys(4, 16)
    N = len(keys)
    valid = jnp.asarray(np.arange(N) % 2 == 0)
    state = build_ivf(jnp.asarray(keys), valid, jnp.arange(N),
                      n_clusters=4, bucket=32)
    q = jnp.asarray(keys[1:2])  # an INVALID row's key
    s, slots, v, hit = ivf_query(state, q, threshold=0.999, k=1, n_probe=4)
    assert int(v[0, 0]) != 1  # must not return the invalid row


def test_ivf_query_jits():
    keys = _clustered_keys(8, 16)
    state = build_ivf(jnp.asarray(keys), jnp.ones(len(keys), bool),
                      jnp.arange(len(keys)), n_clusters=8, bucket=32)
    f = jax.jit(lambda st, q: ivf_query(st, q, 0.9, 2, 2))
    s, slots, v, hit = f(state, jnp.asarray(keys[:4]))
    assert s.shape == (4, 2)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def _scored_pairs(n=2000, sep=1.0):
    labels = rng.integers(0, 2, n).astype(np.int32)
    scores = rng.normal(labels * sep, 0.5)
    return scores, labels


def test_calibrate_for_precision():
    scores, labels = _scored_pairs()
    cal = calibrate_for_precision(scores, labels, min_precision=0.95)
    assert cal.expected_precision >= 0.95
    pred = scores >= cal.threshold
    emp_prec = (pred & (labels == 1)).sum() / max(pred.sum(), 1)
    assert emp_prec >= 0.93


def test_calibrate_for_false_hit_budget():
    scores, labels = _scored_pairs()
    cal = calibrate_for_false_hit_budget(scores, labels,
                                         max_false_hit_rate=0.02)
    assert cal.false_hit_rate <= 0.02 + 1e-9
    neg = scores[labels == 0]
    assert (neg >= cal.threshold).mean() <= 0.025


def test_calibrate_all_positive_labels():
    """No negatives observed: the loosest threshold still hits every
    positive, with a vacuously satisfied budget."""
    scores = np.asarray([0.7, 0.8, 0.9])
    labels = np.ones(3, np.int32)
    for fn, kw in ((calibrate_for_false_hit_budget,
                    {"max_false_hit_rate": 0.01}),
                   (calibrate_for_precision, {"min_precision": 0.95})):
        cal = fn(scores, labels, **kw)
        assert cal.threshold <= 0.7
        assert cal.expected_recall == 1.0
        assert cal.false_hit_rate == 0.0


def test_calibrate_all_negative_labels():
    """No positives observed: the threshold must hit (almost) nothing
    — in particular calibrate_for_precision must not return a cut
    whose actual precision silently misses the target."""
    scores = np.asarray([0.2, 0.5, 0.9])
    labels = np.zeros(3, np.int32)
    cal = calibrate_for_precision(scores, labels, min_precision=0.95)
    assert cal.threshold > 0.9              # admits nothing
    assert cal.false_hit_rate == 0.0
    assert (scores >= cal.threshold).sum() == 0
    cal = calibrate_for_false_hit_budget(scores, labels,
                                         max_false_hit_rate=0.01)
    assert (scores >= cal.threshold).mean() <= 0.01 + 1e-9
    assert cal.expected_recall == 0.0


def test_calibrate_tied_scores_at_the_cut():
    """A threshold admits EVERY tie at its value: a cut inside a tie
    group must not report cumulative stats the threshold cannot
    realize."""
    scores = np.asarray([0.9, 0.9, 0.9, 0.5])
    labels = np.asarray([1, 1, 0, 0], np.int32)
    cal = calibrate_for_precision(scores, labels, min_precision=0.95)
    # the only honest cuts are >0.9 (empty) or >=0.9 (precision 2/3)
    # or >=0.5 (precision 2/4): none reaches 0.95 except the empty one
    pred = scores >= cal.threshold
    emp = (pred & (labels == 1)).sum() / max(pred.sum(), 1)
    assert emp >= 0.95 or pred.sum() == 0
    # expected_precision reflects what the threshold actually admits
    assert abs(cal.expected_precision - emp) < 1e-9 or pred.sum() == 0
    cal2 = calibrate_for_precision(scores, labels, min_precision=0.6)
    pred2 = scores >= cal2.threshold
    emp2 = (pred2 & (labels == 1)).sum() / pred2.sum()
    assert emp2 >= 0.6
    assert abs(cal2.expected_precision - emp2) < 1e-9
    # budget estimator: ties at the quantile are all excluded
    cal3 = calibrate_for_false_hit_budget(scores, labels,
                                          max_false_hit_rate=0.01)
    neg = scores[labels == 0]
    assert (neg >= cal3.threshold).mean() <= 0.01 + 1e-9


def test_calibrate_single_sample():
    for lab, recall in ((1, 1.0), (0, 0.0)):
        cal = calibrate_for_false_hit_budget([0.8], [lab])
        assert cal.expected_recall == recall
        assert cal.false_hit_rate == 0.0
        cal = calibrate_for_precision([0.8], [lab], min_precision=0.95)
        assert cal.expected_recall == recall
        assert cal.false_hit_rate == 0.0


# ---------------------------------------------------------------------------
# continuous batcher
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def batcher_setup():
    cfg = get_config("phi3-mini-3.8b").reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    return cfg, pv


def test_continuous_batching_completes_all(batcher_setup):
    cfg, pv = batcher_setup
    b = ContinuousBatcher(cfg, pv, n_slots=3, max_len=64, prompt_len=8)
    reqs = [Request(uid=i,
                    prompt=rng.integers(4, cfg.vocab_size, 6).astype(
                        np.int32),
                    max_new_tokens=4 + (i % 3))
            for i in range(7)]
    for r in reqs:
        b.submit(r)
    done = b.run(max_ticks=200)
    assert sorted(done) == list(range(7))
    for r in done.values():
        assert 1 <= len(r.generated) <= r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in r.generated)


def test_batcher_drives_maintenance_on_idle_ticks(batcher_setup):
    """The maintenance hook rides the real idle signal: it runs on
    ticks with host headroom (queue drained / free slots), not
    unconditionally on every saturated decode tick."""
    cfg, pv = batcher_setup
    calls = []
    b = ContinuousBatcher(cfg, pv, n_slots=2, max_len=64, prompt_len=8,
                          maintenance=lambda: calls.append(1))
    b.submit(Request(uid=0,
                     prompt=rng.integers(4, cfg.vocab_size, 6).astype(
                         np.int32),
                     max_new_tokens=3))
    b.run(max_ticks=50)
    # one request on two slots: every tick is idle, so the hook runs
    # each tick — the PR-3 behaviour is preserved exactly when idle
    assert b.ticks > 0 and len(calls) == b.ticks
    assert b.maintenance_runs == len(calls) and b.maintenance_skips == 0


def test_batcher_defers_maintenance_under_backlog(batcher_setup):
    """With more pending requests than slots, decode ticks are not
    idle: maintenance is deferred (skips counted), resumes once the
    queue drains, and the starvation bound forces a run regardless."""
    cfg, pv = batcher_setup
    calls = []
    b = ContinuousBatcher(cfg, pv, n_slots=1, max_len=64, prompt_len=8,
                          maintenance=lambda: calls.append(b.ticks),
                          maintenance_max_interval=64)
    for i in range(3):
        b.submit(Request(uid=i,
                         prompt=rng.integers(4, cfg.vocab_size, 6).astype(
                             np.int32),
                         max_new_tokens=4))
    b.run(max_ticks=60)
    # the single-slot pool stays saturated while requests queue: those
    # ticks must skip, and the drained tail must still run the hook
    assert b.maintenance_skips > 0
    assert b.maintenance_runs > 0
    assert b.maintenance_runs + b.maintenance_skips == b.ticks

    # starvation bound: a permanently-backlogged batcher still runs the
    # hook every maintenance_max_interval ticks
    calls2 = []
    b2 = ContinuousBatcher(cfg, pv, n_slots=1, max_len=64, prompt_len=8,
                           maintenance=lambda: calls2.append(1),
                           maintenance_max_interval=5)
    for i in range(8):
        b2.submit(Request(uid=i,
                          prompt=rng.integers(4, cfg.vocab_size, 6).astype(
                              np.int32),
                          max_new_tokens=30))
    for _ in range(20):
        b2.tick()
    assert len(b2.pending) > 0          # still backlogged (never idle)
    assert len(calls2) == 20 // 5


def test_continuous_batching_matches_sequential(batcher_setup):
    """Tokens produced in the slot pool must equal a lone generation
    (slot isolation: no cross-request state leakage)."""
    cfg, pv = batcher_setup
    prompt = rng.integers(4, cfg.vocab_size, 6).astype(np.int32)

    lone = ContinuousBatcher(cfg, pv, n_slots=1, max_len=64, prompt_len=8)
    lone.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    ref = lone.run()[0].generated

    crowd = ContinuousBatcher(cfg, pv, n_slots=3, max_len=64, prompt_len=8)
    crowd.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    for i in range(1, 5):
        crowd.submit(Request(uid=i,
                             prompt=rng.integers(4, cfg.vocab_size, 6
                                                 ).astype(np.int32),
                             max_new_tokens=5))
    out = crowd.run()[0].generated
    assert out == ref, (out, ref)
