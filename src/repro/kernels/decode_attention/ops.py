"""Dispatch wrapper for flash-decode attention (model layout in/out)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention import kernel as _kernel
from repro.kernels.decode_attention import ref as _ref


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k, v, kv_valid, *, use_kernel: bool | None = None,
                     block_l: int = _kernel.DEFAULT_BLOCK_L):
    """q: (B, 1, H, hd) single step (model layout); k, v: (B, L, KV, hd);
    kv_valid: (B, L).  Returns (B, 1, H, hd)."""
    q3 = q[:, 0]
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        o = _kernel.decode_attention(q3, k, v, kv_valid,
                                     block_l=block_l,
                                     interpret=not _on_tpu())
    else:
        o = _ref.decode_attention(q3, k, v, kv_valid)
    return o[:, None]
