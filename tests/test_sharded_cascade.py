"""Sharded warm tier (DESIGN.md §8): shard_map-vs-oracle and
sharded-vs-single-device parity for `cascade_query` (fused and
unfused, fp32 and int8) across 1/2/8 virtual devices, the shared
local-topk/tiny-merge helper, the quantization error bound, a
`warm_publish_index` swap mid-stream and `evict_tenant` on a sharded
warm tier.  Multi-device cases need
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the dedicated
CI job); below that device count they skip, the single-device cases
always run."""
import re
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import commit_insert, plan_lookup
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.cache_service import CacheService, tiers
from repro.core import ivf as ivf_lib
from repro.core.distrib import merge_local_topk, merge_stacked_topk
from repro.launch.mesh import make_host_mesh

rng = np.random.default_rng(11)

N_DEV = len(jax.devices())


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _need_devices(n):
    if N_DEV < n:
        pytest.skip(f"needs {n} devices, have {N_DEV} (run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _hot(Nh=40, D=16, n_tenants=3):
    hk = jnp.asarray(_unit(rng.standard_normal((Nh, D)).astype(np.float32)))
    return tiers.init_hot(Nh, D)._replace(
        keys=hk, valid=jnp.asarray(rng.random(Nh) > 0.3),
        tenants=jnp.asarray(rng.integers(0, n_tenants, Nh), jnp.int32),
        value_ids=jnp.asarray(rng.integers(0, 1000, Nh), jnp.int32))


def _warm_shard(cap, D, K, bucket, n_tenants=3, unindexed=6, vid_base=1000):
    wk = jnp.asarray(_unit(rng.standard_normal((cap, D)).astype(np.float32)))
    wv = jnp.asarray(rng.random(cap) > 0.2)
    cent = ivf_lib.kmeans(wk, wv, K, 4, 0)
    members, sizes = ivf_lib.build_lists(wk, wv, cent, bucket)
    w = tiers.init_warm(cap, D, K, bucket)._replace(
        keys=wk, valid=wv,
        tenants=jnp.asarray(rng.integers(0, n_tenants, cap), jnp.int32),
        # unique per shard (and across shards via vid_base spacing) so
        # tests may invert value id -> row
        value_ids=jnp.asarray(vid_base + rng.permutation(1000)[:cap],
                              jnp.int32),
        write_seq=jnp.asarray(rng.permutation(cap) + 1, jnp.int32),
        cursor=jnp.asarray(int(rng.integers(0, cap)), jnp.int32),
        total=jnp.asarray(cap, jnp.int32), centroids=cent, members=members,
        sizes=sizes, indexed_total=jnp.asarray(cap - unindexed, jnp.int32))
    return tiers.requantize(w)


def _swarm(S, cap=32, D=16, K=4, bucket=8, **kw):
    return tiers.stack_warm(
        [_warm_shard(cap, D, K, bucket, vid_base=1000 + 1000 * s, **kw)
         for s in range(S)])


def _queries(n_q, D, n_tenants=3):
    q = jnp.asarray(_unit(rng.standard_normal((n_q, D)).astype(np.float32)))
    qt = jnp.asarray(rng.integers(0, n_tenants, n_q), jnp.int32)
    thr = jnp.asarray(rng.uniform(0.2, 0.9, n_q).astype(np.float32))
    return q, qt, thr


def _assert_same(a, b, fields=tiers.CascadeResult._fields):
    for name in fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


def _shard_put(swarm, mesh):
    """Lay the stacked warm state out on the mesh (leading axis over
    `model`) so lookups read resident shards instead of resharding."""
    return tiers.place_warm_sharded(swarm, mesh)


# ---------------------------------------------------------------------------
# shared merge helper
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [1, 2])
def test_merge_helper_collective_matches_stacked_and_concat(S):
    _need_devices(S)
    mesh = make_host_mesh(1, S)
    k, Q = 3, 5
    s = jnp.asarray(rng.standard_normal((S, Q, k)).astype(np.float32))
    pay = jnp.asarray(rng.integers(0, 99, (S, Q, k)), jnp.int32)

    sm_o, pm_o = merge_stacked_topk(k, s, pay)
    # the stacked oracle == lax.top_k over the shard-major concat
    flat_s = jnp.moveaxis(s, 0, 1).reshape(Q, S * k)
    flat_p = jnp.moveaxis(pay, 0, 1).reshape(Q, S * k)
    sm_ref, im = jax.lax.top_k(flat_s, k)
    rows = jnp.arange(Q)[:, None]
    np.testing.assert_array_equal(np.asarray(sm_o), np.asarray(sm_ref))
    np.testing.assert_array_equal(np.asarray(pm_o),
                                  np.asarray(flat_p[rows, im]))

    fn = shard_map(
        lambda sl, pl: merge_local_topk(
            "model", k, sl.reshape(Q, k), pl.reshape(Q, k)),
        mesh=mesh, in_specs=(P("model"), P("model")),
        out_specs=(P(), P()), check_rep=False)
    sm_c, pm_c = jax.jit(fn)(s, pay)
    np.testing.assert_array_equal(np.asarray(sm_c), np.asarray(sm_o))
    np.testing.assert_array_equal(np.asarray(pm_c), np.asarray(pm_o))


def test_merge_helper_ties_resolve_to_earliest_shard():
    S, Q, k = 3, 2, 2
    s = jnp.ones((S, Q, k), jnp.float32)          # all-tied scores
    pay = jnp.arange(S * Q * k, dtype=jnp.int32).reshape(S, Q, k)
    sm, pm = merge_stacked_topk(k, s, pay)
    # winners must be shard 0's candidates, in candidate order
    np.testing.assert_array_equal(np.asarray(pm), np.asarray(pay[0]))
    assert float(jnp.min(sm)) == 1.0


# ---------------------------------------------------------------------------
# sharded cascade: oracle vs shard_map, sharded vs single-device
# ---------------------------------------------------------------------------

def test_sharded_oracle_s1_equals_plain_single_device():
    """One shard IS the single-device cascade: the stacked schedule at
    S=1 must be bit-exact with the plain path, fused and unfused."""
    hot = _hot()
    warm = _warm_shard(64, 16, 8, 16)
    swarm = jax.tree_util.tree_map(lambda x: x[None], warm)
    q, qt, thr = _queries(9, 16)
    for fused, uk, quant in [(False, None, False), (True, True, False)]:
        plain = tiers.cascade_query(hot, warm, q, qt, thr, k=2, n_probe=4,
                                    tail=10, fused=fused, use_kernel=uk,
                                    quantized=quant)
        stacked = tiers.cascade_query(hot, swarm, q, qt, thr, k=2, n_probe=4,
                                      tail=10, fused=fused, use_kernel=uk,
                                      quantized=quant)
        _assert_same(plain, stacked)


@pytest.mark.parametrize("S", [1, 2, 8])
@pytest.mark.parametrize("fused,quantized", [(False, False), (True, False),
                                             (True, True)])
def test_shard_map_matches_single_device_oracle(S, fused, quantized):
    """The distributed schedule (shard_map + all-gather merge) is
    bit-exact with its single-device emulation — partial probes, tail
    windows, invalid slots and mixed tenants included."""
    _need_devices(S)
    hot = _hot()
    swarm = _swarm(S)
    q, qt, thr = _queries(9, 16)
    mesh = make_host_mesh(1, S)
    uk = True if fused else None
    oracle = tiers.cascade_query(hot, swarm, q, qt, thr, k=2, n_probe=2,
                                 tail=5, fused=fused, use_kernel=uk,
                                 quantized=quantized)
    dist = jax.jit(lambda h, w, qq, t, th: tiers.cascade_query(
        h, w, qq, t, th, k=2, n_probe=2, tail=5, fused=fused,
        use_kernel=uk, quantized=quantized, mesh=mesh))(
            hot, _shard_put(swarm, mesh), q, qt, thr)
    _assert_same(oracle, dist)


@pytest.mark.parametrize("S", [2, 8])
def test_sharded_fused_bitexact_vs_single_device_unfused_full_probe(S):
    """The acceptance parity: the fused sharded cascade on S virtual
    devices reproduces the single-device unfused path bit-for-bit at
    fp32 (scores, value ids, hit masks) when both sides probe their
    full cluster sets over the same row universe."""
    _need_devices(S)
    D, cap, k = 16, 32, 2
    hot = _hot(D=D)
    # one row universe, partitioned contiguously over shards; every row
    # indexed (no tail) so full-probe candidate sets coincide exactly
    keys = _unit(rng.standard_normal((S * cap, D)).astype(np.float32))
    valid = rng.random(S * cap) > 0.2
    tenants = rng.integers(0, 3, S * cap).astype(np.int32)
    vids = np.arange(1000, 1000 + S * cap, dtype=np.int32)

    def plain_warm():
        wk, wv = jnp.asarray(keys), jnp.asarray(valid)
        cent = ivf_lib.kmeans(wk, wv, 8, 4, 0)
        members, sizes = ivf_lib.build_lists(wk, wv, cent, S * cap)
        return tiers.requantize(tiers.init_warm(S * cap, D, 8, S * cap)
                                ._replace(
            keys=wk, valid=wv, tenants=jnp.asarray(tenants),
            value_ids=jnp.asarray(vids),
            write_seq=jnp.arange(1, S * cap + 1, dtype=jnp.int32),
            cursor=jnp.zeros((), jnp.int32),
            total=jnp.asarray(S * cap, jnp.int32), centroids=cent,
            members=members, sizes=sizes,
            indexed_total=jnp.asarray(S * cap, jnp.int32)))

    def shard(s):
        sl = slice(s * cap, (s + 1) * cap)
        wk, wv = jnp.asarray(keys[sl]), jnp.asarray(valid[sl])
        cent = ivf_lib.kmeans(wk, wv, 2, 4, s)
        members, sizes = ivf_lib.build_lists(wk, wv, cent, cap)
        return tiers.requantize(tiers.init_warm(cap, D, 2, cap)._replace(
            keys=wk, valid=wv, tenants=jnp.asarray(tenants[sl]),
            value_ids=jnp.asarray(vids[sl]),
            write_seq=jnp.arange(1, cap + 1, dtype=jnp.int32),
            cursor=jnp.zeros((), jnp.int32),
            total=jnp.asarray(cap, jnp.int32), centroids=cent,
            members=members, sizes=sizes,
            indexed_total=jnp.asarray(cap, jnp.int32)))

    q, qt, thr = _queries(16, D)
    mesh = make_host_mesh(1, S)
    single = tiers.cascade_query(hot, plain_warm(), q, qt, thr, k=k,
                                 n_probe=8, tail=0, fused=False)
    swarm = _shard_put(tiers.stack_warm([shard(s) for s in range(S)]), mesh)
    dist = jax.jit(lambda h, w, qq, t, th: tiers.cascade_query(
        h, w, qq, t, th, k=k, n_probe=2, tail=0, fused=True,
        use_kernel=True, mesh=mesh))(hot, swarm, q, qt, thr)
    _assert_same(single, dist)


@pytest.mark.parametrize("S", [2])
def test_cross_shard_collective_is_k_shards_not_corpus(S):
    """The only cross-shard collectives in the sharded lookup move
    (Q, k·S)-scale candidate panels (+ the (Q,) hot-slot psum), never a
    corpus-sized (Q, N) score matrix."""
    _need_devices(S)
    cap, Q, k = 256, 8, 2
    hot = _hot()
    swarm = _swarm(S, cap=cap, K=4, bucket=32)
    q, qt, thr = _queries(Q, 16)
    mesh = make_host_mesh(1, S)
    fn = jax.jit(lambda h, w, qq, t, th: tiers.cascade_query(
        h, w, qq, t, th, k=k, n_probe=2, tail=4, fused=True,
        use_kernel=True, mesh=mesh))
    txt = fn.lower(hot, _shard_put(swarm, mesh), q, qt, thr) \
            .compile().as_text()
    # HLO shape syntax: `%x = f32[8,4]{0,1} all-gather(...)`
    gathers = re.findall(r"=\s*\w+\[([\d,]+)\]\S*\s+all-(?:gather|reduce)\(",
                         txt)
    if not gathers:                      # collectives elided / renamed
        pytest.skip("no all-gather in compiled HLO to inspect")
    biggest = max(int(np.prod([int(d) for d in dims.split(",")]))
                  for dims in gathers)
    assert biggest <= Q * k * S, \
        f"collective of {biggest} elements (> Q*k*S = {Q * k * S})"
    assert biggest < Q * cap, "corpus-scale collective leaked into lookup"


# ---------------------------------------------------------------------------
# int8 quantized warm panel
# ---------------------------------------------------------------------------

def test_quantize_rows_error_bound():
    keys = jnp.asarray(_unit(rng.standard_normal((256, 64)
                                                 ).astype(np.float32)))
    q8, sc = tiers.quantize_rows(keys)
    assert q8.dtype == jnp.int8
    recon = q8.astype(jnp.float32) * sc[:, None]
    # per-component: |k - s*q8| <= s/2; cosine vs any unit query is
    # within amax*sqrt(D)/254 (DESIGN.md §8)
    amax = jnp.max(jnp.abs(keys), axis=-1)
    D = keys.shape[1]
    assert float(jnp.max(jnp.abs(recon - keys)
                         / (sc[:, None] / 2 + 1e-12))) <= 1.0 + 1e-3
    q = jnp.asarray(_unit(rng.standard_normal((32, 64)).astype(np.float32)))
    err = jnp.abs(q @ keys.T - q @ recon.T)
    bound = amax * np.sqrt(D) / 254.0
    assert float(jnp.max(err - bound[None, :])) <= 1e-6


def test_int8_scores_are_exact_rescored_cosines():
    """Whatever the quantized scan *selects*, the scores the cascade
    returns must be true fp32 cosines of the selected rows."""
    hot = _hot(Nh=8)
    hot = hot._replace(valid=jnp.zeros_like(hot.valid))   # warm-only
    warm = _warm_shard(64, 16, 4, 16, unindexed=0)
    q, qt, _ = _queries(12, 16)
    thr = jnp.full((12,), -1.0, jnp.float32)
    res = tiers.cascade_query(hot, warm, q, qt, thr, k=2, n_probe=4,
                              tail=0, fused=True, use_kernel=True,
                              quantized=True)
    vids = np.asarray(res.value_ids)
    scores = np.asarray(res.scores)
    wkeys = np.asarray(warm.keys)
    wvids = np.asarray(warm.value_ids)
    qn = np.asarray(q)
    for r in range(12):
        for c in range(2):
            if vids[r, c] < 0:
                continue
            row = int(np.nonzero(wvids == vids[r, c])[0][0])
            exact = float(qn[r] @ wkeys[row])
            assert abs(scores[r, c] - exact) < 1e-5


def test_int8_recall_parity_on_clustered_corpus():
    """On the cache's actual workload (paraphrase clusters, clear
    margins) the quantized scan selects the same hits as fp32."""
    D, n = 32, 512
    cents = _unit(rng.standard_normal((8, D)).astype(np.float32))
    keys = _unit(np.repeat(cents, n // 8, axis=0)
                 + 0.15 * rng.standard_normal((n, D)).astype(np.float32))
    wk = jnp.asarray(keys)
    wv = jnp.ones((n,), bool)
    cent = ivf_lib.kmeans(wk, wv, 8, 4, 0)
    members, sizes = ivf_lib.build_lists(wk, wv, cent, n // 4)
    warm = tiers.requantize(tiers.init_warm(n, D, 8, n // 4)._replace(
        keys=wk, valid=wv, tenants=jnp.zeros((n,), jnp.int32),
        value_ids=jnp.arange(n, dtype=jnp.int32),
        write_seq=jnp.arange(1, n + 1, dtype=jnp.int32),
        total=jnp.asarray(n, jnp.int32),
        centroids=cent, members=members, sizes=sizes,
        indexed_total=jnp.asarray(n, jnp.int32)))
    hot = tiers.init_hot(16, D)
    idx = rng.choice(n, 64, replace=False)
    q = jnp.asarray(_unit(keys[idx] + 0.05 * rng.standard_normal(
        (64, D)).astype(np.float32)))
    qt = jnp.zeros((64,), jnp.int32)
    thr = jnp.full((64,), 0.9, jnp.float32)
    fp32 = tiers.cascade_query(hot, warm, q, qt, thr, k=1, n_probe=4,
                               tail=0, fused=False)
    int8 = tiers.cascade_query(hot, warm, q, qt, thr, k=1, n_probe=4,
                               tail=0, fused=True, use_kernel=True,
                               quantized=True)
    f_hit, i_hit = np.asarray(fp32.hit), np.asarray(int8.hit)
    assert f_hit.sum() > 0
    recall = (f_hit & i_hit).sum() / max(f_hit.sum(), 1)
    assert recall >= 0.995, recall
    # hits agree on the value id too (selection, not just the flag)
    both = f_hit & i_hit
    np.testing.assert_array_equal(np.asarray(fp32.value_ids)[both],
                                  np.asarray(int8.value_ids)[both])


# ---------------------------------------------------------------------------
# sharded CacheService: publish swap mid-stream, tenant eviction
# ---------------------------------------------------------------------------

def _svc(S, **kw):
    cfg = dict(dim=16, hot_capacity=32, warm_capacity=128, n_clusters=8,
               bucket=32, n_probe=4, threshold=0.9, flush_size=8,
               rebuild_every=2, mesh=make_host_mesh(1, S))
    cfg.update(kw)
    return CacheService(**cfg)


def _insert(svc, keys, texts, tenant=0):
    return commit_insert(svc, keys, texts, tenant=tenant)


def _lookup(svc, keys, tenant=0):
    return plan_lookup(svc, keys, tenant=tenant)


@pytest.mark.parametrize("S", [2])
def test_sharded_warm_publish_swap_mid_stream(S):
    """Double-buffered rebuild on the sharded tier: lookups issued
    while the shadow builds read the old per-shard indexes at full
    recall, and the publish swaps every shard's index in one atomic
    step (no shard can be observed half-swapped)."""
    _need_devices(S)
    svc = _svc(S, background_rebuild=True, rebuild_every=3)
    gate = threading.Event()
    real = svc._rebuild
    state = {"first": True}

    def gated(warm):
        if state["first"]:
            state["first"] = False
            assert gate.wait(timeout=60), "gate never opened"
        return real(warm)

    svc._rebuild = gated
    keys = _unit(rng.standard_normal((16, 16)).astype(np.float32))
    _insert(svc, keys, [f"r{i}" for i in range(16)])
    svc.flush(rebuild=True)                    # starts the gated shadow
    assert svc.stats_snapshot().rebuild["in_flight"]
    idx_before = np.asarray(svc.warm.indexed_total).copy()

    # mid-rebuild: old index + per-shard tail windows serve everything
    hit, _, vals = _lookup(svc, keys)
    assert hit.all() and all(v is not None for v in vals)
    keys2 = _unit(rng.standard_normal((8, 16)).astype(np.float32))
    _insert(svc, keys2, [f"s{i}" for i in range(8)])
    svc.flush(rebuild=False)
    hit, _, _ = _lookup(svc, np.concatenate([keys, keys2]))
    assert hit.all()
    np.testing.assert_array_equal(np.asarray(svc.warm.indexed_total),
                                  idx_before)  # nothing published yet

    gate.set()
    rep = svc.maintenance(block=True)
    assert rep.rebuild_published
    idx_after = np.asarray(svc.warm.indexed_total)
    # shard-consistent swap: every shard's indexed_total advanced in
    # the same publish (none left behind on the old snapshot)
    assert (idx_after > idx_before).all(), (idx_before, idx_after)
    hit, _, _ = _lookup(svc, np.concatenate([keys, keys2]))
    assert hit.all()


@pytest.mark.parametrize("S", [2])
def test_evict_tenant_on_sharded_warm_tier(S):
    _need_devices(S)
    svc = _svc(S)
    all_keys = {0: [], 1: []}
    for step in range(12):
        t = step % 2
        e = _unit(rng.standard_normal((8, 16)).astype(np.float32))
        all_keys[t].append(e)
        _insert(svc, e, [f"t{t}-{step}-{i}" for i in range(8)], tenant=t)
    assert svc.stats_snapshot().tiers["demotions"] > 0   # warm populated
    live_before = len(svc.responses)
    n = svc.evict_tenant(0)
    assert n > 0 and len(svc.responses) == live_before - n
    hit, _, _ = _lookup(svc, np.concatenate(all_keys[0]), tenant=0)
    assert not hit.any()
    hit, _, vals = _lookup(svc, np.concatenate(all_keys[1]), tenant=1)
    assert hit.all() and all(v is not None for v in vals)
    # evicted ids are gone from every shard's device arrays
    valid = np.asarray(svc.warm.valid)
    tenants = np.asarray(svc.warm.tenants)
    assert not (valid & (tenants == 0)).any()


@pytest.mark.parametrize("S", [2])
@pytest.mark.parametrize("warm_dtype", ["float32", "int8"])
def test_sharded_service_serves_identically_to_unsharded(S, warm_dtype):
    """Same insert trace through an unsharded and a sharded service:
    hit decisions and served strings agree (the sharded tier holds the
    same rows, just distributed — only the IVF clustering differs, and
    full recall hides it on this workload)."""
    _need_devices(S)
    a = CacheService(dim=16, hot_capacity=32, warm_capacity=128,
                     n_clusters=8, bucket=32, n_probe=4, threshold=0.9,
                     flush_size=8, rebuild_every=2)
    b = _svc(S, warm_dtype=warm_dtype)
    ks = []
    for step in range(12):
        e = _unit(rng.standard_normal((8, 16)).astype(np.float32))
        ks.append(e)
        texts = [f"x{step}-{i}" for i in range(8)]
        _insert(a, e, texts)
        _insert(b, e, texts)
        keys = np.concatenate(ks)
        ha, _, va = _lookup(a, keys)
        hb, _, vb = _lookup(b, keys)
        np.testing.assert_array_equal(ha, hb, err_msg=f"step {step}")
        assert va == vb
    assert b.stats_snapshot().tiers["warm_shards"] == S


# ---------------------------------------------------------------------------
# merge property tests: ties + duplicate value-ids across shards.  The
# sharded cascade (and the §13 fused-ensemble merge on top of it)
# rides on these two helpers agreeing bit-for-bit, ties included —
# fuzzed with hypothesis when installed, else a deterministic grid.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _fuzz(fallback_cases, *strategies):
    """``@given(*strategies)`` when hypothesis is available, else a
    parametrize over ``fallback_cases`` (tuples of the same arity)."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=25,
                            deadline=None)(given(*strategies)(fn))

        def run(case):
            fn(*case)
        run.__name__ = fn.__name__      # not functools.wraps: pytest
        run.__doc__ = fn.__doc__        # would introspect __wrapped__
        return pytest.mark.parametrize("case", fallback_cases)(run)
    return deco


def _tied_candidates(S, Q, k, seed):
    """Shard-stacked candidates engineered for collisions: scores on a
    coarse grid (ties within and across shards) and value ids from a
    pool smaller than the candidate count (duplicates across shards)."""
    r = np.random.default_rng(seed)
    s = r.integers(0, 4, (S, Q, k)).astype(np.float32) / 2.0
    vids = r.integers(0, max(2, S * k // 2), (S, Q, k)).astype(np.int32)
    shard = np.broadcast_to(np.arange(S, dtype=np.int32)[:, None, None],
                            (S, Q, k)).copy()
    return s, vids, shard


_MERGE_CASES = [(1, 1, 1, 0), (2, 3, 2, 1), (3, 5, 3, 2), (8, 2, 4, 3),
                (4, 7, 2, 4), (5, 4, 1, 5)]
_merge_strategies = (st.integers(1, 8), st.integers(1, 8),
                     st.integers(1, 4), st.integers(0, 10**6)) \
    if HAVE_HYPOTHESIS else ()


@_fuzz(_MERGE_CASES, *_merge_strategies)
def test_merge_stacked_topk_is_stable_sort_of_shard_major_concat(
        S, Q, k, seed):
    """The oracle's winners are exactly the first k of a *stable*
    descending sort over the shard-major concat: ties resolve to the
    earliest (shard, candidate) position, never arbitrarily — the
    property that makes the collective and stacked forms comparable
    bit-for-bit at all."""
    s, vids, _ = _tied_candidates(S, Q, k, seed)
    sm, pm = merge_stacked_topk(k, jnp.asarray(s), jnp.asarray(vids))
    sm, pm = np.asarray(sm), np.asarray(pm)
    flat_s = np.moveaxis(s, 0, 1).reshape(Q, S * k)
    flat_p = np.moveaxis(vids, 0, 1).reshape(Q, S * k)
    for row in range(Q):
        order = np.argsort(-flat_s[row], kind="stable")[:k]
        np.testing.assert_array_equal(sm[row], flat_s[row][order],
                                      err_msg=f"row {row} scores")
        np.testing.assert_array_equal(pm[row], flat_p[row][order],
                                      err_msg=f"row {row} payload")
        assert (np.diff(sm[row]) <= 0).all()       # descending output


@_fuzz(_MERGE_CASES, *_merge_strategies)
def test_merge_payload_columns_stay_aligned_under_duplicate_vids(
        S, Q, k, seed):
    """With the same value id living on several shards at different
    scores, every payload column must be gathered with the *same*
    winner indices: each output (score, vid, shard) triple is a triple
    that actually co-occurred at one input position (no cross-shard
    recombination), and re-merging the merged result is the identity."""
    s, vids, shard = _tied_candidates(S, Q, k, seed)
    sm, pm_v, pm_s = merge_stacked_topk(
        k, jnp.asarray(s), jnp.asarray(vids), jnp.asarray(shard))
    sm, pm_v, pm_s = (np.asarray(x) for x in (sm, pm_v, pm_s))
    for row in range(Q):
        for c in range(k):
            sh = int(pm_s[row, c])
            assert any(s[sh, row, cc] == sm[row, c]
                       and vids[sh, row, cc] == pm_v[row, c]
                       for cc in range(k)), \
                (f"row {row} col {c}: (score {sm[row, c]}, vid "
                 f"{pm_v[row, c]}) never co-occurred on shard {sh}")
    # idempotence: the merged panel, treated as one shard, re-merges
    # to itself (top-k of an already sorted panel is a prefix copy)
    sm2, pv2, ps2 = merge_stacked_topk(
        k, jnp.asarray(sm[None]), jnp.asarray(pm_v[None]),
        jnp.asarray(pm_s[None]))
    np.testing.assert_array_equal(np.asarray(sm2), sm)
    np.testing.assert_array_equal(np.asarray(pv2), pm_v)
    np.testing.assert_array_equal(np.asarray(ps2), pm_s)


@pytest.mark.parametrize("S", [1, 2])
def test_merge_local_topk_collective_matches_oracle_under_ties(S):
    """The all-gather form picks identical winners on tie-heavy,
    duplicate-vid candidates — the exact inputs where an unstable
    merge would diverge between the distributed and oracle paths."""
    _need_devices(S)
    Q, k = 5, 3
    s, vids, shard = _tied_candidates(S, Q, k, seed=9)
    sm_o, pv_o, ps_o = merge_stacked_topk(
        k, jnp.asarray(s), jnp.asarray(vids), jnp.asarray(shard))
    mesh = make_host_mesh(1, S)
    fn = shard_map(
        lambda sl, vl, hl: merge_local_topk(
            "model", k, sl.reshape(Q, k), vl.reshape(Q, k),
            hl.reshape(Q, k)),
        mesh=mesh, in_specs=(P("model"), P("model"), P("model")),
        out_specs=(P(), P(), P()), check_rep=False)
    sm_c, pv_c, ps_c = jax.jit(fn)(jnp.asarray(s), jnp.asarray(vids),
                                   jnp.asarray(shard))
    np.testing.assert_array_equal(np.asarray(sm_c), np.asarray(sm_o))
    np.testing.assert_array_equal(np.asarray(pv_c), np.asarray(pv_o))
    np.testing.assert_array_equal(np.asarray(ps_c), np.asarray(ps_o))
