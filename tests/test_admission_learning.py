"""Online per-tenant admission learning (DESIGN.md §9): the feedback
reservoir, every refit hysteresis guard, the learned-vs-fixed claim on
a drifting stream, refit under the batcher's maintenance tick, and the
CI perf-trajectory gate."""
import copy
import json
import pathlib
import subprocess
import sys

import jax
import numpy as np

from repro.cache_service import (
    CacheRequest, CacheService, FeedbackAccumulator, FeedbackConfig,
    PolicyTable, TenantPolicy,
)

rng = np.random.default_rng(29)
DIM = 64


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _fill(acc, tenant, n_dup=60, n_neg=60, dup_loc=0.88, neg_loc=0.35,
          admitted=True):
    """Seed a reservoir with a separable duplicate/distinct mixture."""
    for s in rng.normal(dup_loc, 0.015, n_dup):
        acc.observe(tenant, float(np.clip(s, -1, 1)), True, admitted)
    for s in rng.normal(neg_loc, 0.1, n_neg):
        acc.observe(tenant, float(np.clip(s, -1, 1)), False, admitted)


# ---------------------------------------------------------------------------
# reservoir
# ---------------------------------------------------------------------------

def test_reservoir_bounds_memory_and_counts_stream():
    acc = FeedbackAccumulator(FeedbackConfig(reservoir=64))
    for i in range(1000):
        acc.observe(0, 0.5, i % 3 == 0, True)
    res = acc._res[0]
    assert res.fill == 64 and res.seen == 1000
    assert acc.counters["events"] == 1000
    assert acc.counters["duplicate_events"] == 334
    assert acc.counters["wasted_admissions"] == 334
    scores, labels = res.arrays()
    assert len(scores) == 64 == len(labels)


def test_reservoir_keeps_late_stream_represented():
    """Algorithm R: after 10x capacity from a second era, the sample
    must contain a healthy share of late events (a FIFO or a frozen
    prefix would fail one side)."""
    acc = FeedbackAccumulator(FeedbackConfig(reservoir=128, seed=5))
    for _ in range(128):
        acc.observe(0, 0.2, False, True)     # era 1: score 0.2
    for _ in range(1280):
        acc.observe(0, 0.8, True, True)      # era 2: score 0.8
    scores, _ = acc._res[0].arrays()
    late = float((scores > 0.5).mean())
    assert 0.7 < late < 1.0, late            # ~10/11 expected, never all


# ---------------------------------------------------------------------------
# hysteresis guards
# ---------------------------------------------------------------------------

def test_refit_guard_min_samples_and_class_balance():
    acc = FeedbackAccumulator(FeedbackConfig(min_samples=64, min_class=8))
    pol = TenantPolicy(0.9, 0.02)
    _fill(acc, 0, n_dup=10, n_neg=10)        # 20 < min_samples
    _, rep = acc.fit(0, pol)
    assert not rep.applied and rep.reason == "min-samples"
    _fill(acc, 1, n_dup=2, n_neg=100)        # enough events, starved class
    _, rep = acc.fit(1, pol)
    assert not rep.applied and rep.reason == "class-starved"
    assert acc.counters["refits_applied"] == 0
    # the starved examination still consumes the refit interval: the
    # tenant is not re-examined on every maintenance tick
    assert not acc.refit_due(1)
    _, rep = acc.fit(1, pol)
    assert rep.reason == "interval"


def test_refit_guard_max_step_walks_not_jumps():
    """A far-away target is approached max_step per refit, with the
    interval guard forcing new evidence between steps."""
    cfg = FeedbackConfig(min_samples=32, min_class=8, refit_interval=16,
                        max_step=0.02)
    acc = FeedbackAccumulator(cfg)
    table = PolicyTable(TenantPolicy(0.99, 0.0))
    _fill(acc, 0)                            # duplicate mass near 0.88
    thr_seen = [0.99]
    for _ in range(8):
        for rep in table.refit(acc):
            if rep.applied:
                assert abs(rep.new_threshold - rep.old_threshold) \
                    <= cfg.max_step + 1e-9
                thr_seen.append(rep.new_threshold)
        _fill(acc, 0, n_dup=10, n_neg=10)    # fresh evidence per round
    assert len(thr_seen) >= 3                # it moved, in steps
    assert thr_seen[-1] < 0.95               # toward the duplicate mass
    steps = np.diff(thr_seen)
    assert np.all(np.abs(steps) <= cfg.max_step + 1e-9)


def test_refit_guard_interval_spaces_examinations():
    cfg = FeedbackConfig(min_samples=32, min_class=8, refit_interval=500)
    acc = FeedbackAccumulator(cfg)
    pol = TenantPolicy(0.9, 0.0)
    _fill(acc, 0)
    pol2, rep = acc.fit(0, pol)              # first examination: allowed
    assert rep.reason in ("ok", "no-change")
    _, rep = acc.fit(0, pol2)
    assert not rep.applied and rep.reason == "interval"
    assert not acc.refit_due(0)


def test_refit_guard_budget_refuses_loosening_over_budget():
    """Negatives sitting right under the current threshold: any
    loosening breaches the observed false-hit budget and must be
    refused outright, not clamped into."""
    cfg = FeedbackConfig(min_samples=32, min_class=8,
                        max_false_hit_rate=0.01, max_step=0.5,
                        dup_coverage=1.0)
    acc = FeedbackAccumulator(cfg)
    # duplicates BELOW the negatives: the dup-support floor (coverage
    # 1.0 -> min dup score ~0.6) asks to loosen into the negative mass
    _fill(acc, 0, n_dup=50, n_neg=50, dup_loc=0.62, neg_loc=0.8)
    pol = TenantPolicy(0.97, 0.0)
    pol2, rep = acc.fit(0, pol)
    if rep.applied:                          # tightening never loosens
        assert rep.new_threshold >= pol.threshold
    else:
        assert rep.reason in ("budget-guard", "no-change")
    # and the published threshold never dips below the negative mass
    assert pol2.threshold >= 0.8


def test_refit_floor_stops_at_duplicate_support():
    """Even with negatives far away (budget quantile ~0.45), loosening
    stops at the score capturing dup_coverage of observed duplicates —
    the region below is censored, not free."""
    cfg = FeedbackConfig(min_samples=32, min_class=8, max_step=1.0,
                        dup_coverage=0.95)
    acc = FeedbackAccumulator(cfg)
    _fill(acc, 0, dup_loc=0.88, neg_loc=0.3)
    pol2, rep = acc.fit(0, TenantPolicy(0.95, 0.0))
    assert rep.applied
    scores, labels = acc._res[0].arrays()
    floor = np.quantile(scores[labels == 1], 0.05)
    assert pol2.threshold >= floor - 1e-9
    assert pol2.threshold < 0.95             # but it did loosen


def test_refit_fits_margin_from_duplicate_precision():
    cfg = FeedbackConfig(min_samples=32, min_class=8, max_step=0.05,
                        dup_precision=0.9, max_margin=0.25)
    acc = FeedbackAccumulator(cfg)
    _fill(acc, 0)
    pol2, rep = acc.fit(0, TenantPolicy(0.92, 0.0))
    assert rep.applied
    assert 0.0 < pol2.admission_margin <= cfg.max_margin
    # the band ends at a score that is overwhelmingly duplicate
    scores, labels = acc._res[0].arrays()
    cut = pol2.threshold - pol2.admission_margin
    band = labels[scores >= cut]
    assert band.mean() >= 0.85, (cut, band.mean())


# ---------------------------------------------------------------------------
# the end-to-end claim: learned beats fixed on a drifting stream
# ---------------------------------------------------------------------------

def _drift_stream(stream_rng, intents, n_batches=21, batch=32):
    for b in range(n_batches):
        noise = 0.06 if b >= n_batches // 3 else 0.02
        ids = stream_rng.integers(0, len(intents), batch)
        embs = _unit(intents[ids] + noise * stream_rng.standard_normal(
            (batch, DIM)).astype(np.float32))
        yield embs, ids


def _serve_drift(learned: bool):
    stream_rng = np.random.default_rng(7)
    intents = _unit(stream_rng.standard_normal((48, DIM)
                                               ).astype(np.float32))
    svc = CacheService(
        dim=DIM, hot_capacity=256, warm_capacity=1024, n_clusters=16,
        bucket=128, n_probe=4, threshold=0.95, admission_margin=0.02,
        flush_size=64, kmeans_iters=2,
        learned_admission=learned,
        feedback_config=FeedbackConfig(min_samples=48, refit_interval=32,
                                       max_step=0.03, seed=0)
        if learned else None)
    seen, dup_admits, admits = set(), 0, 0
    for embs, ids in _drift_stream(stream_rng, intents):
        plan = svc.plan(CacheRequest.build(embs))
        svc.commit(plan, [f"ans{i}" for i in ids])
        svc.maintenance()
        for row in plan.miss_rows():
            if not plan.admit[row]:
                continue
            admits += 1
            if int(ids[row]) in seen:
                dup_admits += 1
            seen.add(int(ids[row]))
    probe_pos = _unit(intents + 0.03 * stream_rng.standard_normal(
        intents.shape).astype(np.float32))
    probe_neg = _unit(stream_rng.standard_normal((64, DIM)
                                                 ).astype(np.float32))
    recall = float(svc.plan(CacheRequest.build(probe_pos),
                            coalesce=False).hit.mean())
    false_hits = int(svc.plan(CacheRequest.build(probe_neg),
                              coalesce=False).hit.sum())
    return svc, dup_admits, admits, recall, false_hits


def test_learned_admission_beats_fixed_on_drifting_stream():
    _, dup_fixed, _, recall_fixed, fh_fixed = _serve_drift(False)
    svc, dup_learned, admits, recall_learned, fh_learned = \
        _serve_drift(True)
    # fewer duplicate inserts, recall held, false-hit budget held
    assert dup_learned < dup_fixed, (dup_learned, dup_fixed)
    assert recall_learned >= recall_fixed - 0.02, \
        (recall_learned, recall_fixed)
    assert fh_learned <= max(1, fh_fixed), (fh_learned, fh_fixed)
    st = svc.stats_snapshot().learning
    assert st["refits_applied"] >= 1
    assert st["duplicate_events"] > 0
    assert svc.capabilities().learned_admission
    # the learned operating point is visible and moved off the default
    pol = st["learned_policies"][0]
    assert pol["threshold"] < 0.95
    # every applied refit respected the step guard
    for rep in svc.feedback.refit_log:
        if rep.applied:
            assert abs(rep.new_threshold - rep.old_threshold) <= 0.03 + 1e-9


def test_wasted_admissions_are_counted():
    """A miss admitted despite its generated answer matching the
    stored neighbour's is the signal the whole loop keys off."""
    svc = CacheService(dim=DIM, hot_capacity=64, warm_capacity=128,
                       n_clusters=4, bucket=32, threshold=0.99,
                       learned_admission=True)
    base = _unit(rng.standard_normal((1, DIM)).astype(np.float32))
    svc.commit(svc.plan(CacheRequest.build(base)), ["same-answer"])
    near = _unit(base + 0.05 * rng.standard_normal((1, DIM)
                                                   ).astype(np.float32))
    plan = svc.plan(CacheRequest.build(near))
    assert not plan.hit[0]                   # strict threshold: a miss
    svc.commit(plan, ["same-answer"])        # ... with the same answer
    st = svc.stats_snapshot().learning
    assert st["duplicate_events"] == 1
    assert st["wasted_admissions"] == 1
    assert st["feedback_events"] == 2


def test_plan_carries_margins_and_top_ids():
    svc = CacheService(dim=DIM, hot_capacity=32, warm_capacity=64,
                       n_clusters=4, bucket=32, threshold=0.9)
    e = _unit(rng.standard_normal((4, DIM)).astype(np.float32))
    svc.commit(svc.plan(CacheRequest.build(e)), [f"r{i}" for i in range(4)])
    plan = svc.plan(CacheRequest.build(e))
    assert plan.hit.all()
    np.testing.assert_allclose(plan.margins, 0.9 - plan.scores, atol=1e-6)
    assert (plan.top_value_ids >= 0).all()   # the neighbour id survives
    # tenant with nothing cached: no neighbour, sentinel id
    plan1 = svc.plan(CacheRequest.build(e, tenant=1))
    assert (plan1.top_value_ids == -1).all()


# ---------------------------------------------------------------------------
# refit rides the batcher's idle-tick maintenance hook
# ---------------------------------------------------------------------------

def test_refit_via_continuous_batcher_maintenance():
    from repro.configs import get_config
    from repro.models import init_lm, split
    from repro.serving.scheduler import ContinuousBatcher, Request

    svc = CacheService(dim=DIM, hot_capacity=64, warm_capacity=128,
                       n_clusters=4, bucket=32, threshold=0.97,
                       learned_admission=True,
                       feedback_config=FeedbackConfig(
                           min_samples=48, min_class=8, refit_interval=32,
                           max_step=0.02, seed=0))
    _fill(svc.feedback, 0)                   # the serving loop's deposit

    cfg = get_config("phi3-mini-3.8b").reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    b = ContinuousBatcher(cfg, pv, n_slots=2, max_len=64, prompt_len=8,
                          maintenance=svc.maintenance)
    b.submit(Request(uid=0,
                     prompt=rng.integers(4, cfg.vocab_size, 6).astype(
                         np.int32), max_new_tokens=3))
    b.run(max_ticks=30)
    assert b.maintenance_runs > 0
    # the idle-tick hook applied a refit and reported it upward
    assert svc.stats_snapshot().learning["refits_applied"] >= 1
    assert b.last_maintenance is not None
    assert b.last_maintenance.refits_checked >= 0
    applied = [r for r in svc.feedback.refit_log if r.applied]
    assert applied and all(
        abs(r.new_threshold - r.old_threshold) <= 0.02 + 1e-9
        for r in applied)
    # hysteresis under the hook: repeated ticks with no new evidence
    # must not keep republishing (interval / no-change guards)
    n_applied = svc.stats_snapshot().learning["refits_applied"]
    for _ in range(5):
        svc.maintenance()
    assert svc.stats_snapshot().learning["refits_applied"] == n_applied


# ---------------------------------------------------------------------------
# the CI perf-trajectory gate
# ---------------------------------------------------------------------------

BASE_BENCH = {
    "bench": "tiered_cascade", "backend": "cpu", "devices": 1,
    "sizes": [4096], "q": 128, "dim": 64, "threshold": 0.9,
    "rows": [
        {"name": "tiered/4k/cascade_unfused", "us_per_call": 100.0,
         "p50_us": 1000.0, "recall_at_thr": 1.0},
        {"name": "tiered/admission_fixed", "us_per_call": 50.0,
         "dup_admissions": 500, "false_hits_probe": 0,
         "recall_probe": 0.94},
        {"name": "tiered/admission_learned", "us_per_call": 50.0,
         "dup_admissions": 50, "false_hits_probe": 0,
         "recall_probe": 1.0},
    ],
}


def _run_gate(tmp_path, baseline, fresh, *extra):
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(baseline))
    fp.write_text(json.dumps(fresh))
    script = pathlib.Path(__file__).resolve().parent.parent \
        / "scripts" / "check_bench_trajectory.py"
    return subprocess.run(
        [sys.executable, str(script),
         "--baseline", str(bp), "--fresh", str(fp), *extra],
        capture_output=True, text=True)


def test_trajectory_gate_green_on_identical(tmp_path):
    r = _run_gate(tmp_path, BASE_BENCH, BASE_BENCH)
    assert r.returncode == 0, r.stderr
    assert "clean" in r.stdout


def test_trajectory_gate_fails_on_recall_regression(tmp_path):
    doctored = copy.deepcopy(BASE_BENCH)
    doctored["rows"][0]["recall_at_thr"] = 0.80
    r = _run_gate(tmp_path, BASE_BENCH, doctored)
    assert r.returncode == 1
    assert "recall_at_thr regressed" in r.stderr


def test_trajectory_gate_fails_on_missing_row_and_p50_cliff(tmp_path):
    doctored = copy.deepcopy(BASE_BENCH)
    doctored["rows"][0]["p50_us"] = 10_000.0      # 10x the baseline
    del doctored["rows"][1:]                       # admission rows gone
    r = _run_gate(tmp_path, BASE_BENCH, doctored)
    assert r.returncode == 1
    assert "missing from the fresh run" in r.stderr
    assert "exceeds" in r.stderr


def test_trajectory_gate_skips_p50_on_fleet_mismatch(tmp_path):
    doctored = copy.deepcopy(BASE_BENCH)
    doctored["devices"] = 8                        # multidevice CI job
    doctored["rows"][0]["p50_us"] = 10_000.0
    r = _run_gate(tmp_path, BASE_BENCH, doctored)
    assert r.returncode == 0, r.stderr
    assert "fleet mismatch" in r.stdout


def test_trajectory_gate_skips_size_tiers_absent_from_fresh_sweep(
        tmp_path):
    """A full-sweep baseline (16k/64k rows) must not fail a --smoke
    run on rows the 4k tier cannot produce — only matching tiers and
    size-independent rows are owed."""
    full = copy.deepcopy(BASE_BENCH)
    full["sizes"] = [4096, 16384]
    full["rows"].append({"name": "tiered/16k/cascade_unfused",
                         "us_per_call": 200.0, "p50_us": 2000.0,
                         "recall_at_thr": 1.0})
    r = _run_gate(tmp_path, full, BASE_BENCH)   # fresh = smoke (4k only)
    assert r.returncode == 0, r.stderr
    assert "not in the fresh sweep" in r.stdout
    # but a dropped row inside a covered tier still fails
    doctored = copy.deepcopy(BASE_BENCH)
    doctored["rows"] = BASE_BENCH["rows"][1:]   # 4k row gone
    r = _run_gate(tmp_path, BASE_BENCH, doctored)
    assert r.returncode == 1
    assert "missing from the fresh run" in r.stderr


def test_trajectory_gate_fails_on_broken_admission_claim(tmp_path):
    doctored = copy.deepcopy(BASE_BENCH)
    doctored["rows"][2]["dup_admissions"] = 600    # learned >= fixed
    r = _run_gate(tmp_path, BASE_BENCH, doctored)
    assert r.returncode == 1
    assert "not below fixed" in r.stderr


def _with_embedder_rows(bench):
    out = copy.deepcopy(bench)
    out["rows"] += [
        {"name": "tiered/embedder_frozen", "us_per_call": 60.0,
         "hit_precision": 0.24, "hit_recall": 0.76,
         "overlap_recall": 1.0, "embed_version": 0},
        {"name": "tiered/embedder_refreshed", "us_per_call": 80.0,
         "hit_precision": 0.35, "hit_recall": 0.99,
         "overlap_recall": 1.0, "embed_version": 1},
    ]
    return out


def test_trajectory_gate_green_with_embedder_rows(tmp_path):
    bench = _with_embedder_rows(BASE_BENCH)
    r = _run_gate(tmp_path, bench, bench)
    assert r.returncode == 0, r.stderr


def test_trajectory_gate_fails_on_missing_embedder_row(tmp_path):
    """Once the baseline carries the §11 rows, a fresh run without
    them means the refresh bench path was dropped."""
    bench = _with_embedder_rows(BASE_BENCH)
    r = _run_gate(tmp_path, bench, BASE_BENCH)
    assert r.returncode == 1
    assert "tiered/embedder_frozen missing" in r.stderr
    assert "tiered/embedder_refreshed missing" in r.stderr


def test_trajectory_gate_fails_on_broken_embedder_claim(tmp_path):
    bench = _with_embedder_rows(BASE_BENCH)
    # refreshed no longer beats frozen on either metric
    doctored = _with_embedder_rows(BASE_BENCH)
    doctored["rows"][-1]["hit_precision"] = 0.24
    doctored["rows"][-1]["hit_recall"] = 0.70
    r = _run_gate(tmp_path, bench, doctored)
    assert r.returncode == 1
    assert "hit_precision" in r.stderr and "not above frozen" in r.stderr
    assert "hit_recall" in r.stderr
    # a hot swap that loses committed entries is data loss, not noise
    doctored = _with_embedder_rows(BASE_BENCH)
    doctored["rows"][-1]["overlap_recall"] = 0.97
    r = _run_gate(tmp_path, bench, doctored)
    assert r.returncode == 1
    assert "overlap_recall" in r.stderr
    # a refreshed row that never published proves nothing
    doctored = _with_embedder_rows(BASE_BENCH)
    doctored["rows"][-1]["embed_version"] = 0
    r = _run_gate(tmp_path, bench, doctored)
    assert r.returncode == 1
    assert "never published" in r.stderr
