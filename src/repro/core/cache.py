"""SemanticCache — the paper's artifact, assembled.

Embedding model (compact fine-tuned encoder) + vector store + threshold
policy.  The device half (store state, query/insert/touch) is pure JAX;
this class is the thin host orchestration that also owns the response
strings (which never live on device).

Serving surface: the typed ``CacheBackend`` lifecycle (DESIGN.md §7) —
``plan(CacheRequest)`` answers the batch (read side: TTL sweep, exact
query, LRU touch, response resolution, miss coalescing) and
``commit(plan, responses)`` caches the generated misses:

    cache = SemanticCache(capacity=4096, dim=768, threshold=0.85)
    plan = cache.plan(CacheRequest.build(embeddings))    # (B, D)
    cache.commit(plan, miss_responses)
    cache.stats_snapshot()                               # flat dict

(The pre-v2 ``lookup``/``insert``/``stats`` surface was removed in
v2.0; the README has the migration table.)

This backend is single-tenant (capabilities().tenants is False) and
admits every miss (no admission policy); see
``repro.cache_service.CacheService`` for the tiered multi-tenant
backend behind the same protocol.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache_service.protocol import (
    CacheCapabilities, CachePlan, CacheRequest, CommitReceipt,
    MaintenanceReport, coalesce_misses, ungrouped_misses,
)
from repro.core import store as store_lib
from repro.obs import Telemetry


class SemanticCache:
    def __init__(self, capacity: int, dim: int, threshold: float = 0.85,
                 topk: int = 1, ttl: Optional[int] = None,
                 telemetry: Optional[Telemetry] = None):
        self.capacity = capacity
        self.dim = dim
        self.threshold = threshold
        self.topk = topk
        self.ttl = ttl
        self.state = store_lib.init_store(capacity, dim)
        self.responses: List[str] = []
        # counters live on the telemetry registry (DESIGN.md §10.1);
        # the single-tenant flat store labels every stage tenant "0"
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        reg = self.telemetry.registry
        self._stage_h = self.telemetry.stage_histogram()
        self._c_plans = reg.counter(
            "cache_plans_total", "plan() calls").labels()
        self._c_commits = reg.counter(
            "cache_commits_total", "commit() calls").labels()
        self._c_rows = reg.counter(
            "cache_lookup_rows_total", "rows planned").labels()
        self._c_hits = reg.counter(
            "cache_hits_total", "plan-time hits by tier",
            labels=("tier",)).labels(tier="flat")
        self._c_inserts = reg.counter(
            "cache_admissions_total", "commit-time admission decisions",
            labels=("tenant", "decision")).labels(tenant=0,
                                                  decision="admitted")
        self._query = jax.jit(
            lambda st, q: store_lib.query(st, q, threshold, topk))
        self._insert = jax.jit(store_lib.insert_batch)
        self._touch = jax.jit(store_lib.touch)
        self._evict = (jax.jit(lambda st: store_lib.evict_older_than(st, ttl))
                       if ttl else None)

    # ------------------------------------------------------------------
    # CacheBackend protocol
    # ------------------------------------------------------------------
    def capabilities(self) -> CacheCapabilities:
        return CacheCapabilities()   # flat, single-tenant, admit-all

    def plan(self, request: CacheRequest, *,
             coalesce: bool = True) -> CachePlan:
        """Read side: TTL sweep, exact top-k, LRU touch; responses are
        resolved here so later overwrites cannot invalidate them.
        ``coalesce=False`` skips the miss-grouping work."""
        if np.any(request.tenants != 0):
            raise ValueError("SemanticCache is single-tenant; route "
                             "multi-tenant traffic to CacheService")
        t0 = time.perf_counter()
        if self._evict is not None:
            self.state = self._evict(self.state)
        res = self._query(self.state, jnp.asarray(request.embeddings))
        self.state = self._touch(self.state, res.slots[:, 0], res.hit)
        hit = np.asarray(res.hit)
        scores = np.asarray(res.scores[:, 0])
        vids = np.asarray(res.value_ids[:, 0]).astype(np.int64)
        values = [self.responses[v] if h and 0 <= v < len(self.responses)
                  else None for h, v in zip(hit, vids)]
        self._c_plans.inc()
        self._c_rows.inc(len(hit))
        self._c_hits.inc(int(hit.sum()))
        thr = np.full(len(hit), self.threshold, np.float32)
        leader = coalesce_misses(request.embeddings, hit,
                                 request.tenants, thr) \
            if coalesce else ungrouped_misses(hit)
        wall = time.perf_counter() - t0
        self._stage_h.observe(wall, stage="plan", tenant="0")
        return CachePlan(
            request=request, hit=hit, scores=scores,
            value_ids=np.where(hit, vids, -1), responses=values,
            admit=~hit,                       # no admission policy: cache
            miss_leader=leader,               # every generated miss
            epoch=0, margins=thr - scores, top_value_ids=vids,
            plan_wall_s=wall)

    def commit(self, plan: CachePlan,
               responses: Sequence[Optional[str]]) -> CommitReceipt:
        """Write side: append admitted miss responses and insert their
        embeddings (value ids are list positions, always fresh)."""
        t0 = time.perf_counter()
        self._c_commits.inc()
        rows = plan.miss_rows()
        rows = rows[plan.admit[rows]]
        texts = []
        for i in rows:
            if responses[i] is None:
                raise ValueError(f"admitted row {int(i)} has no response")
            texts.append(responses[i])
        if len(rows):
            base = len(self.responses)
            self.responses.extend(texts)
            vids = jnp.arange(base, base + len(rows), dtype=jnp.int32)
            self.state = self._insert(
                self.state, jnp.asarray(plan.request.embeddings[rows]), vids)
        self._c_inserts.inc(len(rows))
        wall = time.perf_counter() - t0
        self._stage_h.observe(wall, stage="commit", tenant="0")
        return CommitReceipt(admitted=len(rows),
                             skipped=int(len(plan.miss_rows()) - len(rows)),
                             evicted=0, commit_wall_s=wall,
                             trace_id=plan.request.trace_id)

    def maintenance(self, block: bool = False) -> MaintenanceReport:
        """Flat store: no background obligations (TTL sweeps run at
        plan time); still observes the stage so the flat backend's
        span/stage coverage matches the tiered one."""
        t0 = time.perf_counter()
        reg = self.telemetry.registry
        reg.gauge("cache_occupancy",
                  "flat-store occupancy fraction").set(self.occupancy)
        wall = time.perf_counter() - t0
        self._stage_h.observe(wall, stage="maintenance", tenant="-")
        return MaintenanceReport(wall_s=wall)

    def stats_snapshot(self) -> Dict[str, object]:
        """Flat backend snapshot: a plain dict (the protocol allows a
        mapping or an object with ``to_dict()``)."""
        reg = self.telemetry.registry
        return {
            "lookups": int(reg.value("cache_lookup_rows_total")),
            "hits": int(reg.value("cache_hits_total", tier="flat")),
            "inserts": int(reg.value("cache_admissions_total",
                                     decision="admitted")),
            "plans": int(reg.value("cache_plans_total")),
            "commits": int(reg.value("cache_commits_total")),
            "occupancy": self.occupancy,
            "live_responses": len(self.responses),
        }

    # ------------------------------------------------------------------
    @property
    def occupancy(self) -> float:
        return float(store_lib.occupancy(self.state))

    def __len__(self) -> int:
        return int(np.asarray(self.state.valid).sum())
