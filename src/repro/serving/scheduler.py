"""Slot-based continuous batching scheduler.

Real serving systems don't run fixed batches to completion: requests
arrive and finish at different times, and the decode step should always
run at full batch occupancy.  This scheduler keeps a fixed pool of B
slots over ONE jitted decode function:

  * a free slot admits a pending request via `prefill` into that slot's
    cache region (per-slot prefill; batched decode),
  * every engine tick decodes one token for ALL active slots,
  * slots retire on EOS or max_new_tokens and are immediately refilled.

The decode state is the model's stacked pytree; per-slot admission
writes the prefilled slot state into the pool with a dynamic batch
index update — pure-JAX, shape-static, so the decode step never
recompiles.  The semantic cache composes in front: hits never consume a
slot (that is the cost model of the paper).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS
from repro.models import decode_step, init_lm_state, prefill
from repro.obs import Telemetry


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False


def _write_slot(pool_state, slot_state, slot: int):
    """Insert a single-sequence decode state into batch position `slot`."""

    def upd(pool, one):
        if pool.ndim == 0:
            return pool
        # layer-stacked leaves: (n_periods, B, ...); single: (n_periods, 1, ...)
        return jax.lax.dynamic_update_index_in_dim(pool, one[:, 0], slot,
                                                   axis=1)

    new_layers = jax.tree_util.tree_map(upd, pool_state["layers"],
                                        slot_state["layers"])
    return {"layers": new_layers, "cur_len": pool_state["cur_len"]}


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 256, prompt_len: int = 32,
                 maintenance: Optional[Callable[[], object]] = None,
                 maintenance_max_interval: int = 64,
                 telemetry: Optional[Telemetry] = None):
        """``maintenance`` (e.g. a cache backend's bound
        ``maintenance()``) is invoked on *idle* engine ticks — ticks
        where the pending queue is empty (every waiting request has a
        slot) or the slot pool has spare capacity after admission — so
        background cache work (the double-buffered IVF publish) rides
        the real inter-batch gaps instead of stealing host time from
        every saturated decode step.  Starvation is bounded: under
        sustained full load the hook still runs at least every
        ``maintenance_max_interval`` ticks.

        Maintenance accounting lives on the telemetry registry
        (``batcher_maintenance_total{outcome=run|skip}``, DESIGN.md
        §10.1); ``maintenance_runs``/``maintenance_skips`` remain as
        read-only properties over those counters.  The batcher also
        records queue depth / slot occupancy gauges per tick and an
        admission-latency histogram (submit -> slot)."""
        if cfg.is_encoder:
            raise ValueError("decoder configs only")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.maintenance = maintenance
        self.maintenance_max_interval = max(maintenance_max_interval, 1)
        self.last_maintenance: Optional[object] = None
        self._ticks_since_maintenance = 0
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        reg = self.telemetry.registry
        m_maint = reg.counter(
            "batcher_maintenance_total",
            "idle-tick maintenance hook outcomes", labels=("outcome",))
        self._c_maint_run = m_maint.labels(outcome="run")
        self._c_maint_skip = m_maint.labels(outcome="skip")
        self._g_queue = reg.gauge(
            "batcher_queue_depth", "requests waiting for a slot").labels()
        self._g_occupancy = reg.gauge(
            "batcher_occupancy", "active slot fraction").labels()
        self._h_admission = reg.histogram(
            "batcher_admission_latency_seconds",
            "submit -> slot-admission wait").labels()
        self._submit_s: Dict[int, float] = {}
        self.pool = init_lm_state(cfg, n_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pending: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.ticks = 0
        self._next_tok = np.zeros((n_slots, 1), np.int32)

        self._prefill1 = jax.jit(
            lambda pv, toks: prefill(pv, cfg, toks, max_len))
        self._decode = jax.jit(lambda pv, st, tok: decode_step(pv, cfg, st,
                                                               tok))
        self._write = jax.jit(_write_slot, static_argnames=("slot",))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._submit_s[req.uid] = time.perf_counter()
        self.pending.append(req)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.pending:
                req = self.pending.pop(0)
                t_sub = self._submit_s.pop(req.uid, None)
                if t_sub is not None:
                    self._h_admission.observe(time.perf_counter() - t_sub)
                toks = np.full((1, self.prompt_len), EOS, np.int32)
                n = min(len(req.prompt), self.prompt_len)
                toks[0, :n] = req.prompt[:n]
                logits, st = self._prefill1(self.params, jnp.asarray(toks))
                self.pool = self._write(self.pool, st, slot=slot)
                self.slot_req[slot] = req
                first = int(jnp.argmax(logits[0]))
                self._next_tok[slot, 0] = first
                req.generated.append(first)

    def _retire(self) -> None:
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            if (len(req.generated) >= req.max_new_tokens
                    or (req.generated and req.generated[-1] == EOS)):
                req.done = True
                self.finished[req.uid] = req
                self.slot_req[slot] = None

    def idle(self) -> bool:
        """The idle-tick signal driving the maintenance hook: true when
        no request is waiting for a slot (queue drained) or the slot
        pool has spare capacity — i.e. this tick has host headroom that
        a decode-bound tick does not."""
        free = sum(r is None for r in self.slot_req)
        return not self.pending or free > 0

    def tick(self) -> int:
        """One engine iteration: admit, decode all active slots, retire.
        Returns the number of active slots this tick."""
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if active:
            logits, self.pool = self._decode(
                self.params, self.pool, jnp.asarray(self._next_tok))
            nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            for slot in active:
                tok = int(nxt[slot])
                self._next_tok[slot, 0] = tok
                self.slot_req[slot].generated.append(tok)
        self._retire()
        if self.maintenance is not None:
            self._ticks_since_maintenance += 1
            overdue = (self._ticks_since_maintenance
                       >= self.maintenance_max_interval)
            if self.idle() or overdue:
                # keep the hook's report (e.g. a MaintenanceReport with
                # rebuild/refit outcomes) inspectable per tick
                self.last_maintenance = self.maintenance()
                self._c_maint_run.inc()
                self._ticks_since_maintenance = 0
            else:
                self._c_maint_skip.inc()
        self.ticks += 1
        self._g_queue.set(len(self.pending))
        self._g_occupancy.set(self.occupancy)
        return len(active)

    def run(self, max_ticks: int = 10_000) -> Dict[int, Request]:
        while (self.pending or any(r is not None for r in self.slot_req)) \
                and self.ticks < max_ticks:
            self.tick()
        return self.finished

    @property
    def occupancy(self) -> float:
        n = sum(r is not None for r in self.slot_req)
        return n / self.n_slots

    @property
    def maintenance_runs(self) -> int:
        """Registry-backed (batcher_maintenance_total{outcome=run})."""
        return self._c_maint_run.value

    @property
    def maintenance_skips(self) -> int:
        """Registry-backed (batcher_maintenance_total{outcome=skip})."""
        return self._c_maint_skip.value

    def stats(self) -> Dict[str, object]:
        """Batcher snapshot for the serve example / launcher."""
        return {
            "ticks": self.ticks,
            "maintenance_runs": self.maintenance_runs,
            "maintenance_skips": self.maintenance_skips,
            "queue_depth": len(self.pending),
            "occupancy": self.occupancy,
            "finished": len(self.finished),
            "admission_wait_p50_s": self._h_admission.quantile(0.5),
        }
