"""Typed cache serving protocol: ``CacheBackend`` + plan/commit lifecycle.

The serving pipeline used to capability-sniff its cache with
``hasattr(cache, "set_fused")`` / ``supports_tenants`` and drive it
through two untyped calls (``lookup`` then ``insert``).  This module is
the typed replacement (DESIGN.md §7):

  * ``CacheCapabilities`` — a static descriptor every backend returns
    from ``capabilities()``; the pipeline branches on fields, never on
    ``hasattr``.
  * ``CacheRequest``  — one embedded batch: embeddings, the per-row
    tenant column, a trace id.
  * ``CachePlan``     — the backend's read-side verdict per row: hit
    flag, best same-tenant score, value id, the response string
    (resolved at plan time, so a later eviction cannot invalidate a
    response already promised to a request), the admission
    pre-decision carrying the observed neighbour scores, and the
    miss-coalescing map (near-identical misses grouped so one
    generation serves the whole group).
  * ``CommitReceipt`` — the write-side outcome: rows admitted/skipped,
    host strings freed, and maintenance obligations (``rebuild_due``)
    the pipeline discharges by calling ``maintenance()`` between
    batches — the hook behind the double-buffered warm-IVF rebuild.

Lifecycle invariants every backend must honor:

  * ``plan`` performs all read-side effects (LRU touch, TTL sweep) and
    resolves hit responses immediately; ``commit`` performs all
    write-side effects and never re-reads plan-time device state.
  * ``commit`` assigns **fresh** value ids to admitted rows — a plan
    can never resurrect a value id freed (e.g. by ``evict_tenant``)
    between plan and commit.
  * ``commit`` accepts a plan from an older backend epoch; it must
    stay safe (at worst admitting rows the current policy would now
    skip), never corrupt (dangling value ids, leaked host strings).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (
    List, Optional, Protocol, Sequence, Tuple, Union,
    runtime_checkable,
)

import numpy as np

TenantArg = Union[int, Sequence[int], np.ndarray]


# ---------------------------------------------------------------------------
# capability descriptor
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheCapabilities:
    """Static feature descriptor; replaces hasattr capability sniffing.

    ``fused_lookup=True`` additionally guarantees the backend exposes
    ``set_fused(bool)`` (the cascade execution-path switch).
    """
    tenants: bool = False            # isolates per-tenant id spaces
    fused_lookup: bool = False       # has set_fused() / Pallas cascade
    admission: bool = False          # plan carries a real admit decision
    background_rebuild: bool = False  # maintenance() can double-buffer
    tiered: bool = False             # hot/warm cascade vs flat store
    warm_sharded: bool = False       # warm tier spans a mesh axis (§8)
    warm_dtype: str = "float32"      # warm scan precision (int8 = quantized)
    learned_admission: bool = False  # maintenance() refits policies (§9)
    learned_embedder: bool = False   # maintenance() refreshes embedder (§11)
    cold_tier: bool = False          # host-RAM cold tier below warm (§12)
    ensemble: int = 0                # embedder count of the fused multi-
    #                                  embedder cascade (§13); 0 = single
    #                                  embedder.  When > 0, requests carry
    #                                  (B, E, D) embeddings and plans carry
    #                                  per-embedder ``panel_scores``.
    ttl: bool = False                # honours CacheRequest.ttl / default
    #                                  TTL: expired rows masked at plan
    #                                  time, reaped on maintenance (§14.2)
    conformal: bool = False          # per-tenant conformal threshold
    #                                  floor rides every plan (§14.3)


# ---------------------------------------------------------------------------
# request lifecycle dataclasses
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheRequest:
    """One embedded query batch entering the cache.

    ``texts`` (optional) carries the raw query strings alongside their
    embeddings.  Backends that refresh their embedder online (§11)
    retain the text of every admitted row so the corpus can be
    re-embedded under a new embedder version; without texts the entry
    is still served but pinned to the embedding it was admitted with.
    """
    embeddings: np.ndarray           # (B, D) float32, unit-norm rows;
    #                                  (B, E, D) under an ensemble backend
    #                                  (§13), one row per embedder
    tenants: np.ndarray              # (B,)  int32 tenant per row
    trace_id: int = 0
    texts: Optional[Tuple[str, ...]] = None   # raw query strings (§11)
    ttl: Optional[np.ndarray] = None  # (B,) float32 seconds-to-live per
    #                                   row (§14.2); +inf = never expire.
    #                                   None defers to the backend's
    #                                   configured default TTL.

    @classmethod
    def build(cls, embeddings, tenant: TenantArg = 0,
              trace_id: int = 0,
              texts: Optional[Sequence[str]] = None,
              ttl=None) -> "CacheRequest":
        """Normalize a scalar-or-array tenant argument to a (B,) row;
        likewise a scalar-or-array ``ttl`` (seconds) to a (B,) float32
        column (NaN rows fall back to no-TTL)."""
        embs = np.asarray(embeddings)
        t = np.asarray(tenant, np.int32)
        if t.ndim == 0:
            t = np.full(embs.shape[0], int(t), np.int32)
        if t.shape != (embs.shape[0],):
            raise ValueError(f"tenant row {t.shape} != batch "
                             f"({embs.shape[0]},)")
        if texts is not None and len(texts) != embs.shape[0]:
            raise ValueError(f"texts row {len(texts)} != batch "
                             f"({embs.shape[0]},)")
        ttl_col = None
        if ttl is not None:
            ttl_col = np.asarray(ttl, np.float32)
            if ttl_col.ndim == 0:
                ttl_col = np.full(embs.shape[0], float(ttl_col),
                                  np.float32)
            if ttl_col.shape != (embs.shape[0],):
                raise ValueError(f"ttl row {ttl_col.shape} != batch "
                                 f"({embs.shape[0]},)")
            ttl_col = np.where(np.isnan(ttl_col), np.inf, ttl_col)
            if np.any(ttl_col <= 0):
                raise ValueError("ttl must be positive seconds "
                                 "(+inf/NaN = never expire)")
        return cls(embeddings=embs, tenants=t, trace_id=trace_id,
                   texts=tuple(texts) if texts is not None else None,
                   ttl=ttl_col)

    def __len__(self) -> int:
        return int(self.embeddings.shape[0])


@dataclass
class CachePlan:
    """Read-side verdict for every row of one request.

    ``miss_leader`` encodes the miss-coalescing groups: -1 on hit rows;
    on miss rows, the index of the earliest near-identical same-tenant
    miss (its *leader* — ``miss_leader[i] == i`` for leaders).  One
    generation per leader serves its whole group.

    ``admit`` is the admission pre-decision taken at plan time from the
    observed neighbour scores (False on hit rows); ``commit`` honors it
    instead of re-deciding.

    ``top_value_ids`` carries the id of each row's best same-tenant
    neighbour *regardless of the hit flag* (-1 when the tenant had no
    candidate): commit compares a generated miss response against the
    neighbour's stored response to label the event a duplicate for the
    feedback loop (DESIGN.md §9).  ``margins`` records how far each
    row's best score sat from its tenant's threshold *at plan time* —
    with learned admission the thresholds drift between refits, so the
    plan is the only place that context exists; consumers (telemetry,
    tests, future cross-host policy sync) read it here instead of
    re-joining scores against a policy table that has since moved.
    """
    request: CacheRequest
    hit: np.ndarray                  # (B,) bool
    scores: np.ndarray               # (B,) best same-tenant score
    value_ids: np.ndarray            # (B,) int64, -1 on miss rows
    responses: List[Optional[str]]   # hit responses, resolved at plan time
    admit: np.ndarray                # (B,) bool admission pre-decision
    miss_leader: np.ndarray          # (B,) int64 coalescing map
    epoch: int = 0                   # backend epoch at plan time
    margins: Optional[np.ndarray] = None       # (B,) thr - score
    top_value_ids: Optional[np.ndarray] = None  # (B,) int64, -1 = none
    plan_wall_s: float = 0.0         # host wall time of plan() (§10)
    embed_version: int = 0           # embedder version at plan time (§11)
    # (B, E) unweighted per-embedder cosines of each row's best
    # same-tenant candidate under the fused ensemble (§13); None off the
    # ensemble path.  Commit feeds them — with the duplicate verdict —
    # to the per-tenant mixture-weight learner.
    panel_scores: Optional[np.ndarray] = None
    expired_masked: int = 0          # stored rows masked out of this
    #                                  plan's view as TTL-expired (§14.2)

    def miss_rows(self) -> np.ndarray:
        return np.nonzero(~self.hit)[0]

    def leader_rows(self) -> List[int]:
        """Miss rows needing a generation, in row order."""
        return [int(i) for i in self.miss_rows()
                if int(self.miss_leader[i]) == int(i)]

    @property
    def n_coalesced(self) -> int:
        """Miss rows served by another row's generation."""
        return int(sum(int(self.miss_leader[i]) != int(i)
                       for i in self.miss_rows()))

    @classmethod
    def for_insert(cls, request: CacheRequest, admit: np.ndarray,
                   scores: Optional[np.ndarray] = None,
                   epoch: int = 0, embed_version: int = 0) -> "CachePlan":
        """Plan equivalent of a legacy ``insert`` call: every row is an
        ungrouped miss, admission as given."""
        n = len(request)
        if scores is None:
            scores = np.zeros(n, np.float32)
        return cls(request=request, hit=np.zeros(n, bool),
                   scores=np.asarray(scores, np.float32),
                   value_ids=np.full(n, -1, np.int64),
                   responses=[None] * n,
                   admit=np.asarray(admit, bool),
                   miss_leader=np.arange(n, dtype=np.int64), epoch=epoch,
                   embed_version=embed_version)


@dataclass(frozen=True)
class MaintenanceReport:
    """What one ``maintenance()`` call did."""
    rebuild_started: bool = False    # a shadow rebuild was kicked off
    rebuild_published: bool = False  # a finished shadow index was swapped
    rebuild_in_flight: bool = False  # a shadow rebuild is still running
    rebuild_wall_s: float = 0.0      # wall time of the published rebuild
    refits_applied: int = 0          # policies republished this call (§9)
    refits_checked: int = 0          # tenants examined (incl. refusals)
    wall_s: float = 0.0              # host wall time of this call (§10)
    refresh_started: bool = False    # embedder refresh kicked off (§11)
    refresh_published: bool = False  # candidate embedder swapped in (§11)
    refresh_rolled_back: bool = False  # candidate failed the eval gate
    refresh_in_flight: bool = False  # train + re-embed still running
    refresh_wall_s: float = 0.0      # wall time of the published refresh
    embed_version: int = 0           # live embedder version after the call
    cold_promoted: int = 0           # re-hot rows promoted cold -> warm (§12)
    cold_route_rebuilt: bool = False  # cold routing re-fit this tick (§12)
    expired_reaped: int = 0          # TTL-expired rows reaped from every
    #                                  tier this tick (§14.2)


@dataclass(frozen=True)
class CommitReceipt:
    """Write-side outcome of one commit."""
    admitted: int                    # rows cached
    skipped: int                     # rows the admission rule dropped
    evicted: int                     # host strings freed by this commit
    rebuild_due: bool = False        # obligation: call maintenance() soon
    demoted_cold: int = 0            # warm-ring evictions captured by the
                                     # cold tier this commit (§12)
    cold_maintenance_due: bool = False  # obligation: pending cold
                                     # promotions / routing refit (§12)
    embed_version: int = 0           # live embedder version at commit (§11)
    stale_version_skipped: int = 0   # rows rejected: plan embedded under an
                                     # older embedder version than is live
    ttl_stamped: int = 0             # admitted rows carrying a finite
                                     # expiry deadline (§14.2)
    maintenance: MaintenanceReport = field(default_factory=MaintenanceReport)
    commit_wall_s: float = 0.0       # host wall time of commit() (§10)
    trace_id: int = 0                # echoed from the request (§10.2)


# ---------------------------------------------------------------------------
# the backend protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class CacheBackend(Protocol):
    """What the serving pipeline requires of a semantic cache.

    Implemented by ``SemanticCache`` (flat) and ``CacheService``
    (tiered, multi-tenant); see DESIGN.md §7 for the lifecycle diagram.
    """

    def capabilities(self) -> CacheCapabilities: ...

    def plan(self, request: CacheRequest, *,
             coalesce: bool = True) -> CachePlan: ...

    def commit(self, plan: CachePlan,
               responses: Sequence[Optional[str]]) -> CommitReceipt: ...

    def maintenance(self, block: bool = False) -> MaintenanceReport: ...

    def stats_snapshot(self) -> object: ...
    # a structured snapshot: a mapping, or an object with ``to_dict()``
    # (CacheService returns its typed ServiceStats; SemanticCache a
    # plain section dict).  The v1 flat-key ``stats()`` view was
    # removed in v2.0 (README migration table).


# ---------------------------------------------------------------------------
# miss coalescing (shared by both backends' plan())
# ---------------------------------------------------------------------------

def ungrouped_misses(hit: np.ndarray) -> np.ndarray:
    """The no-coalescing miss_leader map: every miss leads itself."""
    hit = np.asarray(hit, bool)
    return np.where(hit, -1, np.arange(len(hit), dtype=np.int64))


def coalesce_misses(embeddings: np.ndarray, hit: np.ndarray,
                    tenants: np.ndarray,
                    thresholds: np.ndarray) -> np.ndarray:
    """Group near-identical misses within one batch.

    Returns the ``miss_leader`` map: -1 on hit rows; on miss rows the
    index of the earliest same-tenant miss whose cosine similarity
    reaches the *member's* hit threshold (so serving the leader's
    response to the member is exactly as sound as a cache hit at the
    member's operating point).  Members only attach to leaders, never
    to other members, so groups cannot chain-drift below threshold.
    """
    hit = np.asarray(hit, bool)
    leader = np.full(len(hit), -1, np.int64)
    miss = np.nonzero(~hit)[0]
    if len(miss) == 0:
        return leader
    em = np.asarray(embeddings, np.float32)[miss]
    em = em / np.maximum(np.linalg.norm(em, axis=-1, keepdims=True), 1e-9)
    sims = em @ em.T                     # one matmul; the scan below is
    tnt = np.asarray(tenants)[miss]      # O(misses) with vector inners
    thr = np.asarray(thresholds)[miss]
    is_leader = np.zeros(len(miss), bool)
    for a in range(len(miss)):
        ok = is_leader[:a] & (tnt[:a] == tnt[a]) & (sims[a, :a] >= thr[a])
        if ok.any():
            leader[miss[a]] = miss[int(np.argmax(ok))]   # earliest leader
        else:
            leader[miss[a]] = miss[a]
            is_leader[a] = True
    return leader
