"""CI perf-trajectory gate over ``BENCH_cascade.json``.

``bench_tiered_cache`` writes every row of each run to a
machine-readable JSON; the copy committed under ``results/`` is the
perf trajectory baseline.  This gate compares a fresh ``--smoke`` run
against it so a PR cannot silently regress what the bench measures:

  * every baseline row must still exist in the fresh run (a vanished
    row means a bench path was dropped, which must be an explicit
    baseline update, never an accident);
  * recall fields (``recall_at_thr``, ``recall_probe``) must not fall
    more than ``--recall-eps`` below the baseline;
  * ``p50_us`` may not exceed ``baseline * --p50-tolerance`` — latency
    ratios, not absolutes, and only when the fresh run's backend AND
    device count match the baseline's.  The fleet tuple is coarse (a
    dev laptop and a hosted CI runner both say ``cpu x1``), so the
    default tolerance is deliberately wide: it exists to catch
    order-of-magnitude cliffs (an accidental recompile per batch, an
    O(N) scan on the hot path), not machine-to-machine jitter.
    Tighten ``--p50-tolerance`` only where baseline and CI hardware
    genuinely match; a mismatched fleet skips the latency check and
    says so;
  * a baseline row whose size tier is absent from the fresh sweep is
    skipped with a note (a full-sweep baseline must not fail every
    ``--smoke`` run on rows the smoke tier cannot produce);
  * the learned-admission claim is re-checked on the artifacts: the
    ``admission_learned`` row must keep ``dup_admissions`` strictly
    below ``admission_fixed``'s and its false-hit probes at zero-ish
    (<= the fixed row's);
  * the embedder-refresh claim (DESIGN.md §11) likewise: once either
    run carries an ``embedder_*`` row, the fresh run owes both the
    ``embedder_frozen`` and ``embedder_refreshed`` rows, the refreshed
    row must beat the frozen one on ``hit_precision`` AND
    ``hit_recall``, both must hold ``overlap_recall`` at exactly 1.0
    (a committed entry lost through a hot swap is data loss, not
    noise), and the refreshed row must have published
    (``embed_version >= 1``);
  * the telemetry stage breakdown (``tiered/serve/stage_*``) must be
    complete: once either run carries any serving-telemetry row, the
    fresh run owes one row per required stage (plan / commit /
    maintenance) — a vanished stage means an instrumentation path was
    dropped, which no aggregate row would notice.  Stage p50s get
    their own (tighter) ratio bound via ``--stage-p50-tolerance``,
    because stage rows exist precisely to localise a regression the
    end-to-end row dilutes;
  * the ``tiered/serve/telemetry_overhead`` row's budget is re-checked
    from the committed fields (the paired per-tick difference estimate
    must fit in 2% of the bare p50 plus a 100us floor — the same bound
    the bench asserts at run time), so a baseline update cannot
    smuggle in an over-budget measurement;
  * the cold-tier claims (DESIGN.md §12) likewise: for every cold size
    tier the fresh sweep covers (``cold_sizes`` meta), the fresh run
    owes the ``warm_only`` / ``cold_enabled`` / ``promotion`` rows and
    the ``tiered/cold/p50_ratio`` row; ``cold_enabled`` recall must
    sit *strictly* above ``warm_only`` at equal device memory with at
    least one cold hit; ``cold_hit_rate`` must not fall more than
    ``--cold-hit-eps`` below the baseline's; and the committed
    ``p50_ratio`` (cold-enabled vs disabled at a warm-feasible size)
    must stay under a fixed 2.0x bound.  Baseline cold rows at sizes
    the fresh sweep does not cover (e.g. the committed 1M tier vs a
    64k ``--smoke`` run) are skipped with a note, like the size-tier
    rule above.

Exit 0 when clean; exit 1 with one line per violation.

    python scripts/check_bench_trajectory.py \
        --baseline results/BENCH_cascade.json \
        --fresh /tmp/BENCH_cascade_fresh.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Tuple

RECALL_FIELDS = ("recall_at_thr", "recall_probe")

# Serving-telemetry rows (DESIGN.md §10).  The stage list and the
# overhead budget mirror benchmarks/bench_tiered_cache.py and
# repro.obs.health.check_overhead_budget; they are restated here
# because this gate runs without PYTHONPATH=src and must not import
# the package it is judging.
STAGE_PREFIX = "tiered/serve/stage_"
REQUIRED_STAGES = ("plan", "commit", "maintenance")
OVERHEAD_ROW = "tiered/serve/telemetry_overhead"
OVERHEAD_MAX_RATIO = 1.02
OVERHEAD_FLOOR_US = 100.0

# Cold-tier rows (DESIGN.md §12): same restatement rule as above.
COLD_PREFIX = "tiered/cold/"
COLD_RATIO_ROW = "tiered/cold/p50_ratio"
COLD_P50_RATIO_MAX = 2.0
COLD_REQUIRED = ("warm_only", "cold_enabled", "promotion")

# Fused multi-embedder ensemble rows (DESIGN.md §13): same rule.  The
# latency claim (fused E-panel pass <= 1.6x the single-embedder p50,
# i.e. speedup over the sequential E-pass path >= E/1.6) only holds on
# accelerator backends; CPU runs must carry a structured skip in
# ``skipped_asserts`` instead — verified below, so the claim can never
# be silently absent.  --ensemble-speedup-min is stated at E=3 (3/1.6
# = 1.875) and scaled linearly for other panel counts.
ENS_PREFIX = "tiered/ensemble/"
ENS_WEIGHT_ROWS = ("tiered/ensemble/weights_uniform",
                   "tiered/ensemble/weights_learned")
SHARDED_ASSERT_MIN_N = 1 << 18


def load(path: str) -> Dict[str, object]:
    with open(path) as f:
        return json.load(f)


def _rows(data: Dict[str, object]) -> Dict[str, Dict[str, object]]:
    return {r["name"]: r for r in data.get("rows", [])}


_SIZE_RE = re.compile(r"^tiered/(\d+)k/")
_COLD_SIZE_RE = re.compile(r"^tiered/cold/(\d+)k/")
_ENS_SIZE_RE = re.compile(r"^tiered/ensemble/(\d+)k/")


def _comparable(name: str, fresh_sizes, fresh_cold_sizes,
                fresh_ens_sizes) -> bool:
    """A baseline row is only owed by the fresh run when the fresh
    sweep covers its size tier: a full-sweep baseline (16k/64k/256k
    rows, 1M cold rows, 64k ensemble) must not make every --smoke run
    (4k + 64k cold + 16k ensemble) fail on rows the smoke tier can
    never produce.  Size-independent rows (admission, the ensemble
    weights_* pair, …) are always owed."""
    m = _ENS_SIZE_RE.match(name)
    if m is not None:
        return int(m.group(1)) * 1024 in set(fresh_ens_sizes or [])
    m = _COLD_SIZE_RE.match(name)
    if m is not None:
        return int(m.group(1)) * 1024 in set(fresh_cold_sizes or [])
    m = _SIZE_RE.match(name)
    if m is None:
        return True
    return int(m.group(1)) * 1024 in set(fresh_sizes or [])


def compare(baseline: Dict[str, object], fresh: Dict[str, object],
            recall_eps: float = 0.005,
            p50_tolerance: float = 5.0,
            stage_p50_tolerance: float = 3.0,
            cold_hit_eps: float = 0.1,
            ensemble_speedup_min: float = 1.875) -> Tuple[List[str],
                                                          List[str]]:
    """Returns (violations, notes).  Violations fail the gate; notes
    explain what was skipped or newly added."""
    violations: List[str] = []
    notes: List[str] = []
    base_rows = _rows(baseline)
    fresh_rows = _rows(fresh)

    same_fleet = (baseline.get("backend") == fresh.get("backend")
                  and baseline.get("devices") == fresh.get("devices"))
    if not same_fleet:
        notes.append(
            f"fleet mismatch (baseline {baseline.get('backend')}"
            f"x{baseline.get('devices')} vs fresh {fresh.get('backend')}"
            f"x{fresh.get('devices')}): p50 ratios not compared")

    fresh_sizes = fresh.get("sizes", [])
    fresh_cold_sizes = fresh.get("cold_sizes", [])
    fresh_ens_sizes = fresh.get("ensemble_sizes", [])
    for name, base in base_rows.items():
        if not _comparable(name, fresh_sizes, fresh_cold_sizes,
                           fresh_ens_sizes):
            notes.append(f"{name}: size tier not in the fresh sweep "
                         f"(sizes {fresh_sizes}, cold {fresh_cold_sizes},"
                         f" ensemble {fresh_ens_sizes}); skipped")
            continue
        row = fresh_rows.get(name)
        if row is None:
            violations.append(
                f"{name}: row present in baseline but missing from the "
                "fresh run (bench path dropped?)")
            continue
        for field in RECALL_FIELDS:
            if field in base:
                if field not in row:
                    violations.append(f"{name}: {field} vanished from "
                                      "the fresh run")
                elif row[field] < base[field] - recall_eps:
                    violations.append(
                        f"{name}: {field} regressed "
                        f"{base[field]:.4f} -> {row[field]:.4f} "
                        f"(eps {recall_eps})")
        if same_fleet and "p50_us" in base and "p50_us" in row:
            tol = stage_p50_tolerance if name.startswith(STAGE_PREFIX) \
                else p50_tolerance
            if row["p50_us"] > base["p50_us"] * tol:
                violations.append(
                    f"{name}: p50 {row['p50_us']:.0f}us exceeds "
                    f"{tol:.1f}x the baseline "
                    f"{base['p50_us']:.0f}us")

    for name in sorted(set(fresh_rows) - set(base_rows)):
        notes.append(f"{name}: new row (not in baseline)")

    fixed = fresh_rows.get("tiered/admission_fixed")
    learned = fresh_rows.get("tiered/admission_learned")
    if fixed is not None and learned is not None:
        if learned["dup_admissions"] >= fixed["dup_admissions"]:
            violations.append(
                "admission: learned dup_admissions "
                f"{learned['dup_admissions']} not below fixed "
                f"{fixed['dup_admissions']}")
        if learned["false_hits_probe"] > fixed["false_hits_probe"]:
            violations.append(
                "admission: learned false_hits_probe "
                f"{learned['false_hits_probe']} exceeds fixed "
                f"{fixed['false_hits_probe']}")

    # embedder-refresh claim (DESIGN.md §11): completeness first —
    # once either run carries the rows, the fresh run owes both sides
    emb_names = ("tiered/embedder_frozen", "tiered/embedder_refreshed")
    if any(n in base_rows or n in fresh_rows for n in emb_names):
        missing = [n for n in emb_names if n not in fresh_rows]
        for n in missing:
            violations.append(
                f"embedder: required row {n} missing from the fresh "
                "run (refresh bench path dropped?)")
        if not missing:
            froz = fresh_rows[emb_names[0]]
            refr = fresh_rows[emb_names[1]]
            if refr.get("hit_precision", 0) <= froz.get(
                    "hit_precision", 0):
                violations.append(
                    "embedder: refreshed hit_precision "
                    f"{refr.get('hit_precision')} not above frozen "
                    f"{froz.get('hit_precision')}")
            if refr.get("hit_recall", 0) <= froz.get("hit_recall", 0):
                violations.append(
                    "embedder: refreshed hit_recall "
                    f"{refr.get('hit_recall')} not above frozen "
                    f"{froz.get('hit_recall')}")
            for name, row in zip(emb_names, (froz, refr)):
                if row.get("overlap_recall") != 1.0:
                    violations.append(
                        f"embedder: {name} overlap_recall "
                        f"{row.get('overlap_recall')} != 1.0 (entries "
                        "lost through the hot swap)")
            if refr.get("embed_version", 0) < 1:
                violations.append(
                    "embedder: refreshed row never published "
                    f"(embed_version {refr.get('embed_version')})")

    # serving-telemetry completeness + overhead budget (DESIGN.md §10)
    def _has_telemetry(rows: Dict[str, Dict[str, object]]) -> bool:
        return OVERHEAD_ROW in rows or any(
            n.startswith(STAGE_PREFIX) for n in rows)

    if _has_telemetry(base_rows) or _has_telemetry(fresh_rows):
        for stage in REQUIRED_STAGES:
            if f"{STAGE_PREFIX}{stage}" not in fresh_rows:
                violations.append(
                    f"telemetry: required stage row "
                    f"{STAGE_PREFIX}{stage} missing from the fresh run "
                    "(instrumentation path dropped?)")
        if OVERHEAD_ROW not in fresh_rows:
            violations.append(
                f"telemetry: {OVERHEAD_ROW} row missing from the "
                "fresh run")
    over = fresh_rows.get(OVERHEAD_ROW)
    if over is not None and "median_extra_us" in over \
            and "p50_off_us" in over:
        # Same assertion the bench makes at run time: the *paired*
        # per-tick difference estimate (not raw p50 on minus p50 off,
        # which still carries uncanceled host jitter) must fit in
        # 2% of the bare tick plus the timer-granularity floor.
        extra = max(over["median_extra_us"], 0.0)
        limit = over["p50_off_us"] * (OVERHEAD_MAX_RATIO - 1.0) \
            + OVERHEAD_FLOOR_US
        if extra > limit:
            violations.append(
                f"telemetry: overhead over budget — paired extra "
                f"{extra:.0f}us per tick vs bare p50 "
                f"{over['p50_off_us']:.0f}us (limit "
                f"{OVERHEAD_MAX_RATIO - 1.0:.0%} + "
                f"{OVERHEAD_FLOOR_US:.0f}us = {limit:.0f}us)")

    # cold-tier claims (DESIGN.md §12): completeness per fresh cold
    # size tier, the strict recall lift, hit-rate non-regression, and
    # the committed overhead ratio bound
    def _has_cold(rows: Dict[str, Dict[str, object]]) -> bool:
        return any(n.startswith(COLD_PREFIX) for n in rows)

    if _has_cold(base_rows) or _has_cold(fresh_rows):
        for n_sz in fresh_cold_sizes:
            tagk = f"{COLD_PREFIX}{n_sz // 1024}k"
            tier = {part: fresh_rows.get(f"{tagk}/{part}")
                    for part in COLD_REQUIRED}
            for part, row in tier.items():
                if row is None:
                    violations.append(
                        f"cold: required row {tagk}/{part} missing from "
                        "the fresh run (cold bench path dropped?)")
            warm, cold = tier["warm_only"], tier["cold_enabled"]
            if warm is not None and cold is not None:
                if cold.get("recall_at_thr", 0.0) \
                        <= warm.get("recall_at_thr", 1.0):
                    violations.append(
                        f"cold: {tagk} cold_enabled recall "
                        f"{cold.get('recall_at_thr')} not strictly above "
                        f"warm_only {warm.get('recall_at_thr')} at equal "
                        "device memory")
                if cold.get("cold_hits", 0) <= 0:
                    violations.append(
                        f"cold: {tagk}/cold_enabled recorded no cold "
                        "hits")
                base_cold = base_rows.get(f"{tagk}/cold_enabled")
                if base_cold is not None \
                        and "cold_hit_rate" in base_cold \
                        and cold.get("cold_hit_rate", 0.0) \
                        < base_cold["cold_hit_rate"] - cold_hit_eps:
                    violations.append(
                        f"cold: {tagk} cold_hit_rate regressed "
                        f"{base_cold['cold_hit_rate']:.3f} -> "
                        f"{cold.get('cold_hit_rate'):.3f} "
                        f"(eps {cold_hit_eps})")
        if fresh_cold_sizes and COLD_RATIO_ROW not in fresh_rows:
            violations.append(
                f"cold: {COLD_RATIO_ROW} row missing from the fresh run")
    ratio = fresh_rows.get(COLD_RATIO_ROW)
    if ratio is not None and "p50_ratio" in ratio \
            and ratio["p50_ratio"] > COLD_P50_RATIO_MAX:
        violations.append(
            f"cold: serving p50 with the cold tier enabled is "
            f"{ratio['p50_ratio']:.2f}x the disabled p50 at a "
            f"warm-feasible size (bound {COLD_P50_RATIO_MAX}x)")

    # fused-ensemble claims (DESIGN.md §13).  Latency first: the
    # <=1.6x bound (speedup over sequential >= E/1.6) is re-checked
    # from BOTH artifacts — the committed baseline and the fresh run —
    # wherever that artifact came off a non-CPU backend, so a baseline
    # update cannot smuggle in an over-budget measurement either.
    for run_tag, run in (("baseline", baseline), ("fresh", fresh)):
        rrows = base_rows if run_tag == "baseline" else fresh_rows
        if run.get("backend") == "cpu":
            continue          # must carry a structured skip; see below
        for name, row in rrows.items():
            if not (_ENS_SIZE_RE.match(name) and name.endswith("/fused")
                    and "speedup_vs_sequential" in row):
                continue
            need = ensemble_speedup_min * row.get("e", 3) / 3.0
            if row["speedup_vs_sequential"] < need:
                violations.append(
                    f"ensemble: {run_tag} {name} speedup over the "
                    f"sequential E-pass path "
                    f"{row['speedup_vs_sequential']:.3f} below "
                    f"{need:.3f} (--ensemble-speedup-min "
                    f"{ensemble_speedup_min} at E=3, scaled to "
                    f"E={row.get('e', 3)})")

    # the ensemble recall claim, re-checked from the fresh artifact:
    # fused recall must not sit below the best single embedder's
    for name, row in fresh_rows.items():
        if _ENS_SIZE_RE.match(name) and name.endswith("/fused") \
                and "best_single_recall" in row \
                and row.get("recall_at_thr", 0.0) \
                < row["best_single_recall"]:
            violations.append(
                f"ensemble: {name} fused recall "
                f"{row.get('recall_at_thr')} below the best single "
                f"embedder's {row['best_single_recall']}")

    # learned-vs-uniform mixture weights: once either run carries the
    # pair, the fresh run owes both rows and the learned side must
    # strictly beat uniform on duplicate admissions and probe recall
    if any(n in base_rows or n in fresh_rows for n in ENS_WEIGHT_ROWS):
        missing = [n for n in ENS_WEIGHT_ROWS if n not in fresh_rows]
        for n in missing:
            violations.append(
                f"ensemble: required row {n} missing from the fresh "
                "run (weight-learning bench path dropped?)")
        if not missing:
            uni = fresh_rows[ENS_WEIGHT_ROWS[0]]
            lrn = fresh_rows[ENS_WEIGHT_ROWS[1]]
            if lrn.get("dup_admissions", 0) \
                    >= uni.get("dup_admissions", 0):
                violations.append(
                    "ensemble: learned-weight dup_admissions "
                    f"{lrn.get('dup_admissions')} not below uniform "
                    f"{uni.get('dup_admissions')}")
            if lrn.get("recall_probe", 0.0) \
                    <= uni.get("recall_probe", 1.0):
                violations.append(
                    "ensemble: learned-weight recall_probe "
                    f"{lrn.get('recall_probe')} not above uniform "
                    f"{uni.get('recall_probe')}")
            if lrn.get("weight_refits", 0) < 1:
                violations.append(
                    "ensemble: learned-weight row applied no weight "
                    "refit")

    # platform-conditional asserts: every one applicable to the fresh
    # sweep must be visibly enforced (checked_asserts) or legally
    # skipped (skipped_asserts; CPU only) — a name in neither list
    # means the assert site itself was dropped.
    checked = set(fresh.get("checked_asserts", []))
    skipped = {s.get("name"): s.get("reason", "")
               for s in fresh.get("skipped_asserts", [])
               if isinstance(s, dict)}
    backend = fresh.get("backend")
    owed = []
    if fresh.get("devices", 1) > 1:
        owed += [f"tiered/{n // 1024}k/sharded_p50_beats_replicated"
                 for n in fresh_sizes if n >= SHARDED_ASSERT_MIN_N]
    owed += [f"tiered/ensemble/{n // 1024}k/ensemble_speedup"
             for n in fresh_ens_sizes]
    for name in owed:
        if name in checked:
            continue
        if name in skipped:
            if backend != "cpu":
                violations.append(
                    f"asserts: {name} skipped on backend "
                    f"{backend!r} ({skipped[name]}) — only a cpu run "
                    "may skip a platform-conditional assert")
            else:
                notes.append(f"{name}: skipped on cpu "
                             f"({skipped[name]})")
        else:
            violations.append(
                f"asserts: platform-conditional assert {name} neither "
                "checked nor skipped in the fresh run (assert site "
                "dropped?)")
    return violations, notes


# Scenario macro-bench rows (DESIGN.md §14.1): the asserts the bench
# owes, restated (this gate runs without PYTHONPATH=src and must not
# import the bench it is judging — same rule as the stage list above).
SCENARIO_OWED_ASSERTS = (
    "scenario_zero_stale_serves",
    "scenario_false_hit_budgets",
    "drift_learned_threshold_leaks",
    "drift_conformal_holds_budget",
    "adversarial_must_miss_budget",
    "ttl_expiry_enforced",
    "ttl_prewindow_hits",
)


def compare_scenarios(baseline: Dict[str, object],
                      fresh: Dict[str, object],
                      p99_tolerance: float = 5.0,
                      hit_eps: float = 0.05) -> Tuple[List[str],
                                                      List[str]]:
    """Gate over ``BENCH_scenarios.json`` (DESIGN.md §14.1):

      * every baseline (scenario, mode) row must survive into the
        fresh run;
      * stale serves must be zero in every fresh row — TTL expiry is
        correctness, not a trajectory;
      * every conformal-mode row must hold its own committed
        ``false_hit_budget``; the drift *learned* row must still LEAK
        (over budget) — if it stops leaking the contrast scenario has
        lost its teeth and the conformal claim is unfalsifiable;
      * ``hit_rate`` must not fall more than ``hit_eps`` below the
        baseline per row (an eviction/threshold bug shows up here
        before anywhere else) — same-tier only: a ``--smoke`` run
        replays shorter traces than the committed full sweep, so its
        rates are not comparable row-for-row;
      * ``p99_us_per_row`` ratios are bounded like the cascade p50s —
        same-fleet, same-tier only, wide tolerance, order-of-magnitude
        cliffs;
      * the owed assert names must ALL appear in the fresh run's
        ``checked_asserts`` — a ``--scenario`` subset run writes
        structured skips, which a gated (full) run may never carry.
    """
    violations: List[str] = []
    notes: List[str] = []

    def rows_of(d):
        return {(r["scenario"], r["mode"]): r for r in d.get("rows", [])}

    base_rows, fresh_rows = rows_of(baseline), rows_of(fresh)
    same_fleet = (baseline.get("backend") == fresh.get("backend")
                  and baseline.get("devices") == fresh.get("devices"))
    if not same_fleet:
        notes.append(
            f"scenario fleet mismatch (baseline "
            f"{baseline.get('backend')}x{baseline.get('devices')} vs "
            f"fresh {fresh.get('backend')}x{fresh.get('devices')}): "
            "p99 ratios not compared")
    same_tier = bool(baseline.get("smoke")) == bool(fresh.get("smoke"))
    if not same_tier:
        notes.append(
            "scenario tier mismatch (baseline smoke="
            f"{bool(baseline.get('smoke'))} vs fresh smoke="
            f"{bool(fresh.get('smoke'))}): traces differ, hit_rate/p99 "
            "not compared row-for-row (budgets/stale/asserts still "
            "gated)")

    for key, base in base_rows.items():
        row = fresh_rows.get(key)
        tag = "/".join(key)
        if row is None:
            violations.append(
                f"scenario {tag}: row present in baseline but missing "
                "from the fresh run (scenario dropped?)")
            continue
        if same_tier and row.get("hit_rate", 0.0) \
                < base.get("hit_rate", 0.0) - hit_eps:
            violations.append(
                f"scenario {tag}: hit_rate regressed "
                f"{base['hit_rate']:.3f} -> {row['hit_rate']:.3f} "
                f"(eps {hit_eps})")
        if same_fleet and same_tier and "p99_us_per_row" in base \
                and base["p99_us_per_row"] > 0 \
                and row.get("p99_us_per_row", 0.0) \
                > base["p99_us_per_row"] * p99_tolerance:
            violations.append(
                f"scenario {tag}: plan p99 "
                f"{row['p99_us_per_row']:.0f}us/row exceeds "
                f"{p99_tolerance:.1f}x the baseline "
                f"{base['p99_us_per_row']:.0f}us/row")

    for key, row in fresh_rows.items():
        tag = "/".join(key)
        if row.get("stale_serves", 0) != 0:
            violations.append(
                f"scenario {tag}: {row['stale_serves']} stale serve(s) "
                "— an expired entry was served")
        budget = row.get("false_hit_budget")
        rate = row.get("false_hit_rate", 0.0)
        if key == ("drift", "learned"):
            if budget is not None and rate <= budget:
                violations.append(
                    f"scenario {tag}: the fixed learned threshold no "
                    f"longer leaks under drift ({rate:.4f} <= budget "
                    f"{budget}) — the conformal contrast is "
                    "unfalsifiable; retune the scenario")
        elif budget is not None and rate > budget:
            violations.append(
                f"scenario {tag}: false-hit rate {rate:.4f} over the "
                f"committed budget {budget}")

    for key in sorted(set(fresh_rows) - set(base_rows)):
        notes.append(f"scenario {'/'.join(key)}: new row "
                     "(not in baseline)")

    checked = set(fresh.get("checked_asserts", []))
    skipped = {s.get("name"): s.get("reason", "")
               for s in fresh.get("skipped_asserts", [])
               if isinstance(s, dict)}
    for name in SCENARIO_OWED_ASSERTS:
        if name in checked:
            continue
        if name in skipped:
            violations.append(
                f"scenario asserts: {name} skipped "
                f"({skipped[name]}) — a gated run must be a full "
                "sweep, which owes every scenario assert")
        else:
            violations.append(
                f"scenario asserts: {name} neither checked nor "
                "skipped in the fresh run (assert site dropped?)")
    return violations, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="results/BENCH_cascade.json",
                    help="committed perf-trajectory baseline")
    ap.add_argument("--fresh", required=True,
                    help="JSON written by the fresh bench run")
    ap.add_argument("--recall-eps", type=float, default=0.005,
                    help="tolerated absolute recall drop per row")
    ap.add_argument("--p50-tolerance", type=float, default=5.0,
                    help="max fresh/baseline p50 ratio (same fleet only)")
    ap.add_argument("--stage-p50-tolerance", type=float, default=3.0,
                    help="max fresh/baseline p50 ratio for the per-stage "
                         "telemetry rows (tiered/serve/stage_*; same "
                         "fleet only)")
    ap.add_argument("--cold-hit-eps", type=float, default=0.1,
                    help="tolerated absolute cold_hit_rate drop vs the "
                         "baseline cold_enabled row")
    ap.add_argument("--ensemble-speedup-min", type=float, default=1.875,
                    help="min fused-ensemble speedup over the sequential "
                         "E-pass path on accelerator runs, stated at E=3 "
                         "(3/1.6 = 1.875 enforces the <=1.6x p50 bound) "
                         "and scaled linearly to each row's E")
    ap.add_argument("--scenario-baseline",
                    default="results/BENCH_scenarios.json",
                    help="committed scenario macro-bench baseline "
                         "(DESIGN.md §14.1)")
    ap.add_argument("--scenario-fresh", default=None,
                    help="JSON written by a fresh bench_scenarios run; "
                         "when given, the scenario gate runs too")
    ap.add_argument("--scenario-p99-tolerance", type=float, default=5.0,
                    help="max fresh/baseline plan-p99 ratio per scenario "
                         "row (same fleet only)")
    ap.add_argument("--scenario-hit-eps", type=float, default=0.05,
                    help="tolerated absolute hit_rate drop per scenario "
                         "row vs baseline")
    args = ap.parse_args(argv)

    violations, notes = compare(load(args.baseline), load(args.fresh),
                                recall_eps=args.recall_eps,
                                p50_tolerance=args.p50_tolerance,
                                stage_p50_tolerance=args.stage_p50_tolerance,
                                cold_hit_eps=args.cold_hit_eps,
                                ensemble_speedup_min=args
                                .ensemble_speedup_min)
    n_rows = len(_rows(load(args.fresh)))
    if args.scenario_fresh:
        sv, sn = compare_scenarios(
            load(args.scenario_baseline), load(args.scenario_fresh),
            p99_tolerance=args.scenario_p99_tolerance,
            hit_eps=args.scenario_hit_eps)
        violations += sv
        notes += sn
        n_rows += len(load(args.scenario_fresh).get("rows", []))
    for n in notes:
        print(f"note: {n}")
    if violations:
        for v in violations:
            print(f"VIOLATION: {v}", file=sys.stderr)
        print(f"perf trajectory gate: {len(violations)} violation(s)",
              file=sys.stderr)
        return 1
    print(f"perf trajectory gate: clean ({n_rows} rows vs baseline)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
