"""Kernel microbenches: Pallas (interpret on CPU) vs pure-jnp oracle.

On this CPU container the interpret-mode timing is NOT the TPU story —
the derived column carries the correctness error and the working-set
arithmetic that the §Roofline analysis uses; ref timings show the
XLA-fallback cost the kernel replaces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_derived, timed
from repro.kernels.cosine_topk import kernel as ctk_kernel, ref as ctk_ref
from repro.kernels.decode_attention import kernel as da_kernel, ref as da_ref
from repro.kernels.flash_attention import kernel as fa_kernel, ref as fa_ref

rng = np.random.default_rng(0)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def bench_kernels():
    # cosine_topk: the cache-lookup hot path at deployment scale
    for (Q, N, D, k) in [(8, 4096, 256, 1), (32, 16384, 256, 4)]:
        q = jnp.asarray(_unit(rng.standard_normal((Q, D)).astype(np.float32)))
        keys = jnp.asarray(_unit(rng.standard_normal((N, D)).astype(
            np.float32)))
        valid = jnp.ones(N, bool)
        (s_ref, i_ref), us_ref = timed(
            lambda: ctk_ref.cosine_topk(q, keys, valid, k))
        (s_k, i_k), us_k = timed(
            lambda: ctk_kernel.cosine_topk(q, keys, valid, k, interpret=True))
        err = float(jnp.max(jnp.abs(s_ref - s_k)))
        vmem_kb = (512 * D + Q * D) * 4 / 1024
        yield (f"kernels/cosine_topk_Q{Q}_N{N}", us_ref,
               fmt_derived({"err_vs_ref": err, "interp_us": us_k,
                            "vmem_tile_kb": vmem_kb}))

    # flash attention prefill tile
    q = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    o_ref, us_ref = timed(lambda: fa_ref.flash_attention(q, kv, kv,
                                                         causal=True))
    o_k, us_k = timed(lambda: fa_kernel.flash_attention(
        q, kv, kv, causal=True, block_q=128, block_kv=128, interpret=True))
    err = float(jnp.max(jnp.abs(o_ref - o_k)))
    yield ("kernels/flash_attention_S512", us_ref,
           fmt_derived({"err_vs_ref": err, "interp_us": us_k,
                        "vmem_tile_kb": (128 * 64 * 3 + 128 * 128) * 4 / 1024}))

    # decode attention against a 32k cache slice
    q1 = jnp.asarray(rng.standard_normal((4, 8, 64)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((4, 8192, 2, 64)), jnp.float32)
    valid = jnp.ones((4, 8192), bool)
    o_ref, us_ref = timed(lambda: da_ref.decode_attention(q1, kc, kc, valid))
    o_k, us_k = timed(lambda: da_kernel.decode_attention(
        q1, kc, kc, valid, block_l=512, interpret=True))
    err = float(jnp.max(jnp.abs(o_ref - o_k)))
    yield ("kernels/decode_attention_L8192", us_ref,
           fmt_derived({"err_vs_ref": err, "interp_us": us_k}))

    # IVF two-level index vs exact flat search (recall + speedup)
    from repro.core.ivf import build_ivf, ivf_query
    from repro.core.store import init_store, insert_batch, query as fquery
    n_clusters, per, D = 64, 128, 128
    cents = _unit(rng.standard_normal((n_clusters, D)).astype(np.float32))
    keys = _unit(np.repeat(cents, per, 0) + 0.15 * rng.standard_normal(
        (n_clusters * per, D)).astype(np.float32))
    N = len(keys)
    vids = jnp.arange(N)
    state = build_ivf(jnp.asarray(keys), jnp.ones(N, bool), vids,
                      n_clusters=n_clusters, bucket=2 * per)
    flat = insert_batch(init_store(N, D), jnp.asarray(keys), vids)
    qi = rng.choice(N, 64, replace=False)
    q = jnp.asarray(_unit(keys[qi] + 0.02 * rng.standard_normal(
        (64, D)).astype(np.float32)))
    jq = jax.jit(lambda st, qq: ivf_query(st, qq, 0.9, 1, 8))
    jf = jax.jit(lambda st, qq: fquery(st, qq, 0.9, 1))
    (s, sl, v, hit), us_ivf = timed(lambda: jax.block_until_ready(
        jq(state, q)))
    res, us_flat = timed(lambda: jax.block_until_ready(jf(flat, q)))
    recall = float(np.mean(np.asarray(v[:, 0]) ==
                           np.asarray(res.value_ids[:, 0])))
    yield ("kernels/ivf_vs_flat_N8192", us_flat,
           fmt_derived({"ivf_us": us_ivf, "top1_recall_vs_exact": recall,
                        "speedup": us_flat / max(us_ivf, 1e-9)}))
