"""Granite-MoE-3B (800M active) — fine-grained 40-expert top-8 MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base family]  32L, d_model=1536,
24 heads, kv=8, expert d_ff=512, vocab=49155, MoE 40 experts top-8.

NOTE: the assignment's spec line says "MoE 40e top-8" while its bracket
comment says "32 experts top-8"; we follow the spec line (40 experts) —
discrepancy recorded in DESIGN.md §Arch-applicability.
"""
from repro.configs.base import (
    ModelConfig, LayerSpec, MoEConfig, ATTN, MOE, register,
)

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    use_rope=True,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, top_k=8, expert_d_ff=512),
    period=(LayerSpec(ATTN, MOE),),
))
