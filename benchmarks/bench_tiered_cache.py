"""Tiered cache vs flat brute force at production corpus sizes, fused
vs unfused cascade, replicated vs sharded warm tier, fp32 vs int8 warm
panel.

Flat exact lookup is O(N·D) per query; the tiered cascade is
O(N_hot·D + (K + n_probe·bucket)·D) — at 64k+ entries the warm IVF tier
probes ~6% of the corpus.  This bench builds a clustered corpus
(paraphrase groups, the cache's actual workload) at 16k / 64k / 256k
entries, serves the same query mix through every path, and reports
per-query latency plus the cascade's recall against the exact hit set
at the operating threshold.

Cascade paths compared per size:

  * ``cascade_unfused``       — the four-op XLA composition
    (`tiers.cascade_lookup`).
  * ``cascade_fused``         — `tiers.cascade_query(fused=True)` as
    dispatched for this backend: the fused Pallas kernel on TPU, the
    single-op jnp oracle on CPU.
  * ``cascade_fused_kernel``  — the Pallas kernel forced on
    (interpret mode off-TPU; correctness-path timing, not the CPU
    production path).
  * ``cascade_fused_blockwise`` — the kernel again, but streaming the
    warm panel in ``warm_block_n``-row blocks with a running argmax
    (DESIGN.md §12) — the residency mode that lets the warm slice
    exceed VMEM.  Asserted bit-exact against the unfused cascade like
    the other fp32 fused rows.
  * ``cascade_int8``          — the warm panel scanned from its int8
    symmetric quantization, selected rows re-scored exactly
    (DESIGN.md §8); recall must stay within 0.5% of fp32.
  * ``cascade_sharded``       — the warm tier split over every visible
    device (`model` mesh axis, one local IVF per shard, per-shard
    probes ``n_probe/shards``); the cross-shard collective is the
    (Q, k·shards) candidate merge, reported as ``gather_cols`` —
    compare with ``n``.  Fused-vs-oracle parity is asserted bit-exact.
  * ``cascade_sharded_int8``  — both together.

The fp32 fused and unfused paths are asserted to produce the identical
hit set (bit-exact parity); the int8 rows assert recall within 0.5% of
fp32 instead (quantization may legitimately flip candidates inside the
error bound).  At the 256k tier the sharded p50 is expected to beat
the replicated p50 — asserted on real multi-device backends, a stderr
warning on CPU where "devices" are threads contending for the same
cores.  Set ``BENCH_TIERED_SIZES=16384,65536`` to override the size
sweep.

The ``tiered/ensemble/*`` rows time the fused multi-embedder cascade
(DESIGN.md §13): one pilot-routed kernel pass over E stacked key
panels with the weighted fused score computed in-VMEM, vs the
single-embedder cascade it must cost at most 1.6x of (the sequential
alternative costs ~E x).  Fused recall is hard-asserted at or above
the best single embedder's exact recall, the forced kernel is asserted
bit-exact against the E-panel four-op oracle, and the
``weights_uniform`` / ``weights_learned`` rows run the per-tenant
mixture-weight refit (ridge on per-embedder score/duplicate events)
against frozen uniform weights on a drifting stream.  Override with
``BENCH_ENSEMBLE_SIZES`` / ``BENCH_ENSEMBLE_E``; ``--smoke`` runs
E=2 at 16k.

Platform-conditional asserts (sharded-beats-replicated at 256k, the
fused-ensemble latency bound) are recorded in the JSON as
``checked_asserts`` / ``skipped_asserts`` so the trajectory gate can
verify each one was enforced — or legally skipped on CPU — rather
than silently absent.

Every row also lands in a machine-readable ``BENCH_cascade.json``
(default ``results/BENCH_cascade.json``, override with
``BENCH_CASCADE_JSON``; set it empty to skip writing) so future PRs
have a perf trajectory to diff against — CI enforces the diff via
``scripts/check_bench_trajectory.py`` (recall must not regress vs the
committed baseline, p50 ratios bounded on a matching fleet).

The ``tiered/cold/*`` rows grow the corpus past device memory
(DESIGN.md §12): the device keeps a fixed hot+warm slice while the
rest of the corpus lives only in the host-RAM cold tier (int8 panel,
coarse routing, budgeted fetch + exact device re-score).  At each
cold size — 1M rows by default, ``BENCH_COLD_SIZES`` to override,
64k under ``--smoke`` — a warm-only service and a cold-enabled
service share byte-identical device states, and the bench
hard-asserts the subsystem's reason to exist: at equal device
memory, cold-enabled recall is *strictly* above warm-only recall.
The ``cold_enabled`` row also carries the cold hit rate and fetch
accounting, ``promotion`` times one maintenance-tick drain of queued
re-hot rows, and ``tiered/cold/p50_ratio`` bounds the overhead the
cold path adds at a warm-only-feasible size (where every query is
answerable on-device, the router should decline almost every fetch).

The ``admission_fixed`` / ``admission_learned`` rows run a drifting
paraphrase stream through two otherwise-identical CacheServices — one
frozen at the static operating point, one with the online feedback
loop (DESIGN.md §9) — and hard-assert the loop's claim: duplicate
admissions drop, probe recall holds, the false-hit budget holds.

The ``embedder_frozen`` / ``embedder_refreshed`` rows do the same for
the online embedder refresh (DESIGN.md §11): two services share one
general-purpose (quora-pretrained) compact encoder; one runs the
maintenance-driven refresh cycle — contrastive fine-tune on pooled
serving pairs with synthetic backfill, eval gate, shadow re-embed,
versioned hot swap with threshold recalibration — between two phases
of a drifting-topic medical stream.  Hard asserts: the refreshed
service beats the frozen one on
both hit precision and hit recall over the drifted phase, the publish
happened (``embed_version >= 1``), and overlap recall through the hot
swap is exactly 1.0 (no committed entry is lost by the re-embed).

Rebuild-stall rows (``serve_inline_rebuild`` / ``serve_bg_rebuild``)
time a serving loop — plan over the live CacheService each tick — in
which one tick triggers the demotion flush + IVF re-cluster: inline
mode eats the whole k-means on that tick (it shows up as the lookup
p99), background mode double-buffers it onto a shadow index and the
p99 stays at lookup scale.  Like the flush+rebuild row, these are
skipped above 64k unless ``BENCH_TIERED_SIZES`` opts in explicitly
(the 256k rebuild alone takes minutes on 2 CPU cores).

The ``tiered/serve/stage_*`` rows read the per-stage latency
histograms (plan / commit / maintenance) straight off the telemetry
registry for a small serving pass (DESIGN.md §10.1), and
``tiered/serve/telemetry_overhead`` hard-asserts that running with the
registry + tracer live costs < 2% extra serving p50 over the same pass
with ``Telemetry.disabled()``.

    PYTHONPATH=src python -m benchmarks.run tiered
    PYTHONPATH=src python -m benchmarks.bench_tiered_cache --smoke
"""
from __future__ import annotations

import gc
import json
import os
import pathlib
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_derived, timed
from repro.cache_service import (
    CacheRequest, CacheService, ColdRoutingPolicy, EmbedderRefreshPolicy,
    FeedbackConfig, tiers,
)
from repro.configs import get_config
from repro.core import EmbedderTrainer, FinetuneConfig
from repro.core import store as store_lib
from repro.data import HashTokenizer, make_pair_dataset
from repro.data.corpora import DOMAINS, render_query
from repro.launch.mesh import make_host_mesh
from repro.obs import Telemetry
from repro.obs.health import check_overhead_budget

HOT = 2048                 # recent-traffic slice held in the hot tier
DIM = 64
N_PROBE = 4
Q = 128
THRESHOLD = 0.9
SEED = 3
# size -> (n_clusters, bucket, kmeans_iters); per-cluster occupancy is
# held near bucket/2 so the inverted lists never overflow
SIZES = {
    1 << 12: (16, 256, 2),      # --smoke / CI tier
    1 << 14: (128, 256, 4),
    1 << 16: (256, 512, 4),
    1 << 18: (512, 1024, 2),
}
DEFAULT_SIZES = [1 << 14, 1 << 16, 1 << 18]
# maintenance-heavy rows (flush+rebuild, rebuild-stall serving) only
# run at or below this size unless BENCH_TIERED_SIZES opts in
MAINT_MAX = 1 << 16
# cold-tier rows: the device keeps this fixed hot+warm slice while the
# rest of the corpus lives only in host RAM (DESIGN.md §12)
COLD_HOT = 1 << 10
COLD_WARM = 1 << 14
COLD_DEFAULT_SIZES = [1 << 20]     # 1M-row corpus; --smoke drops to 64k
# fused multi-embedder ensemble rows (DESIGN.md §13): one kernel pass
# over E stacked key panels, routed on the pilot embedder's centroids.
# The p50 target vs the sequential E-pass alternative is a bandwidth
# claim about accelerator dispatch, so it is hard-asserted off-CPU and
# recorded as a *structured* skip on CPU (see _assert_skipped)
ENS_DEFAULT_SIZES = [1 << 16]
ENS_DEFAULT_E = 3
ENS_MAX_P50_RATIO = 1.6            # fused E-panel p50 vs single-panel p50
# the ensemble operating point sits below the single-embedder one: a
# duplicate one embedder misses scores ((E-1)*0.98 + 0.66)/E fused —
# above this threshold for every E >= 2, while the blind panel's 0.66
# stays below it (the workload _ens_queries builds)
ENS_THRESHOLD = 0.72


def _ensemble_sizes():
    env = os.environ.get("BENCH_ENSEMBLE_SIZES")
    if env is None:
        return list(ENS_DEFAULT_SIZES)
    return [int(s) for s in env.split(",") if s.strip()]


def _ensemble_e():
    return int(os.environ.get("BENCH_ENSEMBLE_E", ENS_DEFAULT_E))


# Platform-conditional asserts.  A claim that only holds on real
# accelerator fleets (sharded beats replicated, fused-ensemble beats
# sequential) used to degrade to a stderr warning on CPU — invisible
# to the trajectory gate, indistinguishable from the assert site being
# deleted.  Every such site now records itself here, and the lists
# land in BENCH_cascade.json (``checked_asserts`` / ``skipped_asserts``)
# so scripts/check_bench_trajectory.py can verify each applicable
# assert was either enforced or legally skipped (CPU only).
_ASSERTS = {"checked": [], "skipped": []}


def _assert_checked(name):
    _ASSERTS["checked"].append(name)


def _assert_skipped(name, reason):
    _ASSERTS["skipped"].append({"name": name, "reason": reason})
    print(f"WARNING: skipped assert {name}: {reason}", file=sys.stderr)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _corpus(rng, n_total, n_clusters):
    """Clustered keys: paraphrase groups around n_clusters centroids."""
    per = n_total // n_clusters
    cents = _unit(rng.standard_normal((n_clusters, DIM)).astype(np.float32))
    keys = np.repeat(cents, per, axis=0)
    return _unit(keys + 0.15 * rng.standard_normal(keys.shape
                                                   ).astype(np.float32))


def _states(keys, n_clusters, bucket, iters):
    """Build flat / hot / warm states directly (bulk load, not the
    sequential insert path — this bench times lookups, not fills)."""
    n = len(keys)
    vids = jnp.arange(n, dtype=jnp.int32)
    flat = store_lib.init_store(n, DIM)._replace(
        keys=jnp.asarray(keys), valid=jnp.ones((n,), bool), value_ids=vids)

    warm_n = n - HOT
    warm = tiers.init_warm(warm_n, DIM, n_clusters, bucket)._replace(
        keys=jnp.asarray(keys[:warm_n]),
        valid=jnp.ones((warm_n,), bool),
        tenants=jnp.zeros((warm_n,), jnp.int32),
        value_ids=vids[:warm_n],
        write_seq=jnp.arange(1, warm_n + 1, dtype=jnp.int32),
        total=jnp.asarray(warm_n, jnp.int32))
    warm = jax.jit(partial(tiers.warm_rebuild, iters=iters, seed=SEED))(warm)
    warm = tiers.requantize(warm)       # int8 panel for the quantized rows

    hot = tiers.init_hot(HOT, DIM)._replace(
        keys=jnp.asarray(keys[warm_n:]),
        valid=jnp.ones((HOT,), bool),
        tenants=jnp.zeros((HOT,), jnp.int32),
        last_used=jnp.arange(1, HOT + 1, dtype=jnp.int32),
        value_ids=vids[warm_n:],
        clock=jnp.asarray(HOT, jnp.int32))
    return flat, hot, warm


def _sharded_warm(keys, n_clusters, bucket, iters, shards, mesh):
    """Stacked warm tier over the same rows as the replicated warm
    (truncated to a shard-divisible count), one local IVF per shard,
    laid out on the mesh so lookups read resident shards."""
    warm_n = ((len(keys) - HOT) // shards) * shards
    cap = warm_n // shards
    k_local = max(n_clusters // shards, 1)
    sw = tiers.init_warm_sharded(shards, cap, DIM, k_local, bucket)._replace(
        keys=jnp.asarray(keys[:warm_n]).reshape(shards, cap, DIM),
        valid=jnp.ones((shards, cap), bool),
        tenants=jnp.zeros((shards, cap), jnp.int32),
        value_ids=jnp.arange(warm_n, dtype=jnp.int32).reshape(shards, cap),
        write_seq=jnp.broadcast_to(
            jnp.arange(1, cap + 1, dtype=jnp.int32), (shards, cap)),
        total=jnp.full((shards,), cap, jnp.int32))
    sw = jax.jit(partial(tiers.warm_rebuild_sharded, iters=iters,
                         seed=SEED))(sw)
    return tiers.place_warm_sharded(tiers.requantize(sw), mesh)


def _queries(rng, keys):
    """Half near-duplicates of random corpus entries, half novel."""
    idx = rng.choice(len(keys), Q // 2, replace=False)
    pos = _unit(keys[idx] + 0.05 * rng.standard_normal(
        (Q // 2, DIM)).astype(np.float32))
    neg = _unit(rng.standard_normal((Q // 2, DIM)).astype(np.float32))
    return jnp.asarray(np.concatenate([pos, neg]))


def _sizes():
    env = os.environ.get("BENCH_TIERED_SIZES")
    if not env:
        return list(DEFAULT_SIZES)
    return [int(s) for s in env.split(",") if s.strip()]


def _cold_sizes():
    env = os.environ.get("BENCH_COLD_SIZES")
    if env is None:
        return list(COLD_DEFAULT_SIZES)
    return [int(s) for s in env.split(",") if s.strip()]


def _maintenance_rows_enabled(n_total):
    return n_total <= MAINT_MAX or bool(os.environ.get("BENCH_TIERED_SIZES"))


def _timed_p50(fn, repeats: int = 7):
    """(p50_us, mean_us) over per-call wall times (after one warmup)."""
    fn()
    lat = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        lat.append(time.perf_counter() - t0)
    lat = np.asarray(lat) * 1e6
    return float(np.percentile(lat, 50)), float(lat.mean())


def _recall(res, exact_hit):
    tier_hit = np.asarray(res.hit)
    recall = float((tier_hit & exact_hit).sum() / max(exact_hit.sum(), 1))
    spurious = int((tier_hit & ~exact_hit).sum())
    return recall, spurious


def _bench_one_size(n_total):
    n_clusters, bucket, iters = SIZES.get(
        n_total, (max(n_total // 512, 16), 1024, 2))
    tag = f"tiered/{n_total // 1024}k"
    rng = np.random.default_rng(SEED)
    keys = _corpus(rng, n_total, n_clusters)
    flat, hot, warm = _states(keys, n_clusters, bucket, iters)
    q = _queries(rng, keys)
    tenants = jnp.zeros((Q,), jnp.int32)
    thresholds = jnp.full((Q,), THRESHOLD, jnp.float32)

    flat_fn = jax.jit(lambda st, qq: store_lib.query(st, qq, THRESHOLD, 1))
    # stream the warm panel in 4 blocks — the §12 residency mode where
    # the warm slice need not fit VMEM at once
    warm_block = max((n_total - HOT + 3) // 4, 256)
    paths = {
        "cascade_unfused": jax.jit(partial(
            tiers.cascade_query, k=1, n_probe=N_PROBE, tail=0, fused=False)),
        "cascade_fused": jax.jit(partial(
            tiers.cascade_query, k=1, n_probe=N_PROBE, tail=0, fused=True)),
        "cascade_fused_kernel": jax.jit(partial(
            tiers.cascade_query, k=1, n_probe=N_PROBE, tail=0, fused=True,
            use_kernel=True)),
        "cascade_fused_blockwise": jax.jit(partial(
            tiers.cascade_query, k=1, n_probe=N_PROBE, tail=0, fused=True,
            use_kernel=True, warm_block_n=warm_block)),
        "cascade_int8": jax.jit(partial(
            tiers.cascade_query, k=1, n_probe=N_PROBE, tail=0, fused=True,
            quantized=True)),
    }

    exact = flat_fn(flat, q)
    jax.block_until_ready(exact)
    exact_hit = np.asarray(exact.hit)
    _, us_flat = timed(
        lambda: jax.block_until_ready(flat_fn(flat, q)), repeats=5)
    yield f"{tag}/flat_bruteforce", us_flat / Q, {
        "n": n_total, "us_per_query": us_flat / Q,
        "hits": int(exact_hit.sum())}

    results, speedups, recalls, p50s = {}, {}, {}, {}
    for name, fn in paths.items():
        res = fn(hot, warm, q, tenants, thresholds)
        jax.block_until_ready(res)
        results[name] = res
        p50, us = _timed_p50(
            lambda fn=fn: jax.block_until_ready(
                fn(hot, warm, q, tenants, thresholds)))
        p50s[name] = p50
        recall, spurious = _recall(res, exact_hit)
        recalls[name] = recall
        speedup = speedups[name] = us_flat / max(us, 1e-9)
        yield f"{tag}/{name}", us / Q, {
            "n": n_total, "us_per_query": us / Q, "p50_us": p50,
            "recall_at_thr": recall, "spurious_hits": spurious,
            "speedup_vs_flat": speedup,
            **({"warm_block_n": warm_block}
               if name == "cascade_fused_blockwise" else {})}
        if name == "cascade_int8":
            # quantized selection may flip candidates inside the error
            # bound; the budget is 0.5% of the fp32 recall
            assert recall >= recalls["cascade_unfused"] - 0.005, \
                f"{tag}/{name} int8 recall {recall} dropped > 0.5% below " \
                f"fp32 {recalls['cascade_unfused']}"
        else:
            assert recall >= 0.95, f"{tag}/{name} recall {recall} < 0.95"

    # the cascade only pays off once the corpus dwarfs the probed slice;
    # judge only the production dispatches — the forced interpret-mode
    # kernel is a correctness path and must not mask a regression here
    if n_total >= 1 << 16:
        prod = {n: s for n, s in speedups.items()
                if n not in ("cascade_fused_kernel",
                             "cascade_fused_blockwise")}
        assert max(prod.values()) > 1.0, \
            f"{tag}: no production cascade path beats flat ({prod})"

    # no recall regression: fp32 fused paths reproduce the unfused
    # cascade bit-exactly (scores, ids, hit set); the int8 row is
    # excluded — its parity budget is the 0.5% recall assert above
    base = results["cascade_unfused"]
    for name in ("cascade_fused", "cascade_fused_kernel",
                 "cascade_fused_blockwise"):
        for field in tiers.CascadeResult._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(base, field)),
                np.asarray(getattr(results[name], field)),
                err_msg=f"{tag}/{name} diverges from unfused on {field}")

    yield from _bench_sharded(tag, n_total, keys, hot, q, tenants,
                              thresholds, n_clusters, bucket, iters,
                              exact_hit, recalls, p50s)

    # amortised maintenance: one demotion flush + one IVF rebuild
    # (skipped at 256k by default — the rebuild alone takes minutes on
    # 2 CPU cores; BENCH_TIERED_SIZES opts in explicitly)
    if _maintenance_rows_enabled(n_total):
        dem_fn = jax.jit(partial(tiers.demote_coldest, m=512))
        app_fn = jax.jit(tiers.warm_append)
        reb_fn = jax.jit(partial(tiers.warm_rebuild, iters=iters, seed=SEED))

        def flush_and_rebuild():
            h2, dem = dem_fn(hot)
            w2, _ = app_fn(warm, dem)
            return jax.block_until_ready(reb_fn(w2))

        flush_and_rebuild()
        _, us_maint = timed(flush_and_rebuild, repeats=3)
        yield f"{tag}/flush+rebuild", us_maint, {
            "flush_size": 512, "n_warm": n_total - HOT,
            "clusters": n_clusters}
        yield from _bench_rebuild_stall(n_total, n_clusters, bucket, iters)


def _bench_sharded(tag, n_total, keys, hot, q, tenants, thresholds,
                   n_clusters, bucket, iters, exact_hit, recalls, p50s):
    """Replicated-vs-sharded rows: the warm tier split over every
    visible device, per-shard fused kernel, (Q, k·shards) merge."""
    shards = len(jax.devices())
    mesh = make_host_mesh(1, shards)
    swarm = _sharded_warm(keys, n_clusters, bucket, iters, shards, mesh)
    # split the probe budget across shards but keep >= 2 probes of
    # slack per local IVF (a top-1-only probe has no tolerance for
    # centroid misranking on noisy near-duplicates), clamped to the
    # per-shard cluster count
    k_local = max(n_clusters // shards, 1)
    probe_local = min(k_local, max(N_PROBE // shards, 2))
    topk = 1           # shared by the lookup and the gather_cols metric
    sharded_paths = {
        "cascade_sharded": {},
        "cascade_sharded_int8": {"quantized": True},
    }
    for name, kw in sharded_paths.items():
        fn = jax.jit(partial(tiers.cascade_query, k=topk,
                             n_probe=probe_local, tail=0, fused=True,
                             mesh=mesh, **kw))
        res = fn(hot, swarm, q, tenants, thresholds)
        jax.block_until_ready(res)
        # bit-exact parity of the distributed schedule against its
        # single-device oracle (per-shard four-op emulation + stacked
        # merge) — the sharded analogue of the fused/unfused assert
        oracle = jax.jit(partial(tiers.cascade_query, k=topk,
                                 n_probe=probe_local, tail=0,
                                 fused=False, **kw))(
            hot, swarm, q, tenants, thresholds)
        for field in tiers.CascadeResult._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(oracle, field)),
                np.asarray(getattr(res, field)),
                err_msg=f"{tag}/{name} diverges from the sharded oracle "
                        f"on {field}")
        p50, us = _timed_p50(
            lambda fn=fn: jax.block_until_ready(
                fn(hot, swarm, q, tenants, thresholds)))
        recall, spurious = _recall(res, exact_hit)
        fp32_ref = recalls["cascade_unfused"]
        yield f"{tag}/{name}", us / Q, {
            "n": n_total, "us_per_query": us / Q, "p50_us": p50,
            "recall_at_thr": recall, "spurious_hits": spurious,
            "shards": shards, "n_probe_local": probe_local,
            "gather_cols": topk * shards,     # the (Q, k·shards) merge
        }
        if "int8" in name:
            assert recall >= fp32_ref - 0.005, \
                f"{tag}/{name} int8 recall {recall} dropped > 0.5% below " \
                f"fp32 {fp32_ref}"
        else:
            assert recall >= 0.95, f"{tag}/{name} recall {recall} < 0.95"
            # the scale claim: at 256k the per-shard slices + tiny merge
            # must beat the replicated cascade.  Hard-assert on real
            # accelerator fleets; on CPU the "devices" are host threads
            # fighting for the same cores, so the claim is recorded as
            # a structured skip the trajectory gate can verify.
            if n_total >= 1 << 18 and shards > 1:
                aname = f"{tag}/sharded_p50_beats_replicated"
                rep_p50 = p50s["cascade_fused"]
                if jax.default_backend() == "cpu":
                    _assert_skipped(
                        aname, "cpu backend: shards are host threads "
                        "contending for the same cores"
                        + ("" if p50 < rep_p50 else
                           f" (and sharded p50 {p50:.0f}us did not beat "
                           f"replicated {rep_p50:.0f}us here)"))
                else:
                    _assert_checked(aname)
                    assert p50 < rep_p50, \
                        f"{tag}: sharded p50 {p50:.0f}us does not beat " \
                        f"replicated p50 {rep_p50:.0f}us over " \
                        f"{shards} shards"


def _ens_corpus(rng, n_total, n_clusters, e):
    """E correlated key panels over one clustered latent corpus — the
    same paraphrase groups seen through E different embedders, each
    with its own observation noise: (n, E, D)."""
    z = _corpus(rng, n_total, n_clusters)
    return np.stack(
        [_unit(z + 0.1 * rng.standard_normal(z.shape).astype(np.float32))
         for _ in range(e)], 1)


def _ens_queries(rng, panels):
    """Half near-duplicates, half novel.  Each near-duplicate is a
    tight paraphrase of one corpus row on every panel except one:
    panel (i mod E) is corrupted toward noise — the embedder that
    "missed" this paraphrase (cos ~0.66, below the ensemble operating
    point) while the others stay confident (cos ~0.98).  Every single
    embedder therefore misses ~1/E of the duplicates; the fused score
    keeps all of them above ENS_THRESHOLD with deterministic margin —
    the ensemble claim as a workload, not a statistical accident."""
    n, e, _ = panels.shape
    idx = rng.choice(n, Q // 2, replace=False)
    base = panels[idx]
    pos = _unit(base + 0.0254 * rng.standard_normal(
        base.shape).astype(np.float32))
    noisy = _unit(base + 0.142 * rng.standard_normal(
        base.shape).astype(np.float32))
    rows = np.arange(Q // 2)
    pos[rows, rows % e] = noisy[rows, rows % e]
    neg = _unit(rng.standard_normal((Q // 2, e, DIM)).astype(np.float32))
    return np.concatenate([pos, neg]).astype(np.float32)


def _ens_exact(panels, qp, weights):
    """Host-exact per-embedder best cosine (Q, E) and fused best (Q,)
    over the full corpus, chunked like _exact_hit_mask."""
    nq, e = qp.shape[0], qp.shape[1]
    best_e = np.full((nq, e), -1.0, np.float32)
    best_f = np.full(nq, -2.0, np.float32)
    for lo in range(0, len(panels), 1 << 16):
        blk = panels[lo:lo + (1 << 16)]
        cos = np.einsum("qed,bed->qbe", qp, blk)
        best_e = np.maximum(best_e, cos.max(axis=1))
        best_f = np.maximum(
            best_f, (cos * weights[:, None, :]).sum(-1).max(axis=1))
    return best_e, best_f


def _bench_ensemble(n_total):
    """Fused E-panel ensemble cascade vs the single-embedder cascade
    (DESIGN.md §13): one pilot-routed kernel pass over E stacked key
    panels with the weighted fused score computed in-VMEM.

    Hard asserts carried by these rows:

      * fused recall >= the best single embedder's *exact* recall (the
        ensemble claim from arxiv 2507.07061 — exact per-panel recall
        is an upper bound on any single-embedder cascade, so this is
        the strong form);
      * the forced kernel is bit-exact with the E-panel four-op oracle
        (scores, ids, hit set — every EnsembleResult field);
      * int8 fused recall within 0.5% of fp32 fused;
      * fused p50 <= 1.6x the single-embedder fused p50 (vs ~E x for
        the sequential path) — asserted off-CPU, recorded as a
        structured skip on CPU where the panels' extra flops are not
        hidden behind the amortized bucket gather.
    """
    e = max(_ensemble_e(), 2)
    n_clusters, bucket, iters = SIZES.get(
        n_total, (max(n_total // 512, 16), 1024, 2))
    tag = f"tiered/ensemble/{n_total // 1024}k"
    rng = np.random.default_rng(SEED + 9)
    panels = _ens_corpus(rng, n_total, n_clusters, e)
    _, hot, warm = _states(panels[:, 0], n_clusters, bucket, iters)
    warm_n = n_total - HOT
    ens = tiers.make_ensemble(
        jnp.asarray(panels[warm_n:].transpose(1, 0, 2)),
        jnp.asarray(panels[:warm_n].transpose(1, 0, 2)))
    qp = _ens_queries(rng, panels)
    w = np.full((Q, e), 1.0 / e, np.float32)
    tenants = jnp.zeros((Q,), jnp.int32)
    thresholds = jnp.full((Q,), ENS_THRESHOLD, jnp.float32)
    pos = slice(0, Q // 2)

    best_e, best_f = _ens_exact(panels, qp, w)
    single_recalls = (best_e[pos] >= ENS_THRESHOLD).mean(axis=0)
    best_single = float(single_recalls.max())

    # the single-embedder production path on the pilot panel — the
    # latency denominator of the tentpole claim
    single_fn = jax.jit(partial(
        tiers.cascade_query, k=1, n_probe=N_PROBE, tail=0, fused=True))
    qpilot = jnp.asarray(qp[:, 0])
    res_s = single_fn(hot, warm, qpilot, tenants, thresholds)
    jax.block_until_ready(res_s)
    p50_single, us_single = _timed_p50(
        lambda: jax.block_until_ready(
            single_fn(hot, warm, qpilot, tenants, thresholds)))
    yield f"{tag}/single_pilot", us_single / Q, {
        "n": n_total, "e": 1, "threshold": ENS_THRESHOLD,
        "us_per_query": us_single / Q, "p50_us": p50_single,
        "recall_at_thr": float(np.asarray(res_s.hit)[pos].mean())}

    qe, wj = jnp.asarray(qp), jnp.asarray(w)
    ens_kw = dict(k=1, n_probe=N_PROBE, tail=0)
    recalls = {}
    for name, kw in (("fused", {}), ("fused_int8", {"quantized": True})):
        fn = jax.jit(partial(tiers.ensemble_cascade_query, fused=True,
                             **ens_kw, **kw))
        res = fn(hot, warm, ens, qe, wj, tenants, thresholds)
        jax.block_until_ready(res)
        hit = np.asarray(res.hit)
        recall = recalls[name] = float(hit[pos].mean())
        false_hits = int(hit[Q // 2:].sum())
        p50, us = _timed_p50(
            lambda fn=fn: jax.block_until_ready(
                fn(hot, warm, ens, qe, wj, tenants, thresholds)))
        ratio = p50 / max(p50_single, 1e-9)
        yield f"{tag}/{name}", us / Q, {
            "n": n_total, "e": e, "threshold": ENS_THRESHOLD,
            "us_per_query": us / Q, "p50_us": p50,
            "recall_at_thr": recall, "false_hits": false_hits,
            "best_single_recall": round(best_single, 4),
            "p50_ratio_vs_single": round(ratio, 4),
            "speedup_vs_sequential": round(
                e * p50_single / max(p50, 1e-9), 4)}
        if name == "fused":
            assert recall >= best_single, \
                f"{tag}: fused recall {recall} below the best single " \
                f"embedder's exact recall {best_single}"
            assert false_hits <= 2, \
                f"{tag}: fused path leaks {false_hits} false hits on " \
                "novel queries"
            aname = f"{tag}/ensemble_speedup"
            if jax.default_backend() == "cpu":
                _assert_skipped(
                    aname, "cpu backend: the <=1.6x claim is a "
                    "bandwidth-amortization property of accelerator "
                    "dispatch; host threads pay the E-panel flops "
                    f"serially (measured ratio {ratio:.2f}x)")
            else:
                _assert_checked(aname)
                assert ratio <= ENS_MAX_P50_RATIO, \
                    f"{tag}: fused E={e} p50 {p50:.0f}us is " \
                    f"{ratio:.2f}x the single-embedder p50 " \
                    f"{p50_single:.0f}us (bound {ENS_MAX_P50_RATIO}x)"
        else:
            assert recall >= recalls["fused"] - 0.005, \
                f"{tag}: int8 fused recall {recall} dropped > 0.5% " \
                f"below fp32 {recalls['fused']}"

    # bit-exact parity: the fused kernel (forced; interpret mode
    # off-TPU) against the E-panel four-op oracle in ref.py
    oracle = jax.jit(partial(tiers.ensemble_cascade_query, fused=False,
                             **ens_kw))(
        hot, warm, ens, qe, wj, tenants, thresholds)
    kernel = jax.jit(partial(tiers.ensemble_cascade_query, fused=True,
                             use_kernel=True, **ens_kw))(
        hot, warm, ens, qe, wj, tenants, thresholds)
    for field in tiers.EnsembleResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(oracle, field)),
            np.asarray(getattr(kernel, field)),
            err_msg=f"{tag}: fused kernel diverges from the E-panel "
                    f"oracle on {field}")


def _ens_stream_panels(rng, z, e, info, tight=0.03, loose=0.85):
    """Panels of a latent batch (B, E, D): the informative embedder
    sees a tight paraphrase of the latent, the rest mostly noise — the
    regime where uniform weights drown the one good signal and the
    learned mixture recovers it."""
    return np.stack(
        [_unit(z + (tight if j == info else loose)
               * rng.standard_normal(z.shape).astype(np.float32))
         for j in range(e)], 1).astype(np.float32)


def _bench_ensemble_weights():
    """Uniform vs learned per-tenant mixture weights on a drifting
    stream (DESIGN.md §13).

    Both services serve the same E-embedder stream in which only one
    embedder separates duplicates from novel traffic; the stream
    starts novel-heavy (the non-duplicate labeled events) and drifts
    duplicate-heavy.  The uniform service averages the informative
    panel down below the operating threshold, re-admitting every
    near-duplicate; the learned service's ridge refit upweights the
    informative embedder from the (per-embedder score, duplicate)
    events and the duplicates start hitting.  Hard asserts: learned
    duplicate admissions strictly below uniform, learned probe recall
    strictly above uniform, the false-hit budget holds, and at least
    one weight refit actually applied with the informative embedder
    upweighted."""
    e = max(_ensemble_e(), 2)
    info = 1
    results = {}
    for mode in ("uniform", "learned"):
        learned = mode == "learned"
        rng = np.random.default_rng(SEED + 8)
        intents = _unit(rng.standard_normal((48, DIM)).astype(np.float32))
        svc = CacheService(
            dim=DIM, hot_capacity=256, warm_capacity=1024, n_clusters=16,
            bucket=128, n_probe=4, threshold=0.9, flush_size=64,
            kmeans_iters=2, seed=SEED, embedders=e,
            learned_admission=learned,
            feedback_config=FeedbackConfig(
                min_samples=48, min_class=8, refit_interval=32,
                max_step=0.03, seed=SEED) if learned else None)
        seen, dup_admits, admits, hits, lat = set(), 0, 0, 0, []
        for b in range(24):
            # drift: the first 3 batches cover every intent once
            # (novel traffic), the rest are duplicate-heavy revisits
            ids = (np.arange(b * 16, b * 16 + 16) % 48
                   if b < 3 else rng.integers(0, 48, 16))
            embs = _ens_stream_panels(rng, intents[ids], e, info)
            t0 = time.perf_counter()
            plan = svc.plan(CacheRequest.build(embs))
            svc.commit(plan, [f"ans{i}" for i in ids])
            svc.maintenance()
            lat.append(time.perf_counter() - t0)
            hits += int(plan.hit.sum())
            for row in plan.miss_rows():
                if not plan.admit[row]:
                    continue
                admits += 1
                if int(ids[row]) in seen:
                    dup_admits += 1
                seen.add(int(ids[row]))
        prng = np.random.default_rng(SEED + 18)
        probe_pos = _ens_stream_panels(prng, intents, e, info)
        probe_neg = _ens_stream_panels(
            prng, _unit(prng.standard_normal((64, DIM)).astype(np.float32)),
            e, info)
        pos_plan = svc.plan(CacheRequest.build(probe_pos), coalesce=False)
        neg_plan = svc.plan(CacheRequest.build(probe_neg), coalesce=False)
        st = svc.feedback.state() if svc.feedback is not None else {}
        wts = svc.policies.get_weights(0, e)
        results[mode] = {
            "queries": 24 * 16, "e": e, "hits": hits, "admitted": admits,
            "dup_admissions": dup_admits,
            "recall_probe": float(pos_plan.hit.mean()),
            "false_hits_probe": int(neg_plan.hit.sum()),
            "weight_refits": int(st.get("weight_refits_applied", 0)),
            "weights_final": [round(float(x), 3) for x in wts],
            "p50_us": float(np.percentile(np.asarray(lat) * 1e6, 50)),
        }
        yield f"tiered/ensemble/weights_{mode}", \
            results[mode]["p50_us"], results[mode]

    uni, lrn = results["uniform"], results["learned"]
    # the learned-mixture rows exist to back these claims
    assert lrn["dup_admissions"] < uni["dup_admissions"], \
        f"learned weights did not reduce duplicate admissions " \
        f"({lrn['dup_admissions']} vs {uni['dup_admissions']})"
    assert lrn["recall_probe"] > uni["recall_probe"], \
        f"learned weights did not lift probe recall " \
        f"({lrn['recall_probe']} vs {uni['recall_probe']})"
    assert lrn["false_hits_probe"] <= max(1, int(0.02 * 64)), \
        f"learned weights leak false hits ({lrn['false_hits_probe']}/64)"
    assert lrn["weight_refits"] >= 1, "no weight refit was ever applied"
    assert lrn["weights_final"][info] > 1.0 / e, \
        f"informative embedder not upweighted ({lrn['weights_final']})"


def _service_on(keys, n_clusters, bucket, iters, background):
    """A live CacheService grafted onto bulk-loaded tier states (this
    bench times serving, not fills)."""
    n_total = len(keys)
    _, hot, warm = _states(keys, n_clusters, bucket, iters)
    svc = CacheService(dim=DIM, hot_capacity=HOT,
                       warm_capacity=n_total - HOT, n_clusters=n_clusters,
                       bucket=bucket, n_probe=N_PROBE,
                       threshold=THRESHOLD, flush_size=512, rebuild_every=2,
                       kmeans_iters=iters, seed=SEED,
                       background_rebuild=background)
    svc.hot, svc.warm = hot, warm
    svc._next_vid = n_total
    return svc


def _stall_trace(svc, q, ticks=32, flush_at=8):
    """Per-tick serving latency; one tick also triggers the demotion
    flush whose IVF re-cluster either runs inline (stalling that tick)
    or double-buffered (shadow build + publish via maintenance())."""
    req = CacheRequest.build(np.asarray(q))
    svc.plan(req)                                    # warmup / compile
    # warm the flush-path jits on discarded states so the stall tick
    # measures the k-means itself, not tracing
    _, dem = svc._demote(svc.hot)
    w2, _ = svc._append(svc.warm, dem)
    jax.block_until_ready(svc._rebuild(w2))
    lat = []
    for t in range(ticks):
        t0 = time.perf_counter()
        if t == flush_at:
            svc.flush(rebuild=True)
        svc.maintenance()            # pipeline step: publish if finished
        svc.plan(req)
        lat.append(time.perf_counter() - t0)
    svc.maintenance(block=True)      # account the rebuild fully
    return np.asarray(lat)


def _bench_rebuild_stall(n_total, n_clusters, bucket, iters):
    """Inline vs background (double-buffered) rebuild: p50/p99 of the
    per-tick serving latency around one flush+re-cluster."""
    tag = f"tiered/{n_total // 1024}k"
    rng = np.random.default_rng(SEED + 1)
    keys = _corpus(rng, n_total, n_clusters)
    q = _queries(rng, keys)
    p50s, p99s, walls = {}, {}, {}
    for mode, background in (("inline", False), ("bg", True)):
        svc = _service_on(keys, n_clusters, bucket, iters, background)
        lat_us = _stall_trace(svc, q) * 1e6
        p50, p99 = np.percentile(lat_us, [50, 99])
        reb = svc.stats_snapshot().rebuild
        assert reb["rebuilds"] >= 1, (mode, reb)
        p50s[mode], p99s[mode] = p50, p99
        walls[mode] = float(reb["total_wall_s"])
        yield f"{tag}/serve_{mode}_rebuild", p50, {
            "p50_us": p50, "p99_us": p99,
            "rebuild_ms": float(reb["total_wall_s"]) * 1e3,
            "bg_rebuilds": reb["shadow_started"], "ticks": len(lat_us)}
    # the claim this bench exists for: once the rebuild dwarfs a
    # serving tick, double-buffering takes it off the serving p99.
    # Below that scale (e.g. 16k on 2 CPU cores, where the re-cluster
    # costs about one tick) the shadow thread's CPU contention can
    # outweigh the stall it removes — and p99-vs-p99 is timing-noisy
    # on contended runners — so a regression here warns loudly instead
    # of aborting the sweep (the recall/parity asserts stay hard).
    if walls["inline"] * 1e6 > 5 * p50s["inline"] \
            and p99s["bg"] >= p99s["inline"]:
        print(f"WARNING: {tag}: background rebuild did not lower the "
              f"serving p99 (inline {p99s['inline']:.0f}us vs bg "
              f"{p99s['bg']:.0f}us, rebuild {walls['inline']:.2f}s)",
              file=sys.stderr)


def _device_states(device_keys, vid0, hot_n, n_clusters, bucket, iters):
    """Bulk hot + warm states over ``device_keys`` whose value ids are
    the *global* corpus indices ``vid0..vid0+len`` — the device slice
    of a corpus whose remainder lives only in the cold tier."""
    n = len(device_keys)
    warm_n = n - hot_n
    vids = jnp.arange(vid0, vid0 + n, dtype=jnp.int32)
    warm = tiers.init_warm(warm_n, DIM, n_clusters, bucket)._replace(
        keys=jnp.asarray(device_keys[:warm_n]),
        valid=jnp.ones((warm_n,), bool),
        tenants=jnp.zeros((warm_n,), jnp.int32),
        value_ids=vids[:warm_n],
        write_seq=jnp.arange(1, warm_n + 1, dtype=jnp.int32),
        total=jnp.asarray(warm_n, jnp.int32))
    warm = jax.jit(partial(tiers.warm_rebuild, iters=iters, seed=SEED))(warm)
    warm = tiers.requantize(warm)
    hot = tiers.init_hot(hot_n, DIM)._replace(
        keys=jnp.asarray(device_keys[warm_n:]),
        valid=jnp.ones((hot_n,), bool),
        tenants=jnp.zeros((hot_n,), jnp.int32),
        last_used=jnp.arange(1, hot_n + 1, dtype=jnp.int32),
        value_ids=vids[warm_n:],
        clock=jnp.asarray(hot_n, jnp.int32))
    return hot, warm


def _cold_service(keys, hot_n, warm_n, n_clusters, bucket, iters,
                  cold_policy=None):
    """A live CacheService whose device tiers hold only the *last*
    ``hot_n + warm_n`` corpus rows; with ``cold_policy`` the remaining
    rows are bulk-loaded into the host-RAM cold tier (equal device
    memory either way — the cold rows never touch HBM)."""
    n = len(keys)
    warm_lo = n - hot_n - warm_n
    hot, warm = _device_states(keys[warm_lo:], warm_lo, hot_n,
                               n_clusters, bucket, iters)
    svc = CacheService(dim=DIM, hot_capacity=hot_n, warm_capacity=warm_n,
                       n_clusters=n_clusters, bucket=bucket,
                       n_probe=N_PROBE, threshold=THRESHOLD,
                       flush_size=256, kmeans_iters=iters, seed=SEED,
                       cold_capacity=warm_lo if cold_policy else 0,
                       cold_policy=cold_policy)
    svc.hot, svc.warm = hot, warm
    svc._next_vid = n
    if cold_policy is not None and warm_lo:
        svc.cold.bulk_load(keys[:warm_lo],
                           np.arange(warm_lo, dtype=np.int64),
                           np.zeros(warm_lo, np.int32))
    return svc, warm_lo


def _exact_hit_mask(keys, qn):
    """Exact max-sim >= THRESHOLD per query over the full corpus,
    chunked on the host (the corpus deliberately exceeds what the flat
    device store should be asked to hold)."""
    best = np.full(len(qn), -1.0, np.float32)
    for lo in range(0, len(keys), 1 << 18):
        best = np.maximum(best, (qn @ keys[lo:lo + (1 << 18)].T
                                 ).max(axis=1))
    return best >= THRESHOLD


def _cold_queries(rng, keys, warm_lo, exclude=None):
    """Half near-duplicates of cold-resident rows, a quarter of
    device-resident rows, a quarter novel — the mix that separates
    warm-only recall from cold-enabled recall."""
    pool = np.arange(warm_lo)
    if exclude is not None:
        pool = np.setdiff1d(pool, exclude)
    ci = rng.choice(pool, Q // 2, replace=False)
    di = warm_lo + rng.choice(len(keys) - warm_lo, Q // 4, replace=False)
    pos = keys[np.concatenate([ci, di])]
    pos = _unit(pos + 0.05 * rng.standard_normal(pos.shape
                                                 ).astype(np.float32))
    neg = _unit(rng.standard_normal((Q - len(pos), DIM)).astype(np.float32))
    return np.concatenate([pos, neg]).astype(np.float32), ci


def _bench_cold_tier(n_total):
    """Warm-only vs cold-enabled recall at equal device memory, cold
    hit-rate/fetch accounting, and one timed promotion drain
    (DESIGN.md §12).  The device slice is fixed at COLD_HOT + COLD_WARM
    rows regardless of ``n_total`` — past 64k the corpus mostly lives
    in host RAM, which is the whole point."""
    tag = f"tiered/cold/{n_total // 1024}k"
    n_groups = max(n_total // 64, 64)
    rng = np.random.default_rng(SEED + 5)
    keys = _corpus(rng, n_total, n_groups)
    cold_n = n_total - COLD_HOT - COLD_WARM
    assert cold_n > 0, f"cold bench needs > {COLD_HOT + COLD_WARM} rows"
    # the router gate self-calibrates to the corpus's cluster spread
    # at route-fit time (cold.rebuild_routes); only the shape knobs
    # scale with the corpus here
    pol = ColdRoutingPolicy(
        n_probe=8, fetch_budget=64, promote_max=512,
        n_clusters=min(256, max(64, cold_n // 4096)),
        kmeans_iters=4, kmeans_sample=1 << 16,
        route_rebuild_every=1 << 30, seed=SEED)
    q, cold_idx = _cold_queries(rng, keys, cold_n)
    exact_hit = _exact_hit_mask(keys, q)
    req = CacheRequest.build(q)

    recalls = {}
    for mode, policy in (("warm_only", None), ("cold_enabled", pol)):
        svc, warm_lo = _cold_service(keys, COLD_HOT, COLD_WARM,
                                     *SIZES[COLD_WARM], cold_policy=policy)
        plan = svc.plan(req, coalesce=False)
        recall, spurious = _recall(plan, exact_hit)
        recalls[mode] = recall
        p50, us = _timed_p50(lambda: svc.plan(req, coalesce=False),
                             repeats=5)
        derived = {
            "n": n_total, "device_rows": COLD_HOT + COLD_WARM,
            "cold_rows": warm_lo if policy else 0,
            "us_per_query": us / Q, "p50_us": p50,
            "recall_at_thr": recall, "spurious_hits": spurious,
            "hits": int(plan.hit.sum()),
            # under an ensemble service the cold tier is consulted on
            # the pilot panel only (DESIGN.md §13)
            "ensemble": "pilot"}
        if policy is not None:
            st = svc.stats_snapshot().tiers["cold"]
            consulted = max(st["cold_fetches"], 1)
            derived.update({
                "cold_hits": st["cold_hits"],
                "cold_hit_rate": round(st["cold_hits"] / consulted, 4),
                "cold_fetches": st["cold_fetches"],
                "cold_fetched_rows": st["cold_fetched_rows"],
                "cold_router_skips": st["cold_router_skips"],
                "cold_route_slack": st["cold_route_slack"]})
        yield f"{tag}/{mode}", us / Q, derived

        if policy is None:
            continue
        # the row this subsystem exists for: at byte-identical device
        # tiers, the cold fallback must strictly lift recall
        assert recalls["cold_enabled"] > recalls["warm_only"], \
            f"{tag}: cold tier did not lift recall " \
            f"({recalls['cold_enabled']} vs {recalls['warm_only']} " \
            f"warm-only at equal device memory)"
        assert st["cold_hits"] > 0, f"{tag}: no cold hits recorded"

        # promotion drain: warm up the append path on the first batch
        # of queued re-hot rows, then time a fresh drain end to end
        svc.maintenance()
        q2, _ = _cold_queries(np.random.default_rng(SEED + 6), keys,
                              cold_n, exclude=cold_idx)
        svc.plan(CacheRequest.build(q2), coalesce=False)
        pending = svc.cold.pending_promotions
        t0 = time.perf_counter()
        rep = svc.maintenance()
        wall_us = (time.perf_counter() - t0) * 1e6
        assert rep.cold_promoted > 0, f"{tag}: promotion drain was empty"
        assert svc.cold.pending_promotions == 0
        yield f"{tag}/promotion", wall_us, {
            "promoted": rep.cold_promoted, "pending_before": pending,
            "wall_us": wall_us,
            "us_per_row": wall_us / rep.cold_promoted}


def _bench_cold_overhead():
    """p50 ratio of the served path with the cold tier enabled vs
    disabled at a warm-only-feasible size: every query is answerable
    on-device, so the cold path's only job is to get out of the way —
    the tight default router margin declines the novel-query fetches.
    The ratio is bounded here and tracked by the trajectory gate."""
    n, hot_n = 1 << 13, COLD_HOT
    n_clusters, bucket, iters = 64, 256, 2
    rng = np.random.default_rng(SEED + 7)
    keys = _corpus(rng, n, n // 64)
    q = np.asarray(_queries(rng, keys))
    req = CacheRequest.build(q)

    p50s = {}
    for mode, policy in (("off", None),
                         ("on", ColdRoutingPolicy(seed=SEED))):
        hot, warm = _device_states(keys, 0, hot_n, n_clusters, bucket,
                                   iters)
        svc = CacheService(dim=DIM, hot_capacity=hot_n,
                           warm_capacity=n - hot_n,
                           n_clusters=n_clusters, bucket=bucket,
                           n_probe=N_PROBE, threshold=THRESHOLD,
                           flush_size=256, kmeans_iters=iters, seed=SEED,
                           cold_capacity=n if policy else 0,
                           cold_policy=policy)
        svc.hot, svc.warm = hot, warm
        svc._next_vid = n
        if policy is not None:
            # a full copy of the corpus in cold — the worst case for
            # router work on every below-threshold query
            svc.cold.bulk_load(keys, np.arange(n, dtype=np.int64),
                               np.zeros(n, np.int32))
        p50s[mode], _ = _timed_p50(
            lambda: svc.plan(req, coalesce=False), repeats=15)
    ratio = p50s["on"] / max(p50s["off"], 1e-9)
    # generous hard bound — the trajectory gate holds the tight one
    # (CPU runners are contended; a genuine regression blows past 2.5x)
    assert ratio < 2.5, \
        f"cold tier inflates warm-feasible serving p50 {ratio:.2f}x " \
        f"({p50s['on']:.0f}us vs {p50s['off']:.0f}us)"
    yield "tiered/cold/p50_ratio", p50s["on"], {
        "n": n, "p50_on_us": p50s["on"], "p50_off_us": p50s["off"],
        "p50_ratio": round(ratio, 4)}


def _drift_stream(rng, intents, n_batches=24, batch=32):
    """A paraphrase stream whose duplicate pressure drifts mid-run: the
    first third is mostly novel traffic with tight paraphrases, the
    rest is duplicate-heavy with noisier paraphrases that land *below*
    the static threshold — the regime where a frozen admission rule
    fills the store with near-duplicates."""
    for b in range(n_batches):
        drift = b >= n_batches // 3
        noise = 0.06 if drift else 0.02
        ids = rng.integers(0, len(intents), batch)
        embs = _unit(intents[ids] + noise * rng.standard_normal(
            (batch, DIM)).astype(np.float32))
        yield embs, ids


def _bench_admission_drift():
    """Learned vs fixed admission on the drifting stream (DESIGN.md §9).

    Both services start from the same static operating point
    (threshold 0.95, margin 0.02); the learned one labels every commit
    against its stored neighbour and lets ``maintenance()`` refit the
    tenant's threshold/margin from the observed duplicate rate.  The
    claim the rows carry: duplicate admissions drop, end recall on
    fresh paraphrases holds, and novel probes stay below the false-hit
    budget — asserted hard, not just reported.
    """
    rng = np.random.default_rng(SEED + 2)
    n_intents = 64
    intents = _unit(rng.standard_normal((n_intents, DIM)
                                        ).astype(np.float32))
    stream = list(_drift_stream(rng, intents))
    n_queries = sum(len(ids) for _, ids in stream)
    # probes: fresh tight paraphrases (recall) + novel queries (budget)
    probe_pos = _unit(intents + 0.03 * rng.standard_normal(
        intents.shape).astype(np.float32))
    probe_neg = _unit(rng.standard_normal((64, DIM)).astype(np.float32))

    results = {}
    for mode in ("fixed", "learned"):
        learned = mode == "learned"
        svc = CacheService(
            dim=DIM, hot_capacity=256, warm_capacity=1024, n_clusters=16,
            bucket=128, n_probe=4, threshold=0.95, admission_margin=0.02,
            flush_size=64, kmeans_iters=2, seed=SEED,
            learned_admission=learned,
            feedback_config=FeedbackConfig(
                min_samples=48, refit_interval=32, max_step=0.03,
                seed=SEED) if learned else None)
        seen, dup_admits, admits, hits, lat = set(), 0, 0, 0, []
        for embs, ids in stream:
            t0 = time.perf_counter()
            plan = svc.plan(CacheRequest.build(embs))
            svc.commit(plan, [f"ans{i}" for i in ids])
            svc.maintenance()
            lat.append(time.perf_counter() - t0)
            hits += int(plan.hit.sum())
            for row in plan.miss_rows():
                if not plan.admit[row]:
                    continue
                admits += 1
                if int(ids[row]) in seen:
                    dup_admits += 1   # a same-intent entry already lives
                seen.add(int(ids[row]))
        pos_plan = svc.plan(CacheRequest.build(probe_pos), coalesce=False)
        neg_plan = svc.plan(CacheRequest.build(probe_neg), coalesce=False)
        learning = svc.stats_snapshot().learning or {}
        pol = svc.policies.get(0)
        results[mode] = {
            "queries": n_queries, "hits": hits, "admitted": admits,
            "dup_admissions": dup_admits,
            "dup_admit_rate": dup_admits / max(admits, 1),
            "recall_probe": float(pos_plan.hit.mean()),
            "false_hits_probe": int(neg_plan.hit.sum()),
            "threshold_final": round(float(pol.threshold), 4),
            "margin_final": round(float(pol.admission_margin), 4),
            "refits": int(learning.get("refits_applied", 0)),
            "p50_us": float(np.percentile(np.asarray(lat) * 1e6, 50)),
        }
        yield f"tiered/admission_{mode}", results[mode]["p50_us"], \
            results[mode]

    fixed, learned = results["fixed"], results["learned"]
    # the learned rows exist to back these three claims
    assert learned["dup_admissions"] < fixed["dup_admissions"], \
        f"learned admission did not reduce duplicate admissions " \
        f"({learned['dup_admissions']} vs {fixed['dup_admissions']})"
    assert learned["recall_probe"] >= fixed["recall_probe"] - 0.02, \
        f"learned admission regressed probe recall " \
        f"({learned['recall_probe']} vs {fixed['recall_probe']})"
    assert learned["false_hits_probe"] <= max(
        1, int(0.02 * len(probe_neg))), \
        f"learned threshold leaks false hits " \
        f"({learned['false_hits_probe']}/{len(probe_neg)} novel probes)"
    assert learned["refits"] >= 1, "no refit was ever applied"


def _topic_stream(rng, n_batches, batch, pool, seen, repeat):
    """Batches of rendered medical queries over a pool of
    (entity, aspect) topics: each query is either a paraphrase of an
    already-seen topic (probability ``repeat`` — a cacheable repeat)
    or a novel topic drawn from ``pool``.  ``seen`` accumulates across
    calls so a later phase keeps revisiting earlier topics."""
    out = []
    for _ in range(n_batches):
        qs = []
        for _ in range(batch):
            if seen and rng.random() < repeat:
                ent, asp = seen[int(rng.integers(len(seen)))]
            else:
                ent, asp = pool[int(rng.integers(len(pool)))]
                if (ent, asp) not in seen:
                    seen.append((ent, asp))
            qs.append(render_query(rng, "medical", ent, asp))
        out.append(qs)
    return out


def _bench_embedder_refresh():
    """Frozen vs online-refreshed embedder on a drifting-topic stream
    (DESIGN.md §11).

    Both services share one general-purpose base embedder (the compact
    encoder pre-trained on out-of-domain quora pairs — the paper's
    general-purpose starting point) and the same serving threshold.
    The stream serves medical-domain traffic in two phases: phase A
    over one topic slice feeds the pair reservoir, then the refreshed
    service runs one ``maintenance()`` refresh cycle — contrastive
    fine-tune on pooled+synthetic pairs, eval gate, shadow re-embed,
    versioned publish — before phase B drifts onto unseen topics.
    Only phase B is measured.

    Hits are scored against intent ground truth (the committed
    response encodes the query's entity+aspect): a hit that serves the
    right intent is a true positive, the wrong intent a false
    positive, and a miss on an already-stored intent a false negative.
    The refresh policy recalibrates at publish — the candidate scores
    pairs on its own scale, so the swap also remaps the serving
    threshold to the candidate's held-out operating point instead of
    reusing the frozen scalar (``recalibrate=True``, DESIGN.md §11).
    The rows carry the paper's core claim as hard asserts: the
    domain-adapted embedder beats the general-purpose one on *both*
    hit precision and hit recall, the publish actually happened
    (``embed_version >= 1``), and every committed entry still hits
    after the hot swap (``overlap_recall == 1.0`` — the re-embed
    rewrote every stored key under the new encoder).
    """
    enc = get_config("modernbert-149m").reduced(vocab_size=2048)
    tok = HashTokenizer(vocab_size=enc.vocab_size)
    base_ft = FinetuneConfig(epochs=4, batch_size=32, max_len=24,
                             lr=5e-4, margin=0.7)
    base = EmbedderTrainer(enc, base_ft)
    base.fit(make_pair_dataset("quora", 1024, seed=1), tok)
    # the serving trainer's ft drives the refresh fit (§11): a longer
    # schedule than the base, since the candidate must overcome the
    # quora prior from a few hundred pooled+synthetic pairs
    serve_ft = FinetuneConfig(epochs=8, batch_size=32, max_len=24,
                              lr=5e-4, margin=0.7)

    entities, aspects = DOMAINS["medical"]
    topics = [(entities[i], aspects[i % len(aspects)])
              for i in range(36)]
    threshold = 0.9

    results = {}
    for mode in ("frozen", "refreshed"):
        refreshed = mode == "refreshed"
        # identical stream per mode: same rng -> same queries
        rng = np.random.default_rng(SEED + 4)
        seen = []
        phase_a = _topic_stream(rng, 10, 16, topics[:12], seen, 0.5)
        phase_b = _topic_stream(rng, 24, 16, topics[12:], seen, 0.6)
        trainer = EmbedderTrainer(enc, serve_ft, params=base.params)
        embed = trainer.make_embed_fn(tok)
        pol = EmbedderRefreshPolicy(
            min_pairs=32, min_class=4, refresh_interval=64,
            synth_domain="medical", synth_min_pairs=768,
            min_precision=0.6, min_recall=0.6, max_f1_regression=1.0,
            recalibrate=True)
        svc = CacheService(
            dim=enc.d_model, hot_capacity=512, warm_capacity=1024,
            n_clusters=16, bucket=128, n_probe=4, threshold=threshold,
            admission_margin=0.02, seed=SEED,
            embedder_trainer=trainer if refreshed else None,
            embedder_tokenizer=tok if refreshed else None,
            refresh_policy=pol if refreshed else None)

        stored, committed = set(), {}
        cnt = {"tp": 0, "fp": 0, "fn": 0}
        lat = []

        def serve(batches, measure):
            for qs in batches:
                texts = [q.text for q in qs]
                t0 = time.perf_counter()
                plan = svc.plan(CacheRequest.build(
                    embed(texts), 0, texts=texts), coalesce=False)
                svc.commit(plan, [
                    None if h else f"ans:{q.entity}|{q.aspect}"
                    for h, q in zip(plan.hit, qs)])
                svc.maintenance()
                if measure:
                    lat.append(time.perf_counter() - t0)
                for row, q in enumerate(qs):
                    truth = f"ans:{q.entity}|{q.aspect}"
                    if measure:
                        if plan.hit[row]:
                            right = plan.responses[row] == truth
                            cnt["tp" if right else "fp"] += 1
                        elif (q.entity, q.aspect) in stored:
                            cnt["fn"] += 1
                    if plan.admit[row] and not plan.hit[row]:
                        stored.add((q.entity, q.aspect))
                        committed[q.text] = truth

        serve(phase_a, measure=False)
        version, refresh_wall = 0, 0.0
        if refreshed:
            svc.maintenance()                 # trips the refresh start
            rep = svc.maintenance(block=True)  # join + publish
            version = rep.embed_version
            refresh_wall = rep.refresh_wall_s
        serve(phase_b, measure=True)
        svc.maintenance(block=True)           # join any trailing cycle

        # overlap recall: every committed entry must still hit through
        # (and after) the hot swap — the shadow re-embed rewrote the
        # stored keys under whichever encoder is now live
        probe = sorted(committed)
        probe_plan = svc.plan(CacheRequest.build(
            embed(probe), 0, texts=probe), coalesce=False)
        tp, fp, fn = cnt["tp"], cnt["fp"], cnt["fn"]
        results[mode] = {
            "queries": 24 * 16, "tp": tp, "fp": fp, "fn": fn,
            # the refresh cycle is mutually exclusive with ensemble
            # serving (a panel publish is the A/B analogue, §13)
            "ensemble": "off",
            "hit_precision": round(tp / max(tp + fp, 1), 4),
            "hit_recall": round(tp / max(tp + fn, 1), 4),
            "overlap_recall": float(probe_plan.hit.mean()),
            "entries": len(probe),
            "embed_version": int(version),
            "threshold_final": round(
                float(svc.policies.get(0).threshold), 4),
            "refresh_wall_s": round(float(refresh_wall), 3),
            "p50_us": float(np.percentile(np.asarray(lat) * 1e6, 50)),
        }
        yield f"tiered/embedder_{mode}", results[mode]["p50_us"], \
            results[mode]

    frozen, refr = results["frozen"], results["refreshed"]
    # the §11 rows exist to back these claims
    assert refr["embed_version"] >= 1, \
        "the refresh cycle never published a new embedder version"
    for mode, row in results.items():
        assert row["overlap_recall"] == 1.0, \
            f"{mode}: committed entries lost through the hot swap " \
            f"(overlap recall {row['overlap_recall']})"
    assert refr["hit_precision"] > frozen["hit_precision"], \
        f"refreshed embedder did not improve hit precision " \
        f"({refr['hit_precision']} vs {frozen['hit_precision']})"
    assert refr["hit_recall"] > frozen["hit_recall"], \
        f"refreshed embedder did not improve hit recall " \
        f"({refr['hit_recall']} vs {frozen['hit_recall']})"


def _bench_telemetry():
    """Per-stage latency rows from the §10 registry plus the overhead
    guard: the same serving tick with the registry/tracer live must
    cost < 2% extra p50 vs ``Telemetry.disabled()`` (the registry's
    series handles are resolved once at construction; the hot path is
    an int/bisect update, DESIGN.md §10.1).  Two otherwise-identical
    services process the same batches tick-interleaved — alternating
    order per tick — so host noise lands on both sides of the pooled
    medians; the budget is asserted here and re-checked from the
    committed JSON by scripts/check_bench_trajectory.py."""
    tag = "tiered/serve"
    rng = np.random.default_rng(SEED + 3)
    intents = _unit(rng.standard_normal((32, DIM)).astype(np.float32))

    tel_on = Telemetry()
    svcs = {
        mode: CacheService(dim=DIM, hot_capacity=512, warm_capacity=1024,
                           n_clusters=16, bucket=128, n_probe=N_PROBE,
                           threshold=THRESHOLD, kmeans_iters=2, seed=SEED,
                           telemetry=tel)
        for mode, tel in (("on", tel_on), ("off", Telemetry.disabled()))}
    # identical warmup through both: pays the jit tracing up front and
    # seeds the store so the timed ticks are hit-heavy and unimodal
    # (32 intents never cross the flush watermark -> no rebuild ticks)
    warm = _unit(intents + 0.04 * rng.standard_normal(
        intents.shape).astype(np.float32))
    for svc in svcs.values():
        plan = svc.plan(CacheRequest.build(warm))
        svc.commit(plan, [f"warm{i}" for i in range(len(warm))])
        svc.maintenance()

    lat = {"on": [], "off": []}
    gc.collect()
    gc.disable()      # collection pauses land on whichever side is
    try:              # mid-tick; keep them out of the comparison
        for b in range(96):
            ids = rng.integers(0, len(intents), 32)
            embs = _unit(intents[ids] + 0.04 * rng.standard_normal(
                (32, DIM)).astype(np.float32))
            answers = [f"ans{i}" for i in ids]
            for mode in ("on", "off") if b % 2 == 0 else ("off", "on"):
                svc = svcs[mode]
                t0 = time.perf_counter()
                plan = svc.plan(CacheRequest.build(embs))
                svc.commit(plan, answers)
                svc.maintenance()
                lat[mode].append(time.perf_counter() - t0)
    finally:
        gc.enable()
    svcs["on"].maintenance(block=True)   # idle tick: drain SLO gauges

    stage_h = tel_on.stage_histogram()
    for stage in ("plan", "commit", "maintenance"):
        agg = stage_h.aggregate(stage=stage)
        assert agg.count, f"{tag}: stage {stage!r} was never observed"
        p50_us = agg.quantile(0.5) * 1e6
        yield f"{tag}/stage_{stage}", p50_us, {
            "p50_us": p50_us, "mean_us": agg.mean * 1e6,
            "count": int(agg.count)}

    # the on/off ticks are paired (same batch, adjacent in time), so
    # per-tick *differences* cancel the +-hundreds-of-us host jitter
    # a contended CPU runner puts on raw medians.  Jitter that still
    # leaks through a block's median only inflates it, never deflates
    # every block — so the min over block medians is the stable
    # overhead estimate, and a real regression (which lifts every
    # block) cannot hide under it.
    on_s, off_s = np.asarray(lat["on"]), np.asarray(lat["off"])
    p50_on = float(np.percentile(on_s * 1e6, 50))
    p50_off = float(np.percentile(off_s * 1e6, 50))
    d = (on_s - off_s).reshape(8, -1) * 1e6
    extra_us = float(np.median(d, axis=1).min())
    problems = check_overhead_budget(
        (p50_off + max(extra_us, 0.0)) / 1e6, p50_off / 1e6)
    assert not problems, f"{tag}: " + "; ".join(problems)
    yield f"{tag}/telemetry_overhead", p50_on, {
        "p50_on_us": p50_on, "p50_off_us": p50_off,
        "median_extra_us": extra_us,
        "overhead_ratio": round(
            (p50_off + max(extra_us, 0.0)) / max(p50_off, 1e-9), 4)}


def _json_path():
    env = os.environ.get("BENCH_CASCADE_JSON")
    if env is not None:
        return pathlib.Path(env) if env else None
    return pathlib.Path(__file__).resolve().parent.parent \
        / "results" / "BENCH_cascade.json"


def bench_tiered_cache():
    """Yields (name, us_per_call, derived_str) rows and, on completion,
    writes the raw rows to BENCH_cascade.json for the perf trajectory."""
    rows = []
    _ASSERTS["checked"], _ASSERTS["skipped"] = [], []
    for n_total in _sizes():
        for name, us, derived in _bench_one_size(n_total):
            rows.append({"name": name, "us_per_call": us, **derived})
            yield name, us, fmt_derived(derived)
    # fused multi-embedder ensemble: E-panel kernel pass + learned
    # mixture weights (DESIGN.md §13)
    for n_total in _ensemble_sizes():
        for name, us, derived in _bench_ensemble(n_total):
            rows.append({"name": name, "us_per_call": us, **derived})
            yield name, us, fmt_derived(derived)
    for name, us, derived in _bench_ensemble_weights():
        rows.append({"name": name, "us_per_call": us, **derived})
        yield name, us, fmt_derived(derived)
    # host-RAM cold tier: recall past device memory + overhead guard
    for n_total in _cold_sizes():
        for name, us, derived in _bench_cold_tier(n_total):
            rows.append({"name": name, "us_per_call": us, **derived})
            yield name, us, fmt_derived(derived)
    for name, us, derived in _bench_cold_overhead():
        rows.append({"name": name, "us_per_call": us, **derived})
        yield name, us, fmt_derived(derived)
    # size-independent: learned-vs-fixed admission on a drifting stream
    for name, us, derived in _bench_admission_drift():
        rows.append({"name": name, "us_per_call": us, **derived})
        yield name, us, fmt_derived(derived)
    # size-independent: frozen-vs-refreshed embedder on a topic drift
    for name, us, derived in _bench_embedder_refresh():
        rows.append({"name": name, "us_per_call": us, **derived})
        yield name, us, fmt_derived(derived)
    # size-independent: §10 stage breakdown + telemetry overhead guard
    for name, us, derived in _bench_telemetry():
        rows.append({"name": name, "us_per_call": us, **derived})
        yield name, us, fmt_derived(derived)
    path = _json_path()
    if path is not None:
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps({
            "bench": "tiered_cascade",
            "backend": jax.default_backend(),
            "devices": len(jax.devices()),
            "sizes": _sizes(),
            "cold_sizes": _cold_sizes(),
            "ensemble_sizes": _ensemble_sizes(),
            "ensemble_e": _ensemble_e(),
            "q": Q, "dim": DIM, "threshold": THRESHOLD,
            "checked_asserts": list(_ASSERTS["checked"]),
            "skipped_asserts": list(_ASSERTS["skipped"]),
            "rows": rows,
        }, indent=1) + "\n")
        print(f"# wrote {len(rows)} rows to {path}", file=sys.stderr)


def main() -> None:
    """Standalone entry with a CI-sized tier:
    ``python -m benchmarks.bench_tiered_cache --smoke`` runs the full
    row set (cascade paths, parity asserts, sharded + int8 rows,
    flush+rebuild, rebuild stall) on a 4k corpus in well under a
    minute."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-corpus run (4k entries, 64k cold tier) "
                         "for CI")
    args = ap.parse_args()
    if args.smoke:
        os.environ["BENCH_TIERED_SIZES"] = str(1 << 12)
        os.environ.setdefault("BENCH_COLD_SIZES", str(1 << 16))
        os.environ.setdefault("BENCH_ENSEMBLE_SIZES", str(1 << 14))
        os.environ.setdefault("BENCH_ENSEMBLE_E", "2")
    print("name,us_per_call,derived")
    for name, us, derived in bench_tiered_cache():
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
