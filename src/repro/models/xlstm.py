"""xLSTM mixers: mLSTM (matrix memory) and sLSTM (scalar memory).

Both follow arXiv:2405.04517 with exponential gating and the max-based
log-space stabiliser m.  The mLSTM recurrence

    C_t = f'_t C_{t-1} + i'_t v_t k_t^T          (per head, hd×hd matrix)
    n_t = f'_t n_{t-1} + i'_t k_t
    h_t = (C_t q_t) / max(|n_t · q_t|, 1)

is evaluated with a sequential ``lax.scan`` in fp32 (the recurrence is
elementwise-gated and does not associate cheaply once stabilised;
sequence-chunked parallelisation is a §Perf candidate).  sLSTM has true
recurrent weight mixing (block-diagonal per head) and is inherently
sequential — exactly why the xLSTM paper keeps it narrow.

State is O(1) in sequence length — these archs run ``long_500k``
natively (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.models.param import Initializer
from repro.models.mamba import _causal_conv

F32 = jnp.float32


def _xcfg(cfg: ModelConfig) -> XLSTMConfig:
    return cfg.xlstm or XLSTMConfig()


# ===========================================================================
# mLSTM
# ===========================================================================

def _mlstm_dims(cfg: ModelConfig):
    x = _xcfg(cfg)
    d_in = x.mlstm_expand * cfg.d_model
    hd = d_in // cfg.n_heads
    return x, d_in, hd


def init_mlstm(ini: Initializer, cfg: ModelConfig):
    x, d_in, hd = _mlstm_dims(cfg)
    d, H = cfg.d_model, cfg.n_heads
    return {
        "w_up": ini.lecun((d, 2 * d_in), ("embed", "mlp"), fan_in=d),
        "conv_w": ini.lecun((x.d_conv, d_in), ("conv", "mlp"), fan_in=x.d_conv),
        "conv_b": ini.zeros((d_in,), ("mlp",)),
        "wq": ini.lecun((d_in, d_in), ("mlp", None), fan_in=d_in),
        "wk": ini.lecun((d_in, d_in), ("mlp", None), fan_in=d_in),
        "wv": ini.lecun((d_in, d_in), ("mlp", None), fan_in=d_in),
        "w_if": ini.lecun((d_in, 2 * H), ("mlp", None), fan_in=d_in),
        "b_if": ini.constant((2 * H,), (None,), value=1.0),
        "norm_scale": ini.ones((d_in,), ("mlp",)),
        "w_down": ini.lecun((d_in, d), ("mlp", "embed"), fan_in=d_in),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int, abstract: bool = False):
    x, d_in, hd = _mlstm_dims(cfg)
    H = cfg.n_heads
    shapes = {
        "C": ((batch, H, hd, hd), F32),
        "n": ((batch, H, hd), F32),
        "m": ((batch, H), F32),
        "conv": ((batch, max(x.d_conv - 1, 1), d_in), jnp.dtype(cfg.dtype)),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def mlstm_state_axes():
    return {"C": ("batch", "heads", None, None),
            "n": ("batch", "heads", None),
            "m": ("batch", "heads"),
            "conv": ("batch", "conv", "mlp")}


def _mlstm_step(carry, inp):
    """carry: (C,n,m); inp: per-token (q,k,v,(i_log,f_log)) in fp32.
    q,k,v: (B,H,hd); gates: (B,H)."""
    C, n, m = carry
    q, k, v, i_log, f_log = inp
    m_new = jnp.maximum(f_log + m, i_log)
    i_p = jnp.exp(i_log - m_new)
    f_p = jnp.exp(f_log + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def _mlstm_qkvg(p, cfg: ModelConfig, x, conv_state):
    """Shared projection path.  x: (B,S,d) -> per-token scan inputs."""
    x_cfg, d_in, hd = _mlstm_dims(cfg)
    H = cfg.n_heads
    dt = x.dtype
    B, S, _ = x.shape
    up = x @ p["w_up"].astype(dt)
    x_in, z = jnp.split(up, 2, axis=-1)
    x_conv, new_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"],
                                    state=conv_state)
    x_c = jax.nn.silu(x_conv)
    q = (x_c @ p["wq"].astype(dt)).reshape(B, S, H, hd).astype(F32)
    k = (x_c @ p["wk"].astype(dt)).reshape(B, S, H, hd).astype(F32) * hd ** -0.5
    v = (x_in @ p["wv"].astype(dt)).reshape(B, S, H, hd).astype(F32)
    gates = (x_in.astype(F32) @ p["w_if"].astype(F32)) + p["b_if"].astype(F32)
    i_log, f_log = jnp.split(gates, 2, axis=-1)              # (B,S,H)
    f_log = jax.nn.log_sigmoid(f_log)
    return (q, k, v, i_log, f_log, z, new_conv)


def _mlstm_out(p, cfg, h, z):
    """h: (B,S,H,hd) fp32; z: (B,S,d_in) gate branch."""
    x_cfg, d_in, hd = _mlstm_dims(cfg)
    B, S = h.shape[:2]
    dt = z.dtype
    hf = h.reshape(B, S, d_in)
    # per-channel RMS "group norm" over heads
    var = jnp.mean(jnp.square(hf.reshape(B, S, cfg.n_heads, hd)),
                   axis=-1, keepdims=True)
    hf = (hf.reshape(B, S, cfg.n_heads, hd) * jax.lax.rsqrt(var + 1e-6)
          ).reshape(B, S, d_in)
    hf = hf * p["norm_scale"].astype(F32)
    y = (hf.astype(dt) * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    return y


def apply_mlstm_full(p, cfg: ModelConfig, x, *, return_state: bool = False,
                     state=None):
    B = x.shape[0]
    if state is None:
        state = init_mlstm_state(cfg, B)
    q, k, v, i_log, f_log, z, new_conv = _mlstm_qkvg(
        p, cfg, x, state["conv"].astype(x.dtype))
    carry0 = (state["C"], state["n"], state["m"])
    xs = tuple(a.transpose(1, 0, 2, 3) if a.ndim == 4 else a.transpose(1, 0, 2)
               for a in (q, k, v, i_log, f_log))
    (C, n, m), hs = jax.lax.scan(_mlstm_step, carry0, xs)
    h = hs.transpose(1, 0, 2, 3)                             # (B,S,H,hd)
    y = _mlstm_out(p, cfg, h, z)
    if return_state:
        x_cfg = _xcfg(cfg)
        return y, {"C": C, "n": n, "m": m,
                   "conv": new_conv[:, -(max(x_cfg.d_conv - 1, 1)):, :].astype(
                       jnp.dtype(cfg.dtype))}
    return y


def apply_mlstm_decode(p, cfg: ModelConfig, x, state):
    y, new_state = apply_mlstm_full(p, cfg, x, return_state=True, state=state)
    return y, new_state


# ===========================================================================
# sLSTM
# ===========================================================================

def _slstm_dims(cfg: ModelConfig):
    x = _xcfg(cfg)
    hd = cfg.d_model // cfg.n_heads
    ffh = int(cfg.d_model * x.slstm_ffn_factor)
    return x, hd, ffh


def init_slstm(ini: Initializer, cfg: ModelConfig):
    x, hd, ffh = _slstm_dims(cfg)
    d, H = cfg.d_model, cfg.n_heads
    return {
        "w_gates": ini.lecun((d, 4 * d), ("embed", "mlp"), fan_in=d),
        "b_gates": ini.zeros((4 * d,), ("mlp",)),
        "r_gates": ini.lecun((4, H, hd, hd), (None, "heads", None, None),
                             fan_in=hd),
        "norm_scale": ini.ones((d,), ("embed",)),
        "ff_gate": ini.lecun((d, ffh), ("embed", "mlp"), fan_in=d),
        "ff_up": ini.lecun((d, ffh), ("embed", "mlp"), fan_in=d),
        "ff_down": ini.lecun((ffh, d), ("mlp", "embed"), fan_in=ffh),
    }


def init_slstm_state(cfg: ModelConfig, batch: int, abstract: bool = False):
    d = cfg.d_model
    shapes = {k: ((batch, d), F32) for k in ("c", "n", "h", "m")}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in shapes.items()}
    out = {k: jnp.zeros(s, dt) for k, (s, dt) in shapes.items()}
    out["n"] = out["n"] + 1.0    # avoid 0/0 on the first step
    return out


def slstm_state_axes():
    return {k: ("batch", "embed") for k in ("c", "n", "h", "m")}


def _slstm_step(p, cfg, carry, x_t):
    """x_t: (B, 4d) pre-computed input gate pre-activations (fp32)."""
    H = cfg.n_heads
    d = cfg.d_model
    hd = d // H
    c, n, h, m = carry
    hh = h.reshape(-1, H, hd)
    rec = jnp.einsum("ghij,bhj->gbhi", p["r_gates"].astype(F32), hh)
    rec = rec.reshape(4, -1, d)
    pre = x_t.reshape(-1, 4, d).transpose(1, 0, 2) + rec     # (4,B,d)
    i_t, f_t, z_t, o_t = pre
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(jax.nn.log_sigmoid(f_t) + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_t)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def apply_slstm_full(p, cfg: ModelConfig, x, *, return_state: bool = False,
                     state=None):
    B, S, d = x.shape
    dt = x.dtype
    if state is None:
        state = init_slstm_state(cfg, B)
    pre = (x @ p["w_gates"].astype(dt) + p["b_gates"].astype(dt)).astype(F32)
    carry0 = (state["c"], state["n"], state["h"], state["m"])
    step = lambda c, x_t: _slstm_step(p, cfg, c, x_t)
    (c, n, h, m), hs = jax.lax.scan(step, carry0, pre.transpose(1, 0, 2))
    hseq = hs.transpose(1, 0, 2)                             # (B,S,d)
    # RMS-normalised head output + gated FFN (the sLSTM block's own FFN)
    var = jnp.mean(jnp.square(hseq), axis=-1, keepdims=True)
    hn = (hseq * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(F32)).astype(dt)
    y = (jax.nn.silu(hn @ p["ff_gate"].astype(dt)) * (hn @ p["ff_up"].astype(dt))
         ) @ p["ff_down"].astype(dt)
    if return_state:
        return y, {"c": c, "n": n, "h": h, "m": m}
    return y


def apply_slstm_decode(p, cfg: ModelConfig, x, state):
    return apply_slstm_full(p, cfg, x, return_state=True, state=state)
