"""Optimizers from scratch (no optax offline): Adam/AdamW + global-norm
clipping, as (init_fn, update_fn) pairs over arbitrary pytrees.

The gradient-norm clip is a first-class citizen here because it is part
of the paper's catastrophic-forgetting recipe (max_grad_norm = 0.5,
Section 3.2).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: object
    v: object


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          max_grad_norm: Optional[float] = None,
          state_dtype=None):
    """Returns (init_fn, update_fn).

    state_dtype: dtype for the m/v moments — bf16 halves optimizer HBM
    for the 398B-class configs (DESIGN.md §2, jamba memory budget).
    update_fn(grads, state, params) -> (updates, new_state, metrics);
    apply with ``apply_updates``.
    """
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init_fn(params):
        def zeros(p):
            dt = state_dtype or p.dtype
            if isinstance(p, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct(p.shape, dt)
            return jnp.zeros(p.shape, dt)
        zl = lambda t: jax.tree_util.tree_map(zeros, t)
        step = (jax.ShapeDtypeStruct((), jnp.int32)
                if any(isinstance(l, jax.ShapeDtypeStruct)
                       for l in jax.tree_util.tree_leaves(params))
                else jnp.zeros((), jnp.int32))
        return AdamState(step=step, m=zl(params), v=zl(params))

    def update_fn(grads, state: AdamState, params):
        metrics = {}
        if max_grad_norm is not None:
            grads, raw_norm = clip_by_global_norm(grads, max_grad_norm)
            metrics["grad_norm"] = raw_norm
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = jnp.asarray(lr_fn(step), jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m_new / bc1
            vhat = v_new / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), m_new.astype(m.dtype), \
                v_new.astype(v.dtype)

        flat = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
        updates = jax.tree_util.tree_map(lambda x: x[0], flat,
                                         is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree_util.tree_map(lambda x: x[1], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree_util.tree_map(lambda x: x[2], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        metrics["lr"] = lr_t
        return updates, AdamState(step=step, m=m_new, v=v_new), metrics

    return init_fn, update_fn


def adam(lr, **kw):
    return adamw(lr, weight_decay=0.0, **kw)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype), params, updates)
