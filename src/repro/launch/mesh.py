"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax
device state — the dry-run must set XLA_FLAGS before first jax init.

Target hardware: TPU v5e pods — 256 chips/pod (16×16), 2 pods = 512.
Hardware constants used by the roofline analysis live here too.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BANDWIDTH = 819e9             # B/s
ICI_LINK_BANDWIDTH = 50e9         # B/s per link


def _mesh_kwargs(n_axes: int) -> dict:
    # AxisType landed after jax 0.4; older versions default to Auto anyway
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many real devices exist (CPU tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"),
                         **_mesh_kwargs(2))


def make_cache_mesh(model: int | None = None):
    """Mesh for the sharded warm tier of the cache service
    (DESIGN.md §8): every warm shard lives on one `model`-axis device,
    queries stay replicated.  ``model=None`` spans all visible devices;
    otherwise the axis is clamped to the device count (all via
    `make_host_mesh` — one mesh builder, two names).  On CPU CI the
    virtual fleet comes from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    n = len(jax.devices())
    return make_host_mesh(1, n if model is None else max(1, model))
