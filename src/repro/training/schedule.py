"""Learning-rate schedules as step -> lr callables."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, peak_lr * cos)
    return fn


def linear_decay(peak_lr: float, total_steps: int):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        return peak_lr * jnp.clip(1.0 - s / max(total_steps, 1), 0.0, 1.0)
    return fn
