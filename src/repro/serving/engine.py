"""Serving engine: batched prefill + decode with carried state.

``ServeEngine`` is the host-side loop around the pure ``prefill`` /
``decode_step`` functions (jitted once per shape).  It serves *batched
requests* — the end-to-end example drivers put the semantic cache in
front of this engine, which is exactly the deployment the paper targets
(cache hit -> skip the engine entirely).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, HashTokenizer
from repro.models import decode_step, prefill
from repro.serving.frontend import stub_frontend_embeds


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_new) int32
    n_prompt: int
    n_generated: int
    cache_hit: bool = False


class ServeEngine:
    """Batched autoregressive serving for any decoder config."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        if cfg.is_encoder:
            raise ValueError(f"{cfg.name} is encoder-only; no decode path")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda pv, toks, fe: prefill(pv, cfg, toks, max_len, fe),
            static_argnames=())
        self._decode = jax.jit(lambda pv, st, tok: decode_step(pv, cfg, st, tok))

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 use_frontend: bool = False) -> GenerationResult:
        """prompts: (B, S) int32.  Greedy (temperature=0) or sampled."""
        B, S = prompts.shape
        fe = stub_frontend_embeds(self.cfg, B, seed) if use_frontend else None
        logits, state = self._prefill(self.params, jnp.asarray(prompts), fe)
        key = jax.random.PRNGKey(seed)
        out = np.zeros((B, max_new_tokens), np.int32)
        tok = self._select(logits, temperature, key)
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok)[:, 0]
            logits, state = self._decode(self.params, state, tok)
            key, sub = jax.random.split(key)
            tok = self._select(logits, temperature, sub)
        return GenerationResult(out, n_prompt=S, n_generated=max_new_tokens)

    @staticmethod
    def _select(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        g = jax.random.gumbel(key, logits.shape)
        return jnp.argmax(logits / temperature + g, axis=-1).astype(
            jnp.int32)[:, None]


@dataclass
class ServedRequest:
    query: str
    response: str
    cache_hit: bool
    score: float = 0.0


class CachedLLMService:
    """The paper's deployment: a semantic cache in front of an LLM.

    Queries are embedded with the (fine-tuned) compact encoder; on a
    cache hit the stored response is returned without touching the
    engine; on a miss the engine generates and the (embedding, response)
    pair is inserted.
    """

    def __init__(self, embed_fn, cache, engine: Optional[ServeEngine],
                 tokenizer: HashTokenizer, max_query_len: int = 32,
                 max_new_tokens: int = 16, fused: Optional[bool] = None):
        """``fused`` (None = leave the backend's choice) selects the
        cache's cascade execution path — the fused Pallas lookup kernel
        vs the four-op composition — when the backend supports it
        (`CacheService.set_fused`); ignored for flat caches."""
        self.embed_fn = embed_fn          # list[str] -> (B, D) unit vectors
        # SemanticCache or the tiered multi-tenant CacheService facade
        self.cache = cache
        self.engine = engine
        self.tok = tokenizer
        self.max_query_len = max_query_len
        self.max_new_tokens = max_new_tokens
        self.stats = {"hits": 0, "misses": 0}
        self._tenant_aware = getattr(cache, "supports_tenants", False)
        if fused is not None:
            if hasattr(cache, "set_fused"):
                cache.set_fused(fused)
            elif fused:
                raise ValueError(
                    f"cache backend {type(cache).__name__} has no fused "
                    "cascade path; use CacheService or drop fused=True")

    def _llm_answer(self, queries: List[str]) -> List[str]:
        if self.engine is None:  # degenerate echo backend for tests
            return [f"answer({q})" for q in queries]
        ids, _ = self.tok.encode_batch(queries, self.max_query_len)
        res = self.engine.generate(ids, self.max_new_tokens)
        return [" ".join(map(str, row)) for row in res.tokens]

    def handle(self, queries: List[str],
               tenant: int = 0) -> List[ServedRequest]:
        embs = self.embed_fn(queries)
        if self._tenant_aware:
            hits, scores, values = self.cache.lookup(embs, tenant=tenant)
        else:
            if tenant != 0:
                raise ValueError(
                    f"cache backend {type(self.cache).__name__} is not "
                    "tenant-aware; serving tenant "
                    f"{tenant} through it would break isolation")
            hits, scores, values = self.cache.lookup(embs)
        out: List[Optional[ServedRequest]] = [None] * len(queries)
        miss_idx = [i for i, h in enumerate(hits) if not h]
        for i, q in enumerate(queries):
            if hits[i]:
                self.stats["hits"] += 1
                out[i] = ServedRequest(q, values[i], True, float(scores[i]))
        if miss_idx:
            answers = self._llm_answer([queries[i] for i in miss_idx])
            sel = np.asarray(miss_idx)
            if self._tenant_aware:
                # pass the observed scores so the admission policy can
                # skip misses already well-covered by a cached neighbour
                self.cache.insert(embs[sel], answers, tenant=tenant,
                                  scores=scores[sel])
            else:
                self.cache.insert(embs[sel], answers)
            for i, a in zip(miss_idx, answers):
                self.stats["misses"] += 1
                out[i] = ServedRequest(queries[i], a, False)
        return out  # type: ignore

    @property
    def hit_rate(self) -> float:
        n = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / n if n else 0.0
