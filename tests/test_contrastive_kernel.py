"""Parity sweep: fused online-contrastive kernel vs the jnp oracle
(`kernels/contrastive/ref.py`), mirroring test_cascade_kernel.py's
interpret-mode discipline.

Exactness contract:

  * the mined extrema (min_neg, max_pos) are order-independent
    reductions — **bit-exact** for every shape and dtype (both sides
    cast to float32 before the distance);
  * the hard-pair loss sums are bit-exact whenever one block covers
    the batch; across blocks the kernel's SMEM partial-sum order can
    differ from the oracle's single reduction by float-associativity
    ulps, so multi-block sums get an ulp-level tolerance instead.

The sweep covers non-multiple-of-block tails (the padded rows carry
label -1 and must be invisible), B < block, B == block, fp32/bf16,
and block-size independence of the padded-tail handling.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.contrastive import kernel as cl_kernel
from repro.kernels.contrastive import ref as cl_ref
from repro.kernels.contrastive.ops import online_contrastive_loss as ocl_op
from repro.core.losses import online_contrastive_loss as ocl_core

rng = np.random.default_rng(23)

SHAPES = [
    (1, 8, 8),        # single row
    (7, 16, 8),       # B < block
    (8, 16, 8),       # B == block
    (13, 32, 8),      # tail: 13 = 8 + 5
    (100, 64, 32),    # tail: 100 = 3*32 + 4
    (128, 48, 128),   # one exact block, odd D
    (256, 96, 64),    # multiple exact blocks
    (257, 40, 64),    # tail of 1
]


def _pairs(B, D, dtype, label_kind="mixed"):
    e1 = jnp.asarray(rng.standard_normal((B, D)), dtype)
    e2 = jnp.asarray(rng.standard_normal((B, D)), dtype)
    if label_kind == "mixed":
        lab = np.zeros(B, np.int32)
        lab[rng.permutation(B)[:max(B // 2, 1)]] = 1
        if B > 1:
            lab[0], lab[-1] = 0, 1      # both classes present
    elif label_kind == "front-pos":
        lab = (np.arange(B) < max(B // 3, 1)).astype(np.int32)
    else:                               # "back-pos": whole blocks one-class
        lab = (np.arange(B) >= B - max(B // 3, 1)).astype(np.int32)
    return e1, e2, jnp.asarray(lab)


@pytest.mark.parametrize("B,D,bb", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_components_parity(B, D, bb, dtype):
    e1, e2, lab = _pairs(B, D, dtype)
    r_pos, r_neg, r_min, r_max = cl_ref.contrastive_components(e1, e2, lab)
    k_pos, k_neg, k_min, k_max = cl_kernel.contrastive_components(
        e1, e2, lab, block_b=bb, interpret=True)
    # extrema: order-independent -> bit-exact at every shape/dtype
    np.testing.assert_array_equal(np.asarray(r_min), np.asarray(k_min))
    np.testing.assert_array_equal(np.asarray(r_max), np.asarray(k_max))
    if -(-B // min(bb, B)) == 1:
        # single block: same reduction order -> sums bit-exact too
        np.testing.assert_array_equal(np.asarray(r_pos), np.asarray(k_pos))
        np.testing.assert_array_equal(np.asarray(r_neg), np.asarray(k_neg))
    else:
        # cross-block SMEM accumulation may reassociate the sum
        np.testing.assert_allclose(float(r_pos), float(k_pos),
                                   rtol=2e-6, atol=1e-6)
        np.testing.assert_allclose(float(r_neg), float(k_neg),
                                   rtol=2e-6, atol=1e-6)


@pytest.mark.parametrize("label_kind", ["front-pos", "back-pos"])
@pytest.mark.parametrize("B,D,bb", [(13, 32, 8), (100, 64, 32)])
def test_one_class_blocks_parity(B, D, bb, label_kind):
    """Blocks that contain only one label class (and padded tail rows
    with label -1) must not perturb the other class's statistics."""
    e1, e2, lab = _pairs(B, D, jnp.float32, label_kind)
    ref = cl_ref.contrastive_components(e1, e2, lab)
    ker = cl_kernel.contrastive_components(e1, e2, lab, block_b=bb,
                                           interpret=True)
    np.testing.assert_array_equal(np.asarray(ref[2]), np.asarray(ker[2]))
    np.testing.assert_array_equal(np.asarray(ref[3]), np.asarray(ker[3]))
    np.testing.assert_allclose(float(ref[0]), float(ker[0]), rtol=2e-6)
    np.testing.assert_allclose(float(ref[1]), float(ker[1]), rtol=2e-6)


@pytest.mark.parametrize("B,D", [(13, 32), (100, 48)])
def test_block_size_independence(B, D):
    """The tail-padding scheme must make the result a function of the
    data only: every block size (including one covering the whole
    batch) yields the same components."""
    e1, e2, lab = _pairs(B, D, jnp.float32)
    outs = [cl_kernel.contrastive_components(e1, e2, lab, block_b=bb,
                                             interpret=True)
            for bb in (4, 8, B, 2 * B)]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(o[2]),
                                      np.asarray(outs[0][2]))
        np.testing.assert_array_equal(np.asarray(o[3]),
                                      np.asarray(outs[0][3]))
        np.testing.assert_allclose(float(o[0]), float(outs[0][0]),
                                   rtol=2e-6, atol=1e-6)
        np.testing.assert_allclose(float(o[1]), float(outs[0][1]),
                                   rtol=2e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bf16_and_fp32_agree_bitwise_per_distance(dtype):
    """Both sides cast inputs to float32 before the distance, so the
    dtype of the *inputs* never splits kernel from oracle: at a
    single-block shape the full component vector is bit-exact."""
    e1, e2, lab = _pairs(64, 32, dtype)
    ref = cl_ref.contrastive_components(e1, e2, lab)
    ker = cl_kernel.contrastive_components(e1, e2, lab, block_b=64,
                                           interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("B", [5, 13, 96])
def test_op_matches_core_loss_with_tails(B):
    """The dispatch wrapper assembles the same scalar as
    core.losses.online_contrastive_loss at tail shapes too."""
    e1 = jnp.asarray(rng.standard_normal((B, 24)), jnp.float32)
    e2 = jnp.asarray(rng.standard_normal((B, 24)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 2, B), jnp.int32)
    a = float(ocl_core(e1, e2, lab))
    b = float(ocl_op(e1, e2, lab, use_kernel=True))
    np.testing.assert_allclose(a, b, atol=1e-6)
