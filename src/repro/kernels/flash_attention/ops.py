"""Dispatch wrapper: Pallas flash attention on TPU, XLA fallback else.

Accepts the model-layout tensors (B, S, H, hd) used by
repro.models.attention and handles the transpose to kernel layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import ref as _ref


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_kernel: bool | None = None,
                    block_q: int = _kernel.DEFAULT_BLOCK_Q,
                    block_kv: int = _kernel.DEFAULT_BLOCK_KV):
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) -> (B, Sq, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        ot = _kernel.flash_attention(qt, kt, vt, causal=causal, window=window,
                                     block_q=block_q, block_kv=block_kv,
                                     interpret=not _on_tpu())
    else:
        ot = _ref.flash_attention(qt, kt, vt, causal=causal, window=window)
    return ot.transpose(0, 2, 1, 3)
