"""IVF (inverted-file) two-level vector index — the production-scale
cache lookup.

Brute-force cosine top-k is exact but O(N·D) per query; past ~10⁶
entries the paper's Redis deployment would use an ANN structure.  The
TPU-native analogue is a two-level dense search with static shapes:

  level 1: score the query against K centroids (tiny matmul),
  level 2: gather the n_probe best clusters' members (fixed bucket
           capacity → a (n_probe · bucket) dense panel) and do exact
           cosine top-k inside them.

Compute per query drops from N·D to (K + n_probe·bucket)·D — e.g. 16×
at N=1M, K=1024, probe=8, bucket=1024 — while recall stays high for
clustered cache keys (paraphrase groups are exactly such clusters).
Everything is jnp with static shapes: build (k-means) and search are
jittable; state is a pytree that shards like the flat store (buckets
over `model`).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class IVFState(NamedTuple):
    centroids: jax.Array    # (K, D) unit-norm
    members: jax.Array      # (K, bucket) int32 row ids into keys, -1 = empty
    keys: jax.Array         # (N, D) unit-norm (the flat store's keys)
    valid: jax.Array        # (N,) bool
    value_ids: jax.Array    # (N,) int32
    sizes: jax.Array        # (K,) int32


def _unit(x, axis=-1):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=axis, keepdims=True), 1e-9)


def _farthest_first_init(keys, valid, k: int, key):
    """Greedy farthest-point seeding (deterministic k-means++ flavour).

    Uniform random seeding can drop two seeds into one paraphrase
    cluster and none into another; the unseeded cluster then merges
    into a neighbour and overflows its bucket.  Farthest-first picks
    one seed per well-separated cluster by construction.  Jittable:
    a k-step scan carrying the max-similarity-to-chosen vector.
    """
    n = keys.shape[0]
    p = valid.astype(jnp.float32)
    p = jnp.where(p.sum() > 0, p, jnp.ones_like(p))    # empty store: uniform
    p = p / p.sum()
    first = jax.random.choice(key, n, p=p)
    nearest = keys @ keys[first]                       # sim to chosen set

    def pick(nearest, _):
        nxt = jnp.argmin(jnp.where(valid, nearest, jnp.inf))
        nearest = jnp.maximum(nearest, keys @ keys[nxt])
        return nearest, nxt

    _, rest = jax.lax.scan(pick, nearest, None, length=k - 1)
    return jnp.concatenate([first[None], rest])


def kmeans(keys, valid, k: int, iters: int = 8, seed: int = 0):
    """Spherical k-means over the valid rows (cosine geometry)."""
    N, D = keys.shape
    key = jax.random.PRNGKey(seed)
    idx = _farthest_first_init(keys, valid, k, key)
    cent = _unit(keys[idx])

    def step(cent, _):
        sims = keys @ cent.T                              # (N, K)
        sims = jnp.where(valid[:, None], sims, -jnp.inf)
        assign = jnp.argmax(sims, axis=1)                 # (N,)
        onehot = jax.nn.one_hot(assign, k, dtype=keys.dtype)
        onehot = onehot * valid[:, None]
        sums = onehot.T @ keys                            # (K, D)
        counts = onehot.sum(0)[:, None]
        new = jnp.where(counts > 0, _unit(sums), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    return cent


def build_lists(keys, valid, centroids, bucket: int):
    """Assign valid rows to their nearest centroid and fill the
    fixed-capacity inverted lists.  Returns (members (K, bucket) int32
    with -1 padding, sizes (K,) int32).  Jittable with static shapes —
    the tiered cache's periodic warm-tier rebuild reuses this directly.
    """
    n_clusters = centroids.shape[0]
    sims = keys @ centroids.T
    sims = jnp.where(valid[:, None], sims, -jnp.inf)
    assign = jnp.argmax(sims, axis=1)                      # (N,)
    assign = jnp.where(valid, assign, n_clusters)          # invalid -> drop

    order = jnp.argsort(assign, stable=True)
    sorted_c = assign[order]
    starts = jnp.searchsorted(sorted_c, jnp.arange(n_clusters), side="left")
    pos = jnp.arange(keys.shape[0]) - starts[jnp.clip(sorted_c, 0,
                                                      n_clusters - 1)]
    keep = (pos < bucket) & (sorted_c < n_clusters)
    dest = jnp.where(keep, sorted_c * bucket + pos, n_clusters * bucket)
    members = jnp.full((n_clusters * bucket,), -1, jnp.int32).at[dest].set(
        order.astype(jnp.int32), mode="drop").reshape(n_clusters, bucket)
    sizes = jnp.minimum(
        jax.nn.one_hot(assign, n_clusters, dtype=jnp.int32).sum(0), bucket)
    return members, sizes


def build_ivf(keys, valid, value_ids, *, n_clusters: int = 64,
              bucket: int = 256, kmeans_iters: int = 8,
              seed: int = 0) -> IVFState:
    """Cluster the store and fill fixed-capacity inverted lists.
    Overflowing members are dropped from the lists (they can still be
    found by a periodic rebuild with a larger bucket — occupancy is
    reported so callers can monitor)."""
    keys = _unit(keys.astype(jnp.float32))
    cent = kmeans(keys, valid, n_clusters, kmeans_iters, seed)
    members, sizes = build_lists(keys, valid, cent, bucket)
    return IVFState(centroids=cent, members=members, keys=keys,
                    valid=valid, value_ids=value_ids.astype(jnp.int32),
                    sizes=sizes)


def ivf_query(state: IVFState, q, threshold: float, k: int = 1,
              n_probe: int = 4):
    """q: (Q, D) -> (scores (Q,k), slots (Q,k), value_ids, hit (Q,))."""
    q = _unit(q.astype(jnp.float32))
    Q = q.shape[0]
    K, bucket = state.members.shape
    n_probe = min(n_probe, K)

    csims = q @ state.centroids.T                         # (Q, K)
    _, probes = jax.lax.top_k(csims, n_probe)             # (Q, n_probe)
    cand = state.members[probes].reshape(Q, n_probe * bucket)  # (Q, P*B)
    cand_safe = jnp.clip(cand, 0, state.keys.shape[0] - 1)
    cand_keys = state.keys[cand_safe]                     # (Q, P*B, D)
    ok = (cand >= 0) & state.valid[cand_safe]
    scores = jnp.einsum("qd,qnd->qn", q, cand_keys)
    scores = jnp.where(ok, scores, -1e30)
    top_s, top_i = jax.lax.top_k(scores, k)               # (Q, k)
    rows = jnp.arange(Q)[:, None]
    slots = cand_safe[rows, top_i]
    return top_s, slots, state.value_ids[slots], top_s[:, 0] >= threshold


def ivf_occupancy(state: IVFState) -> jax.Array:
    """Fraction of valid rows actually reachable through the lists."""
    listed = jnp.sum(state.sizes)
    total = jnp.maximum(jnp.sum(state.valid.astype(jnp.int32)), 1)
    return listed / total
