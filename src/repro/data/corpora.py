"""Deterministic domain corpora with duplicate-pair structure.

Offline stand-ins for the paper's Kaggle Quora and medical
question-pair datasets (same schema: ``(question1, question2,
is_duplicate)``).  Queries come from a templated grammar:

    query  = template(aspect) ⊗ entity ⊗ synonym choices

* **positive pair**   (is_duplicate=1): same (entity, aspect), different
  template + synonyms — "myocardial infarction treatment" vs "how to
  treat a heart attack".
* **hard negative**   (is_duplicate=0): same entity, different aspect —
  the paper's Q1/Q3 diabetes example (topically related, semantically
  distinct).
* **easy negative**   (is_duplicate=0): different entity.

The grammar metadata is retained on every :class:`Query`, which is what
lets the synthetic-data pipeline (repro/core/synth.py) act as the
structural analogue of the paper's LLM prompts in Listings 1 and 2.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Grammar
# ---------------------------------------------------------------------------

_PERSON = ["someone", "a person", "a patient", "an adult", "an individual"]
_FIND_OUT = ["tell", "find out", "know", "determine", "figure out"]
_BEST = ["best", "most effective", "recommended", "proven", "top"]
_WAYS = ["ways", "methods", "strategies", "approaches", "options"]

# aspect -> list of templates; {e}=entity, other slots from the synonym
# tables above.  Each aspect has >=3 surface forms so positives differ.
ASPECT_TEMPLATES = {
    "symptoms": [
        "What are the symptoms of {e}?",
        "How can I {find} if {person} has {e}?",
        "What signs indicate {e}?",
        "Which warning signs point to {e}?",
    ],
    "treatment": [
        "How is {e} treated?",
        "What are the {best} {ways} to treat {e}?",
        "What treatment options exist for {e}?",
        "How do doctors manage {e}?",
    ],
    "causes": [
        "What causes {e}?",
        "Why does {person} develop {e}?",
        "What are the main causes of {e}?",
        "Which factors lead to {e}?",
    ],
    "diagnosis": [
        "How is {e} diagnosed?",
        "Which tests confirm {e}?",
        "What is the diagnostic procedure for {e}?",
        "How do doctors detect {e}?",
    ],
    "prevention": [
        "How can {e} be prevented?",
        "What are the {best} {ways} to prevent {e}?",
        "How does {person} avoid developing {e}?",
        "Which habits reduce the chance of {e}?",
    ],
    "risk": [
        "What are the risk factors for {e}?",
        "Who is most at risk of {e}?",
        "Which groups are more likely to develop {e}?",
        "What raises the risk of {e}?",
    ],
    "prognosis": [
        "What is the prognosis for {e}?",
        "What is the long term outlook for {person} with {e}?",
        "How does {e} progress over time?",
        "What outcomes are expected with {e}?",
    ],
    "diet": [
        "What diet helps with {e}?",
        "Which foods should {person} with {e} avoid?",
        "How should {person} with {e} eat?",
        "What nutrition advice applies to {e}?",
    ],
    # quora-flavoured aspects
    "howto": [
        "How can I become a good {e}?",
        "What should I do to be a great {e}?",
        "What are the {best} {ways} to become a {e}?",
        "How does {person} get started as a {e}?",
    ],
    "salary": [
        "How much does a {e} earn?",
        "What is the typical salary of a {e}?",
        "What does a {e} get paid?",
        "What income can a {e} expect?",
    ],
    "skills": [
        "What skills does a {e} need?",
        "Which abilities are essential for a {e}?",
        "What should a {e} be good at?",
        "What qualifications help a {e}?",
    ],
    "dayinlife": [
        "What does a {e} do every day?",
        "What is the daily routine of a {e}?",
        "How does a {e} spend a typical workday?",
        "What tasks fill a {e}'s day?",
    ],
    "education": [
        "What degree do I need to become a {e}?",
        "Which studies lead to a career as a {e}?",
        "What education is required for a {e}?",
        "Do I need formal training to be a {e}?",
    ],
}

MEDICAL_ENTITIES = [
    "type 2 diabetes", "early-stage diabetes", "hypertension", "asthma",
    "myocardial infarction", "stroke", "pneumonia", "bronchitis",
    "migraine", "epilepsy", "anemia", "arthritis", "osteoporosis",
    "hypothyroidism", "hyperthyroidism", "chronic kidney disease",
    "hepatitis b", "tuberculosis", "malaria", "dengue fever",
    "ear infection", "sinusitis", "tonsillitis", "appendicitis",
    "gallstones", "peptic ulcer", "crohn disease", "ulcerative colitis",
    "psoriasis", "eczema", "glaucoma", "cataract", "sleep apnea",
    "atrial fibrillation", "heart failure", "deep vein thrombosis",
    "parkinson disease", "alzheimer disease", "multiple sclerosis",
    "stress urinary incontinence",
]
MEDICAL_ASPECTS = ["symptoms", "treatment", "causes", "diagnosis",
                   "prevention", "risk", "prognosis", "diet"]

QUORA_ENTITIES = [
    "geologist", "software engineer", "data scientist", "photographer",
    "journalist", "chef", "pilot", "architect", "lawyer", "nurse",
    "electrician", "translator", "game developer", "graphic designer",
    "teacher", "financial analyst", "marine biologist", "astronomer",
    "civil engineer", "pharmacist", "veterinarian", "screenwriter",
    "economist", "statistician", "historian", "chemist", "barista",
    "carpenter", "firefighter", "paramedic", "librarian", "geneticist",
]
QUORA_ASPECTS = ["howto", "salary", "skills", "dayinlife", "education"]

DOMAINS = {
    "medical": (MEDICAL_ENTITIES, MEDICAL_ASPECTS),
    "quora": (QUORA_ENTITIES, QUORA_ASPECTS),
}


@dataclass(frozen=True)
class Query:
    text: str
    domain: str
    entity: str
    aspect: str
    template_idx: int


def render_query(rng: np.random.Generator, domain: str, entity: str,
                 aspect: str, exclude_template: int = -1) -> Query:
    templates = ASPECT_TEMPLATES[aspect]
    choices = [i for i in range(len(templates)) if i != exclude_template]
    ti = int(rng.choice(choices))
    text = templates[ti].format(
        e=entity,
        person=rng.choice(_PERSON),
        find=rng.choice(_FIND_OUT),
        best=rng.choice(_BEST),
        ways=rng.choice(_WAYS),
    )
    return Query(text, domain, entity, aspect, ti)


def sample_query(rng: np.random.Generator, domain: str) -> Query:
    entities, aspects = DOMAINS[domain]
    return render_query(rng, domain, str(rng.choice(entities)),
                        str(rng.choice(aspects)))


# ---------------------------------------------------------------------------
# Pair datasets
# ---------------------------------------------------------------------------

@dataclass
class PairDataset:
    q1: List[str]
    q2: List[str]
    labels: np.ndarray  # (N,) int32
    domain: str

    def __len__(self):
        return len(self.q1)

    def split(self, eval_frac: float = 0.15, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(self.q1))
        n_eval = int(len(idx) * eval_frac)
        ev, tr = idx[:n_eval], idx[n_eval:]

        def take(ix):
            return PairDataset([self.q1[i] for i in ix],
                               [self.q2[i] for i in ix],
                               self.labels[ix], self.domain)

        return take(tr), take(ev)


def make_pair_dataset(domain: str, n_pairs: int, seed: int = 0,
                      pos_frac: float = 0.5,
                      hard_neg_frac: float = 0.7) -> PairDataset:
    """Balanced duplicate-pair dataset with hard/easy negative mix."""
    entities, aspects = DOMAINS[domain]
    rng = np.random.default_rng(seed)
    q1, q2, labels = [], [], []
    for _ in range(n_pairs):
        a = sample_query(rng, domain)
        if rng.random() < pos_frac:
            # positive: same (entity, aspect), forced different template
            b = render_query(rng, domain, a.entity, a.aspect,
                             exclude_template=a.template_idx)
            labels.append(1)
        elif rng.random() < hard_neg_frac:
            # hard negative: same entity, different aspect
            other = [x for x in aspects if x != a.aspect]
            b = render_query(rng, domain, a.entity, str(rng.choice(other)))
            labels.append(0)
        else:
            # easy negative: different entity
            other_e = [e for e in entities if e != a.entity]
            b = render_query(rng, domain, str(rng.choice(other_e)),
                             str(rng.choice(aspects)))
            labels.append(0)
        q1.append(a.text)
        q2.append(b.text)
    return PairDataset(q1, q2, np.asarray(labels, np.int32), domain)


def make_query_stream(domain: str, n: int, seed: int = 0,
                      repeat_frac: float = 0.33) -> List[Query]:
    """A serving-trace-like query stream where ~repeat_frac of queries
    are paraphrases of earlier ones (the paper's ~33% repeated-query
    statistic) — used by the end-to-end cache benchmarks."""
    rng = np.random.default_rng(seed)
    out: List[Query] = []
    for _ in range(n):
        if out and rng.random() < repeat_frac:
            prev = out[int(rng.integers(len(out)))]
            out.append(render_query(rng, prev.domain, prev.entity,
                                    prev.aspect,
                                    exclude_template=prev.template_idx))
        else:
            out.append(sample_query(rng, domain))
    return out
