"""Pure-jnp oracle for the fused cascade lookup.

This is the tiered cache's original four-op path (hot exact top-k, warm
centroid probe, IVF bucket gather + unindexed-tail scan, best-of-tiers
merge — `cache_service/tiers.py`) expressed over plain arrays, so the
Pallas kernel and the NamedTuple-based cascade can both be checked
against one reference.  Candidate ordering matches `jax.lax.top_k`
tie-breaking (lowest index wins) everywhere, which is what the kernel's
masked-argmax rounds reproduce.

Queries are expected unit-norm float32 (the caller normalizes once; the
unfused tiers path normalizes per tier, but `_unit` is idempotent up to
bit-identity on already-unit rows, so parity holds).

``quantized=True`` scores the warm panel from its int8 symmetric
per-row quantization (``warm_keys_q`` + ``warm_scales``) with fp32
accumulation — the selection then runs on approximate scores whose
per-candidate error is bounded by ``amax·sqrt(D)/254`` (DESIGN.md §8);
the caller re-scores the selected rows exactly from the fp32 panel at
merge time, which is why every return includes ``warm_slots`` (the warm
row of each merged candidate, -1 for hot/invalid entries).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def cascade_lookup(q, q_tenants, thresholds,
                   hot_keys, hot_valid, hot_tenants, hot_value_ids,
                   warm_keys, warm_valid, warm_tenants, warm_value_ids,
                   warm_write_seq, centroids, members, cursor, indexed_total,
                   warm_keys_q=None, warm_scales=None,
                   k: int = 1, n_probe: int = 8, tail: int = 0,
                   quantized: bool = False
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                              jax.Array, jax.Array]:
    """q: (Q, D) unit-norm; q_tenants/thresholds: (Q,).

    Returns (scores (Q, k), value_ids (Q, k), warm_slots (Q, k),
    hot_slots (Q,), hot_hit (Q,), hit (Q,)) — ``warm_slots`` is -1 for
    candidates answered by the hot tier (or padding).
    """
    q = q.astype(jnp.float32)
    q_tenants = q_tenants.astype(jnp.int32)
    Q = q.shape[0]
    rows = jnp.arange(Q)[:, None]

    # hot tier: exact tenant-masked top-k
    hs_all = q @ hot_keys.T                                        # (Q, Nh)
    ok = hot_valid[None, :] & (hot_tenants[None, :] == q_tenants[:, None])
    hs_all = jnp.where(ok, hs_all, NEG)
    hs, hslots = jax.lax.top_k(hs_all, k)
    hvids = jnp.where(hs > NEG / 2, hot_value_ids[hslots], -1)

    # warm tier: IVF probe + unindexed tail
    cap = warm_keys.shape[0]
    n_clusters, bucket = members.shape
    n_probe = min(n_probe, n_clusters)
    csims = q @ centroids.T                                        # (Q, K)
    _, probes = jax.lax.top_k(csims, n_probe)
    cand = members[probes].reshape(Q, n_probe * bucket)
    is_tail = jnp.zeros(cand.shape, bool)
    if tail:
        tail_idx = (cursor - 1 - jnp.arange(tail, dtype=jnp.int32)) % cap
        unindexed = warm_write_seq[tail_idx] > indexed_total
        tail_cand = jnp.where(unindexed, tail_idx, -1)
        cand = jnp.concatenate(
            [cand, jnp.broadcast_to(tail_cand[None, :], (Q, tail))], axis=1)
        is_tail = jnp.concatenate(
            [is_tail, jnp.ones((Q, tail), bool)], axis=1)
    safe = jnp.clip(cand, 0, cap - 1)
    ok = (cand >= 0) & warm_valid[safe] \
        & (warm_tenants[safe] == q_tenants[:, None]) \
        & (is_tail | (warm_write_seq[safe] <= indexed_total))
    if quantized:
        # int8 panel, fp32 accumulation: dequantize per candidate row
        panel = warm_keys_q[safe].astype(jnp.float32)
        wscores = jnp.einsum("qd,qnd->qn", q, panel) * warm_scales[safe]
    else:
        wscores = jnp.einsum("qd,qnd->qn", q, warm_keys[safe])
    wscores = jnp.where(ok, wscores, NEG)
    ws, wi = jax.lax.top_k(wscores, k)
    wslots = safe[rows, wi]
    wvids = jnp.where(ws > NEG / 2, warm_value_ids[wslots], -1)
    wslots = jnp.where(ws > NEG / 2, wslots, -1)

    # best-of-tiers merge (hot side first, so ties resolve hot)
    all_s = jnp.concatenate([hs, ws], axis=1)                      # (Q, 2k)
    all_v = jnp.concatenate([hvids, wvids], axis=1)
    all_w = jnp.concatenate([jnp.full((Q, k), -1, jnp.int32),
                             wslots.astype(jnp.int32)], axis=1)
    s, i = jax.lax.top_k(all_s, k)
    vids = all_v[rows, i]
    out_wslots = all_w[rows, i]
    hit = s[:, 0] >= thresholds
    hot_hit = hit & (i[:, 0] < k)
    return s, vids, out_wslots, hslots[:, 0], hot_hit, hit


def ensemble_lookup(q, weights, q_tenants, thresholds,
                    hot_keys, hot_valid, hot_tenants, hot_value_ids,
                    warm_keys, warm_valid, warm_tenants, warm_value_ids,
                    warm_write_seq, centroids, members, cursor, indexed_total,
                    warm_keys_q=None, warm_scales=None,
                    k: int = 1, n_probe: int = 8, tail: int = 0,
                    quantized: bool = False
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                               jax.Array, jax.Array]:
    """E-panel four-op oracle for the fused ensemble cascade
    (DESIGN.md §13): the weighted fused similarity over E embedder key
    panels, routed once on the *pilot* embedder (panel 0).

    q: (E, Q, D) unit-norm, one query embedding per embedder;
    weights: (Q, E) per-query mixture weights (the service resolves
    them per tenant); hot_keys: (E, Nh, D); warm_keys: (E, cap, D)
    (``warm_keys_q``/``warm_scales``: (E, cap, D) int8 / (E, cap) when
    ``quantized``).  All per-slot metadata (valid/tenant/value-id/
    write-seq columns) and the IVF (centroids + inverted lists, built
    from the pilot panel) are shared across panels — the panels are E
    views of the *same* rows, kept row-aligned by construction
    (`tiers.EnsembleState`).

    The fused score of a candidate row is
    ``sum_e weights[q, e] * cos(q_e, key_e[row])``.  The cross-panel
    weighted sum is one einsum contraction over the stacked per-panel
    scores — a single primitive, so eager and jitted evaluation agree
    bitwise and the kernel reproduces it exactly (an unrolled
    multiply-add chain is NOT fusion-stable: XLA reassociates it
    differently across surrounding graphs).  Masking applies after the
    weighted sum.  The probe runs on the unweighted pilot query against
    the shared (pilot-built) centroids, so the bucket gather is issued
    once and amortized over all E panels.  Returns the same 6-tuple as
    `cascade_lookup`, with scores fused.
    """
    E = q.shape[0]
    q = q.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    q_tenants = q_tenants.astype(jnp.int32)
    Q = q.shape[1]
    rows = jnp.arange(Q)[:, None]

    # hot tier: fused tenant-masked top-k over the stacked panels
    hot_pans = [q[e] @ hot_keys[e].T for e in range(E)]            # E×(Q, Nh)
    hs_all = jnp.einsum("qne,qe->qn", jnp.stack(hot_pans, -1), weights)
    ok = hot_valid[None, :] & (hot_tenants[None, :] == q_tenants[:, None])
    hs_all = jnp.where(ok, hs_all, NEG)
    hs, hslots = jax.lax.top_k(hs_all, k)
    hvids = jnp.where(hs > NEG / 2, hot_value_ids[hslots], -1)

    # warm tier: pilot-routed IVF probe + unindexed tail, fused score
    cap = warm_keys.shape[1] if not quantized else warm_keys_q.shape[1]
    n_clusters, bucket = members.shape
    n_probe = min(n_probe, n_clusters)
    csims = q[0] @ centroids.T                  # pilot routing (Q, K)
    _, probes = jax.lax.top_k(csims, n_probe)
    cand = members[probes].reshape(Q, n_probe * bucket)
    is_tail = jnp.zeros(cand.shape, bool)
    if tail:
        tail_idx = (cursor - 1 - jnp.arange(tail, dtype=jnp.int32)) % cap
        unindexed = warm_write_seq[tail_idx] > indexed_total
        tail_cand = jnp.where(unindexed, tail_idx, -1)
        cand = jnp.concatenate(
            [cand, jnp.broadcast_to(tail_cand[None, :], (Q, tail))], axis=1)
        is_tail = jnp.concatenate(
            [is_tail, jnp.ones((Q, tail), bool)], axis=1)
    safe = jnp.clip(cand, 0, cap - 1)
    ok = (cand >= 0) & warm_valid[safe] \
        & (warm_tenants[safe] == q_tenants[:, None]) \
        & (is_tail | (warm_write_seq[safe] <= indexed_total))

    def _panel(e):
        if quantized:
            pan = warm_keys_q[e][safe].astype(jnp.float32)
            return jnp.einsum("qd,qnd->qn", q[e], pan) \
                * warm_scales[e][safe]
        return jnp.einsum("qd,qnd->qn", q[e], warm_keys[e][safe])

    warm_pans = [_panel(e) for e in range(E)]
    wscores = jnp.einsum("qne,qe->qn", jnp.stack(warm_pans, -1), weights)
    wscores = jnp.where(ok, wscores, NEG)
    ws, wi = jax.lax.top_k(wscores, k)
    wslots = safe[rows, wi]
    wvids = jnp.where(ws > NEG / 2, warm_value_ids[wslots], -1)
    wslots = jnp.where(ws > NEG / 2, wslots, -1)

    # best-of-tiers merge (hot side first, so ties resolve hot)
    all_s = jnp.concatenate([hs, ws], axis=1)                      # (Q, 2k)
    all_v = jnp.concatenate([hvids, wvids], axis=1)
    all_w = jnp.concatenate([jnp.full((Q, k), -1, jnp.int32),
                             wslots.astype(jnp.int32)], axis=1)
    s, i = jax.lax.top_k(all_s, k)
    vids = all_v[rows, i]
    out_wslots = all_w[rows, i]
    hit = s[:, 0] >= thresholds
    hot_hit = hit & (i[:, 0] < k)
    return s, vids, out_wslots, hslots[:, 0], hot_hit, hit
