"""Top-level models.

Decoder LM (all 10 assigned backbones) and encoder embedder (the paper's
ModernBERT / LangCache-Embed arch) share one parameter layout:

    params = {
      "embed":   {table, [unembed]},
      "layers":  {"pos0": <stacked over n_periods>, "pos1": ..., ...},
      "final_norm": {...},
    }

Layers are stacked along a leading ``layers`` axis and executed with
``jax.lax.scan`` over periods — O(1) HLO size for 88-layer models, which
keeps the 512-device dry-run compiles tractable (DESIGN.md §3).  The
period body is optionally rematerialised (cfg.remat) for training.

Modality frontends (audio codec / ViT) are stubs per the assignment:
``frontend_embeds`` of shape (B, frontend_len, d_model) are prepended to
the token embeddings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, layers
from repro.models.actsharding import constrain_batch
from repro.models.param import (
    A, Initializer, Param, prefix_axes, split, stack_params, stack_values,
)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key: Optional[jax.Array] = None,
            abstract: bool = False):
    """Returns a Param tree (values may be ShapeDtypeStructs if abstract)."""
    if not abstract and key is None:
        key = jax.random.PRNGKey(0)
    ini = Initializer(key, dtype=jnp.dtype(cfg.param_dtype), abstract=abstract)
    params = {"embed": layers.init_embedding(ini, cfg)}
    layer_params = {}
    for i, spec in enumerate(cfg.period):
        copies = [blocks.init_layer(ini, cfg, spec) for _ in range(cfg.n_periods)]
        layer_params[f"pos{i}"] = stack_params(copies)
    params["layers"] = layer_params
    params["final_norm"] = layers.init_norm(ini, cfg)
    return params


def lm_param_specs(cfg: ModelConfig):
    """(abstract_values, encoded_axes) for the dry-run path."""
    tree = init_lm(cfg, abstract=True)
    return split(tree)


# ---------------------------------------------------------------------------
# Forward (training / encoder full-sequence)
# ---------------------------------------------------------------------------

def _input_embeds(pv, cfg: ModelConfig, tokens, frontend_embeds):
    x = layers.embed_tokens(pv["embed"], cfg, tokens)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    if not cfg.use_rope and cfg.family == "audio":
        x = x + layers.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    # anchor batch sharding so the FSDP table sharding cannot flip the
    # whole network to batch-replicated (§Perf H6)
    return constrain_batch(x)


def _slice_period(layer_params, j):
    return jax.tree_util.tree_map(lambda a: a[j], layer_params)


def _run_layers(pv, cfg: ModelConfig, x, positions):
    """Apply all layers (scan over periods, or unrolled for dry-runs).
    Returns (x, aux)."""

    def body(carry, layer_p):
        x, aux = carry
        for i, spec in enumerate(cfg.period):
            x, a = blocks.apply_full(layer_p[f"pos{i}"], cfg, spec, x,
                                     positions)
            x = constrain_batch(x)
            aux = aux + a
        return (x, aux), None

    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        if cfg.remat:
            body = jax.checkpoint(body)
        carry, _ = jax.lax.scan(body, carry, pv["layers"])
    else:
        for j in range(cfg.n_periods):
            carry, _ = body(carry, _slice_period(pv["layers"], j))
    return carry


def forward_lm(pv, cfg: ModelConfig, tokens, frontend_embeds=None):
    """pv: plain-value param tree.  Returns (logits, aux_loss).

    tokens: (B, S_tok) int32; frontend_embeds: (B, S_fe, d) or None.
    Logits cover the *full* (frontend + token) sequence.
    """
    x = _input_embeds(pv, cfg, tokens, frontend_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, aux = _run_layers(pv, cfg, x, positions)
    x = layers.apply_norm(pv["final_norm"], cfg, x)
    logits = layers.unembed(pv["embed"], cfg, x)
    return logits, aux


def encode(pv, cfg: ModelConfig, tokens, mask=None):
    """Sentence embeddings for the encoder config (mean-pool + L2 norm).

    tokens: (B, S); mask: (B, S) bool validity (None -> all valid).
    Returns (B, d_model) float32, unit-norm — the cache key vectors.
    """
    assert cfg.is_encoder, f"{cfg.name} is not an encoder config"
    x = layers.embed_tokens(pv["embed"], cfg, tokens)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _ = _run_layers(pv, cfg, x, positions)
    x = layers.apply_norm(pv["final_norm"], cfg, x).astype(jnp.float32)
    if mask is None:
        emb = jnp.mean(x, axis=1)
    else:
        m = mask.astype(jnp.float32)[..., None]
        emb = jnp.sum(x * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return emb / jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-9)


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

def init_lm_state(cfg: ModelConfig, batch: int, seq_len: int,
                  abstract: bool = False):
    """Decode-state pytree: per period-position, stacked over periods,
    plus the scalar ``cur_len`` (tokens already consumed)."""
    layer_states = {}
    for i, spec in enumerate(cfg.period):
        copies = [blocks.init_layer_state(cfg, spec, batch, seq_len, abstract)
                  for _ in range(cfg.n_periods)]
        layer_states[f"pos{i}"] = stack_values(copies)
    cur = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
           else jnp.zeros((), jnp.int32))
    return {"layers": layer_states, "cur_len": cur}


def lm_state_axes(cfg: ModelConfig):
    layer_axes = {}
    for i, spec in enumerate(cfg.period):
        layer_axes[f"pos{i}"] = prefix_axes(blocks.layer_state_axes(cfg, spec))
    return {"layers": layer_axes, "cur_len": A()}


# ---------------------------------------------------------------------------
# Prefill + decode
# ---------------------------------------------------------------------------

def prefill(pv, cfg: ModelConfig, tokens, cache_len: int,
            frontend_embeds=None):
    """Full forward over the prompt, building the decode state.

    Returns (last_token_logits, state).
    """
    x = _input_embeds(pv, cfg, tokens, frontend_embeds)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, layer_p):
        states = {}
        for i, spec in enumerate(cfg.period):
            x, ns, _ = blocks.apply_prefill(
                layer_p[f"pos{i}"], cfg, spec, x, positions,
                blocks.init_layer_state(cfg, spec, B, cache_len))
            states[f"pos{i}"] = ns
        return x, states

    if cfg.scan_layers:
        x, layer_states = jax.lax.scan(body, x, pv["layers"])
    else:
        per_period = []
        for j in range(cfg.n_periods):
            x, st = body(x, _slice_period(pv["layers"], j))
            per_period.append(st)
        layer_states = stack_values(per_period)
    x = layers.apply_norm(pv["final_norm"], cfg, x)
    logits = layers.unembed(pv["embed"], cfg, x[:, -1:])[:, 0]
    state = {"layers": layer_states,
             "cur_len": jnp.asarray(S, jnp.int32)}
    return logits, state


def decode_step(pv, cfg: ModelConfig, state, tokens):
    """One decode step.  tokens: (B, 1) int32.  Returns (logits, state)."""
    x = layers.embed_tokens(pv["embed"], cfg, tokens)
    cur_len = state["cur_len"]
    if not cfg.use_rope and cfg.family == "audio":
        # one sinusoidal row at the current position
        pos_emb = layers.sinusoidal_positions(1, cfg.d_model, offset=cur_len)
        x = x + pos_emb.astype(x.dtype)[None]

    def body(x, xs):
        layer_p, layer_s = xs
        new_states = {}
        for i, spec in enumerate(cfg.period):
            x, ns, _ = blocks.apply_decode(
                layer_p[f"pos{i}"], cfg, spec, x, cur_len, layer_s[f"pos{i}"])
            new_states[f"pos{i}"] = ns
        return x, new_states

    if cfg.scan_layers:
        x, new_layer_states = jax.lax.scan(
            body, x, (pv["layers"], state["layers"]))
    else:
        per_period = []
        for j in range(cfg.n_periods):
            x, st = body(x, (_slice_period(pv["layers"], j),
                             _slice_period(state["layers"], j)))
            per_period.append(st)
        new_layer_states = stack_values(per_period)
    x = layers.apply_norm(pv["final_norm"], cfg, x)
    logits = layers.unembed(pv["embed"], cfg, x)[:, 0]
    return logits, {"layers": new_layer_states, "cur_len": cur_len + 1}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _nll(pv, cfg, x_pred, tgt):
    """x_pred: (B, T, d) hidden states; tgt: (B, T) — mean NLL."""
    logits = layers.unembed(pv["embed"], cfg, x_pred).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def lm_loss(pv, cfg: ModelConfig, tokens, frontend_embeds=None):
    """Next-token cross entropy (+ MoE aux).  tokens: (B, S).

    With cfg.loss_chunk > 0 the unembed is fused into the loss over
    sequence chunks, so the (B, S, vocab) logits tensor never fully
    materialises (§Perf lever; exact same value).
    """
    x = _input_embeds(pv, cfg, tokens, frontend_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, aux = _run_layers(pv, cfg, x, positions)
    x = layers.apply_norm(pv["final_norm"], cfg, x)
    # predictions for token t+1 come from stream position (n_fe + t)
    n_fe = 0 if frontend_embeds is None else frontend_embeds.shape[1]
    x_pred = x[:, n_fe:-1]                      # (B, T, d)
    tgt = tokens[:, 1:]                         # (B, T)
    T = tgt.shape[1]
    if cfg.loss_chunk and cfg.loss_chunk < T:
        C = cfg.loss_chunk
        total = jnp.zeros((), jnp.float32)
        for lo in range(0, T, C):               # unrolled (dry-run mode)
            total = total + _nll(pv, cfg, x_pred[:, lo:lo + C],
                                 tgt[:, lo:lo + C])
        nll = total / (tgt.shape[0] * T)
    else:
        nll = _nll(pv, cfg, x_pred, tgt) / (tgt.shape[0] * T)
    return nll + aux, {"nll": nll, "aux": aux}
