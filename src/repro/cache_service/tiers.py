"""Pure-JAX device half of the tiered multi-tenant cache.

Two tiers share one geometry (unit-norm cosine keys) and one id space
(host-side ``value_ids``):

  * HOT  — a small flat store that absorbs every admitted insert and
    answers with exact brute-force top-k.  Rows carry a tenant-id
    column; lookups mask on it, so one set of device arrays serves any
    number of logical caches with zero per-tenant recompiles.
  * WARM — a large ring buffer indexed by an IVF (centroids + fixed
    bucket inverted lists).  Cold hot-tier rows are *demoted* here in
    fixed-size flushes; the IVF is rebuilt periodically (jittable
    k-means), and rows appended since the last rebuild stay reachable
    through a fixed-size brute-force *tail* window, so recall does not
    degrade between rebuilds.

Every operation is a pure function over NamedTuple pytrees with static
shapes — insert, demote, append, rebuild and the cascaded lookup all
jit once per shape and shard like the flat store (rows over `model`).

Cascade semantics: one jitted call scores both tiers and returns the
best of the two top-k sets, plus provenance (``hot_hit``) so the host
only bumps hot-tier LRU clocks.  Scores are cosine in both tiers, so
"hot first, warm fallback" and "max over tiers" pick the same answers.
`cascade_query` selects between the four-op XLA composition and the
fused Pallas kernel (`kernels/cascade_lookup`, DESIGN.md §3) — same
results, one kernel launch.

Scale-out (DESIGN.md §8): the warm tier also exists in a *sharded*
form — a stacked ``WarmState`` whose every leaf carries a leading
``shards`` axis, one independent ring + local IVF per shard, laid over
the mesh ``model`` axis by ``cascade_query(..., mesh=...)`` via
shard_map.  Each shard probes its own centroids and computes a local
top-k (the fused kernel runs per shard on exactly the warm slice its
VMEM budget assumes); the only collective is the tiny
(Q, k·shards) candidate merge shared with `store.query_sharded`
(`core.distrib`).  The hot tier stays replicated and is attributed to
shard 0 so the merge never sees duplicate hot candidates.  The warm
panel can additionally be scanned from an int8 symmetric per-row
quantization (``keys_q``/``scales``, maintained on append) with the
selected rows re-scored exactly from the fp32 keys at merge time.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import ivf as ivf_lib

NEG = -1e30


class HotState(NamedTuple):
    keys: jax.Array        # (N, D) float32, unit-norm rows
    valid: jax.Array       # (N,)  bool
    tenants: jax.Array     # (N,)  int32, -1 when invalid
    last_used: jax.Array   # (N,)  int32 lamport clock
    inserted_at: jax.Array  # (N,) int32
    value_ids: jax.Array   # (N,)  int32 host-side response index
    clock: jax.Array       # ()    int32
    expires_at: jax.Array  # (N,)  float32 wall-clock expiry, +inf = no TTL


class WarmState(NamedTuple):
    """One warm ring + IVF.  In the sharded tier every leaf gains a
    leading ``shards`` axis (one independent ring/index per shard);
    `cascade_query` detects the stacked form by ``keys.ndim == 3``."""
    keys: jax.Array        # (Nw, D) float32 unit-norm
    valid: jax.Array       # (Nw,) bool
    tenants: jax.Array     # (Nw,) int32
    value_ids: jax.Array   # (Nw,) int32
    write_seq: jax.Array   # (Nw,) int32 1-based global write sequence
    cursor: jax.Array      # ()    int32 next ring position
    total: jax.Array       # ()    int32 total rows ever appended
    centroids: jax.Array   # (K, D)
    members: jax.Array     # (K, bucket) int32 row ids, -1 empty
    sizes: jax.Array       # (K,) int32
    indexed_total: jax.Array  # () int32: `total` at the last rebuild
    keys_q: jax.Array      # (Nw, D) int8 symmetric per-row quantization
    scales: jax.Array      # (Nw,) float32 per-row dequant scale
    expires_at: jax.Array  # (Nw,) float32 wall-clock expiry, +inf = no TTL


class Demoted(NamedTuple):
    keys: jax.Array        # (m, D)
    value_ids: jax.Array   # (m,)
    tenants: jax.Array     # (m,)
    mask: jax.Array        # (m,) bool — False rows are padding
    # per-row expiry riding along the demotion (None = no TTL anywhere,
    # kept optional so TTL-free callers build Demoted unchanged)
    expires: jax.Array | None = None


class CascadeResult(NamedTuple):
    scores: jax.Array      # (Q, k) best-of-both-tiers cosine, desc
    value_ids: jax.Array   # (Q, k) -1 where no candidate
    hot_slots: jax.Array   # (Q,)   hot-tier row of the hot top-1
    hot_hit: jax.Array     # (Q,)   hit answered by the hot tier
    hit: jax.Array         # (Q,)   best score >= per-query threshold


# one cosine geometry everywhere: share the flat/IVF normalizer
_unit = ivf_lib._unit


# ---------------------------------------------------------------------------
# hot tier
# ---------------------------------------------------------------------------

def init_hot(capacity: int, dim: int) -> HotState:
    return HotState(
        keys=jnp.zeros((capacity, dim), jnp.float32),
        valid=jnp.zeros((capacity,), bool),
        tenants=jnp.full((capacity,), -1, jnp.int32),
        last_used=jnp.zeros((capacity,), jnp.int32),
        inserted_at=jnp.zeros((capacity,), jnp.int32),
        value_ids=jnp.full((capacity,), -1, jnp.int32),
        clock=jnp.zeros((), jnp.int32),
        expires_at=jnp.full((capacity,), jnp.inf, jnp.float32),
    )


def hot_axes() -> HotState:
    """Logical sharding axes (encoded strings) for the hot pytree."""
    return HotState(keys="corpus,.", valid="corpus", tenants="corpus",
                    last_used="corpus", inserted_at="corpus",
                    value_ids="corpus", clock="", expires_at="corpus")


def _choose_slot(state: HotState) -> jax.Array:
    has_free = jnp.any(~state.valid)
    first_free = jnp.argmax(~state.valid)
    lru = jnp.argmin(jnp.where(state.valid, state.last_used,
                               jnp.iinfo(jnp.int32).max))
    return jnp.where(has_free, first_free, lru).astype(jnp.int32)


def hot_insert(state: HotState, emb: jax.Array, value_id: jax.Array,
               tenant: jax.Array, expires: jax.Array | None = None
               ) -> Tuple[HotState, jax.Array]:
    """Insert one embedding; ``value_id < 0`` is an admission skip (no-op).

    ``expires`` (float32 wall-clock, None = +inf) stamps the row's TTL
    deadline; `mask_expired` hides it at plan time and `reap_expired`
    frees it on the maintenance tick.  Returns (state,
    evicted_value_id) — the response id of an overwritten valid slot
    (else -1) so the host can free its string.
    """
    emb = _unit(emb.astype(jnp.float32))
    exp = jnp.asarray(jnp.inf if expires is None else expires, jnp.float32)
    slot = _choose_slot(state)
    clock = state.clock + 1
    skip = value_id < 0
    evicted = jnp.where(~skip & state.valid[slot], state.value_ids[slot], -1)
    new = HotState(
        keys=state.keys.at[slot].set(emb),
        valid=state.valid.at[slot].set(True),
        tenants=state.tenants.at[slot].set(tenant.astype(jnp.int32)),
        last_used=state.last_used.at[slot].set(clock),
        inserted_at=state.inserted_at.at[slot].set(clock),
        value_ids=state.value_ids.at[slot].set(value_id.astype(jnp.int32)),
        clock=clock,
        expires_at=state.expires_at.at[slot].set(exp),
    )
    state = jax.tree_util.tree_map(
        lambda old, upd: jnp.where(skip, old, upd), state, new)
    return state, evicted.astype(jnp.int32)


def hot_insert_batch(state: HotState, embs: jax.Array, value_ids: jax.Array,
                     tenants: jax.Array,
                     expires: jax.Array | None = None
                     ) -> Tuple[HotState, jax.Array]:
    """Sequential batch insert.  Returns (state, evicted (M,) int32)."""
    if expires is None:
        expires = jnp.full(embs.shape[:1], jnp.inf, jnp.float32)

    def body(s, xs):
        e, vid, t, exp = xs
        s, ev = hot_insert(s, e, vid, t, exp)
        return s, ev

    state, evicted = jax.lax.scan(body, state,
                                  (embs, value_ids, tenants, expires))
    return state, evicted


def hot_touch(state: HotState, slots: jax.Array, hit: jax.Array) -> HotState:
    """LRU bump for hit slots (slots: (Q,), hit: (Q,))."""
    clock = state.clock + 1
    safe = jnp.where(hit, slots, 0)
    new_last = state.last_used.at[safe].max(
        jnp.where(hit, clock, jnp.zeros_like(clock)))
    return state._replace(last_used=new_last, clock=clock)


def hot_query(state: HotState, q: jax.Array, q_tenants: jax.Array,
              k: int = 1) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact tenant-masked top-k.  q: (Q, D), q_tenants: (Q,) int32."""
    qn = _unit(q.astype(jnp.float32))
    scores = qn @ state.keys.T                                    # (Q, N)
    ok = state.valid[None, :] & (state.tenants[None, :]
                                 == q_tenants[:, None])
    scores = jnp.where(ok, scores, NEG)
    s, slots = jax.lax.top_k(scores, k)
    vids = jnp.where(s > NEG / 2, state.value_ids[slots], -1)
    return s, slots, vids


def coldest_slots(state: HotState, m: int) -> jax.Array:
    """The m coldest hot slots in demotion order — the exact selection
    `demote_coldest` pops, exposed so the ensemble flush can gather the
    same rows' panel keys before the demote (DESIGN.md §13)."""
    big = jnp.iinfo(jnp.int32).max
    # int32 throughout: a float32 cast would blur LRU ordering once the
    # clock passes 2^24; invalid rows sort last via the sentinel
    lu = jnp.where(state.valid, state.last_used, big)
    ins = jnp.where(state.valid, state.inserted_at, big)
    return jnp.lexsort((ins, lu))[:m]                             # coldest


def demote_coldest(state: HotState, m: int) -> Tuple[HotState, Demoted]:
    """Pop the m least-recently-used valid rows for warm-tier flush.

    Ties in ``last_used`` — common after a batched `hot_touch`, which
    stamps every hit slot with the same clock — break on the insertion
    sequence (oldest ``inserted_at`` demotes first), NOT on slot index:
    slot-order tie-breaking systematically churned low-index slots
    under uniform traffic.  Remaining ties (same touch clock, same
    insert clock) fall back to slot order, which is then genuinely
    arbitrary.  Returned ``mask`` is False on padding rows (fewer than
    m valid).
    """
    idx = coldest_slots(state, m)
    mask = state.valid[idx]
    new_valid = state.valid.at[idx].set(
        jnp.where(mask, False, state.valid[idx]))
    dem = Demoted(keys=state.keys[idx], value_ids=state.value_ids[idx],
                  tenants=state.tenants[idx], mask=mask,
                  expires=state.expires_at[idx])
    return state._replace(valid=new_valid), dem


# ---------------------------------------------------------------------------
# warm tier
# ---------------------------------------------------------------------------

def quantize_rows(keys: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 per-row quantization of a (…, D) key panel.

    ``keys ≈ q8 * scale[..., None]`` with scale = amax/127; per-row
    reconstruction error is <= scale/2 per component, so a cosine score
    against a unit query is off by at most ``amax·sqrt(D)/254``
    (DESIGN.md §8).  Returns (q8 int8, scale float32).
    """
    amax = jnp.max(jnp.abs(keys), axis=-1)
    scale = jnp.maximum(amax, 1e-9) / 127.0
    q8 = jnp.clip(jnp.round(keys / scale[..., None]),
                  -127, 127).astype(jnp.int8)
    return q8, scale.astype(jnp.float32)


def requantize(state: WarmState) -> WarmState:
    """Refresh ``keys_q``/``scales`` from ``keys`` — required after any
    bulk load that writes ``keys`` directly instead of `warm_append`."""
    q8, sc = quantize_rows(state.keys)
    return state._replace(keys_q=q8, scales=sc)


def init_warm(capacity: int, dim: int, n_clusters: int,
              bucket: int) -> WarmState:
    return WarmState(
        keys=jnp.zeros((capacity, dim), jnp.float32),
        valid=jnp.zeros((capacity,), bool),
        tenants=jnp.full((capacity,), -1, jnp.int32),
        value_ids=jnp.full((capacity,), -1, jnp.int32),
        write_seq=jnp.zeros((capacity,), jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        total=jnp.zeros((), jnp.int32),
        centroids=jnp.zeros((n_clusters, dim), jnp.float32),
        members=jnp.full((n_clusters, bucket), -1, jnp.int32),
        sizes=jnp.zeros((n_clusters,), jnp.int32),
        indexed_total=jnp.zeros((), jnp.int32),
        keys_q=jnp.zeros((capacity, dim), jnp.int8),
        scales=jnp.zeros((capacity,), jnp.float32),
        expires_at=jnp.full((capacity,), jnp.inf, jnp.float32),
    )


def init_warm_sharded(shards: int, capacity: int, dim: int, n_clusters: int,
                      bucket: int) -> WarmState:
    """Stacked warm tier: ``shards`` independent rings of ``capacity``
    rows and ``n_clusters`` local centroids each (leading axis laid
    over the mesh ``model`` axis by `cascade_query`)."""
    one = init_warm(capacity, dim, n_clusters, bucket)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (shards,) + x.shape), one)


def stack_warm(states) -> WarmState:
    """Stack per-shard WarmStates into the sharded (leading-axis) form."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def place_warm_sharded(warm: WarmState, mesh, axis: str = "model"
                       ) -> WarmState:
    """Commit a stacked warm state to the mesh: leading shard axis over
    ``axis``, everything else replicated.  Done once after init/bulk
    load — every later device op (vmapped append/rebuild, eviction,
    lookup) preserves the leading-axis sharding, so lookups read
    resident shards instead of resharding the corpus per call."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    return jax.tree_util.tree_map(
        lambda x: jax.device_put(
            x, NamedSharding(mesh, P(*((axis,) + (None,) * (x.ndim - 1))))),
        warm)


def _dem_expires(dem: Demoted) -> jax.Array:
    """The demoted batch's expiry column, defaulting to +inf (no TTL)."""
    if dem.expires is None:
        return jnp.full(dem.mask.shape, jnp.inf, jnp.float32)
    return dem.expires.astype(jnp.float32)


def warm_append(state: WarmState, dem: Demoted) -> Tuple[WarmState, jax.Array]:
    """Ring-buffer append of a demoted batch (m <= warm capacity).

    Returns (state, evicted (m,) int32) — response ids of overwritten
    ring slots, -1 padding.  Appended rows are unindexed until the next
    rebuild; `warm_query`'s tail window keeps them reachable.  The int8
    panel (``keys_q``/``scales``) and the TTL column (``expires_at``)
    are maintained in the same update.
    """
    cap = state.keys.shape[0]
    offs = jnp.cumsum(dem.mask.astype(jnp.int32)) - 1              # (m,)
    pos = (state.cursor + offs) % cap
    dest = jnp.where(dem.mask, pos, cap)                           # cap=drop
    safe = jnp.clip(dest, 0, cap - 1)
    evicted = jnp.where(dem.mask & state.valid[safe],
                        state.value_ids[safe], -1).astype(jnp.int32)
    n = dem.mask.sum().astype(jnp.int32)
    seqs = state.total + 1 + offs
    kn = _unit(dem.keys.astype(jnp.float32))
    k8, sc = quantize_rows(kn)
    return state._replace(
        keys=state.keys.at[dest].set(kn, mode="drop"),
        valid=state.valid.at[dest].set(True, mode="drop"),
        tenants=state.tenants.at[dest].set(dem.tenants, mode="drop"),
        value_ids=state.value_ids.at[dest].set(dem.value_ids, mode="drop"),
        write_seq=state.write_seq.at[dest].set(seqs, mode="drop"),
        cursor=(state.cursor + n) % cap,
        total=state.total + n,
        keys_q=state.keys_q.at[dest].set(k8, mode="drop"),
        scales=state.scales.at[dest].set(sc, mode="drop"),
        expires_at=state.expires_at.at[dest].set(_dem_expires(dem),
                                                 mode="drop"),
    ), evicted


def warm_append_sharded(state: WarmState, dem: Demoted
                        ) -> Tuple[WarmState, jax.Array]:
    """Round-robin a demoted batch over the shard rings (row j of the
    batch lands on shard ``j % shards``, so every flush loads shards
    evenly).  ``m`` must divide by the shard count — `CacheService`
    snaps ``flush_size`` down to a shard multiple (min. one row per
    shard) to guarantee it.  Returns (state, evicted (m,) int32)."""
    shards = state.keys.shape[0]
    m = dem.keys.shape[0]
    if m % shards:
        raise ValueError(f"demoted batch {m} not divisible by "
                         f"{shards} shards")
    dem = dem._replace(expires=_dem_expires(dem))

    def split(x):
        return jnp.swapaxes(x.reshape((m // shards, shards) + x.shape[1:]),
                            0, 1)

    dem_s = Demoted(*(split(x) for x in dem))
    new, evicted = jax.vmap(warm_append)(state, dem_s)
    return new, evicted.reshape(-1)


def warm_rebuild(state: WarmState, iters: int = 8,
                 seed: int = 0) -> WarmState:
    """Re-cluster the warm corpus and refill the inverted lists
    (jittable: spherical k-means + the same static list fill as
    `build_ivf`).

    Double-buffering (DESIGN.md §7) runs this on a *snapshot* while
    serving keeps reading the published index; `warm_publish_index`
    then grafts the result onto the live state.
    """
    n_clusters, bucket = state.members.shape
    cent = ivf_lib.kmeans(state.keys, state.valid, n_clusters, iters, seed)
    members, sizes = ivf_lib.build_lists(state.keys, state.valid, cent,
                                         bucket)
    return state._replace(centroids=cent, members=members, sizes=sizes,
                          indexed_total=state.total)


def warm_rebuild_sharded(state: WarmState, iters: int = 8,
                         seed: int = 0) -> WarmState:
    """Per-shard re-cluster of the stacked warm tier: each shard runs
    its own spherical k-means over its local rows (vmapped, so one
    compile covers every shard)."""
    return jax.vmap(partial(warm_rebuild, iters=iters, seed=seed))(state)


def warm_publish_index(current: WarmState, shadow: WarmState) -> WarmState:
    """Atomically swap a shadow-built IVF into the live warm state.

    Only the index leaves move (centroids, inverted lists,
    ``indexed_total``); keys/valid/cursor/total stay the *current*
    ring, which may have advanced past the shadow's snapshot.  Because
    ``indexed_total`` becomes the snapshot's total, every row appended
    after the snapshot still satisfies ``write_seq > indexed_total``
    and is served by `warm_query`'s tail window, while ring slots
    overwritten post-snapshot are excluded from the (stale) inverted
    lists by the same epoch partition — so the swap can never create a
    recall dip or a duplicate candidate.

    Works unchanged on the stacked (sharded) form: the index leaves of
    every shard move in one ``_replace``, so the publish is
    shard-consistent — no lookup can ever observe shard A's new index
    next to shard B's old one (the swap happens between, never inside,
    jitted lookups).
    """
    return current._replace(centroids=shadow.centroids,
                            members=shadow.members, sizes=shadow.sizes,
                            indexed_total=shadow.indexed_total)


def publish_reembedded_keys(hot: HotState, warm: WarmState,
                            hot_keys: jax.Array, warm_keys: jax.Array
                            ) -> Tuple[HotState, WarmState]:
    """Atomically swap both tiers' key panels for re-embedded ones
    (DESIGN.md §11).

    The panels are full-capacity replacements built host-side by
    mapping each *currently valid* row's value id to its re-embedding
    under the candidate embedder; rows without a replacement (invalid
    slots, padding) must carry their current key so nothing else moves.
    Only ``keys`` (and the warm int8 mirror, requantized in the same
    update) change: ``valid``/``tenants``/``value_ids``/ring counters
    and the IVF leaves are untouched, so a row evicted while the shadow
    re-embed ran can never be resurrected by the publish, and the tail
    window / inverted-list partition is exactly as sound as before the
    swap.  Rows are re-normalized here so the cosine geometry is
    preserved no matter what the embedder emitted.  Works unchanged on
    the stacked (sharded) warm form — the leading shard axis broadcasts
    through.
    """
    hk = _unit(hot_keys.astype(jnp.float32))
    wk = _unit(warm_keys.astype(jnp.float32))
    q8, sc = quantize_rows(wk)
    return (hot._replace(keys=hk),
            warm._replace(keys=wk, keys_q=q8, scales=sc))


def warm_query(state: WarmState, q: jax.Array, q_tenants: jax.Array,
               k: int = 1, n_probe: int = 8, tail: int = 0
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """IVF probe + unindexed-tail scan, tenant-masked.

    Candidates are the members of the ``n_probe`` nearest clusters plus
    the last ``tail`` ring positions filtered to rows written after the
    last rebuild.  With tail >= flush_size * rebuild_every, every live
    row is reachable, so recall matches a full brute-force scan of the
    probed clusters.
    """
    qn = _unit(q.astype(jnp.float32))
    Q = qn.shape[0]
    cap = state.keys.shape[0]
    n_clusters, bucket = state.members.shape
    n_probe = min(n_probe, n_clusters)

    csims = qn @ state.centroids.T                                 # (Q, K)
    _, probes = jax.lax.top_k(csims, n_probe)
    cand = state.members[probes].reshape(Q, n_probe * bucket)
    # partition candidates by write epoch so a slot overwritten after
    # the rebuild (stale member entry + tail member) never appears
    # twice: IVF side serves rows indexed at the last rebuild, the
    # tail serves rows written after it.
    is_tail = jnp.zeros(cand.shape, bool)
    if tail:
        tail_idx = (state.cursor - 1 - jnp.arange(tail, dtype=jnp.int32)) \
            % cap
        unindexed = state.write_seq[tail_idx] > state.indexed_total
        tail_cand = jnp.where(unindexed, tail_idx, -1)
        cand = jnp.concatenate(
            [cand, jnp.broadcast_to(tail_cand[None, :], (Q, tail))], axis=1)
        is_tail = jnp.concatenate(
            [is_tail, jnp.ones((Q, tail), bool)], axis=1)

    safe = jnp.clip(cand, 0, cap - 1)
    ok = (cand >= 0) & state.valid[safe] \
        & (state.tenants[safe] == q_tenants[:, None]) \
        & (is_tail | (state.write_seq[safe] <= state.indexed_total))
    scores = jnp.einsum("qd,qnd->qn", qn, state.keys[safe])
    scores = jnp.where(ok, scores, NEG)
    top_s, top_i = jax.lax.top_k(scores, k)
    rows = jnp.arange(Q)[:, None]
    slots = safe[rows, top_i]
    vids = jnp.where(top_s > NEG / 2, state.value_ids[slots], -1)
    return top_s, slots, vids


def warm_occupancy(state: WarmState) -> jax.Array:
    return jnp.mean(state.valid.astype(jnp.float32))


# ---------------------------------------------------------------------------
# cascade + tenant eviction
# ---------------------------------------------------------------------------

def cascade_lookup(hot: HotState, warm: WarmState, q: jax.Array,
                   q_tenants: jax.Array, thresholds: jax.Array,
                   k: int = 1, n_probe: int = 8,
                   tail: int = 0) -> CascadeResult:
    """One jitted lookup over both tiers.

    thresholds: (Q,) per-query operating points (host-resolved from the
    per-tenant policy table — a traced array, so mixed-tenant batches
    never retrace).
    """
    hs, hslots, hvids = hot_query(hot, q, q_tenants, k)
    ws, _, wvids = warm_query(warm, q, q_tenants, k, n_probe, tail)
    all_s = jnp.concatenate([hs, ws], axis=1)                      # (Q, 2k)
    all_v = jnp.concatenate([hvids, wvids], axis=1)
    s, i = jax.lax.top_k(all_s, k)
    rows = jnp.arange(s.shape[0])[:, None]
    vids = all_v[rows, i]
    hit = s[:, 0] >= thresholds
    hot_hit = hit & (i[:, 0] < k)
    return CascadeResult(scores=s, value_ids=vids, hot_slots=hslots[:, 0],
                         hot_hit=hot_hit, hit=hit)


def _cascade_ops(hot: HotState, warm: WarmState, qn, qt, thr, k, n_probe,
                 tail, use_kernel, quantized, warm_block_n=None):
    """Flat-array cascade through the kernel-package dispatch; returns
    the 6-tuple (scores, vids, warm_slots, hot_slots, hot_hit, hit)."""
    from repro.kernels.cascade_lookup import ops as _casc_ops
    return _casc_ops.cascade_lookup(
        qn, qt, thr, hot.keys, hot.valid, hot.tenants, hot.value_ids,
        warm.keys, warm.valid, warm.tenants, warm.value_ids,
        warm.write_seq, warm.centroids, warm.members,
        warm.cursor, warm.indexed_total, warm.keys_q, warm.scales,
        k=k, n_probe=n_probe, tail=tail, quantized=quantized,
        use_kernel=use_kernel, warm_block_n=warm_block_n)


def _rescore_exact(qn, keys, s, wslots):
    """Replace quantized-selected warm scores with exact fp32 cosines.

    Only the (Q, k) selected rows are gathered from the fp32 panel, so
    the exact pass costs O(Q·k·D) — the bulk scan stays int8.
    """
    safe = jnp.clip(wslots, 0, keys.shape[0] - 1)
    exact = jnp.einsum("qd,qkd->qk", qn, keys[safe])
    return jnp.where(wslots >= 0, exact, s)


def _shard_cascade(hot: HotState, warm: WarmState, qn, qt, thr, k, n_probe,
                   tail, use_kernel, quantized, shard_index,
                   warm_block_n=None):
    """One shard's candidates for the sharded cascade (DESIGN.md §8).

    The hot tier is replicated but *attributed to shard 0* (its valid
    mask is zeroed elsewhere), so the cross-shard merge never sees the
    same hot row twice.  Returns (scores (Q, k), vids (Q, k),
    is_hot (Q, k) int32, hot_slots (Q,)) — already exact-rescored when
    quantized, so the merge compares true cosines.
    """
    hot = hot._replace(valid=hot.valid & (shard_index == 0))
    s, vids, wslots, hslots, _, _ = _cascade_ops(
        hot, warm, qn, qt, thr, k, n_probe, tail, use_kernel, quantized,
        warm_block_n)
    if quantized:
        s = _rescore_exact(qn, warm.keys, s, wslots)
    is_hot = ((wslots < 0) & (s > NEG / 2)).astype(jnp.int32)
    return s, vids, is_hot, hslots


def _cascade_sharded_oracle(hot: HotState, swarm: WarmState, qn, qt, thr,
                            k, n_probe, tail, use_kernel, quantized,
                            warm_block_n=None) -> CascadeResult:
    """Single-device emulation of the sharded schedule — the bit-exact
    oracle the shard_map path is tested against.  Shard s's candidates
    occupy columns [s·k, (s+1)·k) of the merge panel, exactly like the
    tiled all-gather."""
    from repro.core.distrib import merge_stacked_topk
    shards = swarm.keys.shape[0]
    per = [_shard_cascade(hot,
                          jax.tree_util.tree_map(lambda x, i=i: x[i], swarm),
                          qn, qt, thr, k, n_probe, tail, use_kernel,
                          quantized, i, warm_block_n)
           for i in range(shards)]
    s, vids, is_hot = merge_stacked_topk(
        k, jnp.stack([p[0] for p in per]), jnp.stack([p[1] for p in per]),
        jnp.stack([p[2] for p in per]))
    hit = s[:, 0] >= thr
    hot_hit = hit & (is_hot[:, 0] != 0)
    return CascadeResult(scores=s, value_ids=vids, hot_slots=per[0][3],
                         hot_hit=hot_hit, hit=hit)


def _cascade_sharded(hot: HotState, swarm: WarmState, qn, qt, thr, k,
                     n_probe, tail, use_kernel, quantized, mesh,
                     axis, warm_block_n=None) -> CascadeResult:
    """shard_map execution of the sharded cascade: warm leaves split on
    their leading shard axis over ``axis``, hot/queries replicated, one
    (Q, k·shards) all-gather merge (`core.distrib.merge_local_topk`)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.distrib import merge_local_topk

    def local(hot_, swarm_, qn_, qt_, thr_):
        i = jax.lax.axis_index(axis)
        warm_local = jax.tree_util.tree_map(lambda x: x[0], swarm_)
        s, vids, is_hot, hslots = _shard_cascade(
            hot_, warm_local, qn_, qt_, thr_, k, n_probe, tail,
            use_kernel, quantized, i, warm_block_n)
        sm, vm, hm = merge_local_topk(axis, k, s, vids, is_hot)
        hit = sm[:, 0] >= thr_
        hot_hit = hit & (hm[:, 0] != 0)
        # only shard 0 computed real hot slots; psum broadcasts them
        hslot0 = jax.lax.psum(jnp.where(i == 0, hslots, 0), axis)
        return sm, vm, hslot0, hot_hit, hit

    rep = P()
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: rep, hot),
                  jax.tree_util.tree_map(lambda _: P(axis), swarm),
                  rep, rep, rep),
        out_specs=(rep, rep, rep, rep, rep),
        check_rep=False)
    s, vids, hslots, hot_hit, hit = fn(hot, swarm, qn, qt, thr)
    return CascadeResult(scores=s, value_ids=vids, hot_slots=hslots,
                         hot_hit=hot_hit, hit=hit)


def cascade_query(hot: HotState, warm: WarmState, q: jax.Array,
                  q_tenants: jax.Array, thresholds: jax.Array,
                  k: int = 1, n_probe: int = 8, tail: int = 0,
                  fused: bool = False,
                  use_kernel: bool | None = None,
                  quantized: bool = False,
                  mesh=None, axis: str = "model",
                  warm_block_n: int | None = None) -> CascadeResult:
    """Cascade lookup with a selectable execution path.

    ``fused=False`` runs the original four-op XLA composition
    (`cascade_lookup`), the parity reference.  ``fused=True`` routes
    through `kernels/cascade_lookup` — one fused Pallas kernel on TPU
    (candidate panels stay in VMEM; the bucket-gather round-trip
    through HBM disappears) and the same four-op math as a single jnp
    oracle on CPU / interpret mode.  Both paths return bit-identical
    ``CascadeResult``s, including tenant masking, invalid slots and the
    tail window; ``use_kernel`` forces the Pallas path (interpret mode
    off-TPU) for parity tests.

    A stacked ``warm`` (leading shard axis, ``keys.ndim == 3``) selects
    the sharded schedule (DESIGN.md §8): per-shard local probe + local
    top-k (fused or four-op per shard), tiny (Q, k·shards) merge.  With
    ``mesh`` the shards execute under shard_map over ``axis``; without
    it the single-device oracle emulates the identical schedule (same
    results bit-for-bit).  ``tail`` is then the *per-shard* tail
    window.  ``quantized=True`` scans the warm panel from its int8
    form and re-scores the selected rows exactly (scores in the result
    are true fp32 cosines either way).  ``warm_block_n`` streams the
    warm panel through the fused kernel in blocks of that many rows
    (DESIGN.md §12) so a shard's warm slice may exceed its VMEM budget;
    results are bit-identical for every block count (and the flag is a
    no-op on the four-op / oracle paths).
    """
    sharded = warm.keys.ndim == 3
    if mesh is not None and not sharded:
        raise ValueError("cascade_query(mesh=...) needs the stacked "
                         "(sharded) WarmState; see init_warm_sharded")
    uk = use_kernel if fused else False
    if sharded:
        qn = _unit(q.astype(jnp.float32))
        qt = q_tenants.astype(jnp.int32)
        thr = jnp.asarray(thresholds, jnp.float32)
        if mesh is None:
            return _cascade_sharded_oracle(hot, warm, qn, qt, thr, k,
                                           n_probe, tail, uk, quantized,
                                           warm_block_n)
        return _cascade_sharded(hot, warm, qn, qt, thr, k, n_probe, tail,
                                uk, quantized, mesh, axis, warm_block_n)
    if not fused and not quantized:
        return cascade_lookup(hot, warm, q, q_tenants, thresholds, k=k,
                              n_probe=n_probe, tail=tail)
    qn = _unit(q.astype(jnp.float32))
    s, vids, wslots, hslots, hot_hit, hit = _cascade_ops(
        hot, warm, qn, q_tenants.astype(jnp.int32), thresholds, k,
        n_probe, tail, uk, quantized, warm_block_n)
    if quantized:
        # exact re-score may reorder the k selected candidates
        s = _rescore_exact(qn, warm.keys, s, wslots)
        s, idx = jax.lax.top_k(s, k)
        rows = jnp.arange(s.shape[0])[:, None]
        vids = vids[rows, idx]
        wslots = wslots[rows, idx]
        hit = s[:, 0] >= thresholds
        hot_hit = hit & (wslots[:, 0] < 0)
    return CascadeResult(scores=s, value_ids=vids, hot_slots=hslots,
                         hot_hit=hot_hit, hit=hit)


def evict_tenant(hot: HotState, warm: WarmState, tenant: jax.Array
                 ) -> Tuple[HotState, WarmState, jax.Array, jax.Array]:
    """Invalidate every row of one tenant in both tiers.

    Returns (hot, warm, hot_evicted, warm_evicted) where the evicted
    arrays are capacity-sized value-id lists (-1 padding) for host GC.
    """
    h_kill = hot.valid & (hot.tenants == tenant)
    w_kill = warm.valid & (warm.tenants == tenant)
    h_ev = jnp.where(h_kill, hot.value_ids, -1)
    w_ev = jnp.where(w_kill, warm.value_ids, -1)
    return (hot._replace(valid=hot.valid & ~h_kill),
            warm._replace(valid=warm.valid & ~w_kill), h_ev, w_ev)


# ---------------------------------------------------------------------------
# TTL / staleness (DESIGN.md §14)
# ---------------------------------------------------------------------------

def mask_expired(hot: HotState, warm: WarmState, now: jax.Array
                 ) -> Tuple[HotState, WarmState, jax.Array]:
    """Plan-time staleness mask: views of both tiers with every expired
    row's ``valid`` bit cleared, so the cascade (fused or four-op,
    sharded or not — the mask is elementwise and precedes the lookup)
    can never serve a stale entry.  The underlying state is untouched;
    `reap_expired` frees the rows on the maintenance tick.  Returns
    (hot_view, warm_view, n_masked) where ``n_masked`` counts rows that
    were valid but past their deadline.
    """
    now = jnp.asarray(now, jnp.float32)
    h_live = hot.expires_at > now
    w_live = warm.expires_at > now
    n = (hot.valid & ~h_live).sum() + (warm.valid & ~w_live).sum()
    return (hot._replace(valid=hot.valid & h_live),
            warm._replace(valid=warm.valid & w_live),
            n.astype(jnp.int32))


def reap_expired(hot: HotState, warm: WarmState, now: jax.Array
                 ) -> Tuple[HotState, WarmState, jax.Array, jax.Array]:
    """Free every expired row in both tiers (the maintenance-tick side
    of TTL, mirroring `evict_tenant`'s contract).

    Returns (hot, warm, hot_reaped, warm_reaped) where the reaped
    arrays are capacity-sized value-id lists (-1 padding) for host GC.
    Works unchanged on the stacked (sharded) warm form.
    """
    now = jnp.asarray(now, jnp.float32)
    h_kill = hot.valid & (hot.expires_at <= now)
    w_kill = warm.valid & (warm.expires_at <= now)
    h_ev = jnp.where(h_kill, hot.value_ids, -1)
    w_ev = jnp.where(w_kill, warm.value_ids, -1)
    return (hot._replace(valid=hot.valid & ~h_kill),
            warm._replace(valid=warm.valid & ~w_kill), h_ev, w_ev)


# ---------------------------------------------------------------------------
# multi-embedder ensemble: E stacked key panels over the shared tiers
# ---------------------------------------------------------------------------

class EnsembleState(NamedTuple):
    """E row-aligned key panels over the base tiers (DESIGN.md §13).

    The base ``HotState``/``WarmState`` keep every per-slot column
    (valid/tenant/value-id/write-seq), the ring counters and the IVF;
    panel 0 (the *pilot*) duplicates the base key panels so routing,
    rebuilds and the §11 refresh machinery stay single-embedder.  The
    extra panels are the same rows under the other embedders — row
    alignment is maintained by mirroring every slot decision of the
    base mutation (`ensemble_hot_insert_batch`, `ensemble_warm_append`)
    rather than by permuting, which `warm_rebuild` never does.  In the
    sharded form the warm leaves gain a *leading* shard axis
    ((S, E, cap, D) keys — detected via ``warm_keys.ndim == 4``) while
    ``hot_keys`` stays replicated, mirroring the base tiers.
    """
    hot_keys: jax.Array      # (E, Nh, D) float32 unit-norm
    warm_keys: jax.Array     # (E, Nw, D) float32 unit-norm
    warm_keys_q: jax.Array   # (E, Nw, D) int8 per-row symmetric quant
    warm_scales: jax.Array   # (E, Nw) float32 dequant scales


class EnsembleResult(NamedTuple):
    """`CascadeResult` plus the top-1 candidate's per-embedder cosines
    (``panel_scores``, -1.0 on rows with no candidate) — the feedback
    loop's training signal for per-tenant mixture weights."""
    scores: jax.Array        # (Q, k) fused best-of-tiers, desc
    value_ids: jax.Array     # (Q, k) -1 where no candidate
    hot_slots: jax.Array     # (Q,)
    hot_hit: jax.Array       # (Q,)
    hit: jax.Array           # (Q,)
    panel_scores: jax.Array  # (Q, E) unweighted per-panel cosines


def init_ensemble(n_embedders: int, hot: HotState,
                  warm: WarmState) -> EnsembleState:
    """Broadcast the base key panels into E aligned copies (a fresh
    service starts all-zero; a warm start seeds every panel with the
    pilot keys until each embedder's `publish_panel` lands)."""
    E = n_embedders
    hk = jnp.broadcast_to(hot.keys[None], (E,) + hot.keys.shape) + 0.0
    if warm.keys.ndim == 3:          # sharded: (S, cap, D) -> (S, E, cap, D)
        exp = lambda x: jnp.broadcast_to(
            x[:, None], (x.shape[0], E) + x.shape[1:]) + 0
    else:
        exp = lambda x: jnp.broadcast_to(x[None], (E,) + x.shape) + 0
    return EnsembleState(hot_keys=hk, warm_keys=exp(warm.keys),
                         warm_keys_q=exp(warm.keys_q),
                         warm_scales=exp(warm.scales).astype(jnp.float32))


def make_ensemble(hot_panels: jax.Array,
                  warm_panels: jax.Array) -> EnsembleState:
    """Build an `EnsembleState` from raw stacked panels ((E, Nh, D) /
    (E, Nw, D); sharded warm accepts (S, E, Nw, D)): unit-normalize and
    quantize — the bulk-load constructor for tests and benches."""
    hk = _unit(hot_panels.astype(jnp.float32))
    wk = _unit(warm_panels.astype(jnp.float32))
    q8, sc = quantize_rows(wk)
    return EnsembleState(hot_keys=hk, warm_keys=wk,
                         warm_keys_q=q8, warm_scales=sc)


def place_ensemble_sharded(ens: EnsembleState, mesh,
                           axis: str = "model") -> EnsembleState:
    """Commit a stacked ensemble to the mesh: warm leaves sharded on
    their leading axis, the hot panels replicated (mirrors
    `place_warm_sharded`)."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    shard = lambda x: put(x, P(*((axis,) + (None,) * (x.ndim - 1))))
    return EnsembleState(hot_keys=put(ens.hot_keys, P()),
                         warm_keys=shard(ens.warm_keys),
                         warm_keys_q=shard(ens.warm_keys_q),
                         warm_scales=shard(ens.warm_scales))


def ensemble_hot_insert_batch(hot: HotState, ens: EnsembleState,
                              embs: jax.Array, value_ids: jax.Array,
                              tenants: jax.Array,
                              expires: jax.Array | None = None
                              ) -> Tuple[HotState, EnsembleState, jax.Array]:
    """`hot_insert_batch` with the E panels mirrored: embs is (B, E, D)
    (panel 0 = pilot).  Each step recomputes `_choose_slot` on the
    evolving hot state — the same deterministic choice `hot_insert`
    makes internally — and writes the full (E, D) row there, so the
    panels stay row-aligned with the base tier by construction.
    Returns (hot, ens, evicted (B,))."""
    if expires is None:
        expires = jnp.full(embs.shape[:1], jnp.inf, jnp.float32)

    def body(carry, xs):
        h, ehot = carry
        emb, vid, t, exp = xs                             # (E, D), (), ()
        slot = _choose_slot(h)
        h, ev = hot_insert(h, emb[0], vid, t, exp)
        en = _unit(emb.astype(jnp.float32))
        cur = ehot[:, slot]
        ehot = ehot.at[:, slot].set(jnp.where(vid < 0, cur, en))
        return (h, ehot), ev

    (hot, ehot), evicted = jax.lax.scan(
        body, (hot, ens.hot_keys), (embs, value_ids, tenants, expires))
    return hot, ens._replace(hot_keys=ehot), evicted


def ensemble_warm_append(ens: EnsembleState, warm: WarmState, dem: Demoted,
                         panel_keys: jax.Array) -> EnsembleState:
    """Mirror of `warm_append` for the stacked panels: the identical
    ring arithmetic from the *pre-append* warm state, applied to the
    (E, m, D) panel rows of the demoted batch (gathered by the caller
    via `coldest_slots` before the demote).  Call `warm_append` on the
    base state with the same ``dem`` alongside."""
    cap = warm.keys.shape[0]
    offs = jnp.cumsum(dem.mask.astype(jnp.int32)) - 1
    pos = (warm.cursor + offs) % cap
    dest = jnp.where(dem.mask, pos, cap)                  # cap = drop
    kn = _unit(panel_keys.astype(jnp.float32))            # (E, m, D)
    k8, sc = quantize_rows(kn)
    set_rows = jax.vmap(lambda p, v: p.at[dest].set(v, mode="drop"))
    return ens._replace(
        warm_keys=set_rows(ens.warm_keys, kn),
        warm_keys_q=set_rows(ens.warm_keys_q, k8),
        warm_scales=set_rows(ens.warm_scales, sc))


def ensemble_warm_append_sharded(ens: EnsembleState, warm: WarmState,
                                 dem: Demoted, panel_keys: jax.Array
                                 ) -> EnsembleState:
    """`warm_append_sharded`'s round-robin, mirrored onto the stacked
    panels: batch row j lands on shard ``j % shards`` exactly as the
    base append routes it, so per-shard row alignment is preserved."""
    shards = warm.keys.shape[0]
    m = dem.keys.shape[0]
    if m % shards:
        raise ValueError(f"demoted batch {m} not divisible by "
                         f"{shards} shards")
    dem = dem._replace(expires=_dem_expires(dem))

    def split(x):
        return jnp.swapaxes(x.reshape((m // shards, shards) + x.shape[1:]),
                            0, 1)

    dem_s = Demoted(*(split(x) for x in dem))
    pk_s = jnp.transpose(
        panel_keys.reshape(panel_keys.shape[0], m // shards, shards, -1),
        (2, 0, 1, 3))                                     # (S, E, m/S, D)

    def one(wk, wq, wsc, warm_i, dem_i, pk_i):
        sub = EnsembleState(hot_keys=ens.hot_keys, warm_keys=wk,
                            warm_keys_q=wq, warm_scales=wsc)
        sub = ensemble_warm_append(sub, warm_i, dem_i, pk_i)
        return sub.warm_keys, sub.warm_keys_q, sub.warm_scales

    wk, wq, wsc = jax.vmap(one)(ens.warm_keys, ens.warm_keys_q,
                                ens.warm_scales, warm, dem_s, pk_s)
    return ens._replace(warm_keys=wk, warm_keys_q=wq, warm_scales=wsc)


def publish_panel(ens: EnsembleState, e: int, hot_keys: jax.Array,
                  warm_keys: jax.Array) -> EnsembleState:
    """Atomically swap ONE embedder's key panels — the E-panel
    generalization of `publish_reembedded_keys` (DESIGN.md §13): with
    the panel's mixture weight at w, this IS A/B shadow serving of a
    candidate embedder during a §11 hot-swap.  Rows re-normalize and
    the int8 mirror requantizes in the same update; per-slot metadata
    and the pilot-built IVF are untouched.  Publishing panel 0 must go
    through `publish_reembedded_keys` on the base tiers as well — the
    pilot panel is a duplicate of ``hot.keys``/``warm.keys``."""
    hk = _unit(hot_keys.astype(jnp.float32))
    wk = _unit(warm_keys.astype(jnp.float32))
    q8, sc = quantize_rows(wk)
    if ens.warm_keys.ndim == 4:      # sharded warm leaves: (S, E, cap, D)
        return ens._replace(
            hot_keys=ens.hot_keys.at[e].set(hk),
            warm_keys=ens.warm_keys.at[:, e].set(wk),
            warm_keys_q=ens.warm_keys_q.at[:, e].set(q8),
            warm_scales=ens.warm_scales.at[:, e].set(sc))
    return ens._replace(
        hot_keys=ens.hot_keys.at[e].set(hk),
        warm_keys=ens.warm_keys.at[e].set(wk),
        warm_keys_q=ens.warm_keys_q.at[e].set(q8),
        warm_scales=ens.warm_scales.at[e].set(sc))


def _ensemble_ops(hot: HotState, warm: WarmState, ens: EnsembleState,
                  qe, w, qt, thr, k, n_probe, tail, use_kernel, quantized,
                  warm_block_n=None):
    """E-panel cascade through the kernel-package dispatch; returns the
    6-tuple (scores, vids, warm_slots, hot_slots, hot_hit, hit)."""
    from repro.kernels.cascade_lookup import ops as _casc_ops
    return _casc_ops.ensemble_lookup(
        qe, w, qt, thr, ens.hot_keys, hot.valid, hot.tenants, hot.value_ids,
        ens.warm_keys, warm.valid, warm.tenants, warm.value_ids,
        warm.write_seq, warm.centroids, warm.members, warm.cursor,
        warm.indexed_total, ens.warm_keys_q, ens.warm_scales,
        k=k, n_probe=n_probe, tail=tail, quantized=quantized,
        use_kernel=use_kernel, warm_block_n=warm_block_n)


def _rescore_exact_fused(qe, w, warm_panels, s, wslots):
    """Exact fp32 re-score of quantized-selected warm winners, per
    panel, re-fused with the same stacked contraction the scan used —
    O(Q·k·E·D) on the few selected rows (DESIGN.md §13)."""
    E = qe.shape[0]
    safe = jnp.clip(wslots, 0, warm_panels.shape[1] - 1)
    pans = [jnp.einsum("qd,qkd->qk", qe[e], warm_panels[e][safe])
            for e in range(E)]
    exact = jnp.einsum("qke,qe->qk", jnp.stack(pans, -1), w)
    return jnp.where(wslots >= 0, exact, s)


def _top1_panel_scores(qe, hot_panels, warm_winner_keys, wslot0, hslots,
                       has):
    """Per-embedder cosines of each query's merged top-1 candidate.

    ``warm_winner_keys`` is the (Q, E, D) gather of the winning warm
    rows (caller-side, since the sharded path gathers across shards);
    hot winners resolve through ``hslots`` — every hot candidate in a
    merge comes from the replicated hot tier, whose best row is always
    the hot top-1, so the slot is known whenever the winner is hot.
    """
    hsafe = jnp.clip(hslots, 0, hot_panels.shape[1] - 1)
    hkeys = jnp.swapaxes(hot_panels[:, hsafe], 0, 1)      # (Q, E, D)
    keys = jnp.where((wslot0 >= 0)[:, None, None], warm_winner_keys, hkeys)
    ps = jnp.einsum("eqd,qed->qe", qe, keys)
    return jnp.where(has[:, None], ps, -1.0)


def _shard_ensemble(hot: HotState, warm: WarmState, ens: EnsembleState,
                    qe, w, qt, thr, k, n_probe, tail, use_kernel, quantized,
                    shard_index, warm_block_n=None):
    """One shard's fused-ensemble candidates (mirrors `_shard_cascade`:
    hot attributed to shard 0, exact fused re-score before the merge).
    Returns (scores, vids, is_hot, hot_slots, warm_slots)."""
    hot = hot._replace(valid=hot.valid & (shard_index == 0))
    s, vids, wslots, hslots, _, _ = _ensemble_ops(
        hot, warm, ens, qe, w, qt, thr, k, n_probe, tail, use_kernel,
        quantized, warm_block_n)
    if quantized:
        s = _rescore_exact_fused(qe, w, ens.warm_keys, s, wslots)
    is_hot = ((wslots < 0) & (s > NEG / 2)).astype(jnp.int32)
    return s, vids, is_hot, hslots, wslots


def _ens_shard(ens: EnsembleState, i) -> EnsembleState:
    """Extract one shard's panel view ((S, E, …) -> (E, …)); hot panels
    are replicated, so only the warm leaves index."""
    return ens._replace(warm_keys=ens.warm_keys[i],
                        warm_keys_q=ens.warm_keys_q[i],
                        warm_scales=ens.warm_scales[i])


def _ensemble_sharded_oracle(hot, swarm, ens, qe, w, qt, thr, k, n_probe,
                             tail, use_kernel, quantized,
                             warm_block_n=None) -> EnsembleResult:
    """Single-device emulation of the sharded fused-ensemble schedule —
    the bit-exact oracle the shard_map path is tested against."""
    from repro.core.distrib import merge_stacked_topk
    shards = swarm.keys.shape[0]
    per = [_shard_ensemble(hot,
                           jax.tree_util.tree_map(lambda x, i=i: x[i], swarm),
                           _ens_shard(ens, i), qe, w, qt, thr, k, n_probe,
                           tail, use_kernel, quantized, i, warm_block_n)
           for i in range(shards)]
    Q = qe.shape[1]
    shard_cols = [jnp.full((Q, k), i, jnp.int32) for i in range(shards)]
    s, vids, is_hot, wslot, wshard = merge_stacked_topk(
        k, jnp.stack([p[0] for p in per]), jnp.stack([p[1] for p in per]),
        jnp.stack([p[2] for p in per]), jnp.stack([p[4] for p in per]),
        jnp.stack(shard_cols))
    hit = s[:, 0] >= thr
    hot_hit = hit & (is_hot[:, 0] != 0)
    hslots = per[0][3]
    cap = ens.warm_keys.shape[2]
    wsafe = jnp.clip(wslot[:, 0], 0, cap - 1)
    ssafe = jnp.clip(wshard[:, 0], 0, shards - 1)
    wwin = ens.warm_keys[ssafe, :, wsafe]                 # (Q, E, D)
    ps = _top1_panel_scores(qe, ens.hot_keys, wwin, wslot[:, 0], hslots,
                            vids[:, 0] >= 0)
    return EnsembleResult(scores=s, value_ids=vids, hot_slots=hslots,
                          hot_hit=hot_hit, hit=hit, panel_scores=ps)


def _ensemble_sharded(hot, swarm, ens, qe, w, qt, thr, k, n_probe, tail,
                      use_kernel, quantized, mesh, axis,
                      warm_block_n=None) -> EnsembleResult:
    """shard_map execution of the sharded fused ensemble: warm tiers
    and panel leaves split on their leading shard axis, hot panels and
    queries replicated, one (Q, k·shards) merge carrying (vid, is_hot,
    warm-slot, shard) payloads so the winner's panel keys can be
    gathered after the merge."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.distrib import merge_local_topk

    def local(hot_, swarm_, ewk_, ewq_, ewsc_, ehot_, qe_, w_, qt_, thr_):
        i = jax.lax.axis_index(axis)
        warm_local = jax.tree_util.tree_map(lambda x: x[0], swarm_)
        ens_local = EnsembleState(hot_keys=ehot_, warm_keys=ewk_[0],
                                  warm_keys_q=ewq_[0], warm_scales=ewsc_[0])
        s, vids, is_hot, hslots, wslots = _shard_ensemble(
            hot_, warm_local, ens_local, qe_, w_, qt_, thr_, k, n_probe,
            tail, use_kernel, quantized, i, warm_block_n)
        shard_col = jnp.full(s.shape, i, jnp.int32)
        sm, vm, hm, wm, cm = merge_local_topk(axis, k, s, vids, is_hot,
                                              wslots, shard_col)
        hit = sm[:, 0] >= thr_
        hot_hit = hit & (hm[:, 0] != 0)
        # only shard 0 computed real hot slots; psum broadcasts them
        hslot0 = jax.lax.psum(jnp.where(i == 0, hslots, 0), axis)
        return sm, vm, hslot0, hot_hit, hit, wm, cm

    rep = P()
    shard = lambda x: P(*((axis,) + (None,) * (x.ndim - 1)))
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: rep, hot),
                  jax.tree_util.tree_map(lambda _: P(axis), swarm),
                  shard(ens.warm_keys), shard(ens.warm_keys_q),
                  shard(ens.warm_scales), rep, rep, rep, rep, rep),
        out_specs=(rep,) * 7,
        check_rep=False)
    s, vids, hslots, hot_hit, hit, wslot, wshard = fn(
        hot, swarm, ens.warm_keys, ens.warm_keys_q, ens.warm_scales,
        ens.hot_keys, qe, w, qt, thr)
    shards = swarm.keys.shape[0]
    cap = ens.warm_keys.shape[2]
    wsafe = jnp.clip(wslot[:, 0], 0, cap - 1)
    ssafe = jnp.clip(wshard[:, 0], 0, shards - 1)
    wwin = ens.warm_keys[ssafe, :, wsafe]                 # (Q, E, D)
    ps = _top1_panel_scores(qe, ens.hot_keys, wwin, wslot[:, 0], hslots,
                            vids[:, 0] >= 0)
    return EnsembleResult(scores=s, value_ids=vids, hot_slots=hslots,
                          hot_hit=hot_hit, hit=hit, panel_scores=ps)


def ensemble_cascade_query(hot: HotState, warm: WarmState,
                           ens: EnsembleState, q: jax.Array,
                           weights: jax.Array, q_tenants: jax.Array,
                           thresholds: jax.Array, k: int = 1,
                           n_probe: int = 8, tail: int = 0,
                           fused: bool = False,
                           use_kernel: bool | None = None,
                           quantized: bool = False, mesh=None,
                           axis: str = "model",
                           warm_block_n: int | None = None
                           ) -> EnsembleResult:
    """Fused multi-embedder cascade lookup (DESIGN.md §13).

    q: (Q, E, D) — one embedding per embedder per query, panel 0 the
    pilot; weights: (Q, E) per-query mixture weights (host-resolved
    from the per-tenant policy table, like thresholds).  Execution
    paths, sharding detection, quantization semantics and
    ``warm_block_n`` all mirror `cascade_query`; scores everywhere are
    the weighted fused cosine, and routing runs once on the pilot
    panel against the base tier's (pilot-built) IVF.  The result adds
    ``panel_scores`` — the top-1 candidate's unweighted per-embedder
    cosines, which `feedback` records to learn the weights.
    """
    sharded = ens.warm_keys.ndim == 4
    if sharded != (warm.keys.ndim == 3):
        raise ValueError("ensemble/warm sharding mismatch: warm keys "
                         f"ndim {warm.keys.ndim}, ensemble warm ndim "
                         f"{ens.warm_keys.ndim}")
    if mesh is not None and not sharded:
        raise ValueError("ensemble_cascade_query(mesh=...) needs the "
                         "stacked (sharded) panels; see "
                         "place_ensemble_sharded")
    qe = jnp.swapaxes(_unit(q.astype(jnp.float32)), 0, 1)  # (E, Q, D)
    qt = q_tenants.astype(jnp.int32)
    thr = jnp.asarray(thresholds, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    uk = use_kernel if fused else False
    if sharded:
        if mesh is None:
            return _ensemble_sharded_oracle(hot, warm, ens, qe, w, qt, thr,
                                            k, n_probe, tail, uk, quantized,
                                            warm_block_n)
        return _ensemble_sharded(hot, warm, ens, qe, w, qt, thr, k, n_probe,
                                 tail, uk, quantized, mesh, axis,
                                 warm_block_n)
    s, vids, wslots, hslots, hot_hit, hit = _ensemble_ops(
        hot, warm, ens, qe, w, qt, thr, k, n_probe, tail, uk, quantized,
        warm_block_n)
    if quantized:
        # exact fused re-score may reorder the k selected candidates
        s = _rescore_exact_fused(qe, w, ens.warm_keys, s, wslots)
        s, idx = jax.lax.top_k(s, k)
        rows = jnp.arange(s.shape[0])[:, None]
        vids = vids[rows, idx]
        wslots = wslots[rows, idx]
        hit = s[:, 0] >= thr
        hot_hit = hit & (wslots[:, 0] < 0)
    cap = ens.warm_keys.shape[1]
    wsafe = jnp.clip(wslots[:, 0], 0, cap - 1)
    wwin = jnp.swapaxes(ens.warm_keys[:, wsafe], 0, 1)    # (Q, E, D)
    ps = _top1_panel_scores(qe, ens.hot_keys, wwin, wslots[:, 0], hslots,
                            vids[:, 0] >= 0)
    return EnsembleResult(scores=s, value_ids=vids, hot_slots=hslots,
                          hot_hit=hot_hit, hit=hit, panel_scores=ps)
