"""Typed construction surface for ``CacheService`` (DESIGN.md §14.4).

The service's constructor grew one keyword per subsystem PR — ~30 flat
kwargs by the time the ensemble landed — which made call sites
unreadable and validation ad hoc.  ``CacheConfig`` is the v2 surface:
a frozen dataclass of frozen **grouped sub-configs**, one per
subsystem, each validating its own fields at construction:

  * ``TieringConfig``   — hot/warm/cold capacities, IVF shape, flush
    cadence, fused/quantized/blockwise execution (§2–§4, §12)
  * ``ShardingConfig``  — mesh + axis of the sharded warm tier (§8)
  * ``LearningConfig``  — §9 admission learning, §11 embedder refresh,
    §14.3 conformal hit calibration
  * ``EnsembleConfig``  — §13 fused multi-embedder cascade
  * ``StalenessConfig`` — §14.2 TTL/staleness (default TTL + clock)

Field-level validation (ranges, enums) happens here in
``__post_init__``; *cross-subsystem* validation (cold×sharded,
ensemble×refresh, tail-window clamping) stays in ``CacheService``,
which owns those invariants.

The legacy flat-kwargs constructor maps onto this config through
``CacheConfig.from_kwargs`` and warns once per process; it is kept for
one release (see README migration table).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Union

from repro.cache_service.feedback import FeedbackConfig
from repro.cache_service.policy import ColdRoutingPolicy, EmbedderRefreshPolicy


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclass(frozen=True)
class TieringConfig:
    """Shape and cadence of the hot/warm/cold hierarchy (§2–§4, §12)."""
    hot_capacity: int = 1024
    warm_capacity: int = 16384
    n_clusters: int = 64
    bucket: int = 256
    n_probe: int = 8
    flush_watermark: float = 0.85
    flush_size: Optional[int] = None     # None -> hot_capacity // 4
    rebuild_every: int = 1
    kmeans_iters: int = 4
    fused: bool = False                  # Pallas cascade kernel (§3.1)
    background_rebuild: bool = False     # double-buffered IVF (§7.1)
    warm_dtype: str = "float32"          # "float32" | "int8" (§8.1)
    warm_block: Optional[int] = None     # blockwise fused scan (§12.5)
    cold_capacity: int = 0               # 0 = no cold tier (§12)
    cold_policy: Optional[ColdRoutingPolicy] = None

    def __post_init__(self) -> None:
        _require(self.hot_capacity > 0,
                 f"hot_capacity must be positive: {self.hot_capacity}")
        _require(self.warm_capacity > 0,
                 f"warm_capacity must be positive: {self.warm_capacity}")
        _require(self.n_clusters > 0 and self.bucket > 0,
                 f"n_clusters/bucket must be positive: "
                 f"{self.n_clusters}/{self.bucket}")
        _require(self.n_probe >= 1, f"n_probe must be >= 1: {self.n_probe}")
        _require(0.0 < self.flush_watermark <= 1.0,
                 f"flush_watermark must be in (0, 1]: "
                 f"{self.flush_watermark}")
        _require(self.flush_size is None or self.flush_size > 0,
                 f"flush_size must be positive: {self.flush_size}")
        _require(self.rebuild_every >= 1,
                 f"rebuild_every must be >= 1: {self.rebuild_every}")
        _require(self.warm_dtype in ("float32", "int8"),
                 f"warm_dtype must be float32|int8, got "
                 f"{self.warm_dtype!r}")
        _require(self.warm_block is None or self.warm_block > 0,
                 f"warm_block must be positive: {self.warm_block}")
        _require(self.cold_capacity >= 0,
                 f"cold_capacity must be >= 0: {self.cold_capacity}")


@dataclass(frozen=True)
class ShardingConfig:
    """Warm tier sharding over a device mesh axis (§8)."""
    mesh: Optional[object] = None        # jax.sharding.Mesh
    shard_axis: str = "model"

    def __post_init__(self) -> None:
        _require(bool(self.shard_axis), "shard_axis must be non-empty")


@dataclass(frozen=True)
class LearningConfig:
    """The online learning loops (§9, §11) and the §14.3 conformal
    hit-calibration band.

    ``conformal=True`` maintains a per-tenant recency window of
    observed *negative* (non-duplicate) scores and floors each
    tenant's serving threshold at the split-conformal quantile of that
    window — the learned threshold can drift under §9, but the floor
    guarantees the false-hit budget holds on the recent score
    distribution even mid-drift.  Requires no other learning flag; it
    shares the feedback accumulator with §9 when both are on.
    """
    learned_admission: bool = False
    feedback: Optional[FeedbackConfig] = None   # implies learned_admission
    conformal: bool = False              # §14.3 conformal threshold floor
    learned_embedder: bool = False
    embedder_trainer: Optional[object] = None
    embedder_tokenizer: Optional[object] = None
    refresh_policy: Optional[EmbedderRefreshPolicy] = None  # implies
    #                                      learned_embedder


@dataclass(frozen=True)
class EnsembleConfig:
    """Fused multi-embedder cascade (§13)."""
    embedders: Union[int, Sequence, None] = None   # E or handles
    weights: Optional[Sequence[float]] = None      # default mixture

    def __post_init__(self) -> None:
        if isinstance(self.embedders, int):
            _require(self.embedders > 0,
                     f"embedders must be positive: {self.embedders}")


@dataclass(frozen=True)
class StalenessConfig:
    """TTL/staleness eviction (§14.2).

    ``default_ttl`` (seconds, None = entries never expire unless the
    request says so) stamps every admitted row with
    ``now + ttl``; expired rows are masked out of every tier at plan
    time and reaped on the maintenance tick.  ``clock`` injects the
    time source — benches drive a logical clock through it so expiry
    is deterministic; None uses wall time (``time.time``).  Only
    *differences* of clock values matter: the service rebases all
    times to the clock's value at construction, because deadlines
    live in float32 device arrays where absolute epoch seconds would
    quantize to ~256s steps.
    """
    default_ttl: Optional[float] = None
    clock: Optional[Callable[[], float]] = None

    def __post_init__(self) -> None:
        _require(self.default_ttl is None or self.default_ttl > 0,
                 f"default_ttl must be positive: {self.default_ttl}")


@dataclass(frozen=True)
class CacheConfig:
    """The full typed construction surface of ``CacheService``."""
    dim: int
    topk: int = 1
    threshold: float = 0.85
    admission_margin: float = 0.0
    seed: int = 0
    telemetry: Optional[object] = None   # obs.Telemetry; None = default
    tiering: TieringConfig = field(default_factory=TieringConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    learning: LearningConfig = field(default_factory=LearningConfig)
    ensemble: EnsembleConfig = field(default_factory=EnsembleConfig)
    staleness: StalenessConfig = field(default_factory=StalenessConfig)

    def __post_init__(self) -> None:
        _require(self.dim > 0, f"dim must be positive: {self.dim}")
        _require(self.topk >= 1, f"topk must be >= 1: {self.topk}")
        _require(0.0 < self.threshold <= 1.0,
                 f"threshold must be in (0, 1]: {self.threshold}")
        _require(self.admission_margin >= 0.0,
                 f"admission_margin must be >= 0: {self.admission_margin}")

    # ------------------------------------------------------------------
    # legacy flat-kwargs mapping (one release; see README migration)
    # ------------------------------------------------------------------
    _TIERING_KEYS = ("hot_capacity", "warm_capacity", "n_clusters",
                     "bucket", "n_probe", "flush_watermark", "flush_size",
                     "rebuild_every", "kmeans_iters", "fused",
                     "background_rebuild", "warm_dtype", "warm_block",
                     "cold_capacity", "cold_policy")
    _LEARNING_KEYS = ("learned_admission", "conformal",
                      "learned_embedder", "embedder_trainer",
                      "embedder_tokenizer")
    _TOP_KEYS = ("topk", "threshold", "admission_margin", "seed",
                 "telemetry")

    @classmethod
    def from_kwargs(cls, dim: int, **kwargs) -> "CacheConfig":
        """Map the pre-v2 flat keyword surface onto the grouped config
        (the compatibility shim's engine; also handy for building a
        config from a flat flag namespace)."""
        top = {k: kwargs.pop(k) for k in cls._TOP_KEYS if k in kwargs}
        tiering = {k: kwargs.pop(k) for k in cls._TIERING_KEYS
                   if k in kwargs}
        learning = {k: kwargs.pop(k) for k in cls._LEARNING_KEYS
                    if k in kwargs}
        if "feedback_config" in kwargs:
            learning["feedback"] = kwargs.pop("feedback_config")
        if "refresh_policy" in kwargs:
            learning["refresh_policy"] = kwargs.pop("refresh_policy")
        sharding = {}
        if "mesh" in kwargs:
            sharding["mesh"] = kwargs.pop("mesh")
        if "shard_axis" in kwargs:
            sharding["shard_axis"] = kwargs.pop("shard_axis")
        ensemble = {}
        if "embedders" in kwargs:
            ensemble["embedders"] = kwargs.pop("embedders")
        if "ensemble_weights" in kwargs:
            ensemble["weights"] = kwargs.pop("ensemble_weights")
        staleness = {}
        if "default_ttl" in kwargs:
            staleness["default_ttl"] = kwargs.pop("default_ttl")
        if "clock" in kwargs:
            staleness["clock"] = kwargs.pop("clock")
        if kwargs:
            raise TypeError(
                f"unknown CacheService kwargs: {sorted(kwargs)} "
                "(see cache_service/config.py for the v2 surface)")
        return cls(dim=int(dim), **top,
                   tiering=TieringConfig(**tiering),
                   sharding=ShardingConfig(**sharding),
                   learning=LearningConfig(**learning),
                   ensemble=EnsembleConfig(**ensemble),
                   staleness=StalenessConfig(**staleness))
