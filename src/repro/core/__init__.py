"""The paper's primary contribution: the semantic cache — embedding
model + vector store + threshold policy — plus its training objective
(online contrastive loss), fine-tuning recipe, evaluation metrics, and
the synthetic data generation pipeline."""
from repro.core.cache import SemanticCache
from repro.core.losses import (
    contrastive_loss, cosine_distance, hard_pair_fractions,
    online_contrastive_loss,
)
from repro.core.metrics import (
    average_precision, metrics_at_threshold, pair_classification_metrics,
)
from repro.core.store import (
    QueryResult, StoreState, evict_older_than, init_store, insert,
    insert_batch, occupancy, query, store_axes, touch,
)
from repro.core.synth import (
    LLMGenerator, SynthRecord, TemplateGenerator, export_jsonl,
    generate_synthetic_pairs, import_jsonl, records_to_dataset,
)
from repro.core.trainer import EmbedderTrainer, FinetuneConfig
from repro.core.embedders import (
    EncoderEmbedder, HashNgramEmbedder, RandomProjectionEmbedder,
)
from repro.core.ivf import IVFState, build_ivf, ivf_occupancy, ivf_query
from repro.core.calibration import (
    Calibration, calibrate_for_false_hit_budget, calibrate_for_precision,
)

__all__ = [
    "SemanticCache", "contrastive_loss", "cosine_distance",
    "hard_pair_fractions", "online_contrastive_loss", "average_precision",
    "metrics_at_threshold", "pair_classification_metrics", "QueryResult",
    "StoreState", "evict_older_than", "init_store", "insert", "insert_batch",
    "occupancy", "query", "store_axes", "touch", "LLMGenerator",
    "SynthRecord", "TemplateGenerator", "export_jsonl",
    "generate_synthetic_pairs", "import_jsonl", "records_to_dataset",
    "EmbedderTrainer", "FinetuneConfig",
    "EncoderEmbedder", "HashNgramEmbedder", "RandomProjectionEmbedder",
    "IVFState", "build_ivf", "ivf_occupancy", "ivf_query",
    "Calibration", "calibrate_for_false_hit_budget",
    "calibrate_for_precision",
]
