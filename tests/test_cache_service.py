"""Tiered multi-tenant CacheService: cascade recall vs exact,
tenant isolation, admission, response GC, and the serving wiring."""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import commit_insert, plan_lookup

from repro.cache_service import CacheService, tiers
from repro.core.calibration import calibrate_for_false_hit_budget
from repro.core.embedders import HashNgramEmbedder
from repro.core.store import init_store, insert_batch, query
from repro.data import HashTokenizer, make_query_stream
from repro.serving import CachedLLMService

rng = np.random.default_rng(13)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _clustered(n_clusters=16, per=32, d=32, spread=0.12):
    cents = _unit(rng.standard_normal((n_clusters, d)).astype(np.float32))
    keys = np.repeat(cents, per, axis=0)
    return _unit(keys + spread * rng.standard_normal(keys.shape
                                                     ).astype(np.float32))


# ---------------------------------------------------------------------------
# tiered lookup equivalence vs flat brute force
# ---------------------------------------------------------------------------

def test_cascade_recall_matches_flat_exact():
    """Fill the service far past the hot capacity (most entries demoted
    into the warm IVF ring) and check the cascade reproduces the exact
    brute-force hit set on a clustered corpus."""
    keys = _clustered(n_clusters=16, per=32, d=32)
    N = len(keys)
    thr = 0.9
    svc = CacheService(dim=32, hot_capacity=64, warm_capacity=1024,
                       n_clusters=16, bucket=128, n_probe=6, threshold=thr,
                       flush_size=32, rebuild_every=2, kmeans_iters=6)
    for i in range(0, N, 32):
        commit_insert(svc, keys[i:i + 32],
                      [f"r{j}" for j in range(i, i + 32)])
    # most entries live in warm
    assert svc.stats_snapshot().tiers["demotions"] > N // 2

    q = _unit(keys + 0.02 * rng.standard_normal(keys.shape
                                                ).astype(np.float32))
    q_neg = _unit(rng.standard_normal((64, 32)).astype(np.float32))
    queries = np.concatenate([q, q_neg])

    flat = init_store(N, 32)
    flat = insert_batch(flat, jnp.asarray(keys), jnp.arange(N))
    exact = query(flat, jnp.asarray(queries), threshold=thr, k=1)
    exact_hit = np.asarray(exact.hit)

    hit, scores, values = plan_lookup(svc, queries)
    recall = (hit & exact_hit).sum() / max(exact_hit.sum(), 1)
    assert recall >= 0.95, recall
    # no spurious hits the exact store would miss
    assert not (hit & ~exact_hit).any()
    # every served value is live (never a GC'd placeholder)
    assert all(v is not None for v, h in zip(values, hit) if h)


def test_cascade_is_one_jitted_call_and_mixed_batches_dont_retrace():
    svc = CacheService(dim=16, hot_capacity=32, warm_capacity=128,
                       n_clusters=4, bucket=32)
    e = _unit(rng.standard_normal((8, 16)).astype(np.float32))
    commit_insert(svc, e, [f"r{i}" for i in range(8)], tenant=0)
    plan_lookup(svc, e, tenant=0)
    sizes = svc._lookup._cache_size()
    plan_lookup(svc, e, tenant=np.arange(8) % 3)   # mixed-tenant batch
    svc.set_tenant_policy(2, threshold=0.5)
    plan_lookup(svc, e, tenant=2)           # new per-tenant threshold
    assert svc._lookup._cache_size() == sizes   # same trace: no recompile


# ---------------------------------------------------------------------------
# tenant isolation
# ---------------------------------------------------------------------------

def test_cross_tenant_queries_never_hit():
    """Property-style sweep: identical keys inserted under tenant A must
    be invisible to tenant B, through demotion and ring wrap."""
    d = 24
    svc = CacheService(dim=d, hot_capacity=16, warm_capacity=64,
                       n_clusters=4, bucket=32, n_probe=4, threshold=0.8,
                       flush_size=8, rebuild_every=1)
    owner = {}
    for step in range(12):
        t = step % 3
        e = _unit(rng.standard_normal((8, d)).astype(np.float32))
        commit_insert(svc, e, [f"t{t}-{step}-{i}" for i in range(8)],
                      tenant=t)
        for row in e:
            owner[row.tobytes()] = t
        # every tenant queries every key ever inserted
        all_keys = np.asarray([np.frombuffer(b, np.float32)
                               for b in owner])
        for qt in range(3):
            hit, scores, values = plan_lookup(svc, all_keys, tenant=qt)
            for j, b in enumerate(owner):
                if owner[b] != qt:
                    assert not hit[j], (step, qt, j)
                if hit[j]:
                    assert values[j].startswith(f"t{qt}-")


def test_evict_tenant_between_plan_and_commit():
    """The plan/commit race: a tenant eviction landing between the two
    calls must neither resurrect freed value ids nor leak host response
    strings; hit responses stay valid (resolved at plan time)."""
    from repro.cache_service import CacheRequest

    d = 16
    svc = CacheService(dim=d, hot_capacity=32, warm_capacity=64,
                       n_clusters=4, bucket=32, threshold=0.9)
    e0 = _unit(rng.standard_normal((8, d)).astype(np.float32))
    commit_insert(svc, e0, [f"old{i}" for i in range(8)], tenant=0)

    fresh = _unit(rng.standard_normal((4, d)).astype(np.float32))
    q = np.concatenate([e0[:4], fresh])
    plan = svc.plan(CacheRequest.build(q, 0))
    assert plan.hit[:4].all() and not plan.hit[4:].any()
    assert all(r is not None for r in plan.responses[:4])

    assert svc.evict_tenant(0) == 8          # the race: plan is now stale
    receipt = svc.commit(plan, [None] * 4 + [f"new{i}" for i in range(4)])
    assert receipt.admitted == 4
    assert svc.stats_snapshot().traffic["stale_commits"] == 1
    # value ids 0..7 were freed; commit must have minted fresh ones only
    assert svc.responses and min(svc.responses) >= 8
    assert sorted(svc.responses.values()) == [f"new{i}" for i in range(4)]
    assert len(svc.responses) == len(svc)    # no leaked host strings
    # plan-time responses were already resolved, so the requests that
    # were promised a hit still got a real string (asserted above); but
    # the evicted keys themselves are gone from the device tiers
    hit, _, _ = plan_lookup(svc, e0, tenant=0)
    assert not hit.any()


def test_evict_tenant_only_touches_that_tenant():
    d = 16
    svc = CacheService(dim=d, hot_capacity=32, warm_capacity=64,
                       n_clusters=4, bucket=32, threshold=0.9)
    e0 = _unit(rng.standard_normal((4, d)).astype(np.float32))
    e1 = _unit(rng.standard_normal((4, d)).astype(np.float32))
    commit_insert(svc, e0, ["a"] * 4, tenant=0)
    commit_insert(svc, e1, ["b"] * 4, tenant=1)
    assert svc.evict_tenant(0) == 4
    assert not plan_lookup(svc, e0, tenant=0)[0].any()
    assert plan_lookup(svc, e1, tenant=1)[0].all()
    assert len(svc.responses) == 4


# ---------------------------------------------------------------------------
# admission + response GC
# ---------------------------------------------------------------------------

def test_admission_skips_well_covered_misses():
    d = 16
    svc = CacheService(dim=d, hot_capacity=32, warm_capacity=64,
                       n_clusters=4, bucket=32, threshold=0.95,
                       admission_margin=0.2)
    base = _unit(rng.standard_normal((1, d)).astype(np.float32))
    commit_insert(svc, base, ["orig"])
    orth = rng.standard_normal((1, d)).astype(np.float32)
    orth = _unit(orth - (orth @ base.T) * base)
    near = 0.85 * base + np.sqrt(1 - 0.85 ** 2) * orth  # cos(base,near)=.85
    hit, scores, _ = plan_lookup(svc, near)
    assert not hit[0] and scores[0] > 0.75  # miss, but well-covered
    admitted = commit_insert(svc, near, ["dup"], scores=scores)
    assert admitted == 0
    assert svc.stats_snapshot().admission["skipped"] == 1
    assert len(svc.responses) == 1          # no string leaked for the skip
    far = _unit(rng.standard_normal((1, d)).astype(np.float32))
    hit, scores, _ = plan_lookup(svc, far)
    assert commit_insert(svc, far, ["new"], scores=scores) == 1


def test_response_gc_bounds_host_memory():
    """Sustained traffic overwrites both tiers; the response dict must
    track live entries, not total inserts (the SemanticCache leak)."""
    d = 16
    hot_cap, warm_cap = 16, 32
    svc = CacheService(dim=d, hot_capacity=hot_cap, warm_capacity=warm_cap,
                       n_clusters=4, bucket=16, flush_size=8,
                       rebuild_every=1)
    total = 0
    for step in range(40):
        e = _unit(rng.standard_normal((8, d)).astype(np.float32))
        total += commit_insert(svc, e, [f"s{step}-{i}" for i in range(8)])
    assert total == 320
    assert len(svc.responses) <= hot_cap + warm_cap
    assert len(svc.responses) == len(svc)   # exactly the live entries
    assert svc.stats_snapshot().tiers["evictions"] == total - len(svc)


def test_manual_flushes_never_strand_entries_past_tail():
    """flush(rebuild=False) must not leave demoted rows beyond the tail
    window unreachable: the service forces a rebuild before the
    unindexed backlog outgrows the window."""
    d = 16
    svc = CacheService(dim=d, hot_capacity=32, warm_capacity=64,
                       n_clusters=4, bucket=32, threshold=0.9,
                       flush_size=8, rebuild_every=2)
    e = _unit(rng.standard_normal((32, d)).astype(np.float32))
    commit_insert(svc, e, [f"r{i}" for i in range(32)])
    for _ in range(4):
        svc.flush(rebuild=False)
    hit, _, _ = plan_lookup(svc, e)
    assert hit.all(), int(hit.sum())
    assert len(svc.responses) == len(svc)


def test_warm_ring_overwrite_reports_evictions():
    warm = tiers.init_warm(8, 4, n_clusters=2, bucket=4)
    e = jnp.asarray(_unit(np.eye(4, dtype=np.float32)))
    dem = tiers.Demoted(keys=jnp.tile(e, (2, 1)),
                        value_ids=jnp.arange(8, dtype=jnp.int32),
                        tenants=jnp.zeros(8, jnp.int32),
                        mask=jnp.ones(8, bool))
    warm, ev = tiers.warm_append(warm, dem)
    assert int((ev >= 0).sum()) == 0        # ring was empty
    dem2 = dem._replace(value_ids=jnp.arange(8, 16, dtype=jnp.int32))
    warm, ev = tiers.warm_append(warm, dem2)
    np.testing.assert_array_equal(np.sort(np.asarray(ev)), np.arange(8))


def test_warm_topk_no_duplicates_after_ring_wrap():
    """A slot overwritten after the last rebuild is reachable through a
    stale IVF member entry AND the tail window; it must be served once
    (the epoch partition), not fill two top-k ranks."""
    d = 4
    warm = tiers.init_warm(4, d, n_clusters=2, bucket=4)
    e = jnp.asarray(_unit(np.eye(4, dtype=np.float32)))

    def dem(rows, vids):
        m = len(vids)
        return tiers.Demoted(keys=e[jnp.asarray(rows)],
                             value_ids=jnp.asarray(vids, jnp.int32),
                             tenants=jnp.zeros(m, jnp.int32),
                             mask=jnp.ones(m, bool))

    warm, _ = tiers.warm_append(warm, dem([0, 1], [0, 1]))
    warm = tiers.warm_rebuild(warm, iters=2)       # slots 0,1 indexed
    # wrap the ring: slots 2,3 then 0,1 overwritten post-rebuild
    warm, _ = tiers.warm_append(warm, dem([2, 3, 0, 1], [2, 3, 4, 5]))
    q = e[:1]                                      # near slot 0's new row
    s, slots, vids = tiers.warm_query(warm, q, jnp.zeros(1, jnp.int32),
                                      k=2, n_probe=2, tail=4)
    live = np.asarray(vids[0])[np.asarray(s[0]) > -1e29]
    assert len(set(live.tolist())) == len(live), vids


# ---------------------------------------------------------------------------
# calibration fix + per-tenant thresholds
# ---------------------------------------------------------------------------

def test_calibrate_zero_negatives_no_crash():
    scores = np.asarray([0.7, 0.8, 0.9])
    labels = np.ones(3, np.int32)
    cal = calibrate_for_false_hit_budget(scores, labels)
    assert cal.false_hit_rate == 0.0
    assert cal.true_hit_rate == 1.0
    assert cal.threshold <= 0.7


def test_per_tenant_calibrated_thresholds():
    svc = CacheService(dim=8, hot_capacity=16, warm_capacity=32,
                       n_clusters=2, bucket=16, threshold=0.9)
    strict = rng.normal([0.0, 1.0], 0.1, (500, 2)).reshape(-1)
    labels = np.tile([0, 1], 500).astype(np.int32)
    cal = svc.calibrate_tenant(7, strict, labels, max_false_hit_rate=0.01)
    assert svc.policies.get(7).threshold == cal.threshold
    assert svc.policies.get(3).threshold == 0.9  # others keep the default


def test_calibrate_rescales_admission_margin_with_threshold():
    """Regression: PolicyTable.calibrate used to keep the stale
    admission_margin verbatim when the threshold moved, silently
    changing the band's width relative to the new operating point's
    paraphrase scale (TenantPolicy.with_threshold keeps
    margin/(1-threshold) constant)."""
    from repro.cache_service import PolicyTable, TenantPolicy

    table = PolicyTable(TenantPolicy(0.95, admission_margin=0.02))
    # scored pairs whose budgeted threshold lands well below 0.95
    scores = np.concatenate([rng.normal(0.6, 0.05, 400),
                             rng.normal(0.9, 0.03, 400)])
    labels = np.repeat([0, 1], 400).astype(np.int32)
    cal = table.calibrate(0, scores, labels, max_false_hit_rate=0.01)
    pol = table.get(0)
    assert pol.threshold == cal.threshold < 0.9
    expected = 0.02 * (1 - cal.threshold) / (1 - 0.95)
    assert pol.admission_margin == pytest.approx(expected)
    assert pol.admission_margin > 0.02       # looser point, wider band
    # relative width is preserved exactly
    assert pol.admission_margin / (1 - pol.threshold) \
        == pytest.approx(0.02 / (1 - 0.95))
    # degenerate old threshold ~1.0: no division blow-up, and the
    # safety caps keep the band from swallowing the score space — a
    # query with no similarity to the store must still be admitted
    t2 = PolicyTable(TenantPolicy(1.0, admission_margin=0.1))
    t2.calibrate(0, scores, labels)
    p2 = t2.get(0)
    assert 0.0 <= p2.admission_margin <= 0.5 * p2.threshold
    assert t2.admit_mask(np.zeros(1, np.int32),
                         np.zeros(1, np.float32))[0]


# ---------------------------------------------------------------------------
# serving wiring
# ---------------------------------------------------------------------------

def test_cached_service_with_tiered_backend():
    emb = HashNgramEmbedder(dim=256)
    cache = CacheService(dim=256, hot_capacity=128, warm_capacity=512,
                         n_clusters=8, bucket=128, threshold=0.80,
                         flush_size=32)
    svc = CachedLLMService(emb.embed, cache, engine=None,
                           tokenizer=HashTokenizer())
    stream = [q.text for q in make_query_stream("medical", 120, seed=0,
                                                repeat_frac=0.4)]
    for i in range(0, len(stream), 8):
        out = svc.handle(stream[i:i + 8])
        assert all(r.response is not None for r in out)
    st = svc.stats()
    assert st["hits"] + st["misses"] == 120
    assert st["hits"] > 8, st


def test_cached_service_tenants_are_isolated_end_to_end():
    emb = HashNgramEmbedder(dim=128)
    cache = CacheService(dim=128, hot_capacity=64, warm_capacity=128,
                         n_clusters=4, bucket=64, threshold=0.95)
    svc = CachedLLMService(emb.embed, cache, engine=None,
                           tokenizer=HashTokenizer())
    q = ["What are the symptoms of early stage diabetes?"]
    first = svc.handle(q, tenant=0)[0]
    assert not first.cache_hit
    assert svc.handle(q, tenant=0)[0].cache_hit          # same tenant hits
    assert not svc.handle(q, tenant=1)[0].cache_hit      # other tenant not


# ---------------------------------------------------------------------------
# the one-release flat-kwargs construction shim (CacheConfig is the v2
# surface; the v2.0-removed lookup/insert/stats shims must stay gone)
# ---------------------------------------------------------------------------

def test_flat_kwargs_shim_warns_exactly_once_per_process():
    """Legacy flat-kwargs construction warns on the first use and then
    never again in the process (the flag is class-level, not
    per-instance) — and the message points at the migration table so
    the one shot carries the whole story."""
    import warnings

    saved = CacheService._kwargs_warned
    try:
        CacheService._kwargs_warned = False
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            CacheService(dim=16, hot_capacity=8, warm_capacity=32,
                         n_clusters=2, bucket=16)
            # second construction, same process: silent
            CacheService(dim=16, hot_capacity=8, warm_capacity=32,
                         n_clusters=2, bucket=16)
        deps = [w for w in rec
                if issubclass(w.category, DeprecationWarning)
                and "CacheConfig" in str(w.message)]
        assert len(deps) == 1, [str(w.message) for w in deps]
    finally:
        CacheService._kwargs_warned = saved


def test_v2_removals_are_gone():
    """The deprecated surface announced for v2.0 must actually be
    removed: lookup/insert shims, the flat stats() view, and the
    LegacyStatsView helper class."""
    svc = CacheService(dim=16, hot_capacity=8, warm_capacity=32,
                       n_clusters=2, bucket=16)
    for name in ("lookup", "insert", "stats"):
        assert not hasattr(svc, name), name
    import repro.cache_service as cs
    assert not hasattr(cs, "LegacyStatsView")
