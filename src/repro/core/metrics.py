"""Pair-classification metrics: Precision / Recall / F1 / Accuracy / AP.

Mirrors sentence-transformers' BinaryClassificationEvaluator, which is
what the paper's Figures 1-2 and Table 1 report: accuracy at the best
accuracy threshold, P/R/F1 at the best-F1 threshold, plus average
precision over the full ranking.  Implemented in numpy on host (metric
computation is not a device hot path).
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def _metrics_at(scores: np.ndarray, labels: np.ndarray, thr: float):
    pred = scores >= thr
    tp = float(np.sum(pred & (labels == 1)))
    fp = float(np.sum(pred & (labels == 0)))
    fn = float(np.sum(~pred & (labels == 1)))
    tn = float(np.sum(~pred & (labels == 0)))
    precision = tp / (tp + fp) if tp + fp else 0.0
    recall = tp / (tp + fn) if tp + fn else 0.0
    f1 = (2 * precision * recall / (precision + recall)
          if precision + recall else 0.0)
    accuracy = (tp + tn) / max(len(labels), 1)
    return precision, recall, f1, accuracy


def average_precision(scores: np.ndarray, labels: np.ndarray) -> float:
    order = np.argsort(-scores, kind="stable")
    lab = labels[order]
    n_pos = int(lab.sum())
    if n_pos == 0:
        return 0.0
    tp_cum = np.cumsum(lab)
    k = np.arange(1, len(lab) + 1)
    precision_at_k = tp_cum / k
    return float(np.sum(precision_at_k * lab) / n_pos)


def pair_classification_metrics(scores, labels) -> Dict[str, float]:
    """scores: cosine similarities (N,); labels: 0/1 (N,).

    Returns {precision, recall, f1, accuracy, ap, f1_threshold,
    acc_threshold} with thresholds chosen on this set (the evaluator
    convention used by the paper's numbers).
    """
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels, np.int32)
    assert scores.shape == labels.shape

    # candidate thresholds: midpoints between sorted unique scores
    uniq = np.unique(scores)
    if len(uniq) > 1:
        cands = np.concatenate([[uniq[0] - 1e-6],
                                (uniq[:-1] + uniq[1:]) / 2,
                                [uniq[-1] + 1e-6]])
    else:
        cands = uniq
    best_f1, best_f1_thr = -1.0, 0.0
    best_acc, best_acc_thr = -1.0, 0.0
    best_p, best_r = 0.0, 0.0
    for thr in cands:
        p, r, f1, acc = _metrics_at(scores, labels, thr)
        if f1 > best_f1:
            best_f1, best_f1_thr, best_p, best_r = f1, float(thr), p, r
        if acc > best_acc:
            best_acc, best_acc_thr = acc, float(thr)
    return {
        "precision": best_p,
        "recall": best_r,
        "f1": best_f1,
        "accuracy": best_acc,
        "ap": average_precision(scores, labels),
        "f1_threshold": best_f1_thr,
        "acc_threshold": best_acc_thr,
    }


def metrics_at_threshold(scores, labels, threshold: float) -> Dict[str, float]:
    """Fixed-threshold metrics — what a deployed cache actually sees."""
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels, np.int32)
    p, r, f1, acc = _metrics_at(scores, labels, threshold)
    return {"precision": p, "recall": r, "f1": f1, "accuracy": acc,
            "threshold": threshold}
