"""Synthetic data generation pipeline (paper §2.1, Listings 1-2).

From *unlabeled* in-domain queries, generate:
  * positive samples  — paraphrases preserving intent (is_duplicate=1),
  * negative samples  — topically related but semantically distinct
                        queries (is_duplicate=0),
in one dual-labeling pass.

Two generator backends implement the Listing-1/Listing-2 contracts:

``TemplateGenerator``  (default, fully offline & deterministic): uses the
grammar metadata carried by :class:`repro.data.corpora.Query` — a
paraphrase re-renders the same (entity, aspect) with a different
template/synonyms; a distinct query keeps the entity but switches to a
different aspect ("different subtopics, perspectives, or medical
contexts", Listing 2).  This is the structural analogue of the paper's
Qwen2.5-32B prompting, with the LLM replaced by the grammar that defines
semantic equivalence in this repo (DESIGN.md §6).

``LLMGenerator``: drives an actual JAX decoder (any registry config, the
paper used qwen2.5-32b — which is an assigned backbone here) through the
serving engine with Listing-1/2-style prompts.  Offline weights are
random, so this backend demonstrates the *system* path (prompt → sample
→ parse → dual-label), not linguistic quality.
"""
from __future__ import annotations

import json
import zlib
from dataclasses import dataclass
from typing import List, Protocol, Sequence, Tuple

import numpy as np

from repro.data.corpora import (
    DOMAINS, PairDataset, Query, render_query,
)

PARAPHRASE_PROMPT = (
    "You are a helpful {domain} expert. Generate {n} unique paraphrases of "
    "the given query. Original Query: '{query}' Each paraphrase should "
    "preserve the original meaning but use different wording. Return JSON "
    "with a key 'queries'."
)
DISTINCT_PROMPT = (
    "You are a helpful {domain} expert. Given a query, generate {n} "
    "distinct but related queries that explore different aspects of the "
    "topic. They should not be rewordings. Return JSON with 'queries'."
)


class GeneratorBackend(Protocol):
    def paraphrases(self, q: Query, n: int) -> List[Query]: ...
    def distinct(self, q: Query, n: int) -> List[Query]: ...


class TemplateGenerator:
    """Deterministic grammar-backed generator (default backend).

    Determinism is per *call*, not per instance history: each
    ``paraphrases``/``distinct`` call derives a fresh RNG from the
    construction seed and a stable content hash of the query, so
    `generate_synthetic_pairs` is bit-reproducible for a fixed seed no
    matter how the caller orders or interleaves its queries.  (The
    original design threaded one stateful ``rng`` through every call,
    which made each sample depend on the entire preceding call history
    — iterate the same query set in a different order and every output
    changed.)
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)

    def _rng(self, q: Query, kind: str) -> np.random.Generator:
        key = f"{kind}|{q.domain}|{q.entity}|{q.aspect}|{q.text}"
        return np.random.default_rng(
            [self.seed, zlib.crc32(key.encode("utf-8"))])

    def paraphrases(self, q: Query, n: int) -> List[Query]:
        rng = self._rng(q, "paraphrase")
        out = []
        for _ in range(n):
            out.append(render_query(rng, q.domain, q.entity, q.aspect,
                                    exclude_template=q.template_idx))
        return out

    def distinct(self, q: Query, n: int) -> List[Query]:
        """Related-but-distinct negatives across *both* confusion axes:
        same entity with a different aspect ("different subtopics …",
        Listing 2) and a different entity asked through the same
        aspect's surface form.  A contrastive fit on aspect-swapped
        negatives alone never learns that the entity tokens carry the
        intent, and at serving time its false hits are exactly the
        same-aspect/different-entity neighbours."""
        rng = self._rng(q, "distinct")
        entities, aspects = DOMAINS[q.domain]
        other_aspects = [a for a in aspects if a != q.aspect]
        other_entities = [e for e in entities if e != q.entity]
        out = []
        for _ in range(n):
            entity, aspect = q.entity, q.aspect
            if other_entities and rng.random() < 0.25:
                entity = str(rng.choice(other_entities))
            else:
                aspect = str(rng.choice(other_aspects))
            out.append(render_query(rng, q.domain, entity, aspect))
        return out


class LLMGenerator:
    """LLM-driven backend over the serving engine (system-path demo)."""

    def __init__(self, engine, tokenizer, max_new_tokens: int = 24,
                 seed: int = 0):
        self.engine = engine
        self.tok = tokenizer
        self.max_new = max_new_tokens
        self.seed = seed

    def _gen(self, prompt_tpl: str, q: Query, n: int) -> List[Query]:
        prompt = prompt_tpl.format(domain=q.domain, n=n, query=q.text)
        ids, _ = self.tok.encode_batch([prompt] * n, 48)
        res = self.engine.generate(ids, self.max_new, temperature=1.0,
                                   seed=self.seed)
        out = []
        for row in res.tokens:
            text = " ".join(f"tok{t}" for t in row[:12])
            out.append(Query(text, q.domain, q.entity, q.aspect, -1))
        return out

    def paraphrases(self, q: Query, n: int) -> List[Query]:
        return self._gen(PARAPHRASE_PROMPT, q, n)

    def distinct(self, q: Query, n: int) -> List[Query]:
        return self._gen(DISTINCT_PROMPT, q, n)


@dataclass
class SynthRecord:
    question1: str
    question2: str
    is_duplicate: int
    domain: str
    kind: str  # 'paraphrase' | 'distinct'


def generate_synthetic_pairs(unlabeled: Sequence[Query],
                             backend: GeneratorBackend,
                             n_pos: int = 2, n_neg: int = 2
                             ) -> List[SynthRecord]:
    """The dual-labeling pass: every unlabeled query yields both
    paraphrase positives and related-but-distinct negatives."""
    records: List[SynthRecord] = []
    for q in unlabeled:
        for p in backend.paraphrases(q, n_pos):
            records.append(SynthRecord(q.text, p.text, 1, q.domain,
                                       "paraphrase"))
        for d in backend.distinct(q, n_neg):
            records.append(SynthRecord(q.text, d.text, 0, q.domain,
                                       "distinct"))
    return records


def records_to_dataset(records: Sequence[SynthRecord]) -> PairDataset:
    return PairDataset(
        q1=[r.question1 for r in records],
        q2=[r.question2 for r in records],
        labels=np.asarray([r.is_duplicate for r in records], np.int32),
        domain=records[0].domain if records else "synthetic",
    )


def export_jsonl(records: Sequence[SynthRecord], path: str) -> None:
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r.__dict__) + "\n")


def import_jsonl(path: str) -> List[SynthRecord]:
    out = []
    with open(path) as f:
        for line in f:
            out.append(SynthRecord(**json.loads(line)))
    return out
