"""Unit tests of the model-zoo mixers against naive references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.attention import gqa_attention
from repro.models.param import Initializer, split

rng = np.random.default_rng(3)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def test_chunked_equals_dense_attention():
    B, S, H, KV, hd = 2, 96, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    pos = jnp.arange(S)
    dense = gqa_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                          window=0, chunked=False)
    chunked = gqa_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                            window=0, chunked=True)
    unrolled = gqa_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                             window=0, chunked=True, unroll=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(unrolled),
                               atol=1e-5)


def test_local_window_attention_matches_masked():
    """Structural block-local windowed attention (§Perf lever) equals
    the masked-dense reference for ragged shapes."""
    from repro.models.attention import local_window_attention
    for (S, W, C) in [(64, 8, 16), (100, 16, 32), (130, 32, 32)]:
        B, H, KV, hd = 2, 4, 2, 16
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
        pos = jnp.arange(S)
        ref = gqa_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                            window=W, chunked=False)
        out = local_window_attention(q, k, v, positions=pos, window=W,
                                     causal=True, q_chunk=C)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   atol=1e-5)


def test_window_equals_truncated_context():
    """With window W, position i attends exactly to (i-W, i]."""
    B, S, H, hd, W = 1, 32, 2, 8, 5
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    pos = jnp.arange(S)
    out = gqa_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        window=W, chunked=False)
    # brute force for the last position
    i = S - 1
    lo = i - W + 1
    qq, kk, vv = q[:, i:i + 1], k[:, lo:i + 1], v[:, lo:i + 1]
    ref = gqa_attention(qq, kk, vv, q_pos=pos[i:i + 1], kv_pos=pos[lo:i + 1],
                        causal=True, window=0, chunked=False)
    np.testing.assert_allclose(np.asarray(out[:, i]), np.asarray(ref[:, 0]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# mamba
# ---------------------------------------------------------------------------

def _naive_selective_scan(A_bar, Bx, C):
    """Sequential reference recurrence."""
    B, S, d_in, N = A_bar.shape
    h = np.zeros((B, d_in, N), np.float32)
    ys = np.zeros((B, S, d_in), np.float32)
    for t in range(S):
        h = np.asarray(A_bar[:, t]) * h + np.asarray(Bx[:, t])
        ys[:, t] = (h * np.asarray(C[:, t])[:, None, :]).sum(-1)
    return ys, h


def test_mamba_chunk_scan_equals_naive():
    B, S, d_in, N = 2, 40, 8, 4
    A_bar = jnp.asarray(rng.random((B, S, d_in, N)) * 0.9, jnp.float32)
    Bx = jnp.asarray(rng.standard_normal((B, S, d_in, N)), jnp.float32)
    h0 = jnp.zeros((B, d_in, N), jnp.float32)
    # chunked via the library helper across 4 chunks
    chunk = 10
    h = h0
    outs = []
    for i in range(0, S, chunk):
        h_all, h = mamba_lib._chunk_scan(A_bar[:, i:i + chunk],
                                         Bx[:, i:i + chunk], h)
        outs.append(h_all)
    h_all = jnp.concatenate(outs, axis=1)
    C = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    ys = jnp.einsum("bsdn,bsn->bsd", h_all, C)
    ys_ref, h_ref = _naive_selective_scan(A_bar, Bx, C)
    np.testing.assert_allclose(np.asarray(ys), ys_ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=1e-4)


def test_mamba_full_matches_stepwise():
    cfg = get_config("jamba-1.5-large-398b").reduced()
    ini = Initializer(jax.random.PRNGKey(0))
    p_tree = mamba_lib.init_mamba(ini, cfg)
    pv, _ = split(p_tree)
    x = jnp.asarray(rng.standard_normal((1, 12, cfg.d_model)), jnp.float32)
    y_full, state_f = mamba_lib.apply_full(pv, cfg, x, return_state=True)
    state = mamba_lib.init_state(cfg, 1)
    ys = []
    for t in range(12):
        y_t, state = mamba_lib.apply_decode(pv, cfg, x[:, t:t + 1], state)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_f["h"]),
                               np.asarray(state["h"]), atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _tiny_moe_cfg(capacity_factor=8.0):
    return ModelConfig(
        name="tiny-moe", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=32, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=32,
                      capacity_factor=capacity_factor))


def test_moe_matches_dense_dispatch_reference():
    """Sort-based capacity dispatch == dense one-hot dispatch when
    capacity is ample."""
    cfg = _tiny_moe_cfg()
    ini = Initializer(jax.random.PRNGKey(1))
    pv, _ = split(moe_lib.init_moe(ini, cfg))
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    y, aux = moe_lib.apply_moe(pv, cfg, x)

    # dense reference: every expert on every token, weighted by gates
    m = cfg.moe
    xf = x.reshape(-1, 16)
    logits = xf @ pv["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eid = jax.lax.top_k(probs, m.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xf, pv["w_gate"]))
    u = jnp.einsum("td,edf->tef", xf, pv["w_up"])
    per_expert = jnp.einsum("tef,efd->ted", g * u, pv["w_down"])
    w = jnp.zeros((xf.shape[0], m.num_experts)).at[
        jnp.arange(xf.shape[0])[:, None], eid].set(gate)
    y_ref = jnp.einsum("te,ted->td", w, per_expert).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_moe_capacity_drops_tokens():
    """With capacity_factor -> tiny, overloaded experts drop tokens (the
    dropped tokens contribute zero, not garbage)."""
    cfg = _tiny_moe_cfg(capacity_factor=0.1)  # capacity floor = 8
    ini = Initializer(jax.random.PRNGKey(1))
    pv, _ = split(moe_lib.init_moe(ini, cfg))
    x = jnp.asarray(rng.standard_normal((4, 32, 16)), jnp.float32)
    y, aux = moe_lib.apply_moe(pv, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0


def test_moe_aux_penalises_imbalance():
    cfg = _tiny_moe_cfg()
    ini = Initializer(jax.random.PRNGKey(2))
    pv, _ = split(moe_lib.init_moe(ini, cfg))
    # force the router towards expert 0
    pv_skew = dict(pv)
    pv_skew["router"] = pv["router"].at[:, 0].add(10.0)
    x = jnp.asarray(rng.standard_normal((2, 16, 16)), jnp.float32)
    _, aux_bal = moe_lib.apply_moe(pv, cfg, x)
    _, aux_skew = moe_lib.apply_moe(pv_skew, cfg, x)
    assert float(aux_skew) > float(aux_bal)
