"""Online per-tenant admission/threshold learning (DESIGN.md §9).

PR 1 froze each tenant's operating point into a static
``TenantPolicy(threshold, admission_margin)`` fit once from *offline*
pairs.  The serving loop meanwhile observes every signal that offline
fit was a proxy for — plan-time scores, hit/miss verdicts, and (at
commit) whether a generated miss response turned out identical to its
nearest stored neighbour's — and threw them away.  This module closes
the loop:

  * ``FeedbackAccumulator`` ingests the stream: a per-tenant fixed-size
    reservoir (Vitter's algorithm R, uniform over the tenant's whole
    history) of ``(score, duplicate)`` events, where *score* is the
    best same-tenant score the plan observed for a miss row and
    *duplicate* is the commit-time verdict — the generated response
    matched the stored neighbour's response exactly.  A duplicate that
    was nevertheless admitted is a **wasted admission** (the stored
    neighbour would have served its paraphrases).
  * ``fit()`` re-derives the tenant's threshold and admission margin
    from its own reservoir, reusing ``core/calibration.py``'s
    estimators on live data: ``calibrate_for_false_hit_budget`` maps
    the labeled scores to the loosest threshold inside the false-hit
    budget, and ``calibrate_for_precision`` finds the score above
    which observed misses are duplicates with high precision — the
    admission margin is the gap between the two.

Hysteresis — thresholds must never thrash (``PolicyTable.refit`` runs
on every ``maintenance()`` idle tick):

  * **min-samples / class balance**: no fit below ``min_samples``
    events or ``min_class`` events of either verdict.
  * **refit interval**: a tenant is only re-examined after
    ``refit_interval`` *new* events since its last examination.
  * **max-step**: one refit moves the threshold at most ``max_step``;
    drift is tracked over several refits, never jumped.
  * **monotone false-hit-budget guard**: a refit never *loosens* the
    threshold past the budgeted quantile of observed negatives, and a
    loosening that would breach the observed false-hit budget is
    refused outright.
  * **duplicate-support floor**: loosening stops at the score that
    already captures ``dup_coverage`` of observed duplicates — below
    it there is no observed duplicate mass to convert into hits, only
    unobserved false-hit risk (hit rows are never re-labeled online,
    so the region far under the threshold is censored).

Every decision — applied or refused, with the reason — is recorded as
a ``RefitReport`` in ``refit_log`` so the learned state is inspectable
through ``stats()`` and testable under the batcher's idle tick.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.cache_service.policy import TenantPolicy
from repro.core.calibration import (
    calibrate_for_false_hit_budget, calibrate_for_precision,
)
from repro.data.corpora import PairDataset


@dataclass(frozen=True)
class FeedbackConfig:
    """Knobs of the online learning loop; defaults are sized for the
    smoke-scale streams this repo serves (hundreds-to-thousands of
    events per tenant)."""
    reservoir: int = 1024        # per-tenant event capacity
    min_samples: int = 64        # no fit below this many events
    min_class: int = 8           # ... or this many of either verdict
    refit_interval: int = 64     # new events between examinations
    max_step: float = 0.02      # max threshold move per refit
    max_false_hit_rate: float = 0.01   # the budget the guard enforces
    dup_precision: float = 0.9   # P(duplicate | score >= cut) target
    dup_coverage: float = 0.95   # loosening floor: keep this dup mass
    max_margin: float = 0.25     # admission band width cap
    refit_log_cap: int = 512     # most recent decisions kept
    pair_reservoir: int = 2048   # pooled labeled text pairs kept (§11)
    # §13 mixture-weight learning (fused multi-embedder ensemble): a
    # closed-form ridge regression of the duplicate verdict on the
    # per-embedder scores, under the same hysteresis discipline as the
    # threshold refits (min_samples / min_class / refit_interval above
    # apply to the ensemble reservoirs too)
    weight_lambda: float = 0.05  # ridge regularizer (units of n events)
    max_weight_step: float = 0.1  # max per-component weight move / refit
    # §14.3 conformal hit calibration: a per-tenant *recency window*
    # (ring, newest-wins — deliberately not a reservoir: under drift
    # the recent negative-score distribution is the one the budget
    # must hold on) of observed negative (non-duplicate) scores.  The
    # split-conformal floor is the ceil((n+1)(1-alpha))-th order
    # statistic of the window: serving only above it bounds the
    # false-hit rate on exchangeable recent negatives by alpha.
    conformal_window: int = 256  # per-tenant recent negatives kept
    conformal_min: int = 64      # no floor below this many samples
    conformal_alpha: Optional[float] = None  # None -> max_false_hit_rate
    seed: int = 0


@dataclass(frozen=True)
class RefitReport:
    """One refit decision for one tenant (applied or refused)."""
    tenant: int
    applied: bool
    reason: str                  # "ok" | "min-samples" | "class-starved"
    #                            | "interval" | "budget-guard" | "no-change"
    old_threshold: float
    new_threshold: float
    old_margin: float
    new_margin: float
    step_clamped: bool = False   # max_step truncated the move
    n_events: int = 0
    n_duplicates: int = 0
    false_hit_rate: float = 0.0  # observed, at the published threshold


@dataclass(frozen=True)
class WeightRefitReport:
    """One mixture-weight refit decision for one tenant (§13)."""
    tenant: int
    applied: bool
    reason: str                  # "ok" | "min-samples" | "class-starved"
    #                            | "interval" | "degenerate" | "no-change"
    old_weights: Tuple[float, ...]
    new_weights: Tuple[float, ...]
    old_threshold: float = 0.0
    new_threshold: float = 0.0   # recalibrated against the fused score
    step_clamped: bool = False   # max_weight_step truncated the move
    n_events: int = 0
    n_duplicates: int = 0


class EnsembleReservoir:
    """Fixed-capacity uniform sample of one tenant's
    ``(per-embedder scores (E,), duplicate)`` events — algorithm R,
    the §13 analogue of `TenantReservoir` with a score *vector* per
    event (the plan's ``panel_scores`` row for a committed miss)."""

    def __init__(self, capacity: int, n_embedders: int,
                 rng: np.random.Generator):
        self.capacity = int(capacity)
        self.scores = np.zeros((self.capacity, int(n_embedders)),
                               np.float32)
        self.labels = np.zeros(self.capacity, np.int8)
        self.fill = 0
        self.seen = 0
        self._rng = rng

    def add(self, scores: np.ndarray, duplicate: bool) -> None:
        self.seen += 1
        if self.fill < self.capacity:
            i = self.fill
            self.fill += 1
        else:
            i = int(self._rng.integers(self.seen))
            if i >= self.capacity:
                return
        self.scores[i] = np.clip(np.asarray(scores, np.float32), -1.0, 1.0)
        self.labels[i] = 1 if duplicate else 0

    def arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        return self.scores[:self.fill], self.labels[:self.fill]


class ConformalWindow:
    """Per-tenant recency ring of observed **negative** scores — the
    calibration set of the §14.3 split-conformal threshold floor.

    A ring, not a reservoir: reservoirs keep every era of a drifting
    stream represented (exactly what §9's estimators want), but the
    conformal guarantee must hold on the *current* score distribution,
    so the window keeps only the newest ``capacity`` negatives and
    ages the old era out as drift feeds new ones in."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self.scores = np.zeros(self.capacity, np.float32)
        self.fill = 0
        self._pos = 0
        self.seen = 0

    def add(self, score: float) -> None:
        self.scores[self._pos] = np.clip(score, -1.0, 1.0)
        self._pos = (self._pos + 1) % self.capacity
        self.fill = min(self.fill + 1, self.capacity)
        self.seen += 1

    def floor(self, alpha: float) -> float:
        """The split-conformal threshold floor at miscoverage
        ``alpha``: the ceil((n+1)(1-alpha))-th smallest window score
        (clamped to the max for tiny alpha), nudged by an epsilon so
        a score *equal* to the quantile still counts as a negative.
        Serving hits only at scores >= floor bounds the false-hit
        rate on exchangeable recent negatives by alpha."""
        n = self.fill
        s = np.sort(self.scores[:n])
        rank = min(int(np.ceil((n + 1) * (1.0 - alpha))), n)
        return float(s[rank - 1]) + 1e-6


class TenantReservoir:
    """Fixed-capacity uniform sample of one tenant's (score, duplicate)
    events — algorithm R, so a drifting stream keeps every era
    represented proportionally."""

    def __init__(self, capacity: int, rng: np.random.Generator):
        self.capacity = int(capacity)
        self.scores = np.zeros(self.capacity, np.float32)
        self.labels = np.zeros(self.capacity, np.int8)
        self.fill = 0
        self.seen = 0
        self._rng = rng

    def add(self, score: float, duplicate: bool) -> None:
        self.seen += 1
        if self.fill < self.capacity:
            i = self.fill
            self.fill += 1
        else:
            i = int(self._rng.integers(self.seen))
            if i >= self.capacity:
                return
        self.scores[i] = np.clip(score, -1.0, 1.0)
        self.labels[i] = 1 if duplicate else 0

    def arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        return self.scores[:self.fill], self.labels[:self.fill]


class PairReservoir:
    """Fixed-capacity uniform sample of labeled **text** pairs pooled
    across tenants — the same algorithm-R discipline as
    `TenantReservoir`, but keeping ``(query, stored neighbour,
    duplicate?)`` strings instead of scores.  These are exactly the
    contrastive pairs the paper fine-tunes on; the §11 embedder refresh
    trains on a split of this reservoir and holds the rest out for its
    eval gate."""

    def __init__(self, capacity: int, rng: np.random.Generator):
        self.capacity = int(capacity)
        self.items: List[Tuple[str, str, int]] = []
        self.seen = 0
        self._rng = rng

    def add(self, query: str, neighbour: str, duplicate: bool) -> None:
        self.seen += 1
        item = (str(query), str(neighbour), 1 if duplicate else 0)
        if len(self.items) < self.capacity:
            self.items.append(item)
        else:
            i = int(self._rng.integers(self.seen))
            if i < self.capacity:
                self.items[i] = item

    def __len__(self) -> int:
        return len(self.items)

    @property
    def n_pos(self) -> int:
        return sum(lab for _, _, lab in self.items)

    @property
    def n_neg(self) -> int:
        return len(self.items) - self.n_pos

    def split(self, eval_frac: float = 0.25,
              seed: int = 0) -> Tuple[PairDataset, PairDataset]:
        """Deterministic shuffled (train, eval) split of the current
        sample.  The permutation is keyed on ``seed`` alone, so the
        same reservoir state always yields the same split — the eval
        gate judges every candidate embedder on the same held-out
        slice it was denied at training time."""
        n = len(self.items)
        perm = np.random.default_rng(seed).permutation(n)
        n_eval = int(np.ceil(n * eval_frac)) if n else 0
        ev, tr = perm[:n_eval], perm[n_eval:]

        def ds(idx: np.ndarray) -> PairDataset:
            return PairDataset(
                q1=[self.items[i][0] for i in idx],
                q2=[self.items[i][1] for i in idx],
                labels=np.asarray([self.items[i][2] for i in idx],
                                  np.int32),
                domain="feedback")

        return ds(tr), ds(ev)


class FeedbackAccumulator:
    """The online learning half of the admission policy: ingests the
    serving stream per tenant, answers ``refit_due()`` for the
    maintenance tick, and ``fit()``s one tenant's policy on demand
    (``PolicyTable.refit`` drives it over every due tenant)."""

    def __init__(self, config: Optional[FeedbackConfig] = None):
        self.config = config or FeedbackConfig()
        self._rng = np.random.default_rng(self.config.seed)
        self._res: Dict[int, TenantReservoir] = {}
        self.pairs = PairReservoir(self.config.pair_reservoir, self._rng)
        self._seen_at_fit: Dict[int, int] = {}
        self._ens: Dict[int, EnsembleReservoir] = {}        # §13
        self._ens_seen_at_fit: Dict[int, int] = {}
        self._conf: Dict[int, ConformalWindow] = {}         # §14.3
        self.refit_log: List[RefitReport] = []
        self.weight_refit_log: List[WeightRefitReport] = []
        self.counters = {
            "events": 0, "duplicate_events": 0, "wasted_admissions": 0,
            "plan_hits": 0, "plan_misses": 0, "pair_events": 0,
            "refits_applied": 0, "refits_skipped": 0,
            "ensemble_events": 0, "weight_refits_applied": 0,
            "weight_refits_skipped": 0,
            "hit_audits": 0, "audited_false_hits": 0,
        }

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def observe_plan(self, hit: np.ndarray) -> None:
        """Plan-time verdict counters (hit rows are served uninspected,
        so they only feed observability, never the reservoir)."""
        hit = np.asarray(hit, bool)
        self.counters["plan_hits"] += int(hit.sum())
        self.counters["plan_misses"] += int((~hit).sum())

    def observe(self, tenant: int, score: float, duplicate: bool,
                admitted: bool, text: Optional[str] = None,
                neighbour_text: Optional[str] = None) -> None:
        """One commit-time miss event; a duplicate that was admitted
        anyway counts as a wasted admission.  When the caller also has
        the query/neighbour *texts* in hand (the §11 embedder loop),
        the labeled pair feeds the pooled text reservoir."""
        t = int(tenant)
        res = self._res.get(t)
        if res is None:
            res = self._res[t] = TenantReservoir(self.config.reservoir,
                                                 self._rng)
        res.add(float(score), bool(duplicate))
        self.counters["events"] += 1
        if text is not None and neighbour_text is not None:
            self.pairs.add(text, neighbour_text, duplicate)
            self.counters["pair_events"] += 1
        if duplicate:
            self.counters["duplicate_events"] += 1
            if admitted:
                self.counters["wasted_admissions"] += 1
        else:
            self._conf_add(t, float(score))

    def observe_ensemble(self, tenant: int, panel_scores: np.ndarray,
                         duplicate: bool) -> None:
        """One commit-time miss event on the ensemble path (§13): the
        plan's unweighted per-embedder cosines of the row's best
        same-tenant candidate, labeled with the duplicate verdict.
        Rows with no candidate (all-(-1) panel scores) never reach here
        — a constant row teaches the ridge nothing about mixing."""
        t = int(tenant)
        res = self._ens.get(t)
        if res is None:
            res = self._ens[t] = EnsembleReservoir(
                self.config.reservoir, len(panel_scores), self._rng)
        res.add(panel_scores, bool(duplicate))
        self.counters["ensemble_events"] += 1

    def observe_hit_audit(self, tenant: int, score: float,
                          duplicate: bool) -> None:
        """Post-hoc audit of a *served hit* (§14.3): the response
        equality check ran offline (async audit pipeline, or the bench
        generator's ground truth) and labeled the served answer.  The
        §9 miss stream is censored above the threshold — hit rows are
        served uninspected — so without this channel the conformal
        window can never learn that scores *above* the current
        threshold are producing false hits, which is exactly the drift
        failure mode the floor exists to stop.  A false hit feeds the
        window as a fresh negative (raising the floor); a confirmed
        duplicate is a true hit and feeds nothing."""
        self.counters["hit_audits"] += 1
        if not duplicate:
            self.counters["audited_false_hits"] += 1
            self._conf_add(int(tenant), float(score))

    def _conf_add(self, tenant: int, score: float) -> None:
        win = self._conf.get(tenant)
        if win is None:
            win = self._conf[tenant] = ConformalWindow(
                self.config.conformal_window)
        win.add(score)

    def conformal_floor(self, tenant: int) -> Optional[float]:
        """This tenant's §14.3 split-conformal threshold floor, or
        None while its window holds fewer than ``conformal_min``
        recent negatives (no guarantee worth publishing)."""
        win = self._conf.get(int(tenant))
        if win is None or win.fill < self.config.conformal_min:
            return None
        alpha = self.config.conformal_alpha
        if alpha is None:
            alpha = self.config.max_false_hit_rate
        return win.floor(float(alpha))

    def conformal_state(self) -> Dict[str, object]:
        """The §14.3 stats view: per-tenant window fills and active
        floors, plus the audit counters."""
        return {
            "tenants": {t: {"fill": w.fill, "seen": w.seen,
                            "floor": self.conformal_floor(t)}
                        for t, w in sorted(self._conf.items())},
            "hit_audits": self.counters["hit_audits"],
            "audited_false_hits": self.counters["audited_false_hits"],
        }

    def observe_hit_pair(self, query: str, neighbour: str) -> None:
        """A served hit is the strongest online duplicate evidence: the
        query scored above its tenant's threshold against the stored
        neighbour and was answered from cache.  Hits never feed the
        score reservoirs (§9's estimators rely on commit-time miss
        labels; hit rows are served uninspected) but they are exactly
        the positive contrastive pairs the §11 refresh trains on."""
        self.pairs.add(query, neighbour, True)
        self.counters["pair_events"] += 1

    def reset_scores(self) -> None:
        """Drop every tenant's score reservoir — the embedder-publish
        path (§11): reservoir samples are cosine scores under the
        *previous* embedder version, so any refit over them would
        calibrate the new version's thresholds against a dead score
        space.  The pooled text-pair reservoir survives (texts are
        version-independent training data), and the interval clocks
        reset so §9 re-examines each tenant only after it has seen
        fresh post-swap evidence."""
        self._res.clear()
        self._seen_at_fit.clear()
        # ensemble reservoirs hold per-embedder cosines — every column
        # lives in some embedder version's score space, so a panel swap
        # invalidates them exactly like the scalar reservoirs
        self._ens.clear()
        self._ens_seen_at_fit.clear()
        # conformal windows are score-space too: a floor computed on
        # old-version cosines is meaningless after the swap (§14.3)
        self._conf.clear()

    # ------------------------------------------------------------------
    # refit scheduling
    # ------------------------------------------------------------------
    def tenants(self) -> List[int]:
        return sorted(self._res)

    def refit_due(self, tenant: Optional[int] = None) -> bool:
        """Enough new events since the tenant's last examination (any
        tenant, when ``tenant`` is None) to justify a fit attempt."""
        if tenant is None:
            return any(self.refit_due(t) for t in self._res)
        res = self._res.get(int(tenant))
        if res is None or res.fill < self.config.min_samples:
            return False
        seen_at = self._seen_at_fit.get(int(tenant), 0)
        return res.seen - seen_at >= self.config.refit_interval \
            or seen_at == 0

    def ensemble_tenants(self) -> List[int]:
        return sorted(self._ens)

    def weight_refit_due(self, tenant: Optional[int] = None) -> bool:
        """§13 scheduling twin of `refit_due` over the ensemble
        reservoirs."""
        if tenant is None:
            return any(self.weight_refit_due(t) for t in self._ens)
        res = self._ens.get(int(tenant))
        if res is None or res.fill < self.config.min_samples:
            return False
        seen_at = self._ens_seen_at_fit.get(int(tenant), 0)
        return res.seen - seen_at >= self.config.refit_interval \
            or seen_at == 0

    # ------------------------------------------------------------------
    # the fit itself
    # ------------------------------------------------------------------
    def fit(self, tenant: int,
            policy: TenantPolicy) -> Tuple[TenantPolicy, RefitReport]:
        """Re-derive one tenant's operating point from its reservoir,
        under every hysteresis guard.  Returns the (possibly unchanged)
        policy and the decision record; the caller applies it."""
        t = int(tenant)
        cfg = self.config
        res = self._res.get(t)
        scores, labels = res.arrays() if res is not None \
            else (np.zeros(0, np.float32), np.zeros(0, np.int8))
        n_dup = int(labels.sum())

        def skip(reason: str, fhr: float = 0.0):
            self.counters["refits_skipped"] += 1
            rep = RefitReport(
                tenant=t, applied=False, reason=reason,
                old_threshold=policy.threshold,
                new_threshold=policy.threshold,
                old_margin=policy.admission_margin,
                new_margin=policy.admission_margin,
                n_events=len(scores), n_duplicates=n_dup,
                false_hit_rate=fhr)
            self._log(rep)
            return policy, rep

        if len(scores) < cfg.min_samples:
            return skip("min-samples")
        if not self.refit_due(t):
            return skip("interval")
        # examined now — the interval restarts whether or not a fit
        # applies, so a tenant stuck in a skip state (e.g. too few
        # duplicates) is re-examined every refit_interval new events,
        # not on every maintenance tick
        self._seen_at_fit[t] = res.seen
        if n_dup < cfg.min_class or len(scores) - n_dup < cfg.min_class:
            return skip("class-starved")

        old_thr = float(policy.threshold)
        cal = calibrate_for_false_hit_budget(scores, labels,
                                             cfg.max_false_hit_rate)
        pos = scores[labels == 1]
        neg = scores[labels == 0]
        # duplicate-support floor: loosening below the score that
        # already captures dup_coverage of observed duplicates converts
        # no observed miss into a hit — it only walks into the censored
        # region where false hits would go unnoticed
        floor = float(np.quantile(pos, 1.0 - cfg.dup_coverage))
        target = max(cal.threshold, floor)
        step_clamped = abs(target - old_thr) > cfg.max_step
        new_thr = float(np.clip(target, old_thr - cfg.max_step,
                                old_thr + cfg.max_step))
        fhr = float((neg >= new_thr).mean())
        if new_thr < old_thr and fhr > cfg.max_false_hit_rate:
            # monotone budget guard: never publish a loosening whose
            # observed false-hit rate breaches the budget (a clamped
            # tightening may still be over budget — it moves toward
            # compliance and is allowed)
            return skip("budget-guard", fhr=fhr)

        # admission margin: skip admitting misses above the score at
        # which observed misses are duplicates with dup_precision —
        # their stored neighbour serves the paraphrase cluster already
        dup_cal = calibrate_for_precision(scores, labels,
                                          min_precision=cfg.dup_precision)
        new_margin = float(np.clip(new_thr - dup_cal.threshold, 0.0,
                                   cfg.max_margin))

        if abs(new_thr - old_thr) < 1e-6 \
                and abs(new_margin - policy.admission_margin) < 1e-6:
            return skip("no-change", fhr=fhr)
        self.counters["refits_applied"] += 1
        rep = RefitReport(
            tenant=t, applied=True, reason="ok",
            old_threshold=old_thr, new_threshold=new_thr,
            old_margin=policy.admission_margin, new_margin=new_margin,
            step_clamped=step_clamped, n_events=len(scores),
            n_duplicates=n_dup, false_hit_rate=fhr)
        self._log(rep)
        return replace(policy, threshold=new_thr,
                       admission_margin=new_margin, calibration=cal), rep

    def fit_weights(self, tenant: int, weights: np.ndarray,
                    policy: TenantPolicy
                    ) -> Tuple[np.ndarray, TenantPolicy, WeightRefitReport]:
        """Re-derive one tenant's mixture weights from its ensemble
        reservoir (§13), then recalibrate its threshold against the
        fused score the new weights produce.

        The weight estimate is a closed-form ridge regression of the
        duplicate verdict on the per-embedder scores —
        ``w* = (SᵀS + λ·n·I)⁻¹ Sᵀ y`` — projected to the simplex
        (non-negative, Σw = 1): an embedder whose score separates
        duplicates from distincts for this tenant earns weight, one
        that scores both alike is shrunk toward zero by the
        regularizer.  Hysteresis mirrors `fit()` exactly: min-samples,
        class balance, the refit interval, a per-component
        ``max_weight_step`` clamp, and a no-change floor.

        A weight move changes the score distribution every threshold
        in §9 was calibrated against, so the same reservoir is
        replayed under the *new* fused score and the tenant's
        threshold follows it (``calibrate_for_false_hit_budget`` on
        the fused scores, clamped by ``max_step`` like any refit —
        arxiv 2606.19719's recalibrate-on-swap discipline applied to a
        weight swap).  Returns (weights, policy, report); the caller
        publishes both or neither.
        """
        t = int(tenant)
        cfg = self.config
        res = self._ens.get(t)
        scores, labels = res.arrays() if res is not None \
            else (np.zeros((0, len(weights)), np.float32),
                  np.zeros(0, np.int8))
        n_dup = int(labels.sum())
        weights = np.asarray(weights, np.float64)

        def skip(reason: str):
            self.counters["weight_refits_skipped"] += 1
            rep = WeightRefitReport(
                tenant=t, applied=False, reason=reason,
                old_weights=tuple(float(w) for w in weights),
                new_weights=tuple(float(w) for w in weights),
                old_threshold=policy.threshold,
                new_threshold=policy.threshold,
                n_events=len(scores), n_duplicates=n_dup)
            self._log_weights(rep)
            return np.asarray(weights, np.float32), policy, rep

        if len(scores) < cfg.min_samples:
            return skip("min-samples")
        if not self.weight_refit_due(t):
            return skip("interval")
        self._ens_seen_at_fit[t] = res.seen
        if n_dup < cfg.min_class or len(scores) - n_dup < cfg.min_class:
            return skip("class-starved")

        S = scores.astype(np.float64)
        y = labels.astype(np.float64)
        n, E = S.shape
        lam = cfg.weight_lambda * n
        try:
            w_star = np.linalg.solve(S.T @ S + lam * np.eye(E), S.T @ y)
        except np.linalg.LinAlgError:
            return skip("degenerate")
        w_star = np.maximum(w_star, 0.0)
        if w_star.sum() <= 0.0:
            # the verdict anti-correlates with every panel's score —
            # no mixture of similarities explains it; keep serving
            return skip("degenerate")
        w_star = w_star / w_star.sum()
        step = np.clip(w_star - weights, -cfg.max_weight_step,
                       cfg.max_weight_step)
        step_clamped = bool(np.any(np.abs(w_star - weights)
                                   > cfg.max_weight_step + 1e-12))
        new_w = np.maximum(weights + step, 0.0)
        new_w = new_w / new_w.sum()

        # fused-score threshold recalibration under the new weights
        old_thr = float(policy.threshold)
        fused = (S @ new_w).astype(np.float32)
        cal = calibrate_for_false_hit_budget(fused, labels,
                                             cfg.max_false_hit_rate)
        new_thr = float(np.clip(cal.threshold, old_thr - cfg.max_step,
                                old_thr + cfg.max_step))

        if float(np.abs(new_w - weights).max()) < 1e-6 \
                and abs(new_thr - old_thr) < 1e-6:
            return skip("no-change")
        self.counters["weight_refits_applied"] += 1
        rep = WeightRefitReport(
            tenant=t, applied=True, reason="ok",
            old_weights=tuple(float(w) for w in weights),
            new_weights=tuple(float(w) for w in new_w),
            old_threshold=old_thr, new_threshold=new_thr,
            step_clamped=step_clamped, n_events=n, n_duplicates=n_dup)
        self._log_weights(rep)
        new_policy = policy.with_threshold(new_thr, calibration=cal) \
            if abs(new_thr - old_thr) >= 1e-6 else policy
        return new_w.astype(np.float32), new_policy, rep

    def _log(self, rep: RefitReport) -> None:
        """Bounded decision log: a tenant stuck in a skip reason (e.g.
        class-starved) is re-examined every maintenance tick, so the
        log keeps only the most recent decisions."""
        self.refit_log.append(rep)
        if len(self.refit_log) > self.config.refit_log_cap:
            del self.refit_log[:-self.config.refit_log_cap]

    def _log_weights(self, rep: WeightRefitReport) -> None:
        self.weight_refit_log.append(rep)
        if len(self.weight_refit_log) > self.config.refit_log_cap:
            del self.weight_refit_log[:-self.config.refit_log_cap]

    # ------------------------------------------------------------------
    def state(self) -> Dict[str, object]:
        """Flat snapshot for the backend's ``stats()``."""
        return {
            "feedback_events": self.counters["events"],
            "duplicate_events": self.counters["duplicate_events"],
            "wasted_admissions": self.counters["wasted_admissions"],
            "refits_applied": self.counters["refits_applied"],
            "refits_skipped": self.counters["refits_skipped"],
            "feedback_tenants": len(self._res),
            "pair_events": self.counters["pair_events"],
            "pairs_held": len(self.pairs),
            "ensemble_events": self.counters["ensemble_events"],
            "weight_refits_applied":
                self.counters["weight_refits_applied"],
            "weight_refits_skipped":
                self.counters["weight_refits_skipped"],
            "hit_audits": self.counters["hit_audits"],
            "audited_false_hits": self.counters["audited_false_hits"],
        }


def record_refit(registry, report: RefitReport) -> None:
    """Publish one refit decision as structured registry events
    (DESIGN.md §10.1): a per-(tenant, outcome) counter — outcome is
    ``applied`` or the skip reason, so budget-guard refusals are
    directly alertable — plus, for applied refits, the tenant's
    published operating point as gauges.  ``CacheService.maintenance``
    calls this for every report its refit pass produced."""
    registry.counter(
        "admission_refits_total",
        "per-tenant refit decisions by outcome (applied | skip reason)",
        labels=("tenant", "outcome"),
    ).inc(1, tenant=report.tenant,
          outcome="applied" if report.applied else report.reason)
    if report.applied:
        registry.gauge(
            "admission_threshold", "published per-tenant hit threshold",
            labels=("tenant",)).set(report.new_threshold,
                                    tenant=report.tenant)
        registry.gauge(
            "admission_margin", "published per-tenant admission margin",
            labels=("tenant",)).set(report.new_margin,
                                    tenant=report.tenant)
        registry.gauge(
            "admission_observed_false_hit_rate",
            "observed false-hit rate at the published threshold",
            labels=("tenant",)).set(report.false_hit_rate,
                                    tenant=report.tenant)
