"""Functional JAX vector store — the cache's TPU-resident index.

The paper uses Redis vector search; the TPU-native analogue (DESIGN.md
§3) is a fixed-capacity store whose state is a pytree of device arrays,
so insert/query/evict are pure jittable functions and the whole store
shards under pjit (corpus rows over the `model` axis — each shard
computes a local top-k that a tiny merge resolves).

Eviction policy: free slot first, else least-recently-used (a lamport
clock updated on hits).  TTL eviction is a pure mask update.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class StoreState(NamedTuple):
    keys: jax.Array        # (N, D) float32, unit-norm rows
    valid: jax.Array       # (N,)  bool
    last_used: jax.Array   # (N,)  int32 lamport clock
    inserted_at: jax.Array  # (N,) int32
    value_ids: jax.Array   # (N,)  int32 host-side response index
    clock: jax.Array       # ()    int32


class QueryResult(NamedTuple):
    scores: jax.Array      # (Q, k) cosine similarity, desc
    slots: jax.Array       # (Q, k) store row indices
    value_ids: jax.Array   # (Q, k)
    hit: jax.Array         # (Q,)   best score >= threshold


def init_store(capacity: int, dim: int) -> StoreState:
    return StoreState(
        keys=jnp.zeros((capacity, dim), jnp.float32),
        valid=jnp.zeros((capacity,), bool),
        last_used=jnp.zeros((capacity,), jnp.int32),
        inserted_at=jnp.zeros((capacity,), jnp.int32),
        value_ids=jnp.full((capacity,), -1, jnp.int32),
        clock=jnp.zeros((), jnp.int32),
    )


def store_axes() -> StoreState:
    """Logical sharding axes (encoded strings) for the store pytree."""
    return StoreState(
        keys="corpus,.", valid="corpus", last_used="corpus",
        inserted_at="corpus", value_ids="corpus", clock="",
    )


def _choose_slot(state: StoreState) -> jax.Array:
    """First invalid slot, else LRU."""
    has_free = jnp.any(~state.valid)
    first_free = jnp.argmax(~state.valid)          # first True
    lru = jnp.argmin(jnp.where(state.valid, state.last_used, jnp.iinfo(jnp.int32).max))
    return jnp.where(has_free, first_free, lru).astype(jnp.int32)


def insert(state: StoreState, emb: jax.Array, value_id: jax.Array) -> StoreState:
    """Insert one unit-norm embedding (D,) with its response id."""
    emb = emb.astype(jnp.float32)
    emb = emb / jnp.maximum(jnp.linalg.norm(emb), 1e-9)
    slot = _choose_slot(state)
    clock = state.clock + 1
    return StoreState(
        keys=state.keys.at[slot].set(emb),
        valid=state.valid.at[slot].set(True),
        last_used=state.last_used.at[slot].set(clock),
        inserted_at=state.inserted_at.at[slot].set(clock),
        value_ids=state.value_ids.at[slot].set(value_id.astype(jnp.int32)),
        clock=clock,
    )


def insert_batch(state: StoreState, embs: jax.Array,
                 value_ids: jax.Array) -> StoreState:
    """Sequential batch insert (slot choice is order-dependent)."""

    def body(s, xs):
        e, vid = xs
        return insert(s, e, vid), None

    state, _ = jax.lax.scan(body, state, (embs, value_ids))
    return state


def query(state: StoreState, q: jax.Array, threshold: float,
          k: int = 1, topk_fn=None) -> QueryResult:
    """q: (Q, D).  Returns top-k cosine matches among valid rows.

    topk_fn(q, keys, valid, k) -> (scores, slots): injection point for
    the Pallas `cosine_topk` kernel; defaults to the jnp reference.
    """
    qn = q.astype(jnp.float32)
    qn = qn / jnp.maximum(jnp.linalg.norm(qn, axis=-1, keepdims=True), 1e-9)
    if topk_fn is None:
        from repro.kernels.cosine_topk import ops as _ops
        topk_fn = _ops.cosine_topk
    scores, slots = topk_fn(qn, state.keys, state.valid, k)
    value_ids = state.value_ids[slots]
    hit = scores[:, 0] >= threshold
    return QueryResult(scores=scores, slots=slots, value_ids=value_ids, hit=hit)


def query_sharded(state: StoreState, q: jax.Array, threshold: float,
                  k: int, mesh, axis: str = "model") -> QueryResult:
    """Distributed lookup with an explicit local-topk + tiny-merge
    schedule (beyond-paper §Perf optimization, DESIGN.md §3).

    GSPMD's auto-partition of `query` all-gathers the full (Q, N) score
    matrix across the corpus axis; this shard_map version computes a
    LOCAL top-k per corpus shard and all-gathers only (Q, 2k) candidate
    scores+ids per device — the collective shrinks from O(Q·N) to
    O(Q·k·shards).  The corpus stays sharded over ``axis``; queries may
    stay batch-sharded over the other mesh axes.  The local-topk +
    tiny-merge step itself is `core.distrib.merge_local_topk`, shared
    with the tiered cache's sharded warm lookup (DESIGN.md §8).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.distrib import merge_local_topk

    qn = q.astype(jnp.float32)
    qn = qn / jnp.maximum(jnp.linalg.norm(qn, axis=-1, keepdims=True), 1e-9)
    n_total = state.keys.shape[0]
    n_shards = mesh.shape[axis]
    shard_n = n_total // n_shards
    other = tuple(a for a in mesh.axis_names if a != axis)
    batch_axes = tuple(a for a in other
                       if q.shape[0] % mesh.shape[a] == 0) or None

    def local(keys, valid, value_ids, qloc):
        # keys: (N/shards, D) this shard; qloc: (Q_loc, D)
        scores = qloc @ keys.T                                  # (Q, N_loc)
        scores = jnp.where(valid[None, :], scores, -1e30)
        s, i_loc = jax.lax.top_k(scores, k)                     # local top-k
        vals = value_ids[i_loc]                                 # (Q, k)
        i_glob = i_loc + jax.lax.axis_index(axis) * shard_n
        # tiny merge: gather only (Q, k) candidates from every shard
        return merge_local_topk(axis, k, s, i_glob, vals)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(axis),
                  P(batch_axes, None)),
        out_specs=(P(batch_axes, None), P(batch_axes, None),
                   P(batch_axes, None)),
        check_rep=False)
    scores, slots, value_ids = fn(state.keys, state.valid, state.value_ids,
                                  qn)
    hit = scores[:, 0] >= threshold
    return QueryResult(scores=scores, slots=slots, value_ids=value_ids,
                       hit=hit)


def touch(state: StoreState, slots: jax.Array, hit: jax.Array) -> StoreState:
    """LRU bump for hit slots (slots: (Q,), hit: (Q,))."""
    clock = state.clock + 1
    safe = jnp.where(hit, slots, 0)
    new_last = state.last_used.at[safe].max(
        jnp.where(hit, clock, jnp.zeros_like(clock)))
    return state._replace(last_used=new_last, clock=clock)


def evict_older_than(state: StoreState, max_age: int) -> StoreState:
    """TTL policy: invalidate entries older than ``max_age`` ticks."""
    expired = (state.clock - state.inserted_at) > max_age
    return state._replace(valid=state.valid & ~expired)


def occupancy(state: StoreState) -> jax.Array:
    return jnp.mean(state.valid.astype(jnp.float32))
