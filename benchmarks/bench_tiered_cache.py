"""Tiered cache vs flat brute force at production corpus sizes.

Flat exact lookup is O(N·D) per query; the tiered cascade is
O(N_hot·D + (K + n_probe·bucket)·D) — at 64k+ entries the warm IVF tier
probes ~6% of the corpus.  This bench builds a clustered corpus
(paraphrase groups, the cache's actual workload), serves the same query
mix through both paths, and reports per-query latency plus the tiered
cascade's recall against the exact hit set at the operating threshold.

    PYTHONPATH=src python -m benchmarks.run tiered
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_derived, timed
from repro.cache_service import tiers
from repro.core import store as store_lib

N_TOTAL = 1 << 16          # 64k entries (satisfies the >=64k criterion)
HOT = 2048                 # recent-traffic slice held in the hot tier
DIM = 64
N_CLUSTERS = 256
BUCKET = 512
N_PROBE = 4
Q = 128
THRESHOLD = 0.9
SEED = 3


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _corpus(rng):
    """Clustered keys: paraphrase groups around N_CLUSTERS centroids."""
    per = N_TOTAL // N_CLUSTERS
    cents = _unit(rng.standard_normal((N_CLUSTERS, DIM)).astype(np.float32))
    keys = np.repeat(cents, per, axis=0)
    return _unit(keys + 0.15 * rng.standard_normal(keys.shape
                                                   ).astype(np.float32))


def _states(keys):
    """Build flat / hot / warm states directly (bulk load, not the
    sequential insert path — this bench times lookups, not fills)."""
    n = len(keys)
    vids = jnp.arange(n, dtype=jnp.int32)
    flat = store_lib.init_store(n, DIM)._replace(
        keys=jnp.asarray(keys), valid=jnp.ones((n,), bool), value_ids=vids)

    warm_n = n - HOT
    warm = tiers.init_warm(warm_n, DIM, N_CLUSTERS, BUCKET)._replace(
        keys=jnp.asarray(keys[:warm_n]),
        valid=jnp.ones((warm_n,), bool),
        tenants=jnp.zeros((warm_n,), jnp.int32),
        value_ids=vids[:warm_n],
        write_seq=jnp.arange(1, warm_n + 1, dtype=jnp.int32),
        total=jnp.asarray(warm_n, jnp.int32))
    warm = jax.jit(partial(tiers.warm_rebuild, iters=4, seed=SEED))(warm)

    hot = tiers.init_hot(HOT, DIM)._replace(
        keys=jnp.asarray(keys[warm_n:]),
        valid=jnp.ones((HOT,), bool),
        tenants=jnp.zeros((HOT,), jnp.int32),
        last_used=jnp.arange(1, HOT + 1, dtype=jnp.int32),
        value_ids=vids[warm_n:],
        clock=jnp.asarray(HOT, jnp.int32))
    return flat, hot, warm


def _queries(rng, keys):
    """Half near-duplicates of random corpus entries, half novel."""
    idx = rng.choice(len(keys), Q // 2, replace=False)
    pos = _unit(keys[idx] + 0.05 * rng.standard_normal(
        (Q // 2, DIM)).astype(np.float32))
    neg = _unit(rng.standard_normal((Q // 2, DIM)).astype(np.float32))
    return jnp.asarray(np.concatenate([pos, neg]))


def bench_tiered_cache():
    rng = np.random.default_rng(SEED)
    keys = _corpus(rng)
    flat, hot, warm = _states(keys)
    q = _queries(rng, keys)
    tenants = jnp.zeros((Q,), jnp.int32)
    thresholds = jnp.full((Q,), THRESHOLD, jnp.float32)

    flat_fn = jax.jit(lambda st, qq: store_lib.query(st, qq, THRESHOLD, 1))
    casc_fn = jax.jit(partial(tiers.cascade_lookup, k=1, n_probe=N_PROBE,
                              tail=0))

    exact = flat_fn(flat, q)
    jax.block_until_ready(exact)
    casc = casc_fn(hot, warm, q, tenants, thresholds)
    jax.block_until_ready(casc)

    _, us_flat = timed(
        lambda: jax.block_until_ready(flat_fn(flat, q)), repeats=5)
    _, us_tier = timed(
        lambda: jax.block_until_ready(casc_fn(hot, warm, q, tenants,
                                              thresholds)), repeats=5)

    exact_hit = np.asarray(exact.hit)
    tier_hit = np.asarray(casc.hit)
    recall = float((tier_hit & exact_hit).sum() / max(exact_hit.sum(), 1))
    spurious = int((tier_hit & ~exact_hit).sum())
    speedup = us_flat / max(us_tier, 1e-9)

    yield "tiered/flat_bruteforce", us_flat / Q, fmt_derived(
        {"n": N_TOTAL, "us_per_query": us_flat / Q,
         "hits": int(exact_hit.sum())})
    yield "tiered/cascade_hot+ivf", us_tier / Q, fmt_derived(
        {"n": N_TOTAL, "us_per_query": us_tier / Q,
         "recall_at_thr": recall, "spurious_hits": spurious,
         "speedup_vs_flat": speedup})

    # amortised maintenance: one demotion flush + one IVF rebuild
    dem_fn = jax.jit(partial(tiers.demote_coldest, m=512))
    app_fn = jax.jit(tiers.warm_append)
    reb_fn = jax.jit(partial(tiers.warm_rebuild, iters=4, seed=SEED))

    def flush_and_rebuild():
        h2, dem = dem_fn(hot)
        w2, _ = app_fn(warm, dem)
        return jax.block_until_ready(reb_fn(w2))

    flush_and_rebuild()
    _, us_maint = timed(flush_and_rebuild, repeats=3)
    yield "tiered/flush+rebuild", us_maint, fmt_derived(
        {"flush_size": 512, "n_warm": N_TOTAL - HOT,
         "clusters": N_CLUSTERS})

    assert recall >= 0.95, f"tiered recall {recall} < 0.95"
    assert speedup > 1.0, f"tiered not faster: {speedup:.2f}x"
