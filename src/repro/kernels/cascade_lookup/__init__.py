"""Fused cascade lookup: the tiered cache's whole query path as one
kernel (hot matmul + centroid matmul + IVF bucket gather + tail scan +
tenant-masked top-k).  See DESIGN.md §3 for the dataflow."""
from repro.kernels.cascade_lookup.ops import cascade_lookup

__all__ = ["cascade_lookup"]
