"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — tests and
benches must see the single real CPU device; only launch/dryrun.py
forces the 512-device placeholder fleet."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _x64_off():
    # keep default f32 semantics everywhere
    yield
