#!/usr/bin/env bash
# Tier-1 verify: run the test suite with the src layout on PYTHONPATH.
# Usage: scripts/test.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
