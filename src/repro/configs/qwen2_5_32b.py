"""Qwen2.5-32B — dense GQA decoder with QKV bias.

[hf:Qwen/Qwen2.5-0.5B family card]  64L, d_model=5120, 40 heads, kv=8,
d_ff=27648, vocab=152064.  RoPE + SwiGLU + RMSNorm + QKV bias.
Note: 40 heads do not divide the 16-way model axis; sharding rules fall
back per-tensor (see launch/sharding.py divisibility handling).
"""
from repro.configs.base import ModelConfig, LayerSpec, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    use_rope=True,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    period=(LayerSpec(ATTN, DENSE),),
))
