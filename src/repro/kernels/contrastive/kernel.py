"""Pallas TPU kernel: fused online-contrastive loss.

sentence-transformers mines hard pairs with boolean indexing — dynamic
shapes, two passes over HBM, and a host-device sync on GPU.  The TPU
formulation (DESIGN.md §3) is a two-phase grid over batch tiles with the
cross-batch statistics carried in SMEM scratch:

  phase 0: per-tile pair distances (one fused VMEM pass: dot + norms),
           running (min_neg, max_pos) reduction into SMEM;
  phase 1: distances recomputed in VMEM (cheaper than an HBM round-trip
           for D ≤ a few K), hard-pair masks formed against the SMEM
           stats, masked loss sums accumulated.

Grid iteration on TPU is sequential-lexicographic, which is what makes
the phase-major (2, n_tiles) grid a correct two-pass schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e9


def _pair_dist(e1, e2):
    num = jnp.sum(e1 * e2, axis=-1)
    den = (jnp.sqrt(jnp.sum(e1 * e1, axis=-1)) *
           jnp.sqrt(jnp.sum(e2 * e2, axis=-1)))
    return 1.0 - num / jnp.maximum(den, 1e-9)


def _kernel(e1_ref, e2_ref, lab_ref, out_ref, stats, *, margin: float,
            block_b: int, n_total: int):
    phase = pl.program_id(0)
    jb = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when((phase == 0) & (jb == 0))
    def _init():
        stats[0] = BIG      # min_neg
        stats[1] = -BIG     # max_pos
        stats[2] = 0.0      # pos_loss_sum
        stats[3] = 0.0      # neg_loss_sum

    e1 = e1_ref[...].astype(jnp.float32)
    e2 = e2_ref[...].astype(jnp.float32)
    lab = lab_ref[...]
    d = _pair_dist(e1, e2)                                 # (BB,)
    row = jb * block_b + jax.lax.broadcasted_iota(jnp.int32, d.shape, 0)
    in_range = row < n_total
    is_pos = (lab == 1) & in_range
    is_neg = (lab == 0) & in_range

    @pl.when(phase == 0)
    def _reduce():
        stats[0] = jnp.minimum(stats[0], jnp.min(jnp.where(is_neg, d, BIG)))
        stats[1] = jnp.maximum(stats[1], jnp.max(jnp.where(is_pos, d, -BIG)))

    @pl.when(phase == 1)
    def _loss():
        min_neg = stats[0]
        max_pos = stats[1]
        hard_pos = is_pos & (d > min_neg)
        hard_neg = is_neg & (d < max_pos)
        stats[2] += jnp.sum(jnp.square(d) * hard_pos.astype(jnp.float32))
        stats[3] += jnp.sum(jnp.square(jnp.maximum(margin - d, 0.0)) *
                            hard_neg.astype(jnp.float32))

    @pl.when((phase == 1) & (jb == nb - 1))
    def _done():
        out_ref[0] = stats[2]
        out_ref[1] = stats[3]
        out_ref[2] = stats[0]
        out_ref[3] = stats[1]


@functools.partial(jax.jit, static_argnames=("margin", "block_b", "interpret"))
def contrastive_components(e1, e2, labels, margin: float = 0.5, *,
                           block_b: int = 1024, interpret: bool = True):
    """e1, e2: (B, D); labels: (B,) int -> (pos_loss, neg_loss, min_neg,
    max_pos) as a (4,) float32 vector, matching ref.contrastive_components."""
    B, D = e1.shape
    bb = min(block_b, B)
    nb = -(-B // bb)
    pad = nb * bb - B
    if pad:
        e1 = jnp.pad(e1, ((0, pad), (0, 0)))
        e2 = jnp.pad(e2, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)

    fn = pl.pallas_call(
        functools.partial(_kernel, margin=margin, block_b=bb, n_total=B),
        grid=(2, nb),
        in_specs=[
            pl.BlockSpec((bb, D), lambda p, j: (j, 0)),
            pl.BlockSpec((bb, D), lambda p, j: (j, 0)),
            pl.BlockSpec((bb,), lambda p, j: (j,)),
        ],
        out_specs=pl.BlockSpec((4,), lambda p, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((4,), jnp.float32)],
        interpret=interpret,
    )
    out = fn(e1, e2, labels.astype(jnp.int32))
    return out[0], out[1], out[2], out[3]
