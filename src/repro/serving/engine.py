"""Serving engine: batched prefill + decode with carried state.

``ServeEngine`` is the host-side loop around the pure ``prefill`` /
``decode_step`` functions (jitted once per shape).  It serves *batched
requests* — the end-to-end example drivers put the semantic cache in
front of this engine, which is exactly the deployment the paper targets
(cache hit -> skip the engine entirely).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache_service.protocol import CacheBackend, CacheRequest
from repro.configs.base import ModelConfig
from repro.data.tokenizer import HashTokenizer
from repro.models import decode_step, prefill
from repro.obs import Telemetry
from repro.obs.registry import tenant_label
from repro.serving.frontend import stub_frontend_embeds


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_new) int32
    n_prompt: int
    n_generated: int
    cache_hit: bool = False


class ServeEngine:
    """Batched autoregressive serving for any decoder config."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        if cfg.is_encoder:
            raise ValueError(f"{cfg.name} is encoder-only; no decode path")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda pv, toks, fe: prefill(pv, cfg, toks, max_len, fe),
            static_argnames=())
        self._decode = jax.jit(lambda pv, st, tok: decode_step(pv, cfg, st, tok))

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 use_frontend: bool = False) -> GenerationResult:
        """prompts: (B, S) int32.  Greedy (temperature=0) or sampled."""
        B, S = prompts.shape
        fe = stub_frontend_embeds(self.cfg, B, seed) if use_frontend else None
        logits, state = self._prefill(self.params, jnp.asarray(prompts), fe)
        key = jax.random.PRNGKey(seed)
        out = np.zeros((B, max_new_tokens), np.int32)
        tok = self._select(logits, temperature, key)
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok)[:, 0]
            logits, state = self._decode(self.params, state, tok)
            key, sub = jax.random.split(key)
            tok = self._select(logits, temperature, sub)
        return GenerationResult(out, n_prompt=S, n_generated=max_new_tokens)

    @staticmethod
    def _select(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        g = jax.random.gumbel(key, logits.shape)
        return jnp.argmax(logits / temperature + g, axis=-1).astype(
            jnp.int32)[:, None]


@dataclass
class ServedRequest:
    query: str
    response: str
    cache_hit: bool
    score: float = 0.0


class CachedLLMService:
    """The paper's deployment: a semantic cache in front of an LLM.

    ``handle`` is a thin typed pipeline over any ``CacheBackend``
    (DESIGN.md §7): embed -> ``plan`` (per-row hit/miss verdicts,
    resolved responses, admission pre-decision, miss coalescing) ->
    generate one answer per miss *group* leader -> ``commit`` -> drive
    backend ``maintenance()`` between batches (this is what lets the
    warm-IVF rebuild run double-buffered off the hot path).  Backend
    features are discovered through ``capabilities()``, never hasattr.
    """

    def __init__(self, embed_fn, cache: CacheBackend,
                 engine: Optional[ServeEngine], tokenizer: HashTokenizer,
                 max_query_len: int = 32, max_new_tokens: int = 16,
                 fused: Optional[bool] = None, coalesce: bool = True,
                 telemetry: Optional[Telemetry] = None):
        """``fused`` (None = leave the backend's choice) selects the
        cache's cascade execution path — the fused Pallas lookup kernel
        vs the four-op composition — when the backend's capabilities
        advertise it; ``coalesce=False`` generates per miss row even
        for near-identical queries (the legacy behaviour).

        ``telemetry`` (None = adopt the backend's, so the whole stack
        shares one registry/tracer) wires the §10 spans and serving
        counters; each ``handle`` call produces one span tree rooted at
        ``request`` with embed/plan/generate/commit(/maintenance)
        children, and the engine observes the embed and generate stages
        into the shared ``stage_latency_seconds`` histogram (plan/
        commit/maintenance are observed by the backend itself)."""
        self.embed_fn = embed_fn          # list[str] -> (B, D) unit vectors
        if not isinstance(cache, CacheBackend):
            raise TypeError(
                f"cache backend {type(cache).__name__} does not implement "
                "the CacheBackend protocol (capabilities/plan/commit/"
                "maintenance/stats_snapshot); see "
                "repro.cache_service.protocol")
        self.cache = cache
        self.caps = cache.capabilities()
        self.engine = engine
        self.tok = tokenizer
        self.max_query_len = max_query_len
        self.max_new_tokens = max_new_tokens
        self.coalesce = coalesce
        self.telemetry = (telemetry
                          or getattr(cache, "telemetry", None)
                          or Telemetry())
        reg = self.telemetry.registry
        self._stage_h = self.telemetry.stage_histogram()
        self._m_requests = reg.counter(
            "serve_requests_total", "queries handled", labels=("tenant",))
        self._m_hits = reg.counter(
            "serve_hits_total", "queries served from cache",
            labels=("tenant",))
        self._m_misses = reg.counter(
            "serve_misses_total", "queries that missed", labels=("tenant",))
        self._c_generations = reg.counter(
            "serve_generations_total", "LLM generations (group leaders)"
            ).labels()
        self._c_coalesced = reg.counter(
            "serve_coalesced_misses_total",
            "misses served by another row's generation").labels()
        self._c_maintenance = reg.counter(
            "serve_maintenance_calls_total",
            "between-batch maintenance() calls").labels()
        self._trace = itertools.count()
        if fused is not None:
            if self.caps.fused_lookup:
                self.cache.set_fused(fused)
            elif fused:
                raise ValueError(
                    f"cache backend {type(cache).__name__} has no fused "
                    "cascade path; use CacheService or drop fused=True")

    def _llm_answer(self, queries: List[str]) -> List[str]:
        if self.engine is None:  # degenerate echo backend for tests
            return [f"answer({q})" for q in queries]
        ids, _ = self.tok.encode_batch(queries, self.max_query_len)
        res = self.engine.generate(ids, self.max_new_tokens)
        return [" ".join(map(str, row)) for row in res.tokens]

    def handle(self, queries: List[str],
               tenant: int = 0) -> List[ServedRequest]:
        if not self.caps.tenants and np.any(np.asarray(tenant) != 0):
            raise ValueError(
                f"cache backend {type(self.cache).__name__} is not "
                "tenant-aware; serving tenant "
                f"{tenant} through it would break isolation")
        tracer = self.telemetry.tracer
        lab = tenant_label(np.asarray(tenant))
        trace_id = next(self._trace)
        with tracer.span("request", tenant=lab, trace_id=trace_id,
                         n=len(queries)):
            t0 = time.perf_counter()
            with tracer.span("embed", tenant=lab):
                embs = self.embed_fn(queries)
            self._stage_h.observe(time.perf_counter() - t0,
                                  stage="embed", tenant=lab)
            with tracer.span("plan", tenant=lab):
                # texts ride along so a §11 backend can retain them for
                # re-embedding admitted rows under a refreshed embedder
                plan = self.cache.plan(
                    CacheRequest.build(embs, tenant, trace_id=trace_id,
                                       texts=queries),
                    coalesce=self.coalesce)

            # one generation per miss-group leader serves the whole
            # group (with coalesce=False the plan's map degenerates to
            # one group per miss row, so this needs no special-casing)
            leaders = plan.leader_rows()
            t0 = time.perf_counter()
            with tracer.span("generate", tenant=lab,
                             n_leaders=len(leaders)):
                answers = dict(zip(
                    leaders,
                    self._llm_answer([queries[i] for i in leaders])
                    if leaders else []))
            self._stage_h.observe(time.perf_counter() - t0,
                                  stage="generate", tenant=lab)
            responses: List[Optional[str]] = [None] * len(queries)
            for i in plan.miss_rows():
                responses[int(i)] = answers[int(plan.miss_leader[i])]

            with tracer.span("commit", tenant=lab):
                receipt = self.cache.commit(plan, responses)
            self._m_requests.inc(len(queries), tenant=lab)
            self._m_hits.inc(int(plan.hit.sum()), tenant=lab)
            self._m_misses.inc(int((~plan.hit).sum()), tenant=lab)
            self._c_generations.inc(len(leaders))
            self._c_coalesced.inc(plan.n_coalesced)
            if receipt.rebuild_due:
                # between-batch maintenance: publish/start the
                # background IVF rebuild without stalling any request
                with tracer.span("maintenance", tenant=lab):
                    self.cache.maintenance()
                self._c_maintenance.inc()

        out: List[Optional[ServedRequest]] = [None] * len(queries)
        for i, q in enumerate(queries):
            if plan.hit[i]:
                out[i] = ServedRequest(q, plan.responses[i], True,
                                       float(plan.scores[i]))
            else:
                out[i] = ServedRequest(q, responses[i], False)
        return out  # type: ignore

    def stats(self) -> Dict[str, object]:
        """Unified telemetry snapshot: the serving counters plus the
        backend's ``stats_snapshot()`` nested under ``"backend"`` (the
        protocol allows a mapping or a typed object with ``to_dict()``
        — both normalise to a plain dict here).  Serving keys live at
        the top level, so a backend's plan-level "hits" can never
        shadow the pipeline's."""
        reg = self.telemetry.registry
        snap = self.cache.stats_snapshot()
        backend = snap.to_dict() if hasattr(snap, "to_dict") else dict(snap)
        return {"backend": backend,
                "requests": int(reg.value("serve_requests_total")),
                "hits": int(reg.value("serve_hits_total")),
                "misses": int(reg.value("serve_misses_total")),
                "generations": int(reg.value("serve_generations_total")),
                "coalesced_misses": int(
                    reg.value("serve_coalesced_misses_total")),
                "maintenance_calls": int(
                    reg.value("serve_maintenance_calls_total")),
                "hit_rate": self.hit_rate}

    @property
    def hit_rate(self) -> float:
        reg = self.telemetry.registry
        hits = reg.value("serve_hits_total")
        n = hits + reg.value("serve_misses_total")
        return hits / n if n else 0.0
