"""Per-tenant operating policy: thresholds and admission.

The paper evaluates one global best-F1 threshold; a multi-tenant
deployment runs one *operating point per tenant* (a medical tenant
tolerates far fewer false hits than a chit-chat tenant).  Policies are
plain host-side records resolved to per-query arrays at lookup time —
the device functions only ever see traced (Q,) float thresholds, so a
mixed-tenant batch costs zero recompiles.

Admission: caching every miss fills the store with near-duplicates
(paraphrase clusters collapse onto one representative anyway).  The
score-margin rule skips inserting a miss whose best same-tenant score
already sits within ``admission_margin`` of the hit threshold — the
next paraphrase of that query would have hit the *existing* entry.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional

import numpy as np

from repro.core.calibration import (
    Calibration, calibrate_for_false_hit_budget,
)


@dataclass(frozen=True)
class TenantPolicy:
    threshold: float = 0.85        # hit operating point
    admission_margin: float = 0.0  # skip insert if score >= thr - margin
    calibration: Optional[Calibration] = None


class PolicyTable:
    """tenant id -> TenantPolicy, with a default for unknown tenants."""

    def __init__(self, default: TenantPolicy):
        self.default = default
        self._by_tenant: Dict[int, TenantPolicy] = {}

    def get(self, tenant: int) -> TenantPolicy:
        return self._by_tenant.get(int(tenant), self.default)

    def set(self, tenant: int, policy: TenantPolicy) -> None:
        self._by_tenant[int(tenant)] = policy

    def calibrate(self, tenant: int, scores, labels,
                  max_false_hit_rate: float = 0.01) -> Calibration:
        """Fit this tenant's threshold to a false-hit budget from its
        own scored eval pairs (repro.core.calibration)."""
        cal = calibrate_for_false_hit_budget(scores, labels,
                                             max_false_hit_rate)
        cur = self.get(tenant)
        self.set(tenant, replace(cur, threshold=cal.threshold,
                                 calibration=cal))
        return cal

    # ----- vectorised resolution for a query batch ---------------------
    def thresholds_for(self, tenants: np.ndarray) -> np.ndarray:
        return np.asarray([self.get(t).threshold for t in tenants],
                          np.float32)

    def admit_mask(self, tenants: np.ndarray,
                   scores: Optional[np.ndarray]) -> np.ndarray:
        """Admission decision per miss: True -> cache it."""
        if scores is None:
            return np.ones(len(tenants), bool)
        thr = self.thresholds_for(tenants)
        margin = np.asarray([self.get(t).admission_margin for t in tenants],
                            np.float32)
        return np.asarray(scores, np.float32) < thr - margin

    def pre_decision(self, tenants: np.ndarray, scores: np.ndarray,
                     hit: np.ndarray) -> np.ndarray:
        """Plan-time admission pre-decision (DESIGN.md §7): False on hit
        rows; on miss rows the score-margin rule over the observed
        neighbour scores.  Carried inside the ``CachePlan`` so commit
        honors the decision taken when the scores were observed."""
        return ~np.asarray(hit, bool) & self.admit_mask(tenants, scores)
