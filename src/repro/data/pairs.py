"""Batch iterators over pair datasets (tokenised, optionally sharded)."""
from __future__ import annotations

from typing import Iterator

import jax
import numpy as np

from repro.data.corpora import PairDataset
from repro.data.tokenizer import HashTokenizer


def tokenize_pairs(ds: PairDataset, tok: HashTokenizer, max_len: int = 32):
    t1, m1 = tok.encode_batch(ds.q1, max_len)
    t2, m2 = tok.encode_batch(ds.q2, max_len)
    return {"tok1": t1, "mask1": m1, "tok2": t2, "mask2": m2,
            "label": ds.labels.astype(np.int32)}


def iter_batches(arrays: dict, batch_size: int, *, seed: int = 0,
                 shuffle: bool = True, drop_remainder: bool = True,
                 epochs: int = 1) -> Iterator[dict]:
    n = len(arrays["label"])
    for ep in range(epochs):
        order = (np.random.default_rng(seed + ep).permutation(n)
                 if shuffle else np.arange(n))
        stop = n - (n % batch_size) if drop_remainder else n
        for i in range(0, stop, batch_size):
            ix = order[i:i + batch_size]
            yield {k: v[ix] for k, v in arrays.items()}


def shard_batch(batch: dict, mesh, batch_axes=("pod", "data")):
    """Device-put a host batch with the batch dim sharded over the mesh's
    data axes (used by the real multi-host launcher path)."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    spec = jax.sharding.PartitionSpec(axes)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
