"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants
from launch/mesh.py):

  compute    = per_device_HLO_FLOPs / PEAK_FLOPS_BF16
  memory     = per_device_HLO_bytes / HBM_BANDWIDTH
  collective = per_device_collective_bytes / ICI_LINK_BANDWIDTH

XLA-CPU's ``cost_analysis()`` reports *per-partition* flops/bytes (the
SPMD module is the per-device program), so no /chips is needed.
Collective bytes are NOT in cost_analysis — we parse the optimized HLO
text and sum operand/output sizes of every collective op, weighted by
the standard ring-transfer factors with the replica-group size parsed
per op.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict

from repro.launch.mesh import (
    HBM_BANDWIDTH, ICI_LINK_BANDWIDTH, PEAK_FLOPS_BF16,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_output_bytes(line: str) -> int:
    """Bytes of the op's output (left of the = sign)."""
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1]
    # output shape is the first shape token after '= '
    m = _SHAPE_RE.search(line.split("=", 1)[1])
    if not m:
        return 0
    # tuple outputs: sum every shape up to the op name
    rhs = line.split("=", 1)[1]
    op_pos = min((rhs.find(c) for c in _COLLECTIVES if rhs.find(c) >= 0),
                 default=-1)
    head = rhs[:op_pos] if op_pos > 0 else rhs[:m.end()]
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _IOTA_GROUPS_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return max(int(m.group(2)), 1)
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Per-device bytes moved over ICI, by collective type (ring model).
    Also records the top-8 largest individual collectives for §Perf
    diagnosis (what exactly is being moved)."""
    out: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    top: list = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("//") or "=" not in stripped:
            continue
        for op in _COLLECTIVES:
            if re.search(rf"\b{op}(-start)?\(", stripped):
                b = _line_output_bytes(stripped)
                n = _group_size(stripped, n_devices)
                if op == "all-reduce":
                    moved = 2.0 * (n - 1) / max(n, 1) * b
                elif op == "all-gather":
                    moved = (n - 1) / max(n, 1) * b
                elif op == "reduce-scatter":
                    moved = (n - 1) * b            # output is the shard
                elif op == "all-to-all":
                    moved = (n - 1) / max(n, 1) * b
                else:  # collective-permute
                    moved = b
                out[op] += moved
                counts[op] += 1
                m = _SHAPE_RE.search(stripped.split("=", 1)[1])
                top.append((moved, op, m.group(0) if m else "?", n))
                break
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["counts"] = dict(counts)  # type: ignore
    top.sort(reverse=True)
    out["top_ops"] = [  # type: ignore
        {"moved_bytes": t[0], "op": t[1], "shape": t[2], "group": t[3]}
        for t in top[:8]]
    return dict(out)


def roofline_terms(cost: dict, hlo_text: str, n_devices: int) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text, n_devices)
    terms = {
        "per_device_flops": flops,
        "per_device_bytes": bytes_accessed,
        "per_device_collective_bytes": coll["total"],
        "collective_breakdown": {k: v for k, v in coll.items()
                                 if k not in ("total", "counts", "top_ops")},
        "collective_counts": coll.get("counts", {}),
        "collective_top_ops": coll.get("top_ops", []),
        "t_compute": flops / PEAK_FLOPS_BF16,
        "t_memory": bytes_accessed / HBM_BANDWIDTH,
        "t_collective": coll["total"] / ICI_LINK_BANDWIDTH,
    }
    dom = max(("compute", "memory", "collective"),
              key=lambda k: terms[f"t_{k}"])
    terms["bottleneck"] = dom
    t_max = terms[f"t_{dom}"]
    t_sum = terms["t_compute"] + terms["t_memory"] + terms["t_collective"]
    terms["roofline_fraction"] = (terms["t_compute"] / t_max) if t_max else 0.0
    terms["t_bound"] = t_max
    return terms


def model_flops(cfg, shape, n_layers_active=None) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (forward-only serving), with
    N = active params for MoE."""
    n = cfg.param_count(active_only=cfg.moe is not None)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
