"""Pallas TPU kernel: flash-decode — one query token vs a long KV cache.

The decode-shape hot loop (decode_32k / long_500k).  The KV cache
streams through VMEM in (BLOCK_L × hd) tiles along the cache-length
grid axis while the online-softmax state (m, l, acc) rides in VMEM
scratch; the query vector is resident.  GQA again via index-map head
folding (no KV duplication).  Validity (ring-buffer slots, TTL holes,
sliding-window horizon) arrives as a precomputed (B, L) boolean mask —
one predicated VPU op per tile, no gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_L = 512


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr, acc_scr):
    il = pl.program_id(2)
    nl = pl.num_programs(2)

    @pl.when(il == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)[None, :]          # (1, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)                # (BL, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    valid = valid_ref[0]                                  # (BL,)
    hd = q.shape[-1]
    s = jax.lax.dot_general(q * hd ** -0.5, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, BL)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(il == nl - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       )[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def decode_attention(q, k, v, kv_valid, *, block_l: int = DEFAULT_BLOCK_L,
                     interpret: bool = True):
    """q: (B, H, hd); k, v: (B, L, KV, hd); kv_valid: (B, L) bool."""
    B, H, hd = q.shape
    L, KV = k.shape[1], k.shape[2]
    G = H // KV
    bl = min(block_l, L)
    nl = -(-L // bl)
    pad = nl * bl - L
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))

    grid = (B, H, nl)
    fn = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, il: (b, h, 0)),
            pl.BlockSpec((1, bl, 1, hd), lambda b, h, il: (b, il, h // G, 0)),
            pl.BlockSpec((1, bl, 1, hd), lambda b, h, il: (b, il, h // G, 0)),
            pl.BlockSpec((1, bl), lambda b, h, il: (b, il)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, il: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(q, k, v, kv_valid)
