"""Data pipeline (tokenizer, corpora, batching) + synthetic pipeline."""
import numpy as np
import pytest

from repro.core.synth import (
    TemplateGenerator, export_jsonl, generate_synthetic_pairs, import_jsonl,
    records_to_dataset,
)
from repro.data import (
    HashTokenizer, PAD, BOS, EOS, iter_batches, make_pair_dataset,
    make_query_stream, sample_query, tokenize_pairs,
)


def test_tokenizer_deterministic_and_bounded():
    tok = HashTokenizer(vocab_size=1024)
    a1, m1 = tok.encode("What are the symptoms of diabetes?", 16)
    a2, m2 = tok.encode("What are the symptoms of diabetes?", 16)
    np.testing.assert_array_equal(a1, a2)
    assert a1[0] == BOS and a1[m1.sum() - 1] == EOS
    assert a1.max() < 1024 and (a1[~m1] == PAD).all()


def test_tokenizer_distinguishes_words():
    tok = HashTokenizer(vocab_size=50368)
    a, _ = tok.encode("treat heart attack", 8)
    b, _ = tok.encode("diagnose heart attack", 8)
    assert not np.array_equal(a, b)


@pytest.mark.parametrize("domain", ["medical", "quora"])
def test_pair_dataset_structure(domain):
    ds = make_pair_dataset(domain, 400, seed=1)
    assert len(ds) == 400
    pos_frac = ds.labels.mean()
    assert 0.4 < pos_frac < 0.6
    # positives share entity+aspect wording structure but differ textually
    dup_same = sum(1 for q1, q2, l in zip(ds.q1, ds.q2, ds.labels)
                   if l == 1 and q1 == q2)
    assert dup_same / max(ds.labels.sum(), 1) < 0.2  # mostly paraphrased
    tr, ev = ds.split(0.2, seed=0)
    assert len(tr) + len(ev) == 400 and len(ev) == 80


def test_query_stream_has_repeats():
    stream = make_query_stream("medical", 300, seed=0, repeat_frac=0.33)
    keys = [(q.entity, q.aspect) for q in stream]
    n_repeat = len(keys) - len(set(keys))
    assert n_repeat > 30  # ~33% repetition structure


def test_batching_shapes():
    ds = make_pair_dataset("quora", 100, seed=2)
    tok = HashTokenizer(vocab_size=2048)
    arrays = tokenize_pairs(ds, tok, max_len=24)
    batches = list(iter_batches(arrays, 16, epochs=2))
    assert len(batches) == 2 * (100 // 16)
    b = batches[0]
    assert b["tok1"].shape == (16, 24) and b["label"].shape == (16,)


def test_synth_pipeline_dual_labeling():
    rng = np.random.default_rng(0)
    unlabeled = [sample_query(rng, "medical") for _ in range(20)]
    gen = TemplateGenerator(seed=1)
    records = generate_synthetic_pairs(unlabeled, gen, n_pos=2, n_neg=2)
    assert len(records) == 80
    pos = [r for r in records if r.is_duplicate == 1]
    neg = [r for r in records if r.is_duplicate == 0]
    assert len(pos) == len(neg) == 40
    # paraphrases differ in surface form from the original
    assert all(r.question1 != r.question2 for r in pos)
    ds = records_to_dataset(records)
    assert len(ds) == 80 and ds.labels.sum() == 40


def test_synth_bit_reproducible_for_fixed_seed():
    """`generate_synthetic_pairs` must be a pure function of (queries,
    generator seed): a fresh generator, a reused generator, and a
    different call order over the same queries all produce identical
    records.  The per-query RNG is derived from (seed, query content),
    so no call-order state can leak between queries — the §11 refresh
    backfills training data with this generator on a background thread
    and must be replayable."""
    rng = np.random.default_rng(0)
    queries = [sample_query(rng, "medical") for _ in range(12)]

    def key(recs):
        return [(r.question1, r.question2, r.is_duplicate) for r in recs]

    a = generate_synthetic_pairs(queries, TemplateGenerator(seed=7),
                                 n_pos=2, n_neg=2)
    b = generate_synthetic_pairs(queries, TemplateGenerator(seed=7),
                                 n_pos=2, n_neg=2)
    assert key(a) == key(b)
    # a generator instance already used on other queries yields the
    # same records for these queries (no hidden call-order state)
    gen = TemplateGenerator(seed=7)
    gen.paraphrases(queries[-1], 3)
    gen.distinct(queries[0], 3)
    c = generate_synthetic_pairs(queries, gen, n_pos=2, n_neg=2)
    assert key(a) == key(c)
    # reversed query order: per-query records are order-independent
    d = generate_synthetic_pairs(list(reversed(queries)),
                                 TemplateGenerator(seed=7), n_pos=2,
                                 n_neg=2)
    assert sorted(key(a)) == sorted(key(d))
    # a different seed actually moves the output
    e = generate_synthetic_pairs(queries, TemplateGenerator(seed=8),
                                 n_pos=2, n_neg=2)
    assert key(a) != key(e)


def test_synth_jsonl_roundtrip(tmp_path):
    rng = np.random.default_rng(3)
    unlabeled = [sample_query(rng, "quora") for _ in range(5)]
    records = generate_synthetic_pairs(unlabeled, TemplateGenerator(0))
    p = str(tmp_path / "synth.jsonl")
    export_jsonl(records, p)
    back = import_jsonl(p)
    assert [r.question1 for r in back] == [r.question1 for r in records]
    assert [r.is_duplicate for r in back] == [r.is_duplicate for r in records]
