"""Distributed training launcher.

Runs real sharded training steps for any registry arch on whatever mesh
the host provides (all devices).  On this CPU container it is exercised
with reduced configs (--smoke); on a real pod the same code path takes
the full config and the production mesh.

    PYTHONPATH=src python -m repro.launch.train \
        --arch granite-moe-3b-a800m --smoke --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.sharding import TRAIN_RULES, sharding_tree
from repro.models import init_lm, split
from repro.models.param import A
from repro.serving.frontend import stub_frontend_embeds
from repro.training import adamw, linear_warmup_cosine, make_train_step
from repro.training.checkpoint import save_checkpoint
from repro.training.optim import AdamState


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(len(jax.devices()), 1))
    print(f"arch={cfg.name} params={cfg.param_count():,} "
          f"mesh={dict(mesh.shape)}")

    params = init_lm(cfg, jax.random.PRNGKey(0))
    pv, pax = split(params)
    init_opt, update = adamw(
        linear_warmup_cosine(args.lr, 10, args.steps),
        max_grad_norm=1.0)
    opt = init_opt(pv)
    step_fn = make_train_step(cfg, update)

    in_sh = (sharding_tree(pv, pax, mesh, TRAIN_RULES),)
    rng = np.random.default_rng(0)

    with mesh:
        jitted = jax.jit(step_fn, in_shardings=None, donate_argnums=(0, 1))
        t0 = time.perf_counter()
        for i in range(args.steps):
            batch = {"tokens": jnp.asarray(rng.integers(
                0, cfg.vocab_size, (args.batch, args.seq)), jnp.int32)}
            if cfg.frontend:
                batch["frontend_embeds"] = stub_frontend_embeds(
                    cfg, args.batch, seed=i)
            pv, opt, metrics = jitted(pv, opt, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e}")
        dt = time.perf_counter() - t0
    tokens = args.steps * args.batch * args.seq
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({tokens / dt:.0f} tokens/s on this host)")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": pv, "config": cfg.name})
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
