"""Semantic cache core: store semantics (insert/query/LRU/TTL), the
SemanticCache wrapper, and losses/metrics behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SemanticCache, evict_older_than, init_store, insert, insert_batch,
    metrics_at_threshold, occupancy, online_contrastive_loss,
    contrastive_loss, pair_classification_metrics, query, touch,
)

rng = np.random.default_rng(7)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def test_store_insert_and_exact_query():
    st = init_store(capacity=16, dim=8)
    embs = jnp.asarray(_unit(rng.standard_normal((5, 8)).astype(np.float32)))
    st = insert_batch(st, embs, jnp.arange(5))
    res = query(st, embs, threshold=0.99, k=1)
    assert bool(jnp.all(res.hit))
    np.testing.assert_array_equal(np.asarray(res.value_ids[:, 0]),
                                  np.arange(5))
    np.testing.assert_allclose(np.asarray(res.scores[:, 0]), 1.0, atol=1e-5)


def test_store_miss_below_threshold():
    st = init_store(capacity=8, dim=16)
    a = jnp.asarray(_unit(rng.standard_normal((1, 16)).astype(np.float32)))
    b = jnp.asarray(_unit(rng.standard_normal((1, 16)).astype(np.float32)))
    st = insert(st, a[0], jnp.asarray(0))
    res = query(st, b, threshold=0.95, k=1)
    assert not bool(res.hit[0])


def test_store_lru_eviction():
    st = init_store(capacity=3, dim=4)
    e = jnp.asarray(_unit(np.eye(4, dtype=np.float32)))
    st = insert_batch(st, e[:3], jnp.arange(3))
    # touch slot of key 1 and 2 (make key 0 the LRU)
    res = query(st, e[1:3], threshold=0.9)
    st = touch(st, res.slots[:, 0], res.hit)
    st = insert(st, e[3], jnp.asarray(3))  # must evict key 0
    res0 = query(st, e[0:1], threshold=0.9)
    assert not bool(res0.hit[0])
    res3 = query(st, e[3:4], threshold=0.9)
    assert bool(res3.hit[0])


def test_store_ttl_eviction():
    st = init_store(capacity=8, dim=4)
    e = jnp.asarray(_unit(np.eye(4, dtype=np.float32)))
    st = insert_batch(st, e, jnp.arange(4))
    st = evict_older_than(st, max_age=2)  # clock=4; ages 3,2,1,0
    assert float(occupancy(st)) == pytest.approx(3 / 8)


def test_semantic_cache_end_to_end():
    from repro.cache_service.protocol import CacheRequest
    cache = SemanticCache(capacity=32, dim=16, threshold=0.9)
    e = _unit(rng.standard_normal((4, 16)).astype(np.float32))
    plan = cache.plan(CacheRequest.build(e))
    assert not plan.hit.any()
    cache.commit(cache.plan(CacheRequest.build(e[:2])),
                 ["resp-a", "resp-b"])
    # re-planning after the commit: first two rows now hit
    plan = cache.plan(CacheRequest.build(e))
    assert list(plan.hit) == [True, True, False, False]
    assert plan.responses[0] == "resp-a"
    assert plan.responses[1] == "resp-b"
    assert len(cache) == 2
    # near-duplicate (small perturbation) still hits
    e_near = _unit(e[:1] + 0.01 * rng.standard_normal((1, 16)))
    plan = cache.plan(CacheRequest.build(e_near))
    assert plan.hit[0] and plan.responses[0] == "resp-a"


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def test_online_loss_focuses_on_hard_pairs():
    # construct: 1 easy positive (identical), 1 hard positive (far),
    # 1 easy negative (orthogonal), 1 hard negative (close)
    d = 32
    base = _unit(rng.standard_normal((1, d)).astype(np.float32))
    orth = _unit(rng.standard_normal((1, d)).astype(np.float32))
    e1 = jnp.asarray(np.concatenate([base, base, base, base]))
    e2 = jnp.asarray(np.concatenate([
        base,                         # pos, dist 0 (easy)
        _unit(base + 2.0 * orth),     # pos, far  (hard)
        orth,                         # neg, far  (easy)
        _unit(base + 0.1 * orth),     # neg, close (hard)
    ]))
    lab = jnp.asarray([1, 1, 0, 0])
    loss = online_contrastive_loss(e1, e2, lab)
    # removing the two easy pairs must not change the (unnormalised) loss
    loss_hard_only = online_contrastive_loss(
        e1[jnp.asarray([1, 3])], e2[jnp.asarray([1, 3])],
        jnp.asarray([1, 0]))
    np.testing.assert_allclose(float(loss) * 4, float(loss_hard_only) * 2,
                               rtol=1e-5)


def test_online_loss_gradients_finite():
    e1 = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    e2 = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 2, 16))
    g = jax.grad(lambda a: online_contrastive_loss(a, e2, lab))(e1)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_plain_contrastive_uses_all_pairs():
    e1 = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    e2 = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    lab = jnp.ones(8, jnp.int32)
    assert float(contrastive_loss(e1, e2, lab)) > 0


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_perfect_separation():
    scores = np.concatenate([np.full(50, 0.9), np.full(50, 0.1)])
    labels = np.concatenate([np.ones(50, np.int32), np.zeros(50, np.int32)])
    m = pair_classification_metrics(scores, labels)
    assert m["precision"] == 1.0 and m["recall"] == 1.0
    assert m["ap"] == 1.0 and m["accuracy"] == 1.0
    assert 0.1 < m["f1_threshold"] < 0.9


def test_metrics_random_scores_ap_near_half():
    scores = rng.random(2000)
    labels = rng.integers(0, 2, 2000).astype(np.int32)
    m = pair_classification_metrics(scores, labels)
    assert 0.4 < m["ap"] < 0.6


def test_metrics_at_threshold():
    scores = np.asarray([0.9, 0.8, 0.3, 0.2])
    labels = np.asarray([1, 0, 1, 0], np.int32)
    m = metrics_at_threshold(scores, labels, 0.5)
    assert m["precision"] == 0.5 and m["recall"] == 0.5
