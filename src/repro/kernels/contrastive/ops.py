"""Dispatch wrapper assembling the online-contrastive scalar loss from
the fused kernel's components (same fallback semantics as core.losses)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.contrastive import kernel as _kernel
from repro.kernels.contrastive import ref as _ref


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def online_contrastive_loss(e1, e2, labels, margin: float = 0.5, *,
                            use_kernel: bool | None = None):
    """Scalar loss identical to core.losses.online_contrastive_loss.

    Note: the fused kernel is a forward-value fast path (serving-side
    eval / mining diagnostics).  Training uses the jnp formulation whose
    VJP XLA derives automatically.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    comp = (_kernel.contrastive_components if use_kernel
            else _ref.contrastive_components)
    if use_kernel:
        pos_loss, neg_loss, min_neg, max_pos = comp(
            e1, e2, labels, margin=margin, interpret=not _on_tpu())
    else:
        pos_loss, neg_loss, min_neg, max_pos = comp(e1, e2, labels,
                                                    margin=margin)
    is_pos = labels.astype(bool)
    any_pos = jnp.any(is_pos)
    any_neg = jnp.any(~is_pos)
    # fallback (all pairs of a class) when the opposite class is absent
    d = _ref_distance(e1, e2)
    all_pos = jnp.sum(jnp.square(d) * is_pos.astype(jnp.float32))
    all_neg = jnp.sum(jnp.square(jnp.maximum(margin - d, 0.0)) *
                      (~is_pos).astype(jnp.float32))
    pos_loss = jnp.where(any_neg, pos_loss, all_pos)
    neg_loss = jnp.where(any_pos, neg_loss, all_neg)
    return (pos_loss + neg_loss) / e1.shape[0]


def _ref_distance(e1, e2):
    e1 = e1.astype(jnp.float32)
    e2 = e2.astype(jnp.float32)
    num = jnp.sum(e1 * e2, axis=-1)
    den = jnp.linalg.norm(e1, axis=-1) * jnp.linalg.norm(e2, axis=-1)
    return 1.0 - num / jnp.maximum(den, 1e-9)
