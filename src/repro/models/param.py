"""Parameter container with logical sharding axes.

Pure-JAX module system: parameters are nested dicts whose leaves are
:class:`Param` — a (value, logical_axes) pair.  ``value`` is either a
``jnp.ndarray`` (real init) or a ``jax.ShapeDtypeStruct`` (abstract init
for dry-runs).  Logical axis names are resolved to mesh axes by
``repro.launch.sharding`` with divisibility-aware fallback.

``split(tree)`` -> (values, axes) lets the training/serving code work on
plain array pytrees while the launcher keeps the axes tree for
PartitionSpecs.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Param(NamedTuple):
    value: Any
    axes: Tuple[str, ...]


def is_param(x) -> bool:
    return isinstance(x, Param)


def encode_axes(axes) -> str:
    """Logical axes as a comma-joined *string* so that axes trees are
    valid pytrees structurally identical to their value trees (a tuple
    leaf would be flattened by tree_map)."""
    if isinstance(axes, str):
        return axes
    return ",".join("." if a is None else a for a in axes)


def decode_axes(s: str) -> Tuple:
    if s == "":
        return ()
    return tuple(None if a == "." else a for a in s.split(","))


def A(*names) -> str:
    return encode_axes(names)


def split(tree):
    """Split a Param tree into (values, axes) trees of identical structure.
    Axes leaves are encoded strings (see encode_axes)."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree_util.tree_map(lambda p: encode_axes(p.axes), tree,
                                  is_leaf=is_param)
    return values, axes


def merge(values, axes):
    return jax.tree_util.tree_map(Param, values, axes)


class Initializer:
    """Creates parameters — real arrays or abstract ShapeDtypeStructs.

    A single init codepath serves both the trainer (real=True) and the
    multi-pod dry-run (real=False: no host memory is allocated for the
    398B-parameter configs).
    """

    def __init__(self, key: jax.Array | None, dtype=jnp.float32, abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def normal(self, shape, axes, stddev=0.02):
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        v = jax.random.normal(self._next_key(), tuple(shape), self.dtype) * jnp.asarray(
            stddev, self.dtype)
        return Param(v, tuple(axes))

    def lecun(self, shape, axes, fan_in=None):
        fan = fan_in if fan_in is not None else int(np.prod(shape[:-1]))
        return self.normal(shape, axes, stddev=1.0 / max(1.0, fan) ** 0.5)

    def zeros(self, shape, axes):
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        return Param(jnp.zeros(tuple(shape), self.dtype), tuple(axes))

    def ones(self, shape, axes):
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        return Param(jnp.ones(tuple(shape), self.dtype), tuple(axes))

    def constant(self, shape, axes, value):
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        return Param(jnp.full(tuple(shape), value, self.dtype), tuple(axes))


def stack_params(trees):
    """Stack a list of same-structure Param trees along a new leading
    'layers' axis (used to build scanned layer parameters)."""

    def _stack(*ps):
        vals = [p.value for p in ps]
        axes = ("layers",) + ps[0].axes
        if isinstance(vals[0], jax.ShapeDtypeStruct):
            v = jax.ShapeDtypeStruct((len(vals),) + tuple(vals[0].shape), vals[0].dtype)
        else:
            v = jnp.stack(vals)
        return Param(v, axes)

    return jax.tree_util.tree_map(_stack, *trees, is_leaf=is_param)


def stack_values(trees):
    """Stack a list of same-structure plain-value trees along a new
    leading axis (arrays or ShapeDtypeStructs)."""

    def _stack(*vs):
        if isinstance(vs[0], jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((len(vs),) + tuple(vs[0].shape),
                                        vs[0].dtype)
        return jnp.stack(vs)

    return jax.tree_util.tree_map(_stack, *trees)


def prefix_axes(tree, prefix: str = "layers"):
    """Prepend a leading logical axis to every encoded-axes leaf."""
    return jax.tree_util.tree_map(
        lambda s: prefix + ("," + s if s else ""), tree)


def param_bytes(tree) -> int:
    vals, _ = split(tree)
    leaves = jax.tree_util.tree_leaves(vals)
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves)
