"""GQA/MQA attention: training (full-seq), prefill (+KV cache build) and
single-token decode against a (possibly ring-buffer) KV cache.

TPU adaptation notes (DESIGN.md §3):
 * full-sequence attention uses an online-softmax *chunked* formulation
   (lax.scan over KV blocks) above ``CHUNK_THRESHOLD`` — flash-attention
   expressed in XLA, O(S·chunk) memory instead of O(S²).  The Pallas
   kernel in repro/kernels/flash_attention is the hand-tiled variant of
   the same math; `ops.flash_attention` picks kernel vs this fallback.
 * RoPE is applied to K at cache-write time, so decode needs no position
   recompute; the ring buffer (sliding window) stores absolute positions
   per slot for masking.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.param import Initializer

CHUNK_THRESHOLD = 2048
KV_CHUNK = 1024
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(ini: Initializer, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": ini.lecun((d, h, hd), ("embed", "heads", "head_dim"), fan_in=d),
        "wk": ini.lecun((d, kv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wv": ini.lecun((d, kv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wo": ini.lecun((h, hd, d), ("heads", "head_dim", "embed"), fan_in=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = ini.zeros((h, hd), ("heads", "head_dim"))
        p["bk"] = ini.zeros((kv, hd), ("kv_heads", "head_dim"))
        p["bv"] = ini.zeros((kv, hd), ("kv_heads", "head_dim"))
    return p


def _project_qkv(p, cfg: ModelConfig, x):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _out_proj(p, cfg: ModelConfig, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


# ---------------------------------------------------------------------------
# Core attention math (GQA, masked, dense or chunked)
# ---------------------------------------------------------------------------

def _mask_logits(scores, q_pos, kv_pos, *, causal, window, kv_valid):
    """scores: (..., S_q, S_kv); q_pos: (S_q,); kv_pos: (S_kv,) or (B,S_kv)."""
    if kv_pos.ndim == 1:
        kv_pos = kv_pos[None]            # (1, S_kv)
    rel_q = q_pos[None, :, None]          # (1, S_q, 1)
    rel_k = kv_pos[:, None, :]            # (B|1, 1, S_kv)
    ok = jnp.ones(jnp.broadcast_shapes(rel_q.shape, rel_k.shape), bool)
    if causal:
        ok &= rel_k <= rel_q
    if window > 0:
        ok &= (rel_q - rel_k) < window
        if not causal:
            ok &= (rel_k - rel_q) < window
    if kv_valid is not None:
        ok &= kv_valid[:, None, :]
    # broadcast over head dims: scores (B, KV, G, S_q, S_kv)
    return jnp.where(ok[:, None, None], scores, NEG_INF)


def gqa_attention(q, k, v, *, q_pos, kv_pos, causal, window,
                  kv_valid=None, chunked: Optional[bool] = None,
                  unroll: bool = False, acc_dtype=jnp.float32):
    """q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd).  Returns (B,Sq,H,hd).

    unroll=True replaces the KV-chunk lax.scan with a python loop so the
    dry-run's cost_analysis counts every chunk (see ModelConfig
    .scan_layers); it also widens chunks to bound HLO size.

    acc_dtype: dtype of the softmax probabilities and the PV
    accumulator (the two big attention buffers).  Logit max/denominator
    stay f32.  bf16 here halves attention HBM traffic — the ModelConfig
    .attn_f32=False §Perf lever.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd) * scale
    if chunked is None:
        chunked = Skv > CHUNK_THRESHOLD and Sq > 1
    if not chunked:
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                       k.astype(jnp.float32))
        s = _mask_logits(s, q_pos, kv_pos, causal=causal, window=window,
                         kv_valid=kv_valid)
        w = jax.nn.softmax(s, axis=-1).astype(acc_dtype)
        o = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(acc_dtype))
        return o.reshape(B, Sq, H, hd).astype(q.dtype)

    # ---- chunked online-softmax (flash-in-XLA) ----
    kv_chunk = KV_CHUNK if not unroll else max(KV_CHUNK, -(-Skv // 32))
    n_chunks = -(-Skv // kv_chunk)
    pad = n_chunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos_p = jnp.pad(kv_pos, [(0, 0)] * (kv_pos.ndim - 1) + [(0, pad)],
                           constant_values=-1)
        valid_pad = jnp.pad(
            kv_valid if kv_valid is not None
            else jnp.ones((B, Skv), bool),
            ((0, 0), (0, pad)), constant_values=False)
    else:
        kv_pos_p = kv_pos
        valid_pad = kv_valid if kv_valid is not None else jnp.ones((B, Skv), bool)

    kc = k.reshape(B, n_chunks, kv_chunk, KV, hd)
    vc = v.reshape(B, n_chunks, kv_chunk, KV, hd)
    if kv_pos_p.ndim == 1:
        kv_pos_p = jnp.broadcast_to(kv_pos_p[None], (B, n_chunks * kv_chunk))
    pc = kv_pos_p.reshape(B, n_chunks, kv_chunk)
    mc = valid_pad.reshape(B, n_chunks, kv_chunk)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i, valid_i = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                       k_i.astype(jnp.float32))
        s = _mask_logits(s, q_pos, p_i, causal=causal, window=window,
                         kv_valid=valid_i)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p_ = jnp.exp(s - m_new[..., None]).astype(acc_dtype)
        l_new = l * alpha + jnp.sum(p_, axis=-1, dtype=jnp.float32)
        acc_new = acc * alpha[..., None].astype(acc_dtype) + jnp.einsum(
            "bkgqs,bskh->bkgqh", p_, v_i.astype(acc_dtype))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), acc_dtype)
    if unroll:
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, (kc[:, i], vc[:, i], pc[:, i], mc[:, i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             pc.transpose(1, 0, 2), mc.transpose(1, 0, 2)))
    o = acc.astype(jnp.float32) / jnp.maximum(l[..., None], 1e-30)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return o.astype(q.dtype)


def local_window_attention(q, k, v, *, positions, window, causal,
                           acc_dtype=jnp.float32, q_chunk: int = 1024):
    """Structurally-sparse sliding-window attention: each q chunk
    attends only to its (window + chunk) KV slice — O(S·W) traffic
    instead of O(S²)-with-masking.  This is what the Pallas kernel's
    @pl.when block-skipping achieves; the XLA fallback needs the
    blocking to be explicit (static slices, unrolled — §Perf lever).
    """
    B, S, H, hd = q.shape
    C = min(q_chunk, S)
    nq = -(-S // C)
    pad = nq * C - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, (0, pad),
                            constant_values=int(positions.shape[0]) - 1)
    outs = []
    for iq in range(nq):
        q_lo = iq * C
        q_hi = min(q_lo + C, S)
        kv_lo = max(0, q_lo - window + 1)
        o = gqa_attention(
            q[:, q_lo:q_lo + C], k[:, kv_lo:q_hi], v[:, kv_lo:q_hi],
            q_pos=positions[q_lo:q_lo + C], kv_pos=positions[kv_lo:q_hi],
            causal=causal, window=window, chunked=False,
            acc_dtype=acc_dtype)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1)
    return out[:, :S]


# ---------------------------------------------------------------------------
# Layer-level entry points
# ---------------------------------------------------------------------------

def apply_full(p, cfg: ModelConfig, x, positions):
    """Full-sequence attention (training / encoder).  x: (B,S,d)."""
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.use_rope:
        sin, cos = layers.rope_frequencies(cfg, positions)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    acc_dtype = jnp.float32 if cfg.attn_f32 else jnp.bfloat16
    W = cfg.sliding_window
    if W > 0 and cfg.causal and x.shape[1] > 2 * W:
        o = local_window_attention(q, k, v, positions=positions, window=W,
                                   causal=True, acc_dtype=acc_dtype,
                                   q_chunk=min(1024, W))
    else:
        o = gqa_attention(q, k, v, q_pos=positions, kv_pos=positions,
                          causal=cfg.causal, window=W,
                          unroll=cfg.unroll_inner, acc_dtype=acc_dtype)
    return _out_proj(p, cfg, o)


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    return min(cfg.sliding_window, seq_len) if cfg.sliding_window > 0 else seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, abstract: bool = False):
    """Empty KV cache for one attention layer."""
    L = cache_len_for(cfg, seq_len)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    shapes = {
        "k": ((batch, L, kv, hd), dt),
        "v": ((batch, L, kv, hd), dt),
        "pos": ((batch, L), jnp.dtype(jnp.int32)),
    }
    if abstract:
        return {n: jax.ShapeDtypeStruct(s, d) for n, (s, d) in shapes.items()}
    out = {n: jnp.zeros(s, d) for n, (s, d) in shapes.items() if n != "pos"}
    out["pos"] = jnp.full(shapes["pos"][0], -1, jnp.int32)
    return out


def cache_axes():
    return {
        "k": ("batch", "cache", "kv_heads", "head_dim"),
        "v": ("batch", "cache", "kv_heads", "head_dim"),
        "pos": ("batch", "cache"),
    }


def apply_prefill(p, cfg: ModelConfig, x, positions, cache):
    """Run full attention over the prompt AND fill the cache.

    Returns (y, new_cache).  With a sliding window the cache keeps only
    the last `window` tokens, written at slots (t mod window).
    """
    B, S, _ = x.shape
    L = cache["k"].shape[1]
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.use_rope:
        sin, cos = layers.rope_frequencies(cfg, positions)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    o = gqa_attention(q, k, v, q_pos=positions, kv_pos=positions,
                      causal=True, window=cfg.sliding_window,
                      unroll=cfg.unroll_inner,
                      acc_dtype=jnp.float32 if cfg.attn_f32 else jnp.bfloat16)
    y = _out_proj(p, cfg, o)

    if L >= S:
        new_k = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                             (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                             (0, 0, 0, 0))
        pos_row = jnp.pad(positions.astype(jnp.int32), (0, L - S),
                          constant_values=-1)
        new_pos = jnp.broadcast_to(pos_row[None], (B, L))
    else:
        # keep last L tokens, slot t % L
        tail = positions[S - L:]                       # (L,)
        slots = jnp.mod(tail, L)                       # (L,)
        new_k = cache["k"].at[:, slots].set(k[:, S - L:].astype(cache["k"].dtype))
        new_v = cache["v"].at[:, slots].set(v[:, S - L:].astype(cache["v"].dtype))
        new_pos = jnp.zeros((B, L), jnp.int32).at[:, slots].set(
            jnp.broadcast_to(tail[None], (B, L)))
    return y, {"k": new_k, "v": new_v, "pos": new_pos}


def apply_decode(p, cfg: ModelConfig, x, cur_len, cache):
    """One-token decode.  x: (B,1,d); cur_len: () int32 — tokens already
    in the cache (the new token's absolute position)."""
    B = x.shape[0]
    L = cache["k"].shape[1]
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.use_rope:
        pos = jnp.asarray(cur_len, jnp.int32)[None]      # (1,)
        sin, cos = layers.rope_frequencies(cfg, pos)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    slot = jnp.mod(cur_len, L)
    new_k = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    new_pos = jax.lax.dynamic_update_slice(
        cache["pos"],
        jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32)[None, None], (B, 1)),
        (0, slot))
    valid = new_pos >= 0
    q_pos = jnp.asarray(cur_len, jnp.int32)[None]
    o = gqa_attention(q, new_k, new_v, q_pos=q_pos, kv_pos=new_pos,
                      causal=True, window=cfg.sliding_window,
                      kv_valid=valid, chunked=False)
    y = _out_proj(p, cfg, o)
    return y, {"k": new_k, "v": new_v, "pos": new_pos}
