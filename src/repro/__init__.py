"""LangCache — semantic caching for LLM serving (Gill et al. 2025) as a
multi-pod JAX training/serving framework.

Packages: configs (arch registry), models (backbone zoo), core (the
paper's cache/losses/trainer/synth), data, training, serving, kernels
(Pallas), launch (mesh/sharding/dryrun/roofline).
"""

__version__ = "1.0.0"
