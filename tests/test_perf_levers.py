"""§Perf levers must not change semantics: chunked loss is exact,
bf16 attention is close, shard_map lookup matches the GSPMD reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.store import init_store, insert_batch, query, query_sharded
from repro.launch.mesh import make_host_mesh
from repro.models import init_lm, lm_loss, split

rng = np.random.default_rng(11)


@pytest.fixture(scope="module")
def phi3_setup():
    cfg = get_config("phi3-mini-3.8b").reduced()
    pv, _ = split(init_lm(cfg, jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                              cfg.vocab_size)
    return cfg, pv, toks


def test_loss_chunk_exact(phi3_setup):
    cfg, pv, toks = phi3_setup
    l0, _ = lm_loss(pv, cfg, toks)
    for chunk in (1, 8, 17, 32):
        l1, _ = lm_loss(pv, cfg.replace(loss_chunk=chunk), toks)
        np.testing.assert_allclose(float(l0), float(l1), atol=2e-5)


def test_attn_bf16_close(phi3_setup):
    cfg, pv, toks = phi3_setup
    l0, _ = lm_loss(pv, cfg, toks)
    l1, _ = lm_loss(pv, cfg.replace(attn_f32=False), toks)
    assert abs(float(l0) - float(l1)) < 0.05


def test_attn_bf16_grads_finite(phi3_setup):
    cfg, pv, toks = phi3_setup
    cfg2 = cfg.replace(attn_f32=False, loss_chunk=8)
    g = jax.grad(lambda p: lm_loss(p, cfg2, toks)[0])(pv)
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree_util.tree_leaves(g))


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def test_query_sharded_matches_reference():
    mesh = make_host_mesh(1, 1)  # 'model' axis of size 1 on CPU
    st = init_store(capacity=128, dim=16)
    embs = jnp.asarray(_unit(rng.standard_normal((50, 16)).astype(
        np.float32)))
    st = insert_batch(st, embs, jnp.arange(50))
    q = jnp.asarray(_unit(rng.standard_normal((8, 16)).astype(np.float32)))
    ref = query(st, q, threshold=0.8, k=2)
    with mesh:
        out = jax.jit(lambda s, qq: query_sharded(
            s, qq, threshold=0.8, k=2, mesh=mesh))(st, q)
    np.testing.assert_allclose(np.asarray(ref.scores),
                               np.asarray(out.scores), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ref.value_ids),
                                  np.asarray(out.value_ids))
    np.testing.assert_array_equal(np.asarray(ref.hit), np.asarray(out.hit))
