"""Shared benchmark substrate: datasets, embedder zoo, timing.

CPU-scale stand-ins for the paper's experimental setup (DESIGN.md §5-6):
the embedder is the reduced ModernBERT-family config, datasets are the
deterministic domain corpora, and the paper's closed-source comparison
rows are represented by local baselines of the same character.
"""
from __future__ import annotations

import time
from functools import lru_cache

from repro.configs import get_config
from repro.core import EmbedderTrainer, FinetuneConfig
from repro.core.embedders import (
    EncoderEmbedder, HashNgramEmbedder, RandomProjectionEmbedder,
)
from repro.data import HashTokenizer, make_pair_dataset

VOCAB = 4096
MAX_LEN = 24
N_TRAIN = 2048
N_EVAL = 256


@lru_cache(maxsize=None)
def embedder_cfg():
    return get_config("modernbert-149m").reduced(vocab_size=VOCAB)


@lru_cache(maxsize=None)
def big_encoder_cfg():
    """Stand-in for the '7B-class general encoder' comparison row: the
    same family scaled 4x deeper/wider, untuned."""
    return get_config("modernbert-149m").reduced(
        vocab_size=VOCAB, n_layers=4, d_model=256, n_heads=8,
        head_dim=32, d_ff=512).replace(name="modernbert-149m-big-smoke")


@lru_cache(maxsize=None)
def tokenizer():
    return HashTokenizer(vocab_size=VOCAB)


@lru_cache(maxsize=None)
def dataset(domain: str, split: str):
    ds = make_pair_dataset(domain, N_TRAIN + N_EVAL, seed=0)
    tr, ev = ds.split(eval_frac=N_EVAL / (N_TRAIN + N_EVAL), seed=1)
    return tr if split == "train" else ev


def finetune_cfg(epochs: int = 4, clip: float | None = 0.5):
    # paper recipe scaled to CPU: online contrastive loss; lr/epochs
    # scaled up for the 1000x-smaller smoke model (margin 0.7 widens the
    # 1-vs-N separation the cache needs)
    return FinetuneConfig(epochs=epochs, batch_size=32, max_len=MAX_LEN,
                          lr=5e-4, max_grad_norm=clip, margin=0.7)


@lru_cache(maxsize=None)
def langcache_embed(domain: str, epochs: int = 4):
    """The paper's artifact: fine-tuned compact encoder on `domain`."""
    trainer = EmbedderTrainer(embedder_cfg(), finetune_cfg(epochs))
    trainer.fit(dataset(domain, "train"), tokenizer())
    return trainer


@lru_cache(maxsize=None)
def base_embed():
    """Untuned base ModernBERT row (the paper's true baseline)."""
    return EmbedderTrainer(embedder_cfg(), finetune_cfg(0))


def embedder_rows(domain: str):
    """(name, embed_fn) rows mirroring the paper's Figure-1/2 lineup."""
    tok = tokenizer()
    ft = langcache_embed(domain)
    base = base_embed()
    big = EncoderEmbedder(big_encoder_cfg(), name="big-encoder(untuned)")
    rows = [
        ("LangCache-Embed(finetuned)", lambda t: ft.embed_texts(t, tok)),
        ("modernbert-base(untuned)", lambda t: base.embed_texts(t, tok)),
        ("big-encoder(untuned)", big.embed),
        ("hash-3gram", HashNgramEmbedder(dim=256).embed),
        ("random-projection", RandomProjectionEmbedder(dim=256,
                                                       vocab=VOCAB).embed),
    ]
    return rows


def score_pairs(embed_fn, ds):
    import numpy as np
    e1 = embed_fn(list(ds.q1))
    e2 = embed_fn(list(ds.q2))
    return np.sum(e1 * e2, axis=-1)


def timed(fn, *args, repeats: int = 3):
    """Returns (result, us_per_call)."""
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def fmt_derived(d: dict) -> str:
    return ";".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in d.items())
