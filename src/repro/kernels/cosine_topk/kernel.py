"""Pallas TPU kernel: fused cosine-similarity top-k over a blocked corpus.

The semantic cache's serving hot path (DESIGN.md §3).  The corpus is
streamed through VMEM in (BLOCK_N × D) tiles; the query tile stays
resident; the MXU computes the (Q × BLOCK_N) score panel; and a running
top-k (scores+indices) is carried in VMEM scratch across grid steps —
the (Q × N) score matrix never exists in HBM.

Top-k selection uses k rounds of masked argmax (k is small for cache
lookup, typically 1-4), which vectorises on the VPU — no sort network.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_N = 512


def _select_topk(scores, idx, k):
    """scores: (Q, M) candidates with global indices idx (Q, M) ->
    (Q, k) best by k rounds of masked argmax (unrolled, k small)."""
    out_s, out_i = [], []
    for _ in range(k):
        best = jnp.argmax(scores, axis=-1)                       # (Q,)
        rows = jnp.arange(scores.shape[0])
        out_s.append(scores[rows, best])
        out_i.append(idx[rows, best])
        scores = scores.at[rows, best].set(NEG_INF)
    return jnp.stack(out_s, -1), jnp.stack(out_i, -1)


def _kernel(q_ref, keys_ref, valid_ref, out_s_ref, out_i_ref,
            acc_s, acc_i, *, k: int, block_n: int, n_total: int):
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        acc_s[...] = jnp.full_like(acc_s, NEG_INF)
        acc_i[...] = jnp.zeros_like(acc_i)

    q = q_ref[...].astype(jnp.float32)                # (Q, D)
    kblk = keys_ref[...].astype(jnp.float32)          # (BN, D)
    valid = valid_ref[...]                            # (BN,)
    s = jax.lax.dot_general(q, kblk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, BN)
    col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = valid[None, :] & (col < n_total)
    s = jnp.where(ok, s, NEG_INF)

    blk_s, blk_rel = _select_topk(s, col, k)          # (Q, k) each
    cand_s = jnp.concatenate([acc_s[...], blk_s], axis=-1)   # (Q, 2k)
    cand_i = jnp.concatenate([acc_i[...], blk_rel], axis=-1)
    new_s, new_i = _select_topk(cand_s, cand_i, k)
    acc_s[...] = new_s
    acc_i[...] = new_i

    @pl.when(j == nb - 1)
    def _done():
        out_s_ref[...] = acc_s[...]
        out_i_ref[...] = acc_i[...]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def cosine_topk(q, keys, valid, k: int = 1, *,
                block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """q: (Q, D); keys: (N, D); valid: (N,).  -> ((Q,k) scores, (Q,k) idx)."""
    Q, D = q.shape
    N = keys.shape[0]
    bn = min(block_n, N)
    n_blocks = -(-N // bn)
    pad = n_blocks * bn - N
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, (0, pad))

    grid = (n_blocks,)
    out_shape = (jax.ShapeDtypeStruct((Q, k), jnp.float32),
                 jax.ShapeDtypeStruct((Q, k), jnp.int32))
    fn = pl.pallas_call(
        functools.partial(_kernel, k=k, block_n=bn, n_total=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, D), lambda j: (0, 0)),
            pl.BlockSpec((bn, D), lambda j: (j, 0)),
            pl.BlockSpec((bn,), lambda j: (j,)),
        ],
        out_specs=(pl.BlockSpec((Q, k), lambda j: (0, 0)),
                   pl.BlockSpec((Q, k), lambda j: (0, 0))),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((Q, k), jnp.float32),
            pltpu.VMEM((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )
    return fn(q, keys, valid)
