"""Health / SLO-budget trackers (DESIGN.md §10.3).

The calibrated per-tenant operating point is a *budget* (at most
``max_false_hit_rate`` of novel queries may be served a wrong cached
answer; admissions that duplicate a stored neighbour are waste).  The
registry's counters say what happened since boot; this module tracks
whether each tenant is currently *inside its budget*:

  * per-tenant observed rates, both **EWMA** (drift-sensitive) and
    **windowed** (last ``window`` events, spike-sensitive): plan-time
    hit rate, commit-time duplicate rate, duplicate-*admission*
    (wasted admission) rate;
  * **budget burn**: windowed duplicate-admission rate divided by the
    tenant's false-hit budget — > 1.0 means the tenant is currently
    spending over its calibrated allowance and the feedback loop (§9)
    has not yet caught up;
  * **rebuild overlap accounting**: how many plans were served while a
    shadow IVF rebuild was in flight (the §7.1 overlap window), and
    the distribution of publish stalls (the join+swap on the
    maintenance tick), whose p99 is the number the double-buffer
    exists to keep at lookup scale.

Ingestion (``observe_*``) is a handful of float ops per event and runs
on the hot path; everything that needs a device sync or walks every
tenant (``drain()``) runs at the idle tick — ``CacheService.
maintenance()`` calls it, so the hot path never blocks on host sync.
``drain()`` publishes the current rates as gauges
(``slo_hit_rate``/``slo_dup_admission_rate``/``slo_budget_burn``,
labeled per tenant) into the registry it is given.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclass(frozen=True)
class HealthConfig:
    ewma_alpha: float = 0.05      # per-event EWMA step
    window: int = 512             # windowed-rate width (events)
    default_budget: float = 0.01  # false-hit budget when no better source
    stall_keep: int = 128         # publish stalls kept for the p99


class _Rate:
    """One observed rate: EWMA + sliding window over binary events.

    The window holds (positives, total) *batch* tuples with running
    sums, so ingestion is O(1) in the batch size and the windowed rate
    is a division — no per-event list building on the plan hot path.
    Eviction is at batch granularity: the window covers the most
    recent batches whose totals fit inside ``window`` events (always
    at least the latest batch)."""
    __slots__ = ("ewma", "events", "_alpha", "_window_n", "_batches",
                 "_win_pos", "_win_total")

    def __init__(self, alpha: float, window: int):
        self.ewma: Optional[float] = None
        self.events = 0
        self._alpha = alpha
        self._window_n = window
        self._batches: deque = deque()      # (positives, total)
        self._win_pos = 0
        self._win_total = 0

    def observe(self, outcome: bool) -> None:
        self.observe_batch(1 if outcome else 0, 1)

    def observe_batch(self, positives: int, total: int) -> None:
        """``total`` binary events, ``positives`` of them true, in one
        step: the EWMA decays toward the batch mean with the same time
        constant as ``total`` sequential events (order within a batch
        is meaningless anyway)."""
        if total <= 0:
            return
        positives = min(max(positives, 0), total)
        mean = positives / total
        self.ewma = mean if self.ewma is None else \
            mean + (self.ewma - mean) * (1.0 - self._alpha) ** total
        self._batches.append((positives, total))
        self._win_pos += positives
        self._win_total += total
        while self._win_total > self._window_n and len(self._batches) > 1:
            p, t = self._batches.popleft()
            self._win_pos -= p
            self._win_total -= t
        self.events += total

    @property
    def windowed(self) -> float:
        return self._win_pos / self._win_total if self._win_total else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"ewma": self.ewma if self.ewma is not None else 0.0,
                "windowed": self.windowed, "events": self.events}


class TenantHealth:
    __slots__ = ("hit", "duplicate", "wasted_admission")

    def __init__(self, cfg: HealthConfig):
        self.hit = _Rate(cfg.ewma_alpha, cfg.window)
        self.duplicate = _Rate(cfg.ewma_alpha, cfg.window)
        self.wasted_admission = _Rate(cfg.ewma_alpha, cfg.window)


class HealthTracker:
    def __init__(self, config: Optional[HealthConfig] = None,
                 budget_for: Optional[Callable[[int], float]] = None):
        """``budget_for(tenant)`` supplies the tenant's false-hit
        budget (e.g. from the feedback config); absent, the config
        default applies to every tenant."""
        self.config = config or HealthConfig()
        self._budget_for = budget_for
        self._tenants: Dict[int, TenantHealth] = {}
        # rebuild overlap accounting (§7.1): plans served while a
        # shadow build was in flight, and the publish stalls
        self._plans_at_start: Optional[int] = None
        self._overlap_plans_total = 0
        self._last_overlap_plans = 0
        self._publishes = 0
        self._stalls_s: deque = deque(maxlen=self.config.stall_keep)
        self._drain_gauges: Optional[tuple] = None   # cached per registry

    def _tenant(self, t: int) -> TenantHealth:
        th = self._tenants.get(t)
        if th is None:
            th = self._tenants[t] = TenantHealth(self.config)
        return th

    def set_budget_source(self, budget_for:
                          Optional[Callable[[int], float]]) -> None:
        """Late-bind the budget source (the service wires its feedback
        config here once both objects exist)."""
        self._budget_for = budget_for

    def budget(self, tenant: int) -> float:
        if self._budget_for is not None:
            try:
                b = float(self._budget_for(int(tenant)))
                if b > 0:
                    return b
            except Exception:
                pass
        return self.config.default_budget

    # ------------------------------------------------------------------
    # hot-path ingestion
    # ------------------------------------------------------------------
    def observe_plan(self, tenants, hit) -> None:
        """Plan verdicts for one batch, attributed per tenant."""
        t = np.asarray(tenants).reshape(-1)
        h = np.asarray(hit, bool).reshape(-1)
        if t.size == 0:
            return
        if t[0] == t[-1] and (t == t[0]).all():
            # single-tenant batch (the common case): skip the unique/
            # mask pass entirely
            self._tenant(int(t[0])).hit.observe_batch(
                int(h.sum()), int(t.size))
            return
        for tid in np.unique(t):
            m = t == tid
            self._tenant(int(tid)).hit.observe_batch(
                int(h[m].sum()), int(m.sum()))

    def observe_admission(self, tenant: int, duplicate: bool,
                          admitted: bool) -> None:
        """One commit-time miss event (same stream the §9 feedback
        loop labels): duplicate verdict, and — among admitted rows —
        whether the admission was wasted on a duplicate."""
        th = self._tenant(int(tenant))
        th.duplicate.observe(duplicate)
        if admitted:
            th.wasted_admission.observe(duplicate)

    def observe_rebuild_start(self, plans_now: int) -> None:
        self._plans_at_start = int(plans_now)

    def observe_rebuild_publish(self, plans_now: int,
                                stall_s: float) -> None:
        if self._plans_at_start is not None:
            self._last_overlap_plans = int(plans_now) - self._plans_at_start
            self._overlap_plans_total += self._last_overlap_plans
            self._plans_at_start = None
        self._publishes += 1
        self._stalls_s.append(float(stall_s))

    # ------------------------------------------------------------------
    # idle-tick drain
    # ------------------------------------------------------------------
    def stall_p99_s(self) -> float:
        if not self._stalls_s:
            return 0.0
        return float(np.percentile(np.asarray(self._stalls_s), 99))

    def _gauges(self, registry) -> tuple:
        """Resolve (and cache) the drain gauges for this registry —
        drain() runs every maintenance tick, so it must not rebuild
        metric objects or nested snapshot dicts each time."""
        cached = self._drain_gauges
        if cached is not None and cached[0] is registry:
            return cached
        cached = (
            registry,
            registry.gauge("slo_hit_rate",
                           "observed per-tenant hit rate",
                           labels=("tenant", "kind")),
            registry.gauge("slo_dup_admission_rate",
                           "windowed wasted-admission rate",
                           labels=("tenant",)),
            registry.gauge("slo_budget_burn",
                           "windowed wasted-admission rate / false-hit "
                           "budget", labels=("tenant",)),
            registry.gauge("rebuild_overlap_plans",
                           "plans served during the last shadow-rebuild "
                           "overlap").labels(),
            registry.gauge("rebuild_publish_stall_p99_s",
                           "p99 of shadow-index publish stalls").labels(),
        )
        self._drain_gauges = cached
        return cached

    def drain(self, registry=None) -> None:
        """Publish the current health view as registry gauges, straight
        from the raw rates (no snapshot building).  Called from
        ``maintenance()`` — the idle tick — never from plan/commit;
        use ``snapshot()`` for the structured view."""
        if registry is None:
            return
        _, g_hit, g_dup, g_burn, g_overlap, g_stall = \
            self._gauges(registry)
        for t, th in self._tenants.items():
            hit = th.hit
            g_hit.set(hit.ewma if hit.ewma is not None else 0.0,
                      tenant=t, kind="ewma")
            g_hit.set(hit.windowed, tenant=t, kind="window")
            waste = th.wasted_admission.windowed
            g_dup.set(waste, tenant=t)
            budget = self.budget(t)
            g_burn.set(waste / budget if budget > 0 else 0.0, tenant=t)
        g_overlap.set(self._last_overlap_plans)
        g_stall.set(self.stall_p99_s())

    def snapshot(self) -> Dict[str, object]:
        tenants = {}
        for t, th in sorted(self._tenants.items()):
            budget = self.budget(t)
            waste = th.wasted_admission.windowed
            tenants[str(t)] = {
                "hit": th.hit.snapshot(),
                "duplicate": th.duplicate.snapshot(),
                "wasted_admission": th.wasted_admission.snapshot(),
                "budget": budget,
                "budget_burn": waste / budget if budget > 0 else 0.0,
            }
        return {
            "tenants": tenants,
            "rebuild": {
                "publishes": self._publishes,
                "overlap_plans_total": self._overlap_plans_total,
                "last_overlap_plans": self._last_overlap_plans,
                "in_overlap": self._plans_at_start is not None,
                "stall_p99_s": self.stall_p99_s(),
            },
        }


# ---------------------------------------------------------------------------
# the telemetry-overhead budget (shared by the bench row and the tests)
# ---------------------------------------------------------------------------

def check_overhead_budget(on_p50_s: float, off_p50_s: float,
                          max_ratio: float = 1.02,
                          floor_s: float = 100e-6) -> List[str]:
    """Telemetry-on must cost < 2% of the telemetry-off p50.

    ``floor_s`` absorbs timer granularity and scheduler jitter on
    millisecond-scale CPU ticks (100 us is several times the real
    per-batch recording cost of a few tens of microseconds, but far
    below 2% of any realistic accelerator-backed serving tick); the
    ratio is what the budget is about.  Returns a list of violation
    strings (empty = within budget).
    """
    limit = off_p50_s * max_ratio + floor_s
    if on_p50_s <= limit:
        return []
    return [
        f"telemetry overhead over budget: p50 on {on_p50_s * 1e6:.0f}us "
        f"vs off {off_p50_s * 1e6:.0f}us "
        f"(limit {max_ratio:.2f}x + {floor_s * 1e6:.0f}us "
        f"= {limit * 1e6:.0f}us)"]
