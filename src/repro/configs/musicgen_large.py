"""MusicGen-Large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284]  48L, d_model=2048, 32 heads (MHA: kv=32), d_ff=8192,
vocab=2048 (EnCodec codebook).  The EnCodec conv codec + text conditioner
are the *audio frontend stub*: ``input_specs`` supplies precomputed
conditioning frame embeddings of shape (B, frontend_len, d_model).
MusicGen uses learned positions + LayerNorm + GELU; we keep its GELU MLP
and LayerNorm, with RoPE disabled in favour of learned absolute
positions being approximated by RoPE=False + sinusoidal add (see
models/layers.py).
"""
from repro.configs.base import ModelConfig, LayerSpec, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    norm_type="layernorm",
    use_rope=False,
    period=(LayerSpec(ATTN, DENSE),),
    frontend="audio",
    frontend_len=256,
))
