"""Golden parity: the typed plan/commit lifecycle is bit-exact with the
legacy lookup/insert serving loop — hits, scores, value ids, admissions,
evictions and the full device tier state — for both backends
(SemanticCache and CacheService) and both cascade paths (fused and
unfused).  The query mix includes exact in-batch duplicates, so miss
coalescing is exercised while keeping even the host strings identical."""
import warnings

import numpy as np
import pytest

from repro.cache_service import CacheRequest, CacheService
from repro.core import SemanticCache

rng = np.random.default_rng(29)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _batches(d, n_batches=8, batch=8, repeat_frac=0.4):
    """Query stream with cross-batch repeats and exact in-batch dups."""
    seen = []
    out = []
    for b in range(n_batches):
        rows = []
        for i in range(batch - 1):
            if seen and rng.random() < repeat_frac:
                rows.append(seen[rng.integers(len(seen))])
            else:
                e = _unit(rng.standard_normal(d).astype(np.float32))
                seen.append(e)
                rows.append(e)
        rows.append(rows[0])        # exact duplicate within the batch
        out.append(np.stack(rows))
    return out


def _legacy_serve(cache, embs, tenant, tenant_aware):
    """The pre-protocol serving loop, verbatim (lookup -> generate
    misses -> insert with observed scores)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        if tenant_aware:
            hits, scores, values = cache.lookup(embs, tenant=tenant)
        else:
            hits, scores, values = cache.lookup(embs)
        miss = [i for i, h in enumerate(hits) if not h]
        if miss:
            answers = [f"gen({embs[i].tobytes().hex()[:12]})" for i in miss]
            sel = np.asarray(miss)
            if tenant_aware:
                cache.insert(embs[sel], answers, tenant=tenant,
                             scores=scores[sel])
            else:
                cache.insert(embs[sel], answers)
    return np.asarray(hits), np.asarray(scores), values


def _plan_commit_serve(cache, embs, tenant):
    """The typed pipeline: plan -> one generation per miss-group leader
    -> commit."""
    plan = cache.plan(CacheRequest.build(embs, tenant))
    responses = [None] * len(embs)
    for i in plan.miss_rows():
        lead = int(plan.miss_leader[i])
        responses[int(i)] = f"gen({embs[lead].tobytes().hex()[:12]})"
    cache.commit(plan, responses)
    return plan.hit, plan.scores, plan.responses


def _assert_tree_equal(a, b, names):
    for name, x, y in zip(names, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


PARITY_KEYS = ("lookups", "hot_hits", "warm_hits", "inserts",
               "admission_skips", "demotions", "rebuilds", "evictions")


@pytest.mark.parametrize("fused", [False, True])
def test_cache_service_plan_commit_matches_legacy(fused):
    d = 24
    mk = lambda: CacheService(
        dim=d, hot_capacity=16, warm_capacity=64, n_clusters=4, bucket=32,
        n_probe=4, threshold=0.85, admission_margin=0.05, flush_size=8,
        rebuild_every=2, fused=fused)
    legacy, typed = mk(), mk()
    for b, embs in enumerate(_batches(d)):
        tenant = b % 3
        lh, ls, lv = _legacy_serve(legacy, embs, tenant, tenant_aware=True)
        th, ts, tv = _plan_commit_serve(typed, embs, tenant)
        np.testing.assert_array_equal(lh, th, err_msg=f"batch {b} hits")
        np.testing.assert_array_equal(ls, ts, err_msg=f"batch {b} scores")
        assert lv == tv, f"batch {b} hit responses"
        # full device-state parity after every batch: same admissions,
        # same value-id assignment, same demotions/evictions
        _assert_tree_equal(legacy.hot, typed.hot,
                           [f"hot.{f}" for f in legacy.hot._fields])
        _assert_tree_equal(legacy.warm, typed.warm,
                           [f"warm.{f}" for f in legacy.warm._fields])
        assert legacy.responses == typed.responses, f"batch {b}"
    sl, st = legacy.stats(), typed.stats()
    assert {k: sl[k] for k in PARITY_KEYS} == {k: st[k] for k in PARITY_KEYS}


def test_semantic_cache_plan_commit_matches_legacy():
    d = 24
    legacy = SemanticCache(capacity=64, dim=d, threshold=0.85)
    typed = SemanticCache(capacity=64, dim=d, threshold=0.85)
    for b, embs in enumerate(_batches(d)):
        lh, ls, lv = _legacy_serve(legacy, embs, 0, tenant_aware=False)
        th, ts, tv = _plan_commit_serve(typed, embs, 0)
        np.testing.assert_array_equal(lh, th, err_msg=f"batch {b} hits")
        np.testing.assert_array_equal(ls, ts, err_msg=f"batch {b} scores")
        assert lv == tv
        _assert_tree_equal(legacy.state, typed.state,
                           [f"state.{f}" for f in legacy.state._fields])
        assert legacy.responses == typed.responses
    assert legacy.stats()["inserts"] == typed.stats()["inserts"]


def test_insert_shim_is_commit_for_every_row():
    """The deprecated insert() must behave exactly like committing a
    plan whose rows are all ungrouped misses (admission included)."""
    d = 16
    a = CacheService(dim=d, hot_capacity=16, warm_capacity=32, n_clusters=2,
                     bucket=16, threshold=0.9, admission_margin=0.1)
    b = CacheService(dim=d, hot_capacity=16, warm_capacity=32, n_clusters=2,
                     bucket=16, threshold=0.9, admission_margin=0.1)
    e = _unit(rng.standard_normal((6, d)).astype(np.float32))
    scores = np.asarray([0.0, 0.85, 0.3, 0.95, 0.5, 0.82], np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        n_a = a.insert(e, [f"r{i}" for i in range(6)], tenant=1,
                       scores=scores)
    from repro.cache_service import CachePlan
    req = CacheRequest.build(e, 1)
    admit = b.policies.admit_mask(req.tenants, scores)
    n_b = b.commit(CachePlan.for_insert(req, admit, scores),
                   [f"r{i}" for i in range(6)]).admitted
    assert n_a == n_b == int(admit.sum()) < 6
    _assert_tree_equal(a.hot, b.hot, a.hot._fields)
    assert a.responses == b.responses
