"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes/dtypes (assignment: assert_allclose against ref)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cosine_topk import kernel as ctk_kernel, ref as ctk_ref
from repro.kernels.decode_attention import kernel as da_kernel, ref as da_ref
from repro.kernels.flash_attention import kernel as fa_kernel, ref as fa_ref
from repro.kernels.contrastive import kernel as cl_kernel, ref as cl_ref
from repro.kernels.contrastive.ops import online_contrastive_loss as ocl_op
from repro.core.losses import online_contrastive_loss as ocl_ref

rng = np.random.default_rng(42)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


# ---------------------------------------------------------------------------
# cosine_topk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Q,N,D,k,block_n", [
    (4, 64, 32, 1, 32),
    (8, 1000, 64, 3, 256),    # non-divisible N -> padding path
    (16, 512, 128, 4, 128),
    (1, 2048, 256, 2, 512),
])
def test_cosine_topk_matches_ref(Q, N, D, k, block_n):
    q = _unit(rng.standard_normal((Q, D)).astype(np.float32))
    keys = _unit(rng.standard_normal((N, D)).astype(np.float32))
    valid = rng.random(N) > 0.25
    s_ref, i_ref = ctk_ref.cosine_topk(jnp.asarray(q), jnp.asarray(keys),
                                       jnp.asarray(valid), k)
    s_k, i_k = ctk_kernel.cosine_topk(jnp.asarray(q), jnp.asarray(keys),
                                      jnp.asarray(valid), k,
                                      block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_k), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_k))


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_cosine_topk_dtypes(dtype):
    q = jnp.asarray(_unit(rng.standard_normal((4, 64)).astype(np.float32)),
                    dtype)
    keys = jnp.asarray(_unit(rng.standard_normal((128, 64)).astype(
        np.float32)), dtype)
    valid = jnp.ones(128, bool)
    s_ref, i_ref = ctk_ref.cosine_topk(q, keys, valid, 2)
    s_k, i_k = ctk_kernel.cosine_topk(q, keys, valid, 2, block_n=64,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_k), atol=2e-2)


def test_cosine_topk_all_invalid():
    q = jnp.asarray(_unit(rng.standard_normal((2, 32)).astype(np.float32)))
    keys = jnp.asarray(_unit(rng.standard_normal((64, 32)).astype(np.float32)))
    valid = jnp.zeros(64, bool)
    s, i = ctk_kernel.cosine_topk(q, keys, valid, 1, block_n=32,
                                  interpret=True)
    assert float(jnp.max(s)) < -1e20  # nothing can "hit"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,Sq,hd,causal,window,bq,bkv", [
    (2, 4, 2, 128, 64, True, 0, 64, 64),
    (1, 4, 4, 100, 32, True, 0, 32, 32),     # ragged seq -> padding
    (2, 8, 2, 64, 32, False, 0, 32, 32),     # encoder (bidirectional)
    (1, 4, 2, 128, 32, True, 48, 32, 32),    # sliding window
    (1, 2, 1, 96, 128, True, 0, 48, 24),     # MQA + uneven blocks
])
def test_flash_attention_matches_ref(B, H, KV, Sq, hd, causal, window,
                                     bq, bkv):
    q = jnp.asarray(rng.standard_normal((B, H, Sq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, KV, Sq, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, KV, Sq, hd)), jnp.float32)
    o_ref = fa_ref.flash_attention(q, k, v, causal=causal, window=window)
    o_k = fa_kernel.flash_attention(q, k, v, causal=causal, window=window,
                                    block_q=bq, block_kv=bkv, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_k),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(rng.standard_normal((1, 4, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    o_ref = fa_ref.flash_attention(q, k, v, causal=True)
    o_k = fa_kernel.flash_attention(q, k, v, causal=True, block_q=32,
                                    block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_k, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,L,hd,bl", [
    (2, 4, 2, 300, 64, 128),
    (1, 8, 1, 1000, 32, 256),   # MQA long cache
    (3, 4, 4, 128, 128, 64),
])
def test_decode_attention_matches_ref(B, H, KV, L, hd, bl):
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, L, KV, hd)), jnp.float32)
    valid = jnp.asarray(rng.random((B, L)) > 0.2)
    o_ref = da_ref.decode_attention(q, k, v, valid)
    o_k = da_kernel.decode_attention(q, k, v, valid, block_l=bl,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_k),
                               atol=2e-5, rtol=1e-4)


def test_decode_attention_matches_model_path():
    """Kernel agrees with the model's own decode attention math."""
    from repro.models.attention import gqa_attention
    B, H, KV, L, hd = 2, 4, 2, 64, 32
    q4 = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, L, KV, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    o_model = gqa_attention(q4, k, v, q_pos=jnp.asarray([L - 1]),
                            kv_pos=pos, causal=True, window=0,
                            kv_valid=jnp.ones((B, L), bool), chunked=False)
    o_kernel = da_kernel.decode_attention(q4[:, 0], k, v,
                                          jnp.ones((B, L), bool),
                                          block_l=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o_model[:, 0]),
                               np.asarray(o_kernel), atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# contrastive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,D,bb", [(16, 64, 8), (100, 128, 32),
                                    (256, 768, 128)])
def test_contrastive_components_match(B, D, bb):
    e1 = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    e2 = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 2, B), jnp.int32)
    ref = cl_ref.contrastive_components(e1, e2, lab)
    ker = cl_kernel.contrastive_components(e1, e2, lab, block_b=bb,
                                           interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_allclose(float(a), float(b), atol=1e-5, rtol=1e-5)


def test_contrastive_op_equals_core_loss():
    for B in (16, 64):
        e1 = jnp.asarray(rng.standard_normal((B, 32)), jnp.float32)
        e2 = jnp.asarray(rng.standard_normal((B, 32)), jnp.float32)
        lab = jnp.asarray(rng.integers(0, 2, B), jnp.int32)
        a = float(ocl_ref(e1, e2, lab))
        b = float(ocl_op(e1, e2, lab, use_kernel=True))
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_contrastive_single_class_fallback():
    e1 = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    e2 = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    all_pos = jnp.ones(8, jnp.int32)
    a = float(ocl_ref(e1, e2, all_pos))
    b = float(ocl_op(e1, e2, all_pos, use_kernel=True))
    np.testing.assert_allclose(a, b, atol=1e-6)
