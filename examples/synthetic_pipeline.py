"""Synthetic data generation pipeline (paper §2.1 / Table 1).

From UNLABELED domain queries, generate dual-labeled pairs (paraphrase
positives + related-but-distinct negatives), export JSONL, fine-tune the
embedder on the purely synthetic set, and evaluate on held-out 'real'
pairs.

    PYTHONPATH=src python examples/synthetic_pipeline.py --n-queries 256
Optionally route generation through an actual JAX decoder backend
(--llm-backend qwen2.5-32b — the paper's generator arch, reduced here).
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    EmbedderTrainer, FinetuneConfig, LLMGenerator, TemplateGenerator,
    export_jsonl, generate_synthetic_pairs, records_to_dataset,
)
from repro.data import HashTokenizer, make_pair_dataset, sample_query
from repro.models import init_lm, split
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--domain", default="medical")
    ap.add_argument("--n-queries", type=int, default=256)
    ap.add_argument("--n-pos", type=int, default=2)
    ap.add_argument("--n-neg", type=int, default=2)
    ap.add_argument("--out", default="/tmp/synthetic_pairs.jsonl")
    ap.add_argument("--llm-backend", default=None,
                    help="route generation through a JAX decoder (e.g. "
                         "qwen2.5-32b, reduced) instead of the grammar")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    unlabeled = [sample_query(rng, args.domain)
                 for _ in range(args.n_queries)]
    print(f"unlabeled in-domain queries: {len(unlabeled)}")
    print(f"  e.g. {unlabeled[0].text!r}")

    if args.llm_backend:
        dec_cfg = get_config(args.llm_backend).reduced()
        pv, _ = split(init_lm(dec_cfg, jax.random.PRNGKey(0)))
        tok_llm = HashTokenizer(vocab_size=dec_cfg.vocab_size)
        backend = LLMGenerator(ServeEngine(dec_cfg, pv, max_len=80), tok_llm)
        print(f"generator backend: {dec_cfg.name} (sampled)")
    else:
        backend = TemplateGenerator(seed=1)
        print("generator backend: deterministic grammar (Listings 1-2 "
              "structural analogue)")

    records = generate_synthetic_pairs(unlabeled, backend,
                                       n_pos=args.n_pos, n_neg=args.n_neg)
    n_pos = sum(r.is_duplicate for r in records)
    print(f"generated {len(records)} pairs "
          f"({n_pos} positives / {len(records) - n_pos} negatives)")
    export_jsonl(records, args.out)
    print(f"exported {args.out}")
    for r in records[:2]:
        print(f"  [{r.kind}] {r.question1!r} <-> {r.question2!r} "
              f"dup={r.is_duplicate}")

    # --- Table 1: fine-tune on synthetic only, evaluate on real -------
    cfg = get_config("modernbert-149m").reduced(vocab_size=4096)
    tok = HashTokenizer(vocab_size=cfg.vocab_size)
    real_eval = make_pair_dataset(args.domain, 256, seed=77)
    base = EmbedderTrainer(cfg, FinetuneConfig(max_len=24))
    before = base.evaluate(real_eval, tok)
    ft = EmbedderTrainer(cfg, FinetuneConfig(epochs=2, batch_size=32,
                                             lr=5e-4, max_len=24))
    ft.fit(records_to_dataset(records), tok)
    after = ft.evaluate(real_eval, tok)
    print("\n=== Table-1 style result (real-pair eval) ===")
    print(f"base(untuned):             precision={before['precision']:.3f} "
          f"ap={before['ap']:.3f}")
    print(f"LangCache-Embed-Synthetic: precision={after['precision']:.3f} "
          f"ap={after['ap']:.3f}")


if __name__ == "__main__":
    main()
