"""Modality frontend STUBS (the assignment's single allowed carve-out).

The audio codec (EnCodec/mel+conv) and vision encoder (Pixtral-ViT)
are not implemented; instead these stubs produce deterministic
pseudo-embeddings of the correct shape — (batch, frontend_len, d_model)
— standing in for "precomputed frame/patch embeddings".  The backbone
transformer that *consumes* them is fully implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def stub_frontend_embeds(cfg: ModelConfig, batch: int, seed: int = 0):
    """Deterministic stand-in frame/patch embeddings."""
    if not cfg.frontend:
        return None
    key = jax.random.PRNGKey(seed)
    e = jax.random.normal(key, (batch, cfg.frontend_len, cfg.d_model),
                          jnp.float32) * 0.02
    return e.astype(jnp.dtype(cfg.dtype))


def frontend_spec(cfg: ModelConfig, batch: int):
    """Abstract ShapeDtypeStruct for dry-runs."""
    if not cfg.frontend:
        return None
    return jax.ShapeDtypeStruct((batch, cfg.frontend_len, cfg.d_model),
                                jnp.dtype(cfg.dtype))
