"""Fine-tune the cache embedder — the paper's training driver.

Defaults to the reduced smoke config so it runs on CPU in ~2 minutes;
``--full`` selects the true modernbert-149m geometry (22L, d=768 —
the paper's LangCache-Embed, ~149M params; run on accelerators).

    PYTHONPATH=src python examples/finetune_embedder.py \
        --domain medical --epochs 1 --out /tmp/langcache_embed.msgpack
"""
import argparse

from repro.configs import get_config
from repro.core import EmbedderTrainer, FinetuneConfig
from repro.data import HashTokenizer, make_pair_dataset
from repro.training import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--domain", default="medical",
                    choices=["medical", "quora"])
    ap.add_argument("--epochs", type=int, default=1,
                    help="paper recipe: 1 (see §3.2 on forgetting)")
    ap.add_argument("--pairs", type=int, default=2048)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=6.5383156211679e-5,
                    help="paper's exact lr (use ~5e-4 for --smoke scale)")
    ap.add_argument("--clip", type=float, default=0.5)
    ap.add_argument("--loss", default="online",
                    choices=["online", "contrastive"])
    ap.add_argument("--full", action="store_true",
                    help="true 149M config instead of the smoke variant")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config("modernbert-149m")
    if not args.full:
        cfg = cfg.reduced(vocab_size=4096)
        if args.lr < 1e-4:
            args.lr = 5e-4  # rescale for the 1000x smaller model
    tok = HashTokenizer(vocab_size=cfg.vocab_size)

    ds = make_pair_dataset(args.domain, args.pairs, seed=0)
    train, evl = ds.split(eval_frac=0.15, seed=1)
    ft = FinetuneConfig(epochs=args.epochs, lr=args.lr,
                        batch_size=args.batch_size,
                        max_grad_norm=args.clip, loss=args.loss, max_len=24)
    trainer = EmbedderTrainer(cfg, ft)

    before = trainer.evaluate(evl, tok)
    print("before:", {k: round(v, 4) for k, v in before.items()})
    stats = trainer.fit(train, tok)
    after = trainer.evaluate(evl, tok)
    print(f"trained {stats['steps']} steps in {stats['train_seconds']:.1f}s")
    print("after: ", {k: round(v, 4) for k, v in after.items()})
    print(f"precision {before['precision']:.3f} -> {after['precision']:.3f}, "
          f"AP {before['ap']:.3f} -> {after['ap']:.3f}")
    if args.out:
        save_checkpoint(args.out, {"params": trainer.params,
                                   "config": cfg.name,
                                   "finetune": vars(args)})
        print("saved", args.out)


if __name__ == "__main__":
    main()
