"""One benchmark per paper table/figure.  Each prints CSV rows:
``name,us_per_call,derived``.

Absolute numbers differ from the paper (CPU-scale models, deterministic
corpora, no closed APIs — DESIGN.md §6); the *claims* being reproduced
are the orderings and deltas: fine-tuned-compact > untuned/large
baselines, synthetic data closes the gap, 1-epoch+clip avoids
forgetting, fine-tuned model sits upper-left in the latency/AP plane.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    MAX_LEN, base_embed, dataset, embedder_cfg, embedder_rows, finetune_cfg,
    fmt_derived, langcache_embed, score_pairs, timed, tokenizer,
)
from repro.core import (
    EmbedderTrainer, SemanticCache, TemplateGenerator,
    generate_synthetic_pairs, pair_classification_metrics,
    records_to_dataset,
)
from repro.core.metrics import metrics_at_threshold
from repro.data import make_query_stream, sample_query


def bench_fig1_quora():
    """Figure 1: embedding-model comparison on the Quora-style corpus."""
    ev = dataset("quora", "eval")
    for name, fn in embedder_rows("quora"):
        scores, us = timed(lambda: score_pairs(fn, ev))
        m = pair_classification_metrics(scores, ev.labels)
        yield f"fig1/{name}", us, fmt_derived(
            {k: m[k] for k in ("precision", "recall", "f1", "accuracy",
                               "ap")})


def bench_fig2_medical():
    """Figure 2: same lineup on the specialised medical corpus."""
    ev = dataset("medical", "eval")
    for name, fn in embedder_rows("medical"):
        scores, us = timed(lambda: score_pairs(fn, ev))
        m = pair_classification_metrics(scores, ev.labels)
        yield f"fig2/{name}", us, fmt_derived(
            {k: m[k] for k in ("precision", "recall", "f1", "accuracy",
                               "ap")})


def bench_fig3_forgetting():
    """Figure 3: catastrophic forgetting.  The paper's base model is
    *pretrained* — it has cross-domain knowledge to lose.  We emulate
    that with a mixed-domain 'pretraining' stage, then fine-tune on
    quora only: 6 epochs without clipping erodes the previously-learned
    medical precision, while the paper's 1-epoch + clip-0.5 recipe
    preserves it."""
    import copy

    from repro.data.corpora import PairDataset

    tok = tokenizer()
    ev_q = dataset("quora", "eval")
    ev_m = dataset("medical", "eval")
    mix_q = dataset("quora", "train")
    mix_m = dataset("medical", "train")
    mixed = PairDataset(mix_q.q1 + mix_m.q1, mix_q.q2 + mix_m.q2,
                        np.concatenate([mix_q.labels, mix_m.labels]),
                        "mixed")
    pre = EmbedderTrainer(embedder_cfg(), finetune_cfg(epochs=2))
    pre.fit(mixed, tok)
    pre_params = pre.params

    rows = [("pretrained(mixed)", pre)]
    short = EmbedderTrainer(embedder_cfg(),
                            finetune_cfg(epochs=1, clip=0.5),
                            params=copy.deepcopy(pre_params))
    short.fit(dataset("quora", "train"), tok)
    rows.append(("then-quora-ft(1ep,clip0.5)", short))
    long_ = EmbedderTrainer(embedder_cfg(),
                            finetune_cfg(epochs=6, clip=None),
                            params=copy.deepcopy(pre_params))
    long_.fit(dataset("quora", "train"), tok)
    rows.append(("then-quora-ft(6ep,noclip)", long_))
    for name, tr in rows:
        (mq, mm), us = timed(lambda tr=tr: (tr.evaluate(ev_q, tok),
                                            tr.evaluate(ev_m, tok)),
                             repeats=1)
        yield f"fig3/{name}", us, fmt_derived({
            "quora_precision": mq["precision"], "quora_ap": mq["ap"],
            "medical_precision": mm["precision"], "medical_ap": mm["ap"],
        })


def bench_table1_synthetic():
    """Table 1: fine-tune on PURELY synthetic medical pairs (dual-label
    pipeline), evaluate on held-out 'real' medical pairs."""
    tok = tokenizer()
    ev = dataset("medical", "eval")
    rng = np.random.default_rng(5)
    unlabeled = [sample_query(rng, "medical") for _ in range(256)]
    records = generate_synthetic_pairs(unlabeled, TemplateGenerator(2),
                                       n_pos=1, n_neg=1)
    synth = records_to_dataset(records)

    rows = [("base(untuned)", base_embed())]
    synth_ft = EmbedderTrainer(embedder_cfg(), finetune_cfg(epochs=2))
    synth_ft.fit(synth, tok)
    rows.append(("LangCache-Embed-Synthetic", synth_ft))
    rows.append(("LangCache-Embed(real-ft)", langcache_embed("medical")))
    for name, tr in rows:
        m, us = timed(lambda tr=tr: tr.evaluate(ev, tok), repeats=1)
        yield f"table1/{name}", us, fmt_derived(
            {k: m[k] for k in ("precision", "recall", "f1", "accuracy",
                               "ap")})


def bench_fig4_latency():
    """Figure 4: embedding overhead (us/query) vs AP on quora eval."""
    ev = dataset("quora", "eval")
    queries = list(ev.q1)[:64]
    for name, fn in embedder_rows("quora"):
        _, us_total = timed(lambda: fn(queries))
        scores = score_pairs(fn, ev)
        ap = pair_classification_metrics(scores, ev.labels)["ap"]
        yield f"fig4/{name}", us_total / len(queries), fmt_derived(
            {"ap": ap, "us_per_query": us_total / len(queries)})


def bench_ablation_loss():
    """Paper §2 argument: ONLINE contrastive (hard-pair mining) converges
    to better precision than conventional contrastive under the same
    budget.  Head-to-head at identical steps/lr/data."""
    from repro.core import EmbedderTrainer as ET
    tok = tokenizer()
    ev = dataset("medical", "eval")
    tr = dataset("medical", "train")
    for loss in ("online", "contrastive"):
        cfg = finetune_cfg(epochs=2)
        cfg = type(cfg)(**{**cfg.__dict__, "loss": loss})
        trainer = ET(embedder_cfg(), cfg)
        _, us = timed(lambda tr_=trainer: tr_.fit(tr, tok), repeats=1)
        m = trainer.evaluate(ev, tok)
        yield f"ablation/loss={loss}", us, fmt_derived(
            {k: m[k] for k in ("precision", "recall", "f1", "ap")})


def bench_cache_hit_rate():
    """System-level: deployed-cache hit quality on a repeated-query
    stream (the 33%-repeats serving trace).  The 1-vs-N lookup is much
    harder than pairwise eval (a query competes against every stored
    entry), which is exactly why the paper's precision argument matters:
    the fine-tuned embedder dominates the untuned base at every
    threshold."""
    tok = tokenizer()
    stream = make_query_stream("medical", 200, seed=9, repeat_frac=0.4)
    texts = [q.text for q in stream]
    models = [("finetuned", langcache_embed("medical")),
              ("base", base_embed())]
    for model_name, trainer in models:
        embs = trainer.embed_texts(texts, tok)
        # calibrate on the eval split (the paper's evaluator convention):
        # probe the best-F1 threshold and stricter serving points
        ev = dataset("medical", "eval")
        scores = score_pairs(lambda t: trainer.embed_texts(t, tok), ev)
        thr0 = pair_classification_metrics(scores, ev.labels)["f1_threshold"]
        for threshold in (round(thr0, 4), round(thr0 + 0.1, 4),
                          round(thr0 + 0.2, 4)):
            def run():
                from repro.cache_service import CacheRequest
                cache = SemanticCache(capacity=2048,
                                      dim=embedder_cfg().d_model,
                                      threshold=threshold)
                inserted = {}
                th = fh = miss = 0
                for q, e in zip(stream, embs):
                    plan = cache.plan(CacheRequest.build(e[None]))
                    key = (q.entity, q.aspect)
                    if plan.hit[0]:
                        if inserted.get(plan.responses[0]) == key:
                            th += 1
                        else:
                            fh += 1
                    else:
                        rid = f"r{miss}"
                        inserted[rid] = key
                        cache.commit(plan, [rid])
                        miss += 1
                return th, fh, miss
            (th, fh, miss), us = timed(run, repeats=1)
            yield (f"cache/{model_name}@thr={threshold}", us / len(stream),
                   fmt_derived({"true_hit_rate": th / len(stream),
                                "false_hit_rate": fh / len(stream),
                                "miss_rate": miss / len(stream)}))
