"""Pallas TPU kernel: blocked flash attention (prefill/training path).

MXU-oriented tiling (DESIGN.md §3): Q blocks stay VMEM-resident while KV
blocks stream; the running (m, l, acc) online-softmax state lives in
VMEM scratch across the innermost (KV) grid dimension.  GQA is handled
with *zero* KV duplication — the K/V BlockSpec index_map folds the query
head onto its KV head (h // group_size), so HBM traffic is that of the
true KV head count (this replaces the CUDA trick of shared-memory
broadcast within a warpgroup).

Causal + sliding-window masking is positional; fully-masked KV blocks
are skipped with @pl.when (a real schedule win for causal prefill:
~2× fewer MXU blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_KV = 256


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, block_q: int, block_kv: int,
            sq: int, skv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * block_q
    k_lo = ik * block_kv
    # block-level reachability (skip fully-masked blocks)
    live = jnp.asarray(True)
    if causal:
        live &= k_lo <= q_lo + block_q - 1
    if window > 0:
        live &= (q_lo - (k_lo + block_kv - 1)) < window
        if not causal:
            live &= (k_lo - (q_lo + block_q - 1)) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (BKV, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(q * hd ** -0.5, k,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (BQ,BKV)
        row = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        col = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = col < skv
        if causal:
            ok &= col <= row
        if window > 0:
            ok &= (row - col) < window
            if not causal:
                ok &= (col - row) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                              # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    interpret: bool = True):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    nq = -(-Sq // bq)
    nk = -(-Skv // bkv)
    pad_q = nq * bq - Sq
    pad_k = nk * bkv - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (B, H, nq, nk)
    fn = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window,
                          block_q=bq, block_kv=bkv, sq=Sq, skv=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )
    out = fn(q, k, v)
    return out[:, :, :Sq] if pad_q else out
