"""Double-buffered warm-IVF rebuild: lookups issued mid-rebuild read
the old *published* index and recall never dips — before the shadow
build, during the overlap (including extra demotion flushes), across
the atomic publish, and under sustained traffic.  Also covers the
maintenance obligations surfaced by CommitReceipt and the pipeline."""
import threading

import numpy as np
import pytest
from conftest import commit_insert, plan_lookup

from repro.cache_service import CacheRequest, CacheService
from repro.core.embedders import HashNgramEmbedder
from repro.data import HashTokenizer
from repro.serving import CachedLLMService

rng = np.random.default_rng(41)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _mk(background, **kw):
    cfg = dict(dim=16, hot_capacity=16, warm_capacity=64, n_clusters=4,
               bucket=32, n_probe=4, threshold=0.9, flush_size=8,
               rebuild_every=2, background_rebuild=background)
    cfg.update(kw)
    return CacheService(**cfg)


def _gate_first_rebuild(svc):
    """Wrap svc._rebuild so the FIRST call blocks on an Event (the
    shadow thread parks there); later calls run through."""
    gate = threading.Event()
    real = svc._rebuild
    state = {"first": True}

    def gated(warm):
        if state["first"]:
            state["first"] = False
            assert gate.wait(timeout=60), "test gate never opened"
        return real(warm)

    svc._rebuild = gated
    return gate


def _lookup(svc, keys, tenant=0):
    return plan_lookup(svc, keys, tenant=tenant)


def _insert(svc, keys, texts, tenant=0):
    return commit_insert(svc, keys, texts, tenant=tenant)


def test_mid_rebuild_lookup_reads_old_published_index():
    # tail = flush_size * rebuild_every = 24: wide enough that the two
    # flushes below never force a (blocking) join of the gated shadow
    svc = _mk(background=True, rebuild_every=3)
    gate = _gate_first_rebuild(svc)
    keys = _unit(rng.standard_normal((16, 16)).astype(np.float32))
    _insert(svc, keys, [f"r{i}" for i in range(16)])

    svc.flush(rebuild=True)                    # starts the gated shadow
    st = svc.stats_snapshot().rebuild
    assert st["in_flight"] and st["shadow_started"] == 1
    assert st["rebuilds"] == 0                 # nothing published yet
    idx_before = int(np.asarray(svc.warm.indexed_total))

    # mid-rebuild serving: the old (empty) index is still published, the
    # tail window serves the freshly demoted rows — full recall
    hit, _, vals = _lookup(svc, keys)
    assert hit.all()
    assert all(v is not None for v in vals)
    assert int(np.asarray(svc.warm.indexed_total)) == idx_before

    # demote MORE rows while the shadow is still building: the overlap
    # must keep every row reachable (tail covers post-snapshot writes)
    keys2 = _unit(rng.standard_normal((8, 16)).astype(np.float32))
    _insert(svc, keys2, [f"s{i}" for i in range(8)])
    svc.flush(rebuild=False)
    hit, _, _ = _lookup(svc, np.concatenate([keys, keys2]))
    assert hit.all()
    assert svc.stats_snapshot().rebuild["in_flight"]   # same build

    gate.set()
    rep = svc.maintenance(block=True)
    assert rep.rebuild_published and not rep.rebuild_in_flight
    assert rep.rebuild_wall_s > 0
    st = svc.stats_snapshot().rebuild
    assert st["rebuilds"] == 1 and not st["in_flight"]
    # the publish kept indexed_total at the SNAPSHOT's total: rows
    # appended during the overlap stay in the tail window
    assert int(np.asarray(svc.warm.indexed_total)) > idx_before
    assert svc._backlog() > 0
    hit, _, _ = _lookup(svc, np.concatenate([keys, keys2]))
    assert hit.all()


def test_background_mode_never_strands_rows_under_sustained_traffic():
    """No gating: real threads racing real flushes.  After every batch,
    every live entry must remain reachable, exactly as inline mode."""
    bg, inline = _mk(True), _mk(False)
    all_keys = []
    for step in range(20):
        e = _unit(rng.standard_normal((8, 16)).astype(np.float32))
        all_keys.append(e)
        texts = [f"b{step}-{i}" for i in range(8)]
        _insert(bg, e, texts)
        _insert(inline, e, texts)
        keys = np.concatenate(all_keys)
        hb, _, _ = _lookup(bg, keys)
        hi, _, _ = _lookup(inline, keys)
        # identical ring/demotion schedule => identical live sets; both
        # modes must serve every live row whatever the index state
        np.testing.assert_array_equal(hb, hi, err_msg=f"step {step}")
        assert len(bg.responses) == len(inline.responses)
    bg.maintenance(block=True)
    st = bg.stats_snapshot().rebuild
    assert st["shadow_started"] > 0
    assert st["rebuilds"] + int(st["in_flight"]) >= 1


def test_commit_receipt_surfaces_maintenance_obligation():
    svc = _mk(background=True, rebuild_every=1)
    due = False
    for step in range(6):
        e = _unit(rng.standard_normal((8, 16)).astype(np.float32))
        plan = svc.plan(CacheRequest.build(e, 0))
        receipt = svc.commit(plan, [f"c{step}-{i}" for i in range(8)])
        due = due or receipt.rebuild_due
    assert due                                  # obligation surfaced
    svc.maintenance(block=True)
    assert svc.stats_snapshot().rebuild["rebuilds"] > 0


def test_pipeline_drives_maintenance_between_batches():
    emb = HashNgramEmbedder(dim=64)
    cache = CacheService(dim=64, hot_capacity=16, warm_capacity=128,
                         n_clusters=4, bucket=64, threshold=0.95,
                         flush_size=8, rebuild_every=2,
                         background_rebuild=True)
    svc = CachedLLMService(emb.embed, cache, engine=None,
                           tokenizer=HashTokenizer())
    for step in range(12):
        out = svc.handle([f"question {step} variant {i}" for i in range(8)])
        assert all(r.response is not None for r in out)
    cache.maintenance(block=True)
    st = svc.stats()
    assert st["backend"]["rebuild"]["shadow_started"] > 0, st
    assert st["maintenance_calls"] > 0, st


def test_background_flag_is_advertised():
    assert _mk(True).capabilities().background_rebuild
    assert not _mk(False).capabilities().background_rebuild
    with pytest.raises(TypeError):
        CachedLLMService(lambda t: np.zeros((len(t), 4), np.float32),
                         cache=object(), engine=None,
                         tokenizer=HashTokenizer())
