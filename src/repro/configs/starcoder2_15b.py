"""StarCoder2-15B — GQA + RoPE code model.

[arXiv:2402.19173]  40L, d_model=6144, 48 heads, kv=4, d_ff=24576,
vocab=49152.  StarCoder2 uses a GELU MLP (non-gated) and LayerNorm, with
QKV bias.
"""
from repro.configs.base import ModelConfig, LayerSpec, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="starcoder2-15b",
    family="dense",
    source="arXiv:2402.19173",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    norm_type="layernorm",
    use_rope=True,
    qkv_bias=True,
    period=(LayerSpec(ATTN, DENSE),),
))
