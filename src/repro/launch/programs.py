"""Program builders for the dry-run and launchers.

``build_program(cfg, shape)`` assembles, for one (architecture × input
shape), the pure function to lower plus abstract (ShapeDtypeStruct)
arguments and their logical-axes trees:

  train_4k     -> train_step(params, opt_state, batch)
  prefill_32k  -> prefill(params, batch)          (serve_step, prompt)
  decode_32k   -> decode_step(params, state, tok) (serve_step, 1 token)
  long_500k    -> decode_step with a 524288-token state; pure-attention
                  archs switch to the sliding-window variant
                  (cfg.for_long_context()), SSM/hybrids run natively.

Nothing here allocates device memory — the 398B config lowers from
structs only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.models import (
    decode_step, init_lm_state, lm_param_specs, lm_state_axes, prefill,
)
from repro.models.param import A
from repro.training.optim import AdamState, adamw
from repro.training.train import make_train_step

BIG_MODEL_PARAMS = 100e9   # above this, Adam moments go bf16


@dataclass
class Program:
    name: str
    cfg: ModelConfig
    shape: ShapeConfig
    fn: Callable
    args: Tuple[Any, ...]        # SDS trees
    arg_axes: Tuple[Any, ...]    # encoded-axes trees
    out_axes: Any                # encoded-axes tree matching fn output


def resolve_config(cfg: ModelConfig, shape: ShapeConfig,
                   unroll: bool = True) -> ModelConfig:
    if (shape.name == "long_500k"
            and all(s.mixer == ATTN for s in cfg.period)):
        # pure-attention archs need the bounded-window variant at 500k
        cfg = cfg.for_long_context()
    if unroll:
        # Unrolled layers + inner chunks for honest cost_analysis (XLA
        # counts while-loop bodies once; see ModelConfig.scan_layers).
        cfg = cfg.replace(scan_layers=False, unroll_inner=True, remat=False)
    return cfg


def _batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Token (+ frontend stub) specs; frontend tokens count toward S."""
    B, S = shape.global_batch, shape.seq_len
    s_tok = S - (cfg.frontend_len if cfg.frontend else 0)
    batch = {"tokens": jax.ShapeDtypeStruct((B, s_tok), jnp.int32)}
    axes = {"tokens": A("batch", "seq")}
    if cfg.frontend:
        batch["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype))
        axes["frontend_embeds"] = A("batch", "seq", "embed")
    return batch, axes


def _opt_axes(param_axes):
    return AdamState(step=A(), m=param_axes, v=param_axes)


def _metric_axes(tree):
    return jax.tree_util.tree_map(lambda _: A(), tree)


def build_program(cfg: ModelConfig, shape: ShapeConfig,
                  unroll: bool = True, overrides: dict | None = None
                  ) -> Program:
    cfg = resolve_config(cfg, shape, unroll=unroll)
    if overrides:
        cfg = cfg.replace(**overrides)
    pv, pax = lm_param_specs(cfg)

    if shape.kind == "train":
        state_dtype = (jnp.bfloat16 if cfg.param_count() > BIG_MODEL_PARAMS
                       else None)
        init_opt, update = adamw(3e-4, max_grad_norm=1.0,
                                 state_dtype=state_dtype)
        opt = init_opt(pv)
        batch, batch_axes = _batch_specs(cfg, shape)
        fn = make_train_step(cfg, update)
        args = (pv, opt, batch)
        arg_axes = (pax, _opt_axes(pax), batch_axes)
        out_sds = jax.eval_shape(fn, *args)
        out_axes = (pax, _opt_axes(pax), _metric_axes(out_sds[2]))
        return Program("train_step", cfg, shape, fn, args, arg_axes, out_axes)

    if shape.kind == "prefill":
        batch, batch_axes = _batch_specs(cfg, shape)
        cache_len = shape.seq_len

        def fn(pv_, batch_):
            return prefill(pv_, cfg, batch_["tokens"], cache_len,
                           batch_.get("frontend_embeds"))

        args = (pv, batch)
        arg_axes = (pax, batch_axes)
        out_axes = (A("batch", "vocab"), lm_state_axes(cfg))
        return Program("serve_prefill", cfg, shape, fn, args, arg_axes,
                       out_axes)

    if shape.kind == "decode":
        B = shape.global_batch
        state = init_lm_state(cfg, B, shape.seq_len, abstract=True)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)

        def fn(pv_, state_, tok_):
            return decode_step(pv_, cfg, state_, tok_)

        args = (pv, state, tok)
        arg_axes = (pax, lm_state_axes(cfg), A("batch", "seq"))
        out_axes = (A("batch", "vocab"), lm_state_axes(cfg))
        return Program("serve_decode", cfg, shape, fn, args, arg_axes,
                       out_axes)

    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# The paper's own serving step: semantic-cache lookup at pod scale
# ---------------------------------------------------------------------------

CACHE_SHAPE = ShapeConfig("cache_lookup", "cache", 64, 1024)  # 64-tok queries
CACHE_CAPACITY = 1_048_576     # 1M cached queries


def build_cache_program(corpus: int = CACHE_CAPACITY,
                        batch: int = CACHE_SHAPE.global_batch,
                        max_len: int = CACHE_SHAPE.seq_len,
                        variant: str = "auto",
                        keys_dtype=jnp.float32,
                        multi_pod: bool = False,
                        overrides: dict | None = None) -> Program:
    """cache_serve(params, store, tokens, mask) -> (hit, scores, slots).

    Embeds a batch of queries with the encoder (modernbert-149m) and
    queries a 1M-entry store sharded over the `model` axis — the
    distributed analogue of the paper's Redis lookup (DESIGN.md §3).
    EXTRA program beyond the 40 assigned pairs: this is the technique's
    own hot path, used as the third hillclimb target.

    variant: 'auto' = GSPMD auto-partitioned lookup (baseline);
    'shardmap' = explicit local-topk + tiny-merge schedule
    (store.query_sharded, the beyond-paper optimization).
    """
    from repro.configs import get_config
    from repro.core.store import (
        StoreState, query as store_query, query_sharded, store_axes,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.models import encode

    cfg = get_config("modernbert-149m").replace(
        scan_layers=False, unroll_inner=True, remat=False,
        **(overrides or {}))
    pv, pax = lm_param_specs(cfg)
    d = cfg.d_model
    store = StoreState(
        keys=jax.ShapeDtypeStruct((corpus, d), keys_dtype),
        valid=jax.ShapeDtypeStruct((corpus,), jnp.bool_),
        last_used=jax.ShapeDtypeStruct((corpus,), jnp.int32),
        inserted_at=jax.ShapeDtypeStruct((corpus,), jnp.int32),
        value_ids=jax.ShapeDtypeStruct((corpus,), jnp.int32),
        clock=jax.ShapeDtypeStruct((), jnp.int32),
    )
    tokens = jax.ShapeDtypeStruct((batch, max_len), jnp.int32)
    mask = jax.ShapeDtypeStruct((batch, max_len), jnp.bool_)

    if variant == "shardmap":
        mesh = make_production_mesh(multi_pod=multi_pod)

        def fn(pv_, store_, tokens_, mask_):
            emb = encode(pv_, cfg, tokens_, mask_)
            res = query_sharded(store_, emb, threshold=0.9, k=1, mesh=mesh)
            return res.hit, res.scores, res.slots
    else:
        def fn(pv_, store_, tokens_, mask_):
            emb = encode(pv_, cfg, tokens_, mask_)
            res = store_query(store_, emb, threshold=0.9, k=1)
            return res.hit, res.scores, res.slots

    args = (pv, store, tokens, mask)
    arg_axes = (pax, store_axes(), A("batch", "seq"), A("batch", "seq"))
    out_axes = (A("batch"), A("batch", "."), A("batch", "."))
    shape = CACHE_SHAPE
    return Program(f"cache_serve_{variant}", cfg, shape, fn, args, arg_axes,
                   out_axes)


def get_program(arch: str, shape_name: str, unroll: bool = True,
                overrides: dict | None = None,
                multi_pod: bool = False) -> Program:
    from repro.configs import get_config
    if arch.startswith("langcache") or shape_name == "cache_lookup":
        variant = "auto" if arch == "langcache" else "shardmap"
        keys_dtype = jnp.bfloat16 if arch.endswith("-v3") else jnp.float32
        return build_cache_program(variant=variant, keys_dtype=keys_dtype,
                                   multi_pod=multi_pod, overrides=overrides)
    return build_program(get_config(arch), INPUT_SHAPES[shape_name],
                         unroll=unroll, overrides=overrides)
