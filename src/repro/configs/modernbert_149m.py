"""ModernBERT-base (~149M) — the paper's own embedding-model architecture.

[arXiv:2412.13663]  22L, d_model=768, 12 heads, GeGLU d_ff=1152,
vocab=50368.  Encoder-only (bidirectional), RoPE, alternating
global/local (sliding-window 128) attention in the real model — we keep
global attention with an optional window.  Mean-pooled, L2-normalised
sentence embeddings; fine-tuned into **LangCache-Embed** with online
contrastive loss (repro/core/losses.py).

This is the 11th config: the cache-side embedder, not an assigned
serving backbone.  It has no decode path (encoder-only) — serving means
batched query embedding.
"""
from repro.configs.base import ModelConfig, LayerSpec, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="modernbert-149m",
    family="encoder",
    source="arXiv:2412.13663",
    n_layers=22,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=1152,
    vocab_size=50368,
    mlp_type="geglu",
    norm_type="layernorm",
    use_rope=True,
    causal=False,
    tie_embeddings=True,
    period=(LayerSpec(ATTN, DENSE),),
    max_seq_len=8192,
))
