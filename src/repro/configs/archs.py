"""Imports every architecture config module, populating the registry."""
from repro.configs import (  # noqa: F401
    musicgen_large,
    granite_34b,
    starcoder2_15b,
    phi3_mini,
    pixtral_12b,
    jamba_1_5_large,
    phi3_5_moe,
    xlstm_125m,
    qwen2_5_32b,
    granite_moe_3b,
    modernbert_149m,
)
