"""Tiered multi-tenant cache service (beyond-paper subsystem).

A `CacheService` facade composes a hot exact tier, a warm IVF tier with
demotion + double-buffered rebuild, per-tenant thresholds/admission,
and host-side response GC — the production serving layer between the
store primitives (repro.core) and the LLM engine (repro.serving).  The
serving pipeline drives any backend through the typed ``CacheBackend``
protocol (plan/commit lifecycle, DESIGN.md §7).
"""
from repro.cache_service.config import (
    CacheConfig, EnsembleConfig, LearningConfig, ShardingConfig,
    StalenessConfig, TieringConfig,
)
from repro.cache_service.feedback import (
    ConformalWindow, FeedbackAccumulator, FeedbackConfig, RefitReport,
    TenantReservoir, record_refit,
)
from repro.cache_service.feedback import PairReservoir
from repro.cache_service.cold import ColdFetch, ColdTier, Promotion
from repro.cache_service.policy import (
    ColdRoutingPolicy, EmbedderRefreshPolicy, PolicyTable, TenantPolicy,
)
from repro.cache_service.protocol import (
    CacheBackend, CacheCapabilities, CachePlan, CacheRequest,
    CommitReceipt, MaintenanceReport, coalesce_misses, ungrouped_misses,
)
from repro.cache_service.service import (
    CacheService, ServiceStats,
)
from repro.cache_service.tiers import (
    CascadeResult, Demoted, HotState, WarmState, cascade_lookup,
    cascade_query, demote_coldest, evict_tenant, hot_insert,
    hot_insert_batch, hot_query, hot_touch, init_hot, init_warm,
    init_warm_sharded, mask_expired, place_warm_sharded,
    publish_reembedded_keys, quantize_rows, reap_expired, requantize,
    stack_warm, warm_append, warm_append_sharded, warm_occupancy,
    warm_publish_index, warm_query, warm_rebuild, warm_rebuild_sharded,
)

__all__ = [
    "CacheService", "ServiceStats",
    "CacheConfig", "TieringConfig", "ShardingConfig", "LearningConfig",
    "EnsembleConfig", "StalenessConfig", "ConformalWindow",
    "ColdFetch", "ColdRoutingPolicy", "ColdTier", "Promotion",
    "EmbedderRefreshPolicy", "PolicyTable", "TenantPolicy",
    "FeedbackAccumulator", "FeedbackConfig", "PairReservoir",
    "RefitReport", "TenantReservoir", "record_refit",
    "CacheBackend", "CacheCapabilities", "CachePlan", "CacheRequest",
    "CommitReceipt", "MaintenanceReport", "coalesce_misses",
    "ungrouped_misses",
    "CascadeResult", "Demoted", "HotState", "WarmState", "cascade_lookup",
    "cascade_query", "demote_coldest", "evict_tenant", "hot_insert",
    "hot_insert_batch", "hot_query", "hot_touch", "init_hot", "init_warm",
    "init_warm_sharded", "mask_expired", "place_warm_sharded",
    "publish_reembedded_keys", "quantize_rows", "reap_expired",
    "requantize", "stack_warm", "warm_append", "warm_append_sharded",
    "warm_occupancy", "warm_publish_index", "warm_query", "warm_rebuild",
    "warm_rebuild_sharded",
]
