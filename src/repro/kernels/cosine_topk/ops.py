"""jit'd dispatch wrapper for the cosine top-k lookup.

Chooses the Pallas kernel on TPU (or interpret mode when asked) and the
pure-jnp oracle otherwise.  Both paths share the exact signature, so the
vector store is agnostic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.cosine_topk import kernel as _kernel
from repro.kernels.cosine_topk import ref as _ref


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cosine_topk(q, keys, valid, k: int = 1, *, use_kernel: bool | None = None,
                block_n: int = _kernel.DEFAULT_BLOCK_N):
    """q: (Q,D); keys: (N,D); valid: (N,) -> ((Q,k) scores, (Q,k) int32 idx).

    use_kernel: None -> kernel on TPU, oracle elsewhere (interpret-mode
    kernels are for correctness tests, not the CPU hot path).
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        return _kernel.cosine_topk(q, keys, valid, k, block_n=block_n,
                                   interpret=not _on_tpu())
    return _ref.cosine_topk(q, keys, valid, k)
