"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.losses import cosine_distance, online_contrastive_loss
from repro.core.metrics import average_precision, pair_classification_metrics
from repro.core.store import init_store, insert, insert_batch, query
from repro.data.tokenizer import HashTokenizer
from repro.kernels.cosine_topk import kernel as ctk_kernel, ref as ctk_ref
from repro.launch.sharding import TRAIN_RULES, resolve_pspec
from repro.launch.mesh import make_host_mesh

SETTINGS = dict(max_examples=25, deadline=None)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


# ---------------------------------------------------------------------------
# kernel vs oracle under random shapes
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(1, 12), st.integers(8, 300), st.integers(4, 96),
       st.integers(1, 4), st.integers(0, 10**6))
def test_cosine_topk_property(Q, N, D, k, seed):
    rng = np.random.default_rng(seed)
    k = min(k, N)
    q = jnp.asarray(_unit(rng.standard_normal((Q, D)).astype(np.float32)))
    keys = jnp.asarray(_unit(rng.standard_normal((N, D)).astype(np.float32)))
    valid = jnp.asarray(rng.random(N) > 0.2)
    s_ref, i_ref = ctk_ref.cosine_topk(q, keys, valid, k)
    s_k, i_k = ctk_kernel.cosine_topk(q, keys, valid, k, block_n=64,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_k), atol=1e-5)
    # scores sorted desc
    assert bool(jnp.all(s_k[:, :-1] >= s_k[:, 1:] - 1e-6))


# ---------------------------------------------------------------------------
# loss invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(2, 64), st.integers(2, 64), st.integers(0, 10**6))
def test_online_loss_nonneg_finite(B, D, seed):
    rng = np.random.default_rng(seed)
    e1 = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    e2 = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 2, B), jnp.int32)
    loss = float(online_contrastive_loss(e1, e2, lab))
    assert np.isfinite(loss) and loss >= 0.0


@settings(**SETTINGS)
@given(st.integers(2, 32), st.integers(0, 10**6))
def test_cosine_distance_range(B, seed):
    rng = np.random.default_rng(seed)
    e1 = jnp.asarray(rng.standard_normal((B, 16)), jnp.float32)
    e2 = jnp.asarray(rng.standard_normal((B, 16)), jnp.float32)
    d = np.asarray(cosine_distance(e1, e2))
    assert (d >= -1e-5).all() and (d <= 2 + 1e-5).all()
    # identical inputs -> distance 0
    d0 = np.asarray(cosine_distance(e1, e1))
    np.testing.assert_allclose(d0, 0.0, atol=1e-5)


# ---------------------------------------------------------------------------
# metric invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(10, 300), st.integers(0, 10**6))
def test_metric_ranges(n, seed):
    rng = np.random.default_rng(seed)
    scores = rng.random(n)
    labels = rng.integers(0, 2, n).astype(np.int32)
    if labels.sum() in (0, n):
        labels[0] = 1 - labels[0]
    m = pair_classification_metrics(scores, labels)
    for k in ("precision", "recall", "f1", "accuracy", "ap"):
        assert 0.0 <= m[k] <= 1.0, (k, m[k])
    # AP of a perfect ranking is 1
    perfect = np.concatenate([np.ones(labels.sum()),
                              np.zeros(n - labels.sum())])
    srt = np.concatenate([np.linspace(1, 0.6, labels.sum()),
                          np.linspace(0.4, 0, n - labels.sum())])
    assert average_precision(srt, perfect.astype(np.int32)) == 1.0


# ---------------------------------------------------------------------------
# store invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(1, 16), st.integers(1, 30), st.integers(0, 10**6))
def test_store_never_exceeds_capacity(cap, n_ins, seed):
    rng = np.random.default_rng(seed)
    st_ = init_store(cap, 8)
    embs = jnp.asarray(_unit(rng.standard_normal((n_ins, 8)).astype(
        np.float32)))
    st_ = insert_batch(st_, embs, jnp.arange(n_ins))
    assert int(np.asarray(st_.valid).sum()) == min(cap, n_ins)
    # most recent insert is always findable
    res = query(st_, embs[-1:], threshold=0.999)
    assert bool(res.hit[0])


# ---------------------------------------------------------------------------
# tokenizer invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.text(min_size=0, max_size=200), st.integers(8, 64))
def test_tokenizer_total(text, max_len):
    tok = HashTokenizer(vocab_size=4096)
    ids, mask = tok.encode(text, max_len)
    assert ids.shape == (max_len,) and mask.shape == (max_len,)
    assert ids.min() >= 0 and ids.max() < 4096
    # deterministic
    ids2, _ = tok.encode(text, max_len)
    np.testing.assert_array_equal(ids, ids2)


# ---------------------------------------------------------------------------
# sharding resolution invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.lists(st.sampled_from([1, 2, 3, 5, 8, 16, 40, 48, 128, 1536]),
                min_size=1, max_size=4),
       st.integers(0, 10**6))
def test_resolve_pspec_total(dims, seed):
    rng = np.random.default_rng(seed)
    mesh = make_host_mesh(1, 1)
    names = ["batch", "embed", "heads", "mlp", "vocab", "experts", "cache",
             "."]
    axes = ",".join(names[int(rng.integers(len(names)))] for _ in dims)
    spec = resolve_pspec(tuple(dims), axes, mesh, TRAIN_RULES)
    # every mesh axis used at most once
    used = [a for part in spec if part
            for a in (part if isinstance(part, tuple) else (part,))]
    assert len(used) == len(set(used))
    # divisibility always holds
    for dim, part in zip(dims, tuple(spec) + (None,) * len(dims)):
        if part:
            parts = part if isinstance(part, tuple) else (part,)
            total = int(np.prod([mesh.shape[a] for a in parts]))
            assert dim % total == 0
