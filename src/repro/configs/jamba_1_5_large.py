"""Jamba-1.5-Large (398B total / 94B active) — Mamba+attention hybrid MoE.

[arXiv:2403.19887]  72L, d_model=8192, 64 heads, kv=8, d_ff=24576,
MoE 16 experts top-2.  Attention:Mamba interleave is 1:7 (one attention
layer per period of 8); MoE replaces the dense FFN on every second
layer (e=16, top-2), matching the published 398B-total / 94B-active
split.  Sub-quadratic in sequence except for the 9 attention layers, so
``long_500k`` runs natively (attention KV for 9 layers is bounded and
sharded).
"""
from repro.configs.base import (
    ModelConfig, LayerSpec, MoEConfig, SSMConfig,
    ATTN, MAMBA, DENSE, MOE, register,
)

# period of 8: attention at position 4 (1:7), MoE on odd positions (1:2)
_PERIOD = tuple(
    LayerSpec(
        mixer=ATTN if i == 4 else MAMBA,
        ffn=MOE if i % 2 == 1 else DENSE,
    )
    for i in range(8)
)

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    use_rope=False,          # Jamba uses no positional encoding
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=24576),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    period=_PERIOD,
    # 398B params cannot hold fp32 master + fp32 Adam moments in one
    # v5e pod (4.8TB > 4TB HBM); bf16 params + bf16 moments fit
    # (DESIGN.md §2).  The launcher also selects bf16 moments for any
    # config above 100B params.
    param_dtype="bfloat16",
))
