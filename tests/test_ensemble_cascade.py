"""Fused multi-embedder ensemble cascade (DESIGN.md §13): kernel vs
the four-op oracle (fp32/int8, blockwise), the E=1 degenerate identity
with the single cascade, panel/base mutation alignment, sharded
shard_map-vs-oracle parity across 1/2/8 virtual devices, panel
versioning via `publish_panel`, and the service-level round trip
(plan/commit/flush alignment, mixture-weight learning through
`maintenance()`, stale-version commit rejection).  Multi-device cases
need ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
dedicated CI job); below that device count they skip."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.cache_service import CacheRequest, CacheService, FeedbackConfig
from repro.cache_service import tiers
from repro.kernels.cascade_lookup import kernel as clk_kernel
from repro.kernels.cascade_lookup import ref as clk_ref

rng = np.random.default_rng(7)

N_DEV = len(jax.devices())
E, D = 3, 16
NH, CAP, NK, BUCKET = 24, 64, 4, 20
Q = 11


def _need_devices(n):
    if N_DEV < n:
        pytest.skip(f"needs {n} devices, have {N_DEV} (run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _corr_panels(n, e=E, d=D):
    """Latent-factor correlated panels, (n, E, D): the E embedders see
    the same latent with embedder-specific projections + noise."""
    z = rng.normal(size=(n, 8))
    A = rng.normal(size=(e, 8, d))
    out = np.einsum("nz,ezd->ned", z, A) + 0.3 * rng.normal(size=(n, e, d))
    return _unit(out).astype(np.float32)


def _weights(n_q, e=E):
    w = rng.uniform(0.1, 1.0, size=(n_q, e)).astype(np.float32)
    return w / w.sum(1, keepdims=True)


def _assert_same(a, b, fields=tiers.EnsembleResult._fields, msg=""):
    for name in fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=f"{msg}{name}")


# ---------------------------------------------------------------------------
# kernel layer: pallas kernel vs the four-op reference
# ---------------------------------------------------------------------------

def _kernel_fixture(e=E, n_q=9, nh=40, cap=96, n_k=6, bucket=24):
    q = _unit(rng.normal(size=(e, n_q, D))).astype(np.float32)
    w = _weights(n_q, e)
    qt = rng.integers(0, 3, n_q).astype(np.int32)
    thr = np.full(n_q, 0.3, np.float32)
    hk = _unit(rng.normal(size=(e, nh, D))).astype(np.float32)
    hv = rng.random(nh) < 0.8
    ht = rng.integers(0, 3, nh).astype(np.int32)
    hvid = np.arange(nh, dtype=np.int32)
    wk = _unit(rng.normal(size=(e, cap, D))).astype(np.float32)
    wv = rng.random(cap) < 0.85
    wt = rng.integers(0, 3, cap).astype(np.int32)
    wvid = 1000 + np.arange(cap, dtype=np.int32)
    wseq = rng.permutation(cap).astype(np.int32) + 1
    cent = _unit(rng.normal(size=(n_k, D))).astype(np.float32)
    members = np.full((n_k, bucket), -1, np.int32)
    for i, s in enumerate(rng.permutation(cap)):
        c, col = i % n_k, i // n_k
        if col < bucket:
            members[c, col] = s
    amax = np.abs(wk).max(-1)
    scales = (amax / 127.0).astype(np.float32)
    wkq = np.clip(np.round(wk / scales[..., None]), -127, 127) \
        .astype(np.int8)
    args = tuple(jnp.asarray(a) for a in (
        qt, thr, hk, hv, ht, hvid, wk, wv, wt, wvid, wseq, cent, members,
        np.int32(37), np.int32(cap - 20)))
    return jnp.asarray(q), jnp.asarray(w), args, \
        jnp.asarray(wkq), jnp.asarray(scales)


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("warm_block_n", [None, 32, 17])
def test_kernel_matches_four_op_oracle(quantized, warm_block_n):
    """One pallas pass over the E stacked panels is bit-exact with the
    unfused four-op reference — partial probes, tail window, invalid
    slots, mixed tenants, uneven warm blocking included."""
    q, w, args, wkq, scales = _kernel_fixture()
    ref = clk_ref.ensemble_lookup(q, w, *args, warm_keys_q=wkq,
                                  warm_scales=scales, k=3, n_probe=4,
                                  tail=12, quantized=quantized)
    ker = clk_kernel.cascade_lookup_ensemble(
        q, w, *args, warm_keys_q=wkq, warm_scales=scales, k=3, n_probe=4,
        tail=12, quantized=quantized, warm_block_n=warm_block_n,
        interpret=True)
    for name, a, b in zip(("scores", "vids", "wslots", "hslots",
                           "hot_hit", "hit"), ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_kernel_e1_degenerate_equals_single_cascade():
    """E=1 with weight 1.0 collapses to the plain cascade bit-for-bit
    (the fused score is the one cosine times 1.0)."""
    q, _, args, wkq, scales = _kernel_fixture()
    qt, thr, hk, hv, ht, hvid, wk, wv, wt, wvid, wseq, cent, members, \
        cur, idx = args
    one = jnp.ones((q.shape[1], 1), jnp.float32)
    single = clk_ref.cascade_lookup(
        q[0], qt, thr, hk[0], hv, ht, hvid, wk[0], wv, wt, wvid, wseq,
        cent, members, cur, idx, warm_keys_q=wkq[0],
        warm_scales=scales[0], k=2, n_probe=4, tail=12)
    ens = clk_ref.ensemble_lookup(
        q[:1], one, qt, thr, hk[:1], hv, ht, hvid, wk[:1], wv, wt, wvid,
        wseq, cent, members, cur, idx, warm_keys_q=wkq[:1],
        warm_scales=scales[:1], k=2, n_probe=4, tail=12)
    for name, a, b in zip(("scores", "vids", "wslots", "hslots",
                           "hot_hit", "hit"), single, ens):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# tiers layer: mutation alignment + query parity
# ---------------------------------------------------------------------------

def _tiers_fixture():
    """Populated hot+warm with aligned E-panels, via the real mirrored
    mutation path (insert batch -> demote -> append -> rebuild)."""
    hot = tiers.init_hot(NH, D)
    warm = tiers.init_warm(CAP, D, NK, BUCKET)
    ens = tiers.init_ensemble(E, hot, warm)
    n1 = 40
    embs = _corr_panels(n1)
    vids = np.arange(n1, dtype=np.int32)
    vids[5] = -1                              # one admission skip
    tens = (np.arange(n1) % 3).astype(np.int32)
    hot, ens, _ = tiers.ensemble_hot_insert_batch(
        hot, ens, jnp.asarray(embs), jnp.asarray(vids), jnp.asarray(tens))
    m = 8
    slots = tiers.coldest_slots(hot, m)
    pk = ens.hot_keys[:, slots]
    hot, dem = tiers.demote_coldest(hot, m)
    warm_pre = warm
    warm, _ = tiers.warm_append(warm, dem)
    ens = tiers.ensemble_warm_append(ens, warm_pre, dem, pk)
    return hot, tiers.warm_rebuild(warm, iters=4), ens


def test_mutations_keep_pilot_panel_bit_equal_to_base():
    """Panel 0 mirrors every slot decision of the base tiers — after
    insert/demote/append the pilot leaves are bit-equal to the base
    key panels (keys, int8 codes and scales)."""
    hot, warm, ens = _tiers_fixture()
    np.testing.assert_array_equal(np.asarray(ens.hot_keys[0]),
                                  np.asarray(hot.keys), err_msg="hot")
    # warm was rebuilt *after* the mirrored append: rebuild never
    # permutes rows, so the panels stay aligned through it
    np.testing.assert_array_equal(np.asarray(ens.warm_keys[0]),
                                  np.asarray(warm.keys), err_msg="warm")
    np.testing.assert_array_equal(np.asarray(ens.warm_keys_q[0]),
                                  np.asarray(warm.keys_q), err_msg="q8")
    np.testing.assert_array_equal(np.asarray(ens.warm_scales[0]),
                                  np.asarray(warm.scales), err_msg="sc")


@pytest.mark.parametrize("quantized", [False, True])
def test_tiers_fused_kernel_matches_oracle(quantized):
    hot, warm, ens = _tiers_fixture()
    qp = _corr_panels(Q)
    w = _weights(Q)
    qt = jnp.asarray((np.arange(Q) % 3).astype(np.int32))
    thr = jnp.full((Q,), 0.8, jnp.float32)
    ref = tiers.ensemble_cascade_query(
        hot, warm, ens, jnp.asarray(qp), jnp.asarray(w), qt, thr, k=2,
        n_probe=2, tail=8, fused=False, quantized=quantized)
    ker = tiers.ensemble_cascade_query(
        hot, warm, ens, jnp.asarray(qp), jnp.asarray(w), qt, thr, k=2,
        n_probe=2, tail=8, fused=True, use_kernel=True,
        quantized=quantized, warm_block_n=32)
    _assert_same(ref, ker, msg=f"quant={quantized} ")
    # panel_scores consistency: where a candidate exists, the fused
    # top-1 equals the weighted sum of the reported per-panel cosines
    has = np.asarray(ref.value_ids[:, 0]) >= 0
    assert has.any()
    fused = np.einsum("qe,qe->q", np.asarray(ref.panel_scores), w)
    np.testing.assert_allclose(fused[has],
                               np.asarray(ref.scores[:, 0])[has],
                               rtol=0, atol=2e-6)


def test_tiers_e1_degenerate_matches_cascade_query():
    hot, warm, _ = _tiers_fixture()
    ens1 = tiers.init_ensemble(1, hot, warm)
    qp = _corr_panels(Q)
    qt = jnp.asarray((np.arange(Q) % 3).astype(np.int32))
    thr = jnp.full((Q,), 0.8, jnp.float32)
    r1 = tiers.ensemble_cascade_query(
        hot, warm, ens1, jnp.asarray(qp[:, :1]),
        jnp.ones((Q, 1), jnp.float32), qt, thr, k=2, n_probe=2, tail=8)
    rb = tiers.cascade_query(hot, warm, jnp.asarray(qp[:, 0]), qt, thr,
                             k=2, n_probe=2, tail=8)
    _assert_same(r1, rb, fields=tiers.CascadeResult._fields, msg="E=1 ")


# ---------------------------------------------------------------------------
# sharded: shard_map vs single-device oracle (1/2/8 virtual devices)
# ---------------------------------------------------------------------------

def _sharded_fixture(S):
    hot, _, ens = _tiers_fixture()
    per_warm, per_panels = [], []
    for si in range(S):
        wme = tiers.init_warm(CAP, D, NK, BUCKET)
        kp = _corr_panels(48)
        dem = tiers.Demoted(
            keys=jnp.asarray(kp[:, 0]),
            value_ids=jnp.asarray(2000 + 100 * si
                                  + np.arange(48, dtype=np.int32)),
            tenants=jnp.asarray((np.arange(48) % 3).astype(np.int32)),
            mask=jnp.ones(48, bool))
        wme, _ = tiers.warm_append(wme, dem)
        # panel rows follow the ring placement (append from cursor 0 is
        # the identity for m<=cap rows on a fresh ring); normalize per
        # 2-D slice so bits match warm_append's _unit exactly
        pw = jnp.zeros((E, CAP, D), jnp.float32)
        for e in range(E):
            pw = pw.at[e, :48].set(tiers._unit(jnp.asarray(kp[:, e])))
        per_panels.append(pw)
        per_warm.append(tiers.warm_rebuild(wme, iters=4))
    swarm = tiers.stack_warm(per_warm)
    wk_stack = jnp.stack(per_panels)                    # (S, E, cap, D)
    q8, sc = tiers.quantize_rows(wk_stack)
    ens_s = tiers.EnsembleState(hot_keys=ens.hot_keys, warm_keys=wk_stack,
                                warm_keys_q=q8, warm_scales=sc)
    np.testing.assert_array_equal(np.asarray(ens_s.warm_keys[0][0]),
                                  np.asarray(swarm.keys[0]))
    return hot, swarm, ens_s


@pytest.mark.parametrize("S", [1, 2, 8])
@pytest.mark.parametrize("quantized", [False, True])
def test_sharded_fused_matches_single_device_oracle(S, quantized):
    """The distributed schedule (shard_map + one (Q, k·S) merge over
    (vid, is_hot, slot, shard) payloads) is bit-exact with its
    single-device stacked emulation, `panel_scores` included."""
    _need_devices(S)
    from repro.launch.mesh import make_host_mesh

    hot, swarm, ens_s = _sharded_fixture(S)
    qp = jnp.asarray(_corr_panels(Q))
    w = jnp.asarray(_weights(Q))
    qt = jnp.asarray((np.arange(Q) % 3).astype(np.int32))
    thr = jnp.full((Q,), 0.8, jnp.float32)
    oracle = tiers.ensemble_cascade_query(
        hot, swarm, ens_s, qp, w, qt, thr, k=2, n_probe=2, tail=8,
        quantized=quantized)
    mesh = make_host_mesh(1, S)
    dist = jax.jit(lambda h, sw, es, qq, ww, t, th:
                   tiers.ensemble_cascade_query(
                       h, sw, es, qq, ww, t, th, k=2, n_probe=2, tail=8,
                       quantized=quantized, mesh=mesh))(
        hot, tiers.place_warm_sharded(swarm, mesh),
        tiers.place_ensemble_sharded(ens_s, mesh), qp, w, qt, thr)
    _assert_same(oracle, dist, msg=f"S={S} quant={quantized} ")


# ---------------------------------------------------------------------------
# publish_panel: per-embedder A/B swap
# ---------------------------------------------------------------------------

def test_publish_panel_swaps_only_the_target_panel():
    _, _, ens = _tiers_fixture()
    new_hot = _unit(rng.normal(size=(NH, D))).astype(np.float32)
    new_warm = _unit(rng.normal(size=(CAP, D))).astype(np.float32)
    ens2 = tiers.publish_panel(ens, 2, jnp.asarray(new_hot),
                               jnp.asarray(new_warm))
    np.testing.assert_array_equal(np.asarray(ens2.hot_keys[1]),
                                  np.asarray(ens.hot_keys[1]))
    np.testing.assert_array_equal(np.asarray(ens2.warm_keys[0]),
                                  np.asarray(ens.warm_keys[0]))
    np.testing.assert_allclose(np.asarray(ens2.hot_keys[2]),
                               _unit(new_hot), atol=1e-6)
    q8, sc = tiers.quantize_rows(ens2.warm_keys[2])
    np.testing.assert_array_equal(np.asarray(ens2.warm_keys_q[2]),
                                  np.asarray(q8))
    np.testing.assert_array_equal(np.asarray(ens2.warm_scales[2]),
                                  np.asarray(sc))


# ---------------------------------------------------------------------------
# service layer: plan/commit/flush alignment, weights, versioning
# ---------------------------------------------------------------------------

def _panels(n, noise=(0.9, 0.05, 0.9)):
    """Embedder 1 is informative; 0 and 2 are mostly noise."""
    z = _unit(rng.normal(size=(n, D)))
    out = np.stack([_unit(z + s * rng.normal(size=(n, D)))
                    for s in noise], 1)
    return out.astype(np.float32)


def _ens_svc(**kw):
    cfg = dict(dim=D, embedders=E, hot_capacity=32, warm_capacity=256,
               n_clusters=4, bucket=64, n_probe=4, threshold=0.80,
               flush_watermark=0.75, flush_size=8)
    cfg.update(kw)
    return CacheService(**cfg)


def test_service_plan_commit_flush_keep_panels_aligned():
    svc = _ens_svc()
    assert svc.capabilities().ensemble == E
    base = _panels(12)
    plan = svc.plan(CacheRequest.build(base,
                                       texts=[f"q{i}" for i in range(12)]))
    assert not plan.hit.any()
    assert plan.panel_scores is not None \
        and plan.panel_scores.shape == (12, E)
    rc = svc.commit(plan, [f"r{i}" for i in range(12)])
    assert rc.admitted == 12
    np.testing.assert_array_equal(np.asarray(svc.ens.hot_keys[0]),
                                  np.asarray(svc.hot.keys),
                                  err_msg="pilot hot panel after commit")
    plan2 = svc.plan(CacheRequest.build(base))
    assert plan2.hit.all()
    with pytest.raises(ValueError):
        svc.plan(CacheRequest.build(base[:, 0]))   # rank-2 under ensemble
    for i in range(6):
        b = _panels(8)
        p = svc.plan(CacheRequest.build(
            b, texts=[f"f{i}-{j}" for j in range(8)]))
        svc.commit(p, [f"fr{i}-{j}" for j in range(8)])
    svc.flush()
    np.testing.assert_array_equal(np.asarray(svc.ens.warm_keys[0]),
                                  np.asarray(svc.warm.keys),
                                  err_msg="pilot warm panel after flush")
    np.testing.assert_array_equal(np.asarray(svc.ens.warm_keys_q[0]),
                                  np.asarray(svc.warm.keys_q))


def test_service_learns_mixture_weights_from_feedback():
    """Only embedder 1 separates duplicates from impostors on this
    stream; the closed-form ridge refit must upweight it (and the
    refit must flow through `maintenance()` + the policy table)."""
    svc = _ens_svc(learned_admission=True,
                   feedback_config=FeedbackConfig(
                       min_samples=24, min_class=4, refit_interval=10,
                       reservoir=256, max_weight_step=0.5, seed=3))
    corp = _panels(16)
    pc = svc.plan(CacheRequest.build(corp,
                                     texts=[f"c{i}" for i in range(16)]))
    svc.commit(pc, [f"ans{i}" for i in range(16)])
    for step in range(30):
        i = step % 16
        # true duplicate whose noisy panels drag the uniform fused
        # score under the threshold; embedder 1 stays confident
        near = corp[i:i + 1].copy()
        near[:, 0] = _unit(0.4 * corp[i:i + 1, 0]
                           + rng.normal(size=(1, D)))
        near[:, 2] = _unit(0.4 * corp[i:i + 1, 2]
                           + rng.normal(size=(1, D)))
        near[:, 1] = _unit(corp[i:i + 1, 1]
                           + 0.05 * rng.normal(size=(1, D)))
        imp = corp[i:i + 1].copy()               # panels 0/2 agree
        imp[:, 1] = _unit(rng.normal(size=(1, D)))
        batch = np.concatenate([_unit(near), imp]).astype(np.float32)
        p = svc.plan(CacheRequest.build(batch,
                                        texts=[f"d{step}", f"i{step}"]))
        svc.commit(p, [f"ans{i}", f"other{step}"])
    assert svc.feedback.counters["ensemble_events"] > 0
    svc.maintenance(block=True)
    assert svc.feedback.weight_refit_log, "no weight refit attempted"
    applied = [r for r in svc.feedback.weight_refit_log if r.applied]
    assert applied, [(r.tenant, r.reason)
                     for r in svc.feedback.weight_refit_log]
    w = np.asarray(svc.policies.weights_state()[0])
    assert w[1] > 1.0 / E - 1e-6, w   # informative embedder upweighted
    snap = svc.stats_snapshot()
    assert snap.learning is not None and "ensemble_weights" in snap.learning
    assert snap.tiers["ensemble"] == E


def test_service_tenant_weight_override():
    svc = _ens_svc()
    svc.set_tenant_weights(5, [0.2, 0.6, 0.2])
    wq = svc.policies.weights_for(np.array([5, 99], np.int32), E)
    np.testing.assert_allclose(wq[0], [0.2, 0.6, 0.2], atol=1e-6)
    np.testing.assert_allclose(wq[1], np.full(E, 1.0 / E), atol=1e-6)


def test_service_publish_panel_versioning():
    """`publish_panel` is the A/B shadow-serving hook: it bumps the
    embed version, so a plan issued against the old panels is skipped
    at commit; panel-0 publish swaps the base tiers too."""
    svc = _ens_svc()
    base = _panels(12)
    p = svc.plan(CacheRequest.build(base,
                                    texts=[f"q{i}" for i in range(12)]))
    svc.commit(p, [f"r{i}" for i in range(12)])
    stale = svc.plan(CacheRequest.build(_panels(2), texts=["s0", "s1"]))
    nh = svc.hot.keys.shape[0]
    nw = svc.warm.keys.shape[0]
    svc.publish_panel(2,
                      _unit(rng.normal(size=(nh, D))).astype(np.float32),
                      _unit(rng.normal(size=(nw, D))).astype(np.float32))
    rcs = svc.commit(stale, ["x", "y"])
    assert rcs.stale_version_skipped == 2 and rcs.admitted == 0
    k0 = _unit(rng.normal(size=(nh, D))).astype(np.float32)
    w0 = _unit(rng.normal(size=(nw, D))).astype(np.float32)
    svc.publish_panel(0, k0, w0)
    np.testing.assert_array_equal(np.asarray(svc.ens.hot_keys[0]),
                                  np.asarray(svc.hot.keys),
                                  err_msg="pilot panel after panel-0 swap")


def test_service_constructor_guards():
    with pytest.raises(ValueError):
        CacheService(dim=D, embedders=E, learned_embedder=True)
    with pytest.raises(ValueError):
        CacheService(dim=D, ensemble_weights=[0.5, 0.5])
