"""Unified layer block: pre-norm mixer + residual + (dense|MoE|no) FFN.

Dispatches on :class:`LayerSpec` so that dense, MoE, Mamba, xLSTM and
hybrid architectures all share one code path (and one scanned-params
layout).  Three entry points per layer, mirroring the mixers:

  apply_full    — full-sequence (training / encoder)          -> (x, aux)
  apply_prefill — full-sequence + build decode state          -> (x, state, aux)
  apply_decode  — one token against carried state             -> (x, state, aux)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN, DENSE, MAMBA, MLSTM, MOE, NONE, SLSTM, LayerSpec, ModelConfig,
)
from repro.models import attention, layers, mamba, moe, xlstm
from repro.models.param import A, Initializer, prefix_axes


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_layer(ini: Initializer, cfg: ModelConfig, spec: LayerSpec):
    p = {"norm1": layers.init_norm(ini, cfg)}
    if spec.mixer == ATTN:
        p["mixer"] = attention.init_attention(ini, cfg)
    elif spec.mixer == MAMBA:
        p["mixer"] = mamba.init_mamba(ini, cfg)
    elif spec.mixer == MLSTM:
        p["mixer"] = xlstm.init_mlstm(ini, cfg)
    elif spec.mixer == SLSTM:
        p["mixer"] = xlstm.init_slstm(ini, cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == DENSE:
        p["norm2"] = layers.init_norm(ini, cfg)
        p["ffn"] = layers.init_mlp(ini, cfg)
    elif spec.ffn == MOE:
        p["norm2"] = layers.init_norm(ini, cfg)
        p["ffn"] = moe.init_moe(ini, cfg)
    return p


def init_layer_state(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     seq_len: int, abstract: bool = False):
    if spec.mixer == ATTN:
        return attention.init_cache(cfg, batch, seq_len, abstract)
    if spec.mixer == MAMBA:
        return mamba.init_state(cfg, batch, abstract)
    if spec.mixer == MLSTM:
        return xlstm.init_mlstm_state(cfg, batch, abstract)
    if spec.mixer == SLSTM:
        return xlstm.init_slstm_state(cfg, batch, abstract)
    raise ValueError(spec.mixer)


def layer_state_axes(cfg: ModelConfig, spec: LayerSpec):
    if spec.mixer == ATTN:
        raw = attention.cache_axes()
    elif spec.mixer == MAMBA:
        raw = mamba.state_axes()
    elif spec.mixer == MLSTM:
        raw = xlstm.mlstm_state_axes()
    elif spec.mixer == SLSTM:
        raw = xlstm.slstm_state_axes()
    else:
        raise ValueError(spec.mixer)
    return {k: A(*v) for k, v in raw.items()}


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _ffn(p, cfg: ModelConfig, spec: LayerSpec, x):
    if spec.ffn == NONE:
        return x, jnp.zeros((), jnp.float32)
    h = layers.apply_norm(p["norm2"], cfg, x)
    if spec.ffn == DENSE:
        return x + layers.apply_mlp(p["ffn"], cfg, h), jnp.zeros((), jnp.float32)
    y, aux = moe.apply_moe(p["ffn"], cfg, h)
    return x + y, aux


def apply_full(p, cfg: ModelConfig, spec: LayerSpec, x, positions):
    h = layers.apply_norm(p["norm1"], cfg, x)
    if spec.mixer == ATTN:
        y = attention.apply_full(p["mixer"], cfg, h, positions)
    elif spec.mixer == MAMBA:
        y = mamba.apply_full(p["mixer"], cfg, h)
    elif spec.mixer == MLSTM:
        y = xlstm.apply_mlstm_full(p["mixer"], cfg, h)
    else:
        y = xlstm.apply_slstm_full(p["mixer"], cfg, h)
    x = x + y
    return _ffn(p, cfg, spec, x)


def apply_prefill(p, cfg: ModelConfig, spec: LayerSpec, x, positions, state):
    h = layers.apply_norm(p["norm1"], cfg, x)
    if spec.mixer == ATTN:
        y, ns = attention.apply_prefill(p["mixer"], cfg, h, positions, state)
    elif spec.mixer == MAMBA:
        y, ns = mamba.apply_prefill(p["mixer"], cfg, h)
    elif spec.mixer == MLSTM:
        y, ns = xlstm.apply_mlstm_full(p["mixer"], cfg, h, return_state=True)
    else:
        y, ns = xlstm.apply_slstm_full(p["mixer"], cfg, h, return_state=True)
    x = x + y
    x, aux = _ffn(p, cfg, spec, x)
    return x, ns, aux


def apply_decode(p, cfg: ModelConfig, spec: LayerSpec, x, cur_len, state):
    h = layers.apply_norm(p["norm1"], cfg, x)
    if spec.mixer == ATTN:
        y, ns = attention.apply_decode(p["mixer"], cfg, h, cur_len, state)
    elif spec.mixer == MAMBA:
        y, ns = mamba.apply_decode(p["mixer"], cfg, h, state)
    elif spec.mixer == MLSTM:
        y, ns = xlstm.apply_mlstm_decode(p["mixer"], cfg, h, state)
    else:
        y, ns = xlstm.apply_slstm_decode(p["mixer"], cfg, h, state)
    x = x + y
    x, aux = _ffn(p, cfg, spec, x)
    return x, ns, aux
